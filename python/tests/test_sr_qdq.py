"""Stochastic-rounding qdq: oracle match + unbiasedness property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.sr_qdq import sr_qdq

CODES = [ref.FP16, ref.BF16, ref.FP32]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def _noise(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random(shape, dtype=np.float32))


@pytest.mark.parametrize("code", CODES)
@pytest.mark.parametrize("shape", [(17,), (1024,), (3, 7, 11)])
def test_sr_qdq_matches_ref(code, shape):
    x = _rand(shape, seed=hash((code, shape)) % 2**31, scale=5.0)
    noise = _noise(shape, seed=1)
    got = sr_qdq(x, noise, jnp.int32(code))
    want = ref.sr_qdq_ref(x, noise, code)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sr_output_is_bf16_representable():
    x = _rand((4096,), seed=2, scale=3.0)
    out = np.asarray(sr_qdq(x, _noise((4096,), seed=3), jnp.int32(ref.BF16)))
    rt = out.astype(np.float32).view(np.uint32)
    assert np.all((rt & 0xFFFF) == 0), "all outputs must have zero low mantissa bits"


def test_sr_is_unbiased_in_expectation():
    # E[sr(x)] ≈ x — the whole point vs round-to-nearest.
    x = jnp.full((1,), 1.0 + 2.0**-9, jnp.float32)  # strictly between bf16 grid pts
    trials = 4000
    rng = np.random.default_rng(4)
    noise = jnp.asarray(rng.random((trials,), dtype=np.float32))
    xs = jnp.broadcast_to(x, (trials,))
    out = np.asarray(sr_qdq(xs, noise, jnp.int32(ref.BF16)))
    assert abs(out.mean() - float(x[0])) < 2.0**-9 * 0.15


def test_sr_exact_values_pass_through():
    x = jnp.asarray([1.0, 2.0, 0.0, -4.0, 0.5], jnp.float32)  # bf16-exact
    out = np.asarray(sr_qdq(x, _noise((5,), seed=5), jnp.int32(ref.BF16)))
    np.testing.assert_array_equal(out, np.asarray(x))


def test_sr_fp32_identity_any_noise():
    x = _rand((256,), seed=6, scale=1e8)
    out = sr_qdq(x, _noise((256,), seed=7), jnp.int32(ref.FP32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_sr_gradient_is_straight_through():
    x = _rand((64,), seed=8)
    noise = _noise((64,), seed=9)
    g = jax.grad(lambda x: jnp.sum(sr_qdq(x, noise, jnp.int32(ref.BF16)) * 2.0))(x)
    np.testing.assert_array_equal(np.asarray(g), np.full((64,), 2.0, np.float32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 1024),
    code=st.sampled_from(CODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_sr_hypothesis_matches_ref(n, code, seed):
    x = _rand((n,), seed=seed, scale=10.0)
    noise = _noise((n,), seed=seed + 1)
    got = sr_qdq(x, noise, jnp.int32(code))
    want = ref.sr_qdq_ref(x, noise, code)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sr_within_one_ulp(seed):
    x = _rand((512,), seed=seed)
    out = np.asarray(sr_qdq(x, _noise((512,), seed=seed + 1), jnp.int32(ref.BF16)))
    # SR picks one of the two bracketing grid points → error ≤ 1 bf16 ULP,
    # which is up to 2^-7 relative to values just above a binade boundary.
    np.testing.assert_allclose(out, np.asarray(x), rtol=2.0**-7 + 1e-9, atol=1e-30)
