//! A training session: model parameters + optimizer + BN state held as
//! host literals, with train / eval / curvature entry points that call the
//! corresponding AOT executables.
//!
//! IO orderings here mirror manifest `io` exactly:
//!   train: params*N, mom*N, state*S, x, y, codes, lr_scales, lr, loss_scale, wd
//!       -> params*N, mom*N, state*S, loss, correct, grad_var, grad_norm, overflow
//!   eval:  params*N, state*S, x, y, codes -> loss, correct
//!   curv:  params*N, state*S, x, y, u*N, codes -> u_next*N, lambdas
//!   init:  seed -> params*N, state*S

use anyhow::{Context, Result};

use super::engine::Engine;
use crate::manifest::ModelEntry;
use crate::util::rng::Rng;

/// One training batch in host memory (NHWC f32 images + i32 labels).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
}

impl Batch {
    pub fn new(x: Vec<f32>, y: Vec<i32>) -> Batch {
        let n = y.len();
        assert_eq!(x.len(), n * 32 * 32 * 3, "batch image payload mismatch");
        Batch { x, y, n }
    }
}

/// Per-step control surface — everything the Tri-Accel coordinator steers.
#[derive(Clone, Debug)]
pub struct StepCtrl {
    pub codes: Vec<i32>,
    pub lr_scales: Vec<f32>,
    pub lr: f32,
    pub loss_scale: f32,
    pub weight_decay: f32,
}

impl StepCtrl {
    pub fn uniform(num_layers: usize, code: i32, lr: f32, wd: f32) -> StepCtrl {
        StepCtrl {
            codes: vec![code; num_layers],
            lr_scales: vec![1.0; num_layers],
            lr,
            loss_scale: 1.0,
            weight_decay: wd,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainOutputs {
    pub loss: f32,
    pub correct: i64,
    pub grad_var: Vec<f32>,
    pub grad_norm: Vec<f32>,
    pub overflow: bool,
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub loss: f32,
    pub correct: i64,
    pub total: usize,
}

pub struct Session<'e> {
    pub engine: &'e Engine,
    pub entry: ModelEntry,
    params: Vec<xla::Literal>,
    mom: Vec<xla::Literal>,
    state: Vec<xla::Literal>,
    /// Power-iteration probe vectors, persisted across curvature firings.
    probes: Option<Vec<xla::Literal>>,
    pub steps: u64,
}

fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

fn vec_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn vec_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

impl<'e> Session<'e> {
    /// Materialize params/state by executing the model's `init` artifact
    /// with `seed` (threefry inside XLA — no weight blobs on disk).
    pub fn init(engine: &'e Engine, model_key: &str, seed: i32) -> Result<Session<'e>> {
        let entry = engine.manifest.model(model_key)?.clone();
        let exe = engine.executable(&entry, "init")?;
        let outs = engine.run(&exe, &[xla::Literal::scalar(seed)])?;
        let n = entry.params.len();
        let s = entry.state_shapes.len();
        anyhow::ensure!(outs.len() == n + s, "init output arity {} != {}", outs.len(), n + s);
        let mut outs = outs.into_iter();
        let params: Vec<_> = outs.by_ref().take(n).collect();
        let state: Vec<_> = outs.collect();
        let mom = entry
            .params
            .iter()
            .map(|p| {
                let zeros = vec![0f32; p.elems];
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                vec_f32(&zeros).reshape(&dims).context("zeros reshape")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Session { engine, entry, params, mom, state, probes: None, steps: 0 })
    }

    pub fn num_layers(&self) -> usize {
        self.entry.num_layers
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let x = vec_f32(&batch.x).reshape(&[batch.n as i64, 32, 32, 3])?;
        let y = vec_i32(&batch.y);
        Ok((x, y))
    }

    /// One optimizer step through the `train_b{n}` executable.
    pub fn train_step(&mut self, batch: &Batch, ctrl: &StepCtrl) -> Result<TrainOutputs> {
        anyhow::ensure!(
            self.entry.train_buckets.contains(&batch.n),
            "batch size {} is not an AOT bucket {:?}",
            batch.n,
            self.entry.train_buckets
        );
        anyhow::ensure!(ctrl.codes.len() == self.entry.num_layers, "codes arity");
        anyhow::ensure!(ctrl.lr_scales.len() == self.entry.num_layers, "lr_scales arity");
        let exe = self
            .engine
            .executable(&self.entry, &format!("train_b{}", batch.n))?;
        let (x, y) = self.batch_literals(batch)?;

        // Literal isn't Copy; execute takes Borrow<Literal>, so borrow the
        // resident params/mom/state and the freshly-built control literals.
        let mut refs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() * 2 + self.state.len() + 7);
        refs.extend(self.params.iter());
        refs.extend(self.mom.iter());
        refs.extend(self.state.iter());
        let codes = vec_i32(&ctrl.codes);
        let lr_scales = vec_f32(&ctrl.lr_scales);
        let lr = scalar_f32(ctrl.lr);
        let ls = scalar_f32(ctrl.loss_scale);
        let wd = scalar_f32(ctrl.weight_decay);
        refs.push(&x);
        refs.push(&y);
        refs.push(&codes);
        refs.push(&lr_scales);
        refs.push(&lr);
        refs.push(&ls);
        refs.push(&wd);

        let outs = run_refs(&exe, &refs)?;
        let n = self.params.len();
        let s = self.state.len();
        anyhow::ensure!(outs.len() == 2 * n + s + 5, "train output arity {}", outs.len());
        let mut it = outs.into_iter();
        self.params = it.by_ref().take(n).collect();
        self.mom = it.by_ref().take(n).collect();
        self.state = it.by_ref().take(s).collect();
        let loss = it.next().unwrap().get_first_element::<f32>()?;
        let correct = it.next().unwrap().get_first_element::<i32>()? as i64;
        let grad_var = it.next().unwrap().to_vec::<f32>()?;
        let grad_norm = it.next().unwrap().to_vec::<f32>()?;
        let overflow = it.next().unwrap().get_first_element::<i32>()? != 0;
        self.steps += 1;
        Ok(TrainOutputs { loss, correct, grad_var, grad_norm, overflow })
    }

    /// Evaluate one batch through `eval_b{n}`. Codes let callers measure
    /// quantized inference; pass all-FP32 for the paper's test protocol.
    pub fn eval_batch(&self, batch: &Batch, codes: &[i32]) -> Result<EvalResult> {
        anyhow::ensure!(
            self.entry.eval_buckets.contains(&batch.n),
            "eval batch size {} not in buckets {:?}",
            batch.n,
            self.entry.eval_buckets
        );
        let exe = self
            .engine
            .executable(&self.entry, &format!("eval_b{}", batch.n))?;
        let (x, y) = self.batch_literals(batch)?;
        let codes_l = vec_i32(codes);
        let mut refs: Vec<&xla::Literal> = Vec::new();
        refs.extend(self.params.iter());
        refs.extend(self.state.iter());
        refs.push(&x);
        refs.push(&y);
        refs.push(&codes_l);
        let outs = run_refs(&exe, &refs)?;
        anyhow::ensure!(outs.len() == 2, "eval output arity");
        Ok(EvalResult {
            loss: outs[0].get_first_element::<f32>()?,
            correct: outs[1].get_first_element::<i32>()? as i64,
            total: batch.n,
        })
    }

    /// One amortized power-iteration step on the curvature batch; returns
    /// per-layer Rayleigh quotients λ_l. Probe vectors persist in the
    /// session and warm-start the next firing.
    pub fn curv_step(&mut self, batch: &Batch, codes: &[i32], seed: u64) -> Result<Vec<f32>> {
        anyhow::ensure!(batch.n == self.entry.curv_batch, "curvature batch size");
        let exe = self.engine.executable(&self.entry, "curv")?;
        if self.probes.is_none() {
            self.probes = Some(self.fresh_probes(seed)?);
        }
        let (x, y) = self.batch_literals(batch)?;
        let codes_l = vec_i32(codes);
        let probes = self.probes.as_ref().unwrap();
        let mut refs: Vec<&xla::Literal> = Vec::new();
        refs.extend(self.params.iter());
        refs.extend(self.state.iter());
        refs.push(&x);
        refs.push(&y);
        refs.extend(probes.iter());
        refs.push(&codes_l);
        let outs = run_refs(&exe, &refs)?;
        let n = self.params.len();
        anyhow::ensure!(outs.len() == n + 1, "curv output arity");
        let mut it = outs.into_iter();
        self.probes = Some(it.by_ref().take(n).collect());
        let lambdas = it.next().unwrap().to_vec::<f32>()?;
        Ok(lambdas)
    }

    /// Reset the power iteration (e.g. after large parameter jumps).
    pub fn reset_probes(&mut self) {
        self.probes = None;
    }

    fn fresh_probes(&self, seed: u64) -> Result<Vec<xla::Literal>> {
        let mut rng = Rng::stream(seed, 0xC0FFEE);
        self.entry
            .params
            .iter()
            .map(|p| {
                let v: Vec<f32> = if p.layer_idx >= 0 {
                    (0..p.elems).map(|_| rng.next_normal()).collect()
                } else {
                    vec![0f32; p.elems] // non-precision params don't probe
                };
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                vec_f32(&v).reshape(&dims).context("probe reshape")
            })
            .collect()
    }

    /// L2 norm of a parameter tensor (telemetry / tests).
    pub fn param_norm(&self, idx: usize) -> Result<f64> {
        let v = self.params[idx].to_vec::<f32>()?;
        Ok(v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt())
    }

    /// Snapshot of all parameters as host vectors (tests / checkpoints).
    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Serialize the full optimizer state into a [`Checkpoint`].
    pub fn export(&self, step: u64) -> Result<crate::checkpoint::Checkpoint> {
        use crate::checkpoint::{Checkpoint, Tensor};
        let mut tensors = Vec::new();
        let mut push = |role: &str, i: usize, lit: &xla::Literal, dims: &[usize]| -> Result<()> {
            tensors.push(Tensor {
                name: format!("{role}/{i}"),
                dims: dims.iter().map(|&d| d as u64).collect(),
                data: lit.to_vec::<f32>()?,
            });
            Ok(())
        };
        for (i, (p, spec)) in self.params.iter().zip(&self.entry.params).enumerate() {
            push("param", i, p, &spec.shape)?;
        }
        for (i, (m, spec)) in self.mom.iter().zip(&self.entry.params).enumerate() {
            push("mom", i, m, &spec.shape)?;
        }
        for (i, (s, shape)) in self.state.iter().zip(&self.entry.state_shapes).enumerate() {
            push("state", i, s, shape)?;
        }
        Ok(Checkpoint { model_key: self.entry.key.clone(), step, tensors })
    }

    /// Restore params/momentum/state from a checkpoint. Model key and
    /// every tensor shape are validated against the manifest; probe
    /// vectors are reset (they are re-warmed cheaply).
    pub fn restore(&mut self, ckpt: &crate::checkpoint::Checkpoint) -> Result<u64> {
        anyhow::ensure!(
            ckpt.model_key == self.entry.key,
            "checkpoint is for model `{}`, session is `{}`",
            ckpt.model_key,
            self.entry.key
        );
        let lit_for = |t: &crate::checkpoint::Tensor, want: &[usize]| -> Result<xla::Literal> {
            let dims: Vec<usize> = t.dims.iter().map(|&d| d as usize).collect();
            anyhow::ensure!(
                dims == want,
                "tensor {}: checkpoint shape {:?} != manifest {:?}",
                t.name,
                dims,
                want
            );
            let d64: Vec<i64> = want.iter().map(|&d| d as i64).collect();
            Ok(vec_f32(&t.data).reshape(&d64)?)
        };
        let mut params = Vec::with_capacity(self.params.len());
        let mut mom = Vec::with_capacity(self.mom.len());
        let mut state = Vec::with_capacity(self.state.len());
        for (i, spec) in self.entry.params.iter().enumerate() {
            params.push(lit_for(ckpt.tensor(&format!("param/{i}"))?, &spec.shape)?);
            mom.push(lit_for(ckpt.tensor(&format!("mom/{i}"))?, &spec.shape)?);
        }
        for (i, shape) in self.entry.state_shapes.iter().enumerate() {
            state.push(lit_for(ckpt.tensor(&format!("state/{i}"))?, shape)?);
        }
        self.params = params;
        self.mom = mom;
        self.state = state;
        self.probes = None;
        self.steps = ckpt.step;
        Ok(ckpt.step)
    }
}

/// Execute with borrowed literals and flatten the single tuple result.
fn run_refs(
    exe: &xla::PjRtLoadedExecutable,
    refs: &[&xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let out = exe.execute::<&xla::Literal>(refs)?;
    anyhow::ensure!(out.len() == 1 && out[0].len() == 1, "expected 1x1 output");
    let lit = out[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}
