"""L2 train step — the single jitted computation the Rust hot loop executes.

One SGD+momentum step with the paper's three control surfaces exposed as
*runtime inputs* so the Rust coordinator can steer every knob without
recompilation:

  * `codes`      i32[L]  — per-layer precision p_l(t)           (§3.1)
  * `lr_scales`  f32[L]  — per-layer curvature LR scaling η_l/η₀ (§3.2)
  * `loss_scale` f32     — dynamic loss scale for FP16 layers
  * `lr`, `wd`   f32     — cosine-schedule LR and weight decay

and the control *signals* exposed as outputs:

  * `grad_var`  f32[L] — per-layer gradient variance (via the fused
                         grad_stats kernel), feeding the EMA v_l(t)
  * `grad_norm` f32[L] — per-layer gradient L2² (diagnostics / telemetry)
  * `overflow`  i32    — any non-finite grad → the step was skipped and the
                         Rust side should halve the loss scale (AMP-style)

Batch size is baked per artifact (PJRT executables are shape-specialized);
the elastic controller snaps to the bucket ladder (DESIGN.md §6.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import api
from .models import common as C

MOMENTUM = 0.9


def _per_layer_grad_stats(model, grads):
    """Combine per-param moments into per-precision-layer variance/norm.

    Counts are static, so the weighted-moment combination is exact:
      E[x²]_layer = Σ n_p·E[x²]_p / Σ n_p,  var = E[x²] − mean².
    """
    L = model.num_layers
    sums = [jnp.float32(0.0)] * L
    sqs = [jnp.float32(0.0)] * L
    counts = [0] * L
    for spec, g in zip(model.param_specs, grads):
        li = spec.layer_idx
        if li < 0:
            continue  # BN/bias params don't drive precision decisions
        n = 1
        for d in spec.shape:
            n *= d
        mean, var = api.grad_stats(g)
        sums[li] = sums[li] + n * mean
        sqs[li] = sqs[li] + n * (var + mean * mean)
        counts[li] += n
    grad_var = []
    grad_norm = []
    for li in range(L):
        n = max(counts[li], 1)
        mean = sums[li] / n
        ex2 = sqs[li] / n
        grad_var.append(jnp.maximum(ex2 - mean * mean, 0.0))
        grad_norm.append(sqs[li])  # Σ g² over the layer
    return jnp.stack(grad_var), jnp.stack(grad_norm)


def make_train_step(model):
    """Returns train_step(params, mom, state, x, y, codes, lr_scales, lr,
    loss_scale, wd) -> (params', mom', state', loss, correct, grad_var,
    grad_norm, overflow)."""

    layer_of_param = [s.layer_idx for s in model.param_specs]

    def loss_fn(params, state, x, y, codes, loss_scale):
        logits, new_state = model.apply(params, state, x, codes, train=True)
        loss = C.cross_entropy(logits, y)
        correct = C.correct_count(logits, y)
        # Scale only the loss that produces grads; report the true loss.
        return loss * loss_scale, (loss, correct, new_state)

    def train_step(params, mom, state, x, y, codes, lr_scales, lr, loss_scale, wd):
        params = tuple(params)
        mom = tuple(mom)
        state = tuple(state)
        grads, (loss, correct, new_state) = jax.grad(loss_fn, has_aux=True)(
            params, state, x, y, codes, loss_scale
        )
        inv_scale = 1.0 / loss_scale
        grads = [g * inv_scale for g in grads]

        # Overflow detection over every grad tensor (cheap reductions).
        finite = jnp.bool_(True)
        for g in grads:
            finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))
        overflow = jnp.logical_not(finite)

        grad_var, grad_norm = _per_layer_grad_stats(model, grads)

        # Fused optimizer update (L1 sgd_update kernel): one streaming
        # pass per tensor computing g_eff/momentum/step with the overflow
        # gate as a runtime mask -- no branch recompilation (same design
        # as the precision codes).
        apply_mask = jnp.where(overflow, jnp.float32(0.0), jnp.float32(1.0))
        new_params = []
        new_mom = []
        for p, m, g, li in zip(params, mom, grads, layer_of_param):
            scale = lr_scales[li] if li >= 0 else jnp.float32(1.0)
            p_new, m_new = api.sgd_update(p, m, g, lr * scale, wd, apply_mask)
            new_params.append(p_new)
            new_mom.append(m_new)

        # BN state also holds on overflow (the batch stats came from a
        # poisoned forward only if activations overflowed; conservative).
        new_state = [
            jnp.where(overflow, old, new) for old, new in zip(state, new_state)
        ]

        return (
            tuple(new_params),
            tuple(new_mom),
            tuple(new_state),
            loss,
            correct,
            grad_var,
            grad_norm,
            overflow.astype(jnp.int32),
        )

    return train_step


def example_args(model, batch: int):
    """ShapeDtypeStructs for AOT lowering (order = HLO parameter order)."""
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    params = tuple(sds(p.shape, f32) for p in model.params)
    mom = tuple(sds(p.shape, f32) for p in model.params)
    state = tuple(sds(s.shape, f32) for s in model.state)
    x = sds((batch, 32, 32, 3), f32)
    y = sds((batch,), jnp.int32)
    codes = sds((model.num_layers,), jnp.int32)
    lr_scales = sds((model.num_layers,), f32)
    scalar = sds((), f32)
    return (params, mom, state, x, y, codes, lr_scales, scalar, scalar, scalar)
