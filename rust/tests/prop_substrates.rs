//! Property tests on the substrates the coordinator trusts: the VRAM
//! simulator's monotonicity laws, the data pipeline's coverage
//! guarantees, the LR schedule, and checkpoint serialization.

use std::collections::BTreeSet;

use tri_accel::checkpoint::{Checkpoint, Tensor};
use tri_accel::data::{synthetic::SyntheticCifar, BatchIter};
use tri_accel::manifest::{LayerSpec, ModelEntry, BF16, FP16, FP32};
use tri_accel::memsim::{MemoryMonitor, VramSim};
use tri_accel::schedule::LrSchedule;
use tri_accel::util::prop::{check, log_uniform, small_usize, uniform};
use tri_accel::util::rng::Rng;

fn random_entry(rng: &mut Rng) -> ModelEntry {
    let layers = small_usize(rng, 1, 10);
    ModelEntry {
        key: "prop".into(),
        model: "prop".into(),
        num_classes: 10,
        num_layers: layers,
        param_count: 0,
        layers: (0..layers)
            .map(|i| LayerSpec {
                name: format!("l{i}"),
                kind: "conv".into(),
                param_elems: small_usize(rng, 100, 1_000_000),
                act_elems: small_usize(rng, 10, 200_000),
                flops: small_usize(rng, 1000, 10_000_000),
            })
            .collect(),
        params: vec![],
        nodes: vec![],
        state_shapes: vec![],
        train_buckets: vec![16, 32, 64, 96, 128],
        eval_buckets: vec![16],
        curv_batch: 32,
        artifacts: Default::default(),
    }
    .with_param_count()
}

trait Fixup {
    fn with_param_count(self) -> Self;
}

impl Fixup for ModelEntry {
    fn with_param_count(mut self) -> Self {
        self.param_count = self.layers.iter().map(|l| l.param_elems).sum();
        self
    }
}

// ---------------------------------------------------------------- memsim

#[test]
fn prop_memsim_monotone_in_batch() {
    check("usage is strictly increasing in batch size", |rng| {
        let e = random_entry(rng);
        let mut sim = VramSim::new(&e, 10.0, 0.0, 0);
        let codes: Vec<i32> = (0..e.num_layers)
            .map(|_| [FP16, BF16, FP32][small_usize(rng, 0, 2)])
            .collect();
        let mut prev = 0.0;
        for &b in &[16usize, 32, 64, 96, 128] {
            let u = sim.usage(b, &codes, false).total_gb;
            if u <= prev {
                return Err(format!("usage({b}) = {u} ≤ usage(prev) = {prev}"));
            }
            prev = u;
        }
        Ok(())
    });
}

#[test]
fn prop_memsim_lower_precision_never_costs_more() {
    check("uniformly lower-precision codes never increase usage", |rng| {
        let e = random_entry(rng);
        let mut sim = VramSim::new(&e, 10.0, 0.0, 0);
        let b = [16usize, 32, 64, 96][small_usize(rng, 0, 3)];
        let hi = vec![FP32; e.num_layers];
        let lo: Vec<i32> = (0..e.num_layers)
            .map(|_| [FP16, BF16][small_usize(rng, 0, 1)])
            .collect();
        let u_hi = sim.usage(b, &hi, false).total_gb;
        let u_lo = sim.usage(b, &lo, false).total_gb;
        if u_lo > u_hi {
            return Err(format!("half-precision usage {u_lo} > fp32 {u_hi}"));
        }
        Ok(())
    });
}

#[test]
fn prop_memsim_peak_is_monotone_nondecreasing() {
    check("peak never decreases over a run", |rng| {
        let e = random_entry(rng);
        let mut sim = VramSim::new(&e, 10.0, uniform(rng, 0.0, 0.05), 7);
        let codes = vec![BF16; e.num_layers];
        let mut peak = sim.peak_gb();
        for _ in 0..50 {
            let b = [16usize, 32, 64, 96, 128][small_usize(rng, 0, 4)];
            sim.usage(b, &codes, rng.bernoulli(0.2));
            if sim.peak_gb() < peak - 1e-12 {
                return Err(format!("peak dropped {peak} → {}", sim.peak_gb()));
            }
            peak = sim.peak_gb();
        }
        Ok(())
    });
}

#[test]
fn prop_memsim_would_fit_consistent_with_usage() {
    check("would_fit(b) ⇔ usage(b) ≤ budget (noise-free)", |rng| {
        let e = random_entry(rng);
        let budget = log_uniform(rng, -2.0, 1.0);
        let mut sim = VramSim::new(&e, budget, 0.0, 0);
        let codes = vec![BF16; e.num_layers];
        for &b in &[16usize, 64, 128] {
            let fits = sim.would_fit(b, &codes, false);
            let u = sim.usage(b, &codes, false).total_gb;
            if fits != (u <= budget) {
                return Err(format!("would_fit {fits} but usage {u} vs budget {budget}"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------------ data

#[test]
fn prop_batchiter_covers_epoch_exactly_once() {
    check("fixed-B epoch serves every example exactly once", |rng| {
        let n_batches = small_usize(rng, 2, 12);
        let b = small_usize(rng, 1, 32);
        let n = n_batches * b;
        let ds = SyntheticCifar::new(10, n, true, rng.next_u64());
        let mut it = BatchIter::new(Box::new(ds), rng.next_u64(), false);
        let mut seen = BTreeSet::new();
        let mut labels = Vec::new();
        for _ in 0..n_batches {
            let batch = it.next_batch(b).map_err(|e| e.to_string())?;
            labels.extend_from_slice(&batch.y);
        }
        // Labels are idx % 10 and the permutation is a bijection, so the
        // label histogram must match the dataset's exactly.
        let mut want: Vec<i32> = (0..n).map(|i| (i % 10) as i32).collect();
        want.sort_unstable();
        labels.sort_unstable();
        if labels != want {
            return Err("epoch coverage broken: label multiset mismatch".into());
        }
        seen.insert(0);
        Ok(())
    });
}

#[test]
fn prop_batchiter_deterministic_across_batch_splits() {
    check("example content independent of batch-size history", |rng| {
        let n = 240;
        let seed = rng.next_u64();
        let mk = || {
            let ds = SyntheticCifar::new(10, n, true, seed);
            BatchIter::new(Box::new(ds), seed, true)
        };
        // Drain the same epoch with two different batch-size schedules.
        let mut a = mk();
        let mut b = mk();
        let mut xa = Vec::new();
        let mut xb = Vec::new();
        for _ in 0..5 {
            xa.extend(a.next_batch(24).map_err(|e| e.to_string())?.x);
        }
        let splits = [16usize, 32, 8, 40, 24];
        for &s in &splits {
            xb.extend(b.next_batch(s).map_err(|e| e.to_string())?.x);
        }
        if xa != xb {
            return Err("same stream position, different pixels".into());
        }
        Ok(())
    });
}

// -------------------------------------------------------------- schedule

#[test]
fn prop_schedule_bounded_and_decaying() {
    check("lr ∈ [0, base]; monotone non-increasing after warmup", |rng| {
        let base = uniform(rng, 1e-4, 1.0) as f32;
        let total = small_usize(rng, 10, 2000) as u64;
        let warmup = small_usize(rng, 0, 500) as u64;
        let s = LrSchedule::new(base, warmup.min(total / 2), total);
        let mut prev = f32::INFINITY;
        for step in 0..total + 10 {
            let lr = s.lr_at(step);
            if !(0.0..=base + 1e-6).contains(&lr) {
                return Err(format!("lr {lr} out of [0, {base}] at {step}"));
            }
            if step >= s.warmup_steps && lr > prev + 1e-6 {
                return Err(format!("lr increased after warmup at {step}"));
            }
            if step >= s.warmup_steps {
                prev = lr;
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------ checkpoint

#[test]
fn prop_checkpoint_roundtrip_any_shapes() {
    check("checkpoint save/load is identity for arbitrary tensors", |rng| {
        let n_tensors = small_usize(rng, 1, 8);
        let tensors: Vec<Tensor> = (0..n_tensors)
            .map(|i| {
                let ndim = small_usize(rng, 0, 4);
                let dims: Vec<u64> =
                    (0..ndim).map(|_| small_usize(rng, 1, 8) as u64).collect();
                let elems: u64 = dims.iter().product();
                Tensor {
                    name: format!("t/{i}"),
                    dims,
                    data: (0..elems).map(|_| rng.next_normal()).collect(),
                }
            })
            .collect();
        let n_ctrl = small_usize(rng, 0, 5);
        let ctrl: Vec<(String, Vec<f64>)> = (0..n_ctrl)
            .map(|i| {
                let len = small_usize(rng, 0, 16);
                (
                    format!("ctrl/{i}"),
                    (0..len).map(|_| rng.next_normal() as f64 * 1e3).collect(),
                )
            })
            .collect();
        let c = Checkpoint {
            model_key: format!("m{}", small_usize(rng, 0, 99)),
            method_key: format!("meth{}", small_usize(rng, 0, 9)),
            graph_digest: rng.next_u64(),
            step: rng.next_u64() % 1_000_000,
            tensors,
            ctrl,
        };
        let p = std::env::temp_dir().join(format!(
            "triaccel_prop_ckpt_{}_{}.bin",
            std::process::id(),
            rng.next_u64()
        ));
        c.save(&p).map_err(|e| e.to_string())?;
        let d = Checkpoint::load(&p).map_err(|e| e.to_string())?;
        std::fs::remove_file(&p).ok();
        if d.model_key != c.model_key || d.step != c.step {
            return Err("header mismatch".into());
        }
        if d.method_key != c.method_key || d.graph_digest != c.graph_digest {
            return Err("compat header mismatch".into());
        }
        for (a, b) in c.tensors.iter().zip(&d.tensors) {
            if a.name != b.name || a.dims != b.dims || a.data != b.data {
                return Err(format!("tensor {} mismatch", a.name));
            }
        }
        if d.ctrl != c.ctrl {
            return Err("ctrl section mismatch".into());
        }
        Ok(())
    });
}

// ------------------------------------------- graph-executor gradients

/// Central-difference check of `analytic` against the scalar map `f`
/// at randomly probed components (FD noise tolerances tuned for f32
/// forwards, matching the in-crate op gradchecks).
fn fd_probe(
    rng: &mut Rng,
    inputs: &mut [f32],
    analytic: &[f32],
    checks: usize,
    mut f: impl FnMut(&[f32]) -> f64,
) -> Result<(), String> {
    for _ in 0..checks {
        let i = rng.below(inputs.len() as u64) as usize;
        let eps = 3e-2f32;
        let orig = inputs[i];
        inputs[i] = orig + eps;
        let lp = f(inputs);
        inputs[i] = orig - eps;
        let lm = f(inputs);
        inputs[i] = orig;
        let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
        let diff = (numeric - analytic[i]).abs();
        let scale = numeric.abs().max(analytic[i].abs()).max(3e-2);
        if diff / scale >= 0.07 {
            return Err(format!("[{i}]: numeric {numeric} vs analytic {}", analytic[i]));
        }
    }
    Ok(())
}

/// Fixed-weight scalar loss so cotangents are non-trivial but known.
fn wsum(v: &[f32]) -> (f64, Vec<f32>) {
    let mut l = 0f64;
    let mut g = vec![0f32; v.len()];
    for (i, &x) in v.iter().enumerate() {
        let wgt = ((i % 7) as f32 - 3.0) * 0.25;
        l += (x * wgt) as f64;
        g[i] = wgt;
    }
    (l, g)
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal()).collect()
}

#[test]
fn prop_fd_strided_conv() {
    use tri_accel::runtime::native::ops;
    check("stride-2 conv backward matches finite differences", |rng| {
        let (n, h, w) = (small_usize(rng, 1, 2), 2 * small_usize(rng, 2, 3), 2 * small_usize(rng, 2, 3));
        let (cin, cout) = (small_usize(rng, 1, 3), small_usize(rng, 1, 4));
        let mut x = randv(rng, n * h * w * cin);
        let mut wt = randv(rng, 9 * cin * cout);
        let out = ops::conv_fwd(&x, n, h, w, cin, &wt, cout, 3, 2);
        let (_, g) = wsum(&out);
        let (dx, dw) = ops::conv_bwd(&x, n, h, w, cin, &wt, cout, 3, 2, &g);
        let wt2 = wt.clone();
        fd_probe(rng, &mut x, &dx, 6, |xs| {
            wsum(&ops::conv_fwd(xs, n, h, w, cin, &wt2, cout, 3, 2)).0
        })
        .map_err(|e| format!("dx{e}"))?;
        let x2 = x.clone();
        fd_probe(rng, &mut wt, &dw, 6, |ws| {
            wsum(&ops::conv_fwd(&x2, n, h, w, cin, ws, cout, 3, 2)).0
        })
        .map_err(|e| format!("dw{e}"))
    });
}

#[test]
fn prop_fd_conv1x1() {
    use tri_accel::runtime::native::ops;
    check("1×1 conv backward matches finite differences", |rng| {
        let (n, h, w) = (small_usize(rng, 1, 2), small_usize(rng, 3, 5), small_usize(rng, 3, 5));
        let (cin, cout) = (small_usize(rng, 1, 4), small_usize(rng, 1, 4));
        let stride = small_usize(rng, 1, 2);
        let mut x = randv(rng, n * h * w * cin);
        let mut wt = randv(rng, cin * cout);
        let out = ops::conv_fwd(&x, n, h, w, cin, &wt, cout, 1, stride);
        let (_, g) = wsum(&out);
        let (dx, dw) = ops::conv_bwd(&x, n, h, w, cin, &wt, cout, 1, stride, &g);
        let wt2 = wt.clone();
        fd_probe(rng, &mut x, &dx, 6, |xs| {
            wsum(&ops::conv_fwd(xs, n, h, w, cin, &wt2, cout, 1, stride)).0
        })
        .map_err(|e| format!("dx{e}"))?;
        let x2 = x.clone();
        fd_probe(rng, &mut wt, &dw, 6, |ws| {
            wsum(&ops::conv_fwd(&x2, n, h, w, cin, ws, cout, 1, stride)).0
        })
        .map_err(|e| format!("dw{e}"))
    });
}

#[test]
fn prop_fd_depthwise_conv() {
    use tri_accel::runtime::native::ops;
    check("depthwise conv backward matches finite differences", |rng| {
        let (n, c) = (small_usize(rng, 1, 2), small_usize(rng, 1, 4));
        let (h, w) = (2 * small_usize(rng, 2, 3), 2 * small_usize(rng, 2, 3));
        let stride = small_usize(rng, 1, 2);
        let mut x = randv(rng, n * h * w * c);
        let mut wt = randv(rng, 9 * c);
        let out = ops::dwconv_fwd(&x, n, h, w, c, 3, stride, &wt);
        let (_, g) = wsum(&out);
        let (dx, dw) = ops::dwconv_bwd(&x, n, h, w, c, 3, stride, &wt, &g);
        let wt2 = wt.clone();
        fd_probe(rng, &mut x, &dx, 6, |xs| {
            wsum(&ops::dwconv_fwd(xs, n, h, w, c, 3, stride, &wt2)).0
        })
        .map_err(|e| format!("dx{e}"))?;
        let x2 = x.clone();
        fd_probe(rng, &mut wt, &dw, 6, |ws| {
            wsum(&ops::dwconv_fwd(&x2, n, h, w, c, 3, stride, ws)).0
        })
        .map_err(|e| format!("dw{e}"))
    });
}

/// A minimal residual graph (conv → relu → conv → add → gap → dense):
/// the relu output forks into both the second conv and the residual
/// add, so this pins the executor's cotangent accumulation at joins.
const RES_TOY: &str = r#"{
  "precision_codes": {"fp16":0,"bf16":1,"fp32":2},
  "models": {
    "res_toy_c10": {
      "model":"res_toy","num_classes":10,"num_layers":3,"param_count":734,
      "layers":[
        {"name":"stem","kind":"conv","param_elems":108,"act_elems":4096,"flops":110592},
        {"name":"c2","kind":"conv","param_elems":576,"act_elems":4096,"flops":147456},
        {"name":"head","kind":"dense","param_elems":40,"act_elems":10,"flops":40}
      ],
      "params":[
        {"name":"stem/w","shape":[3,3,3,4],"layer_idx":0,"elems":108},
        {"name":"c2/w","shape":[3,3,4,4],"layer_idx":1,"elems":576},
        {"name":"head/w","shape":[4,10],"layer_idx":2,"elems":40},
        {"name":"head/b","shape":[10],"layer_idx":-1,"elems":10}
      ],
      "graph":[
        {"op":"conv","k":3,"stride":1,"w":0,"layer":0,"in":-1},
        {"op":"relu","in":0},
        {"op":"conv","k":3,"stride":1,"w":1,"layer":1,"in":1},
        {"op":"add","rhs":1,"in":2},
        {"op":"gap","in":3},
        {"op":"dense","w":2,"b":3,"layer":2,"in":4},
        {"op":"softmax_ce","in":5}
      ],
      "state_shapes":[],
      "train_buckets":[16],"eval_buckets":[16],"curv_batch":16,
      "artifacts":{}
    }
  }
}"#;

fn cifar_batch(n: usize, classes: u64, seed: u64) -> tri_accel::runtime::Batch {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
    tri_accel::runtime::Batch::new(x, y)
}

#[test]
fn residual_add_gradients_match_finite_differences() {
    use tri_accel::manifest::Manifest;
    use tri_accel::runtime::native::{graph, Exec};
    let m = Manifest::parse(RES_TOY, std::path::Path::new("/toy")).unwrap();
    let entry = m.model("res_toy_c10").unwrap().clone();
    let mut ex = Exec::new(1);
    let mut st = graph::init(&entry, 5).unwrap();
    let b = cifar_batch(2, 10, 3);
    let codes = vec![FP32; entry.num_layers];
    let (_, grads) = graph::loss_and_grads(&mut ex, &entry, &st, &b, &codes).unwrap();
    let mut rng = Rng::new(0xADD);
    // Probe every parameter tensor — the residual fork touches all of
    // them (stem/w sits upstream of both branches).
    for pi in 0..st.params.len() {
        for _ in 0..4 {
            let k = rng.below(st.params[pi].len() as u64) as usize;
            let eps = 5e-3f32;
            let orig = st.params[pi][k];
            st.params[pi][k] = orig + eps;
            let lp = graph::loss_at(&mut ex, &entry, &st.params, &st.state, &b, &codes).unwrap()
                as f64;
            st.params[pi][k] = orig - eps;
            let lm = graph::loss_at(&mut ex, &entry, &st.params, &st.state, &b, &codes).unwrap()
                as f64;
            st.params[pi][k] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let analytic = grads[pi][k];
            let diff = (numeric - analytic).abs();
            let scale = numeric.abs().max(analytic.abs()).max(3e-2);
            assert!(
                diff / scale < 0.15,
                "param {pi}[{k}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }
}

#[test]
fn resnet_mini_whole_model_gradcheck_fp32() {
    use tri_accel::runtime::native::{builtin_manifest, graph, Exec};
    let entry = builtin_manifest().model("resnet_mini_c10").unwrap().clone();
    let mut ex = Exec::from_env();
    let mut st = graph::init(&entry, 7).unwrap();
    let b = cifar_batch(2, 10, 1);
    let codes = vec![FP32; entry.num_layers];
    let (_, grads) = graph::loss_and_grads(&mut ex, &entry, &st, &b, &codes).unwrap();
    let mut rng = Rng::new(0xFD);
    // Spot-check components of every parameter tensor — stem, both
    // residual-branch convs, the 1×1 downsample shortcuts, BN affine
    // params, and the head all get probed.
    for pi in 0..st.params.len() {
        for _ in 0..3 {
            let k = rng.below(st.params[pi].len() as u64) as usize;
            let eps = 5e-3f32;
            let orig = st.params[pi][k];
            st.params[pi][k] = orig + eps;
            let lp = graph::loss_at(&mut ex, &entry, &st.params, &st.state, &b, &codes).unwrap()
                as f64;
            st.params[pi][k] = orig - eps;
            let lm = graph::loss_at(&mut ex, &entry, &st.params, &st.state, &b, &codes).unwrap()
                as f64;
            st.params[pi][k] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let analytic = grads[pi][k];
            let diff = (numeric - analytic).abs();
            let scale = numeric.abs().max(analytic.abs()).max(3e-2);
            assert!(
                diff / scale < 0.15,
                "{}[{k}]: numeric {numeric} vs analytic {analytic}",
                entry.params[pi].name
            );
        }
    }
}

// ------------------------------------------------- thread determinism

/// Train 3 steps on the native backend with 1, 2, and 4 worker threads
/// and require bit-identical losses, grad stats, parameters, and
/// controller state. This is the contract the deterministic worker
/// pool (`runtime/native/pool.rs`) guarantees: fixed work chunks +
/// ordered reductions, so `TRIACCEL_THREADS` is a pure performance
/// knob. Each case runs 9 full train steps, so it draws a fixed small
/// case count instead of PROP_CASES; the failing seed is printed.
#[test]
fn prop_train_bit_identical_across_thread_counts() {
    use tri_accel::config::{Config, Method};
    use tri_accel::coordinator::Controller;
    use tri_accel::runtime::{Batch, Engine, Session, StepCtrl};

    let precisions = [FP16, BF16, FP32];
    for case in 0..6u64 {
        let mut rng = Rng::stream(0xD17E, case);
        let seed = rng.below(1000) as i32;
        let codes: Vec<i32> = (0..4)
            .map(|_| precisions[small_usize(&mut rng, 0, 2)])
            .collect();
        let lr = uniform(&mut rng, 0.01, 0.1) as f32;
        let loss_scale = [1.0f32, 256.0, 65536.0][small_usize(&mut rng, 0, 2)];
        let n = 16usize;
        let mut brng = Rng::stream(0xBA7C4, case);
        let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| brng.next_normal()).collect();
        let y: Vec<i32> = (0..n).map(|_| brng.below(10) as i32).collect();
        let batch = Batch::new(x, y);

        let run = |threads: usize| -> Vec<u64> {
            let engine = Engine::native_with_threads(threads);
            let mut s = Session::init(&engine, "tiny_cnn_c10", seed).unwrap();
            let entry = s.entry.clone();
            let cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, seed as u64);
            let mut ctl = Controller::new(&cfg, &entry);
            let mut ctrl = StepCtrl::uniform(4, FP32, lr, 5e-4);
            ctrl.codes = codes.clone();
            ctrl.loss_scale = loss_scale;
            let mut trace: Vec<u64> = Vec::new();
            for _ in 0..3 {
                let out = s.train_step(&batch, &ctrl).unwrap();
                ctl.observe_step(&out.grad_var, out.overflow);
                trace.push(out.loss.to_bits() as u64);
                trace.push(out.overflow as u64);
                trace.extend(out.grad_var.iter().map(|v| v.to_bits() as u64));
                trace.extend(out.grad_norm.iter().map(|v| v.to_bits() as u64));
            }
            for p in s.params_host().unwrap() {
                trace.extend(p.iter().map(|v| v.to_bits() as u64));
            }
            for (_, vals) in ctl.export_state() {
                trace.extend(vals.iter().map(|v| v.to_bits()));
            }
            trace
        };

        let t1 = run(1);
        for threads in [2usize, 4] {
            assert_eq!(
                t1,
                run(threads),
                "case {case} (seed {seed}, codes {codes:?}): \
                 {threads}-thread run diverged from 1-thread"
            );
        }
    }
}

/// Same contract over the graph-executor model grid: resnet_mini
/// (residual forks, strided + 1×1 convs) and effnet_lite (depthwise
/// convs) must also be bit-identical across 1/2/4 worker threads,
/// controller state included.
#[test]
fn prop_graph_models_bit_identical_across_thread_counts() {
    use tri_accel::config::{Config, Method};
    use tri_accel::coordinator::Controller;
    use tri_accel::runtime::{Batch, Engine, Session, StepCtrl};

    let precisions = [FP16, BF16, FP32];
    for model in ["resnet_mini_c10", "effnet_lite_c10"] {
        for case in 0..2u64 {
            let mut rng = Rng::stream(0x6AF, case);
            let seed = rng.below(1000) as i32;
            let n = 16usize;
            let mut brng = Rng::stream(0x6BA7C4, case);
            let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| brng.next_normal()).collect();
            let y: Vec<i32> = (0..n).map(|_| brng.below(10) as i32).collect();
            let batch = Batch::new(x, y);
            let lr = uniform(&mut rng, 0.01, 0.1) as f32;
            let codes_rng = Rng::stream(0x6C0DE, case);

            let run = |threads: usize| -> Vec<u64> {
                let engine = Engine::native_with_threads(threads);
                let mut s = Session::init(&engine, model, seed).unwrap();
                let entry = s.entry.clone();
                let l = entry.num_layers;
                let mut crng = codes_rng.clone();
                let codes: Vec<i32> =
                    (0..l).map(|_| precisions[small_usize(&mut crng, 0, 2)]).collect();
                let cfg = Config::cell(model, Method::TriAccel, seed as u64);
                let mut ctl = Controller::new(&cfg, &entry);
                let mut ctrl = StepCtrl::uniform(l, FP32, lr, 5e-4);
                ctrl.codes = codes;
                ctrl.loss_scale = 256.0;
                let mut trace: Vec<u64> = Vec::new();
                for _ in 0..2 {
                    let out = s.train_step(&batch, &ctrl).unwrap();
                    ctl.observe_step(&out.grad_var, out.overflow);
                    trace.push(out.loss.to_bits() as u64);
                    trace.extend(out.grad_var.iter().map(|v| v.to_bits() as u64));
                    trace.extend(out.grad_norm.iter().map(|v| v.to_bits() as u64));
                }
                for p in s.params_host().unwrap() {
                    trace.extend(p.iter().map(|v| v.to_bits() as u64));
                }
                for (_, vals) in ctl.export_state() {
                    trace.extend(vals.iter().map(|v| v.to_bits()));
                }
                trace
            };

            let t1 = run(1);
            for threads in [2usize, 4] {
                assert_eq!(
                    t1,
                    run(threads),
                    "{model} case {case}: {threads}-thread run diverged from 1-thread"
                );
            }
        }
    }
}

// ---------------------------------------------------------- qdq kernels

#[test]
fn prop_qdq_idempotent_and_ordered() {
    use tri_accel::runtime::native::qdq::qdq1;
    check("qdq is idempotent, monotone, and magnitude-bounded", |rng| {
        let v = (rng.next_normal() as f64 * log_uniform(rng, -6.0, 4.0)) as f32;
        let w = (rng.next_normal() as f64 * log_uniform(rng, -6.0, 4.0)) as f32;
        for code in [FP16, BF16, FP32] {
            let qv = qdq1(v, code);
            if qdq1(qv, code) != qv {
                return Err(format!("code {code}: not idempotent at {v}"));
            }
            let (lo, hi) = if v <= w { (v, w) } else { (w, v) };
            if qdq1(lo, code) > qdq1(hi, code) {
                return Err(format!("code {code}: order flipped at ({lo}, {hi})"));
            }
        }
        if qdq1(v, FP32) != v {
            return Err("fp32 must be the identity".into());
        }
        Ok(())
    });
}

// ------------------------------------------- simd dispatch & autotune

/// Every runtime-dispatched tier must agree with the scalar reference
/// tier to fp tolerance on ragged shapes (m, k, n deliberately not
/// multiples of the 4×8/4×16 micro-kernel footprint), across all three
/// GEMM entry points. Exact bit equality is *not* required across
/// tiers — FMA contracts the multiply-add — only within one.
#[test]
fn prop_gemm_tiers_agree_on_ragged_shapes() {
    use tri_accel::runtime::native::{arena::Arena, autotune::TuneCfg, gemm, pool::Pool, simd};
    check("each SIMD tier matches the scalar tier within fp tolerance", |rng| {
        let (m, k) = (small_usize(rng, 1, 33), small_usize(rng, 1, 41));
        let n = small_usize(rng, 1, 37);
        let a = randv(rng, m * k);
        let b = randv(rng, k * n);
        let mut bt = vec![0f32; k * n];
        gemm::transpose(&b, k, n, &mut bt);
        let ab = randv(rng, m * n);
        let nr = [8usize, 16][small_usize(rng, 0, 1)];
        let cfg = TuneCfg { row_chunk: 8 * small_usize(rng, 1, 8), nr };
        let pool = Pool::new(1);
        let mut arena = Arena::new();
        let mut run = |tier: simd::Tier| {
            let mut c = vec![0f32; m * n];
            gemm::gemm_with(tier, cfg, &pool, &mut arena, &a, &b, &mut c, m, k, n, false);
            let mut cbt = vec![0f32; m * n];
            gemm::gemm_a_bt_with(tier, cfg, &pool, &mut arena, &a, &bt, &mut cbt, m, k, n, false);
            let mut catb = vec![0f32; k * n];
            gemm::gemm_at_b_with(tier, &pool, &mut arena, &a, &ab, &mut catb, m, k, n);
            (c, cbt, catb)
        };
        let (sc, sbt, satb) = run(simd::Tier::Scalar);
        for tier in simd::available_tiers() {
            let (c, cbt, catb) = run(tier);
            let pairs = [("gemm", &c, &sc), ("a_bt", &cbt, &sbt), ("at_b", &catb, &satb)];
            for (what, got, want) in pairs {
                for (i, (&x, &y)) in got.iter().zip(want.iter()).enumerate() {
                    let scale = x.abs().max(y.abs()).max(1.0);
                    if (x - y).abs() / scale > 1e-4 {
                        return Err(format!("{tier}/{what}[{i}] {m}x{k}x{n}: {x} vs {y}"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Within one tier, worker-thread count must be a pure performance
/// knob: the shape crosses the parallel-dispatch threshold, and 1-, 2-,
/// and 4-thread runs must produce bit-identical output for every
/// available tier and candidate row blocking.
#[test]
fn prop_gemm_thread_bits_identical_in_every_tier() {
    use tri_accel::runtime::native::{arena::Arena, autotune::TuneCfg, gemm, pool::Pool, simd};
    check("threads are a pure perf knob within each dispatch tier", |rng| {
        let m = 4 * small_usize(rng, 70, 90);
        let (k, n) = (small_usize(rng, 64, 80), small_usize(rng, 32, 40));
        let a = randv(rng, m * k);
        let b = randv(rng, k * n);
        let nr = [8usize, 16][small_usize(rng, 0, 1)];
        let cfg = TuneCfg { row_chunk: [32usize, 64, 128][small_usize(rng, 0, 2)], nr };
        for tier in simd::available_tiers() {
            let run = |threads: usize| {
                let pool = Pool::new(threads);
                let mut arena = Arena::new();
                let mut c = vec![0f32; m * n];
                gemm::gemm_with(tier, cfg, &pool, &mut arena, &a, &b, &mut c, m, k, n, false);
                c.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            };
            let base = run(1);
            for t in [2usize, 4] {
                if run(t) != base {
                    return Err(format!("tier {tier}: {t}-thread bits diverged ({cfg:?})"));
                }
            }
        }
        Ok(())
    });
}

/// The tuning cache must round-trip: record a random candidate per
/// tier, persist, reload, and require the identical config back — and
/// identical GEMM bits under the reloaded config, so a cache file can
/// never change numerics.
#[test]
fn prop_autotune_cache_roundtrip_preserves_selection_and_bits() {
    use tri_accel::runtime::native::{arena::Arena, autotune, gemm, pool::Pool, simd};
    check("tuning entries survive save/load with identical bits", |rng| {
        let path = std::env::temp_dir().join(format!(
            "triaccel_prop_tune_{}_{}.json",
            std::process::id(),
            rng.next_u64()
        ));
        let (m, k) = (small_usize(rng, 1, 48), small_usize(rng, 1, 48));
        let n = small_usize(rng, 1, 48);
        let threads = small_usize(rng, 1, 4);
        let cands = autotune::candidates();
        let mut tuner = autotune::Tuner::new(&path);
        for tier in simd::available_tiers() {
            let pick = cands[small_usize(rng, 0, cands.len() - 1)];
            tuner.record(tier, threads, m, k, n, pick);
        }
        tuner.save().map_err(|e| e.to_string())?;
        let back = autotune::Tuner::load(&path);
        std::fs::remove_file(&path).ok();
        if back.len() != tuner.len() {
            return Err(format!("entry count {} → {} across reload", tuner.len(), back.len()));
        }
        let a = randv(rng, m * k);
        let b = randv(rng, k * n);
        let pool = Pool::new(threads);
        let mut arena = Arena::new();
        for tier in simd::available_tiers() {
            let before = tuner.lookup(tier, threads, m, k, n);
            let after = back.lookup(tier, threads, m, k, n);
            if before != after {
                return Err(format!("{tier}: config {before:?} reloaded as {after:?}"));
            }
            let mut c0 = vec![0f32; m * n];
            let mut c1 = vec![0f32; m * n];
            gemm::gemm_with(tier, before, &pool, &mut arena, &a, &b, &mut c0, m, k, n, false);
            gemm::gemm_with(tier, after, &pool, &mut arena, &a, &b, &mut c1, m, k, n, false);
            if c0.iter().map(|v| v.to_bits()).ne(c1.iter().map(|v| v.to_bits())) {
                return Err(format!("{tier}: bits changed across a cache reload"));
            }
        }
        Ok(())
    });
}
