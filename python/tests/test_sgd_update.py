"""L1 sgd_update Pallas kernel vs the pure-jnp oracle, plus semantic
checks of the fused overflow gate and momentum accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import api, ref
from compile.kernels import sgd_update as k


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize(
    "shape",
    [(7,), (32,), (8, 8), (3, 3, 3, 16), (1,), (257,), (64 * 1024 + 3,)],
)
def test_matches_ref_across_shapes(shape):
    p, m, g = rand(shape, 0), rand(shape, 1), rand(shape, 2)
    got_p, got_m = k.sgd_update(p, m, g, 0.1, 5e-4, 1.0)
    want_p, want_m = ref.sgd_update_ref(p, m, g, 0.1, 5e-4, 1.0)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-6, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2048),
    lr=st.floats(min_value=1e-5, max_value=1.0),
    wd=st.floats(min_value=0.0, max_value=0.1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis(n, lr, wd, seed):
    p, m, g = rand((n,), seed), rand((n,), seed + 1), rand((n,), seed + 2)
    got_p, got_m = k.sgd_update(p, m, g, lr, wd, 1.0)
    want_p, want_m = ref.sgd_update_ref(p, m, g, lr, wd, 1.0)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)


def test_mask_zero_holds_params_and_momentum():
    p, m, g = rand((100,), 3), rand((100,), 4), rand((100,), 5)
    got_p, got_m = k.sgd_update(p, m, g, 0.1, 5e-4, 0.0)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(m))


def test_momentum_accumulates_like_sgd():
    # Two steps with constant gradient: m2 = μ(μ·0 + g) + g = (1+μ)g.
    p = jnp.zeros((10,))
    m = jnp.zeros((10,))
    g = jnp.ones((10,))
    p1, m1 = k.sgd_update(p, m, g, 1.0, 0.0, 1.0)
    p2, m2 = k.sgd_update(p1, m1, g, 1.0, 0.0, 1.0)
    mu = ref.SGD_MOMENTUM
    np.testing.assert_allclose(m2, (1 + mu) * np.ones(10), rtol=1e-6)
    np.testing.assert_allclose(p2, -(1.0 + (1 + mu)) * np.ones(10), rtol=1e-6)


def test_weight_decay_pulls_toward_zero():
    p = jnp.full((10,), 2.0)
    m = jnp.zeros((10,))
    g = jnp.zeros((10,))
    p1, _ = k.sgd_update(p, m, g, 0.1, 0.5, 1.0)
    assert np.all(np.asarray(p1) < 2.0)


def test_api_dispatch_backends_agree():
    p, m, g = rand((500,), 7), rand((500,), 8), rand((500,), 9)
    with api.backend("pallas"):
        a = api.sgd_update(p, m, g, 0.05, 1e-4, 1.0)
    with api.backend("ref"):
        b = api.sgd_update(p, m, g, 0.05, 1e-4, 1.0)
    np.testing.assert_allclose(a[0], b[0], rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(a[1], b[1], rtol=2e-5, atol=1e-6)


def test_jit_and_block_boundary():
    # Exactly one block and one block + 1 (padding path), jitted.
    for n in (k.BLOCK, k.BLOCK + 1):
        p, m, g = rand((n,), 10), rand((n,), 11), rand((n,), 12)
        f = jax.jit(lambda p, m, g: k.sgd_update(p, m, g, 0.1, 0.0, 1.0))
        got_p, got_m = f(p, m, g)
        want_p, want_m = ref.sgd_update_ref(p, m, g, 0.1, 0.0, 1.0)
        np.testing.assert_allclose(got_p, want_p, rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(got_m, want_m, rtol=2e-5, atol=1e-6)


def test_momentum_constant_consistent_with_train_graph():
    from compile import train_graph

    assert train_graph.MOMENTUM == ref.SGD_MOMENTUM == k.MOMENTUM
