//! In-tree substitute for the `anyhow` crate (offline-build substrate,
//! same spirit as the crate-free JSON/RNG/bench modules in `tri-accel`).
//!
//! Implements exactly the subset this workspace uses:
//!
//! * [`Error`] — a message plus a cause chain of messages. `{e}` prints
//!   the outermost message, `{e:#}` the full `outer: cause: cause` chain.
//! * [`Result`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(msg)` / `.with_context(|| msg)` on both
//!   `Result` and `Option`.
//! * `anyhow!` / `bail!` / `ensure!` — format-string macros.
//!
//! Not implemented (unused in this workspace): backtraces, downcasting,
//! `source()` chaining of live error values.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// impl below cannot overlap with the identity `From<Error>`.
pub struct Error {
    msg: String,
    /// Causes, outermost first (each `context` pushes the old `msg`).
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message.
    fn wrap<M: fmt::Display>(self, m: M) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: m.to_string(), chain }
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading file")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("v={}", 7);
        assert_eq!(e.to_string(), "v=7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<u32> {
            let n: u32 = "x".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), Error> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }
}
