//! # Tri-Accel
//!
//! Reproduction of *"Tri-Accel: Curvature-Aware Precision-Adaptive and
//! Memory-Elastic Optimization for Efficient GPU Usage"* as a
//! three-layer stack with pluggable runtime backends:
//!
//! * **L1** — numeric-format kernels (qdq / mp_matmul / grad_stats).
//!   Reference semantics live in `python/compile/kernels/ref.py`; the
//!   default build runs the pure-Rust port in
//!   [`runtime::native::qdq`] + `runtime/native/ops.rs`.
//! * **L2** — the train/eval/curvature graphs. The native backend
//!   executes them directly in Rust (`runtime/native/tiny_cnn.rs`);
//!   the optional `pjrt` feature executes JAX-lowered HLO artifacts
//!   instead (`make artifacts` + an external `xla` crate).
//! * **L3** — this crate: the unified control loop (precision ×
//!   curvature × elastic batching), backend-agnostic sessions, and
//!   every substrate (data pipeline, VRAM simulator, metrics, config,
//!   offline-build utilities).
//!
//! ## Backend selection
//!
//! The [`runtime::Backend`] trait covers the four entry points the
//! manifest contract names (`init`, `train_b{n}`, `eval_b{n}`,
//! `curv`). Two implementations ship:
//!
//! * `native` (default) — [`runtime::native::NativeBackend`], a
//!   pure-Rust reference executor with a built-in manifest. The
//!   default build is fully hermetic: `cargo build && cargo test`
//!   needs no `artifacts/` directory, no `xla` crate, and no Python
//!   step — Python never runs at all on this path.
//! * `pjrt` (`--features pjrt`) — the PJRT/XLA executor over AOT HLO
//!   artifacts. Requires supplying the external `xla` crate and
//!   running `make artifacts` once; after that the binary is
//!   self-contained.
//!
//! Select at the CLI with `--backend native|pjrt`, or in code via
//! [`runtime::Engine::native`] / `Engine::pjrt` / [`runtime::Engine::new`].
//!
//! ## Performance
//!
//! The native backend's compute core runs convolution as fused-qdq
//! im2col + cache-blocked register-tiled GEMM
//! (`runtime/native/gemm.rs`), multi-threaded by a deterministic
//! worker pool (`runtime/native/pool.rs`): `TRIACCEL_THREADS=N` (or
//! `--threads N` / [`runtime::Engine::native_with_threads`]) changes
//! wall-clock only — fixed work chunks and ordered reductions keep
//! training output bit-identical for every thread count. Scratch
//! comes from a zero-alloc arena (`runtime/native/arena.rs`): a warm
//! train step performs no buffer allocations. `cargo bench --bench
//! micro` records the hot-path latencies to `BENCH_native.json` (see
//! README "Performance" for the schema).

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod manifest;
pub mod memsim;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod train;
pub mod util;
