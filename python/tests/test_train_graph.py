"""Train-step graph: learning, control inputs, overflow machinery, stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, train_graph
from compile.kernels import api, ref


@pytest.fixture(scope="module")
def setup():
    m = models.build("tiny_cnn", num_classes=10)
    step = jax.jit(train_graph.make_train_step(m))
    return m, step


def _blob_batch(b, seed=0, num_classes=10):
    """Linearly separable class blobs — learnable in a few steps."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, b).astype(np.int32)
    protos = np.random.default_rng(12345).standard_normal((num_classes, 32, 32, 3))
    x = 0.5 * protos[y] + 0.1 * rng.standard_normal((b, 32, 32, 3))
    return jnp.asarray(x.astype(np.float32)), jnp.asarray(y)


def _ctrl(m, code=api.FP32):
    codes = jnp.full((m.num_layers,), code, jnp.int32)
    lrs = jnp.ones((m.num_layers,), jnp.float32)
    return codes, lrs


def test_loss_decreases_over_steps(setup):
    m, step = setup
    params, mom, state = tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state)
    codes, lrs = _ctrl(m)
    losses = []
    for i in range(40):
        x, y = _blob_batch(32, seed=i)
        params, mom, state, loss, correct, gv, gn, of = step(
            params, mom, state, x, y, codes, lrs,
            jnp.float32(0.05), jnp.float32(1.0), jnp.float32(0.0),
        )
        losses.append(float(loss))
        assert int(of) == 0
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.55, losses


def test_grad_var_positive_and_finite(setup):
    m, step = setup
    x, y = _blob_batch(16, seed=99)
    codes, lrs = _ctrl(m)
    out = step(
        tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
        x, y, codes, lrs, jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0),
    )
    gv, gn = np.asarray(out[5]), np.asarray(out[6])
    assert gv.shape == (m.num_layers,) and gn.shape == (m.num_layers,)
    assert np.all(np.isfinite(gv)) and np.all(gv >= 0)
    assert np.all(gn > 0)


def test_grad_var_matches_direct_computation(setup):
    """The in-graph per-layer variance == variance of concatenated grads."""
    m, step = setup
    x, y = _blob_batch(16, seed=5)
    codes, lrs = _ctrl(m)
    out = step(
        tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
        x, y, codes, lrs, jnp.float32(0.0), jnp.float32(1.0), jnp.float32(0.0),
    )
    gv = np.asarray(out[5])

    # Recompute grads directly (lr=0 so params unchanged by `step`).
    from compile.models import common as C

    def loss_fn(params):
        logits, _ = m.apply(params, tuple(m.state), x, codes, train=True)
        return C.cross_entropy(logits, y)

    grads = jax.grad(loss_fn)(tuple(m.params))
    for li in range(m.num_layers):
        parts = [
            np.asarray(g).ravel()
            for g, s in zip(grads, m.param_specs)
            if s.layer_idx == li
        ]
        want = np.var(np.concatenate(parts))
        np.testing.assert_allclose(gv[li], want, rtol=1e-3, atol=1e-12)


def test_loss_scale_invariance(setup):
    """Reported loss/grad_var are unscaled regardless of loss_scale."""
    m, step = setup
    x, y = _blob_batch(16, seed=6)
    codes, lrs = _ctrl(m)
    args = (tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
            x, y, codes, lrs, jnp.float32(0.05))
    o1 = step(*args, jnp.float32(1.0), jnp.float32(0.0))
    o2 = step(*args, jnp.float32(1024.0), jnp.float32(0.0))
    np.testing.assert_allclose(float(o1[3]), float(o2[3]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(o1[5]), np.asarray(o2[5]), rtol=1e-3)
    for p1, p2 in zip(o1[0], o2[0]):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-4, atol=1e-7)


def test_overflow_skips_update(setup):
    m, step = setup
    x, y = _blob_batch(16, seed=7)
    # Poison the batch: inf inputs → non-finite grads end to end.
    x = x.at[0, 0, 0, 0].set(jnp.inf)
    codes, lrs = _ctrl(m)
    out = step(
        tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
        x, y, codes, lrs, jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0),
    )
    assert int(out[7]) == 1, "expected overflow flag"
    for newp, oldp in zip(out[0], m.params):
        np.testing.assert_array_equal(np.asarray(newp), np.asarray(oldp))
    for news, olds in zip(out[2], m.state):
        np.testing.assert_array_equal(np.asarray(news), np.asarray(olds))


def test_fp16_layers_have_higher_grad_var_floor(setup):
    """FP16 rounding noise inflates gradient variance vs FP32 — the signal
    the paper's controller keys on (§3.1)."""
    m, step = setup
    deltas = []
    for seed in range(4):
        x, y = _blob_batch(64, seed=100 + seed)
        _, lrs = _ctrl(m)
        base = (tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params),
                tuple(m.state), x, y)
        o32 = step(*base, jnp.full((m.num_layers,), api.FP32, jnp.int32), lrs,
                   jnp.float32(0.0), jnp.float32(1.0), jnp.float32(0.0))
        o16 = step(*base, jnp.full((m.num_layers,), api.FP16, jnp.int32), lrs,
                   jnp.float32(0.0), jnp.float32(1.0), jnp.float32(0.0))
        deltas.append(np.asarray(o16[5]) - np.asarray(o32[5]))
    # Not guaranteed per layer per batch, but on average quantization noise
    # must not *reduce* variance.
    assert np.mean(np.stack(deltas)) > -1e-9


def test_lr_scales_modulate_update(setup):
    m, step = setup
    x, y = _blob_batch(16, seed=8)
    codes, _ = _ctrl(m)
    args = (tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
            x, y, codes)
    full = step(*args, jnp.ones((m.num_layers,), jnp.float32),
                jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0))
    frozen = step(*args, jnp.zeros((m.num_layers,), jnp.float32),
                  jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0))
    # lr_scale=0 freezes precision-layer weights; BN params still move.
    moved_full, moved_frozen = 0, 0
    for pf, pz, p0, spec in zip(full[0], frozen[0], m.params, m.param_specs):
        if spec.layer_idx >= 0:
            moved_full += int(not np.array_equal(np.asarray(pf), np.asarray(p0)))
            moved_frozen += int(not np.array_equal(np.asarray(pz), np.asarray(p0)))
    assert moved_full == m.num_layers and moved_frozen == 0


def test_weight_decay_shrinks_weights(setup):
    m, step = setup
    x, y = _blob_batch(16, seed=9)
    codes, lrs = _ctrl(m)
    args = (tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state),
            x, y, codes, lrs, jnp.float32(0.1), jnp.float32(1.0))
    o_nowd = step(*args, jnp.float32(0.0))
    o_wd = step(*args, jnp.float32(0.1))
    w0 = np.linalg.norm(np.asarray(m.params[0]))
    assert np.linalg.norm(np.asarray(o_wd[0][0])) < np.linalg.norm(np.asarray(o_nowd[0][0]))
    del w0


def test_momentum_accumulates(setup):
    m, step = setup
    codes, lrs = _ctrl(m)
    params, mom, state = tuple(m.params), tuple(jnp.zeros_like(p) for p in m.params), tuple(m.state)
    x, y = _blob_batch(16, seed=10)
    o1 = step(params, mom, state, x, y, codes, lrs,
              jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0))
    o2 = step(o1[0], o1[1], o1[2], x, y, codes, lrs,
              jnp.float32(0.1), jnp.float32(1.0), jnp.float32(0.0))
    m1 = np.linalg.norm(np.asarray(o1[1][0]))
    m2 = np.linalg.norm(np.asarray(o2[1][0]))
    assert m2 > m1 * 1.2, "momentum buffer should grow on repeated batch"
