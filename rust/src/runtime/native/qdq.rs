//! Precision emulation for the native backend — the pure-Rust port of
//! `python/compile/kernels/ref.py::qdq_ref`.
//!
//! * FP16: exact IEEE binary16 round-trip (round-to-nearest-even,
//!   subnormals preserved, overflow to ±inf) via bit manipulation —
//!   matches `x.astype(float16).astype(float32)` bit-for-bit.
//! * BF16: round-to-nearest-even on the top 16 bits — matches
//!   `x.astype(bfloat16).astype(float32)` bit-for-bit.
//! * FP32: identity.
//!
//! The backward-pass contract mirrors the Pallas kernels' custom VJPs:
//! cotangents flowing out of a layer at precision p are themselves
//! rounded to p (see `qdq.py` / `mp_matmul.py`), which is what makes
//! FP16 overflow observable as non-finite gradients.

use crate::manifest::{BF16, FP16};

/// 2^-24 as f32 — the value of one binary16 subnormal ULP.
const F16_SUBNORMAL_ULP: f32 = 5.960_464_5e-8;

/// Round-trip one f32 through IEEE binary16 (RNE, saturating to inf).
pub fn f16_qdq(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 -> binary16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN (canonical quiet-NaN payload).
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // Normal half; RNE on the 13 dropped mantissa bits. A mantissa
        // carry naturally increments the exponent (and can round the
        // largest normals up to inf, which is correct RNE).
        let m = (mant >> 13) as u16;
        let rem = mant & 0x1FFF;
        let mut h = (((e + 15) as u16) << 10) | m;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            h += 1;
        }
        return sign | h;
    }
    if e < -25 {
        return sign; // underflow to signed zero
    }
    // Subnormal half: value = round(1.mant * 2^(e+24)) * 2^-24.
    let m = mant | 0x0080_0000;
    let shift = (-e - 1) as u32; // 14..=24
    let sub = (m >> shift) as u16;
    let rem = m & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut h = sub;
    if rem > half || (rem == half && (sub & 1) == 1) {
        h += 1;
    }
    sign | h
}

/// binary16 bits -> f32 (exact widening).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (mant << 13));
    }
    if exp == 0 {
        if mant == 0 {
            return f32::from_bits(sign); // signed zero
        }
        let v = mant as f32 * F16_SUBNORMAL_ULP;
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (mant << 13))
}

/// Round-trip one f32 through bfloat16 (RNE on the top 16 bits).
pub fn bf16_qdq(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let round = ((bits >> 16) & 1).wrapping_add(0x7FFF);
    f32::from_bits(bits.wrapping_add(round) & 0xFFFF_0000)
}

/// Quantize-dequantize one scalar through the precision named by `code`.
#[inline]
pub fn qdq1(x: f32, code: i32) -> f32 {
    match code {
        FP16 => f16_qdq(x),
        BF16 => bf16_qdq(x),
        _ => x,
    }
}

/// Quantize-dequantize a slice into a fresh vector.
///
/// Compat/test convenience only — it allocates on every call. The hot
/// path (tiny_cnn forward/backward, the fused im2col pack) uses the
/// slice-based [`qdq_into`] / [`qdq_inplace`] over arena buffers.
pub fn qdq(x: &[f32], code: i32) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    qdq_into(x, &mut out, code);
    out
}

/// Quantize-dequantize `src` into `dst` — the allocation-free batch
/// API. Lengths must match; FP32 degenerates to a plain copy.
pub fn qdq_into(src: &[f32], dst: &mut [f32], code: i32) {
    debug_assert_eq!(src.len(), dst.len());
    match code {
        FP16 => {
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = f16_qdq(s);
            }
        }
        BF16 => {
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = bf16_qdq(s);
            }
        }
        _ => dst.copy_from_slice(src),
    }
}

/// In-place quantize-dequantize.
pub fn qdq_inplace(x: &mut [f32], code: i32) {
    match code {
        FP16 => {
            for v in x.iter_mut() {
                *v = f16_qdq(*v);
            }
        }
        BF16 => {
            for v in x.iter_mut() {
                *v = bf16_qdq(*v);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::FP32;
    use crate::util::rng::Rng;

    #[test]
    fn fp32_is_identity() {
        let v = [1.0f32, -2.5, 1e-30, f32::INFINITY];
        assert_eq!(qdq(&v, FP32), v.to_vec());
    }

    #[test]
    fn f16_known_values() {
        // Exactly representable values pass through.
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, 65504.0, -65504.0] {
            assert_eq!(f16_qdq(v), v, "{v}");
        }
        // Half max is 65504; the RNE boundary to inf is 65520.
        assert_eq!(f16_qdq(65519.9), 65504.0);
        assert_eq!(f16_qdq(65520.0), f32::INFINITY);
        assert_eq!(f16_qdq(-65520.0), f32::NEG_INFINITY);
        assert_eq!(f16_qdq(1e30), f32::INFINITY);
        // Smallest subnormal half is 2^-24; below 2^-25 flushes to 0.
        assert_eq!(f16_qdq(5.960_464_5e-8), 5.960_464_5e-8);
        assert_eq!(f16_qdq(2.0f32.powi(-26)), 0.0);
        // 2^-25 is exactly halfway between 0 and one ULP: ties to even (0).
        assert_eq!(f16_qdq(2.0f32.powi(-25)), 0.0);
        assert!(f16_qdq(f32::NAN).is_nan());
        assert_eq!(f16_qdq(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn f16_rne_tie_to_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10 in
        // half precision; RNE picks the even mantissa (1.0).
        let tie = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f16_qdq(tie), 1.0);
        // 1 + 3*2^-11 is halfway between odd 1+2^-10 and even 1+2^-9.
        let tie2 = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f16_qdq(tie2), 1.0 + 2.0f32.powi(-9));
    }

    #[test]
    fn bf16_known_values() {
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5] {
            assert_eq!(bf16_qdq(v), v, "{v}");
        }
        // bf16 has an 8-bit mantissa: 1 + 2^-9 is halfway, ties to even.
        assert_eq!(bf16_qdq(1.0 + 2.0f32.powi(-9)), 1.0);
        assert_eq!(bf16_qdq(1.0 + 3.0 * 2.0f32.powi(-9)), 1.0 + 2.0f32.powi(-7));
        assert_eq!(bf16_qdq(f32::MAX), f32::INFINITY, "RNE overflow");
        assert_eq!(bf16_qdq(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_qdq(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(bf16_qdq(f32::NAN).is_nan());
    }

    #[test]
    fn qdq_into_matches_vec_api() {
        let mut rng = Rng::new(11);
        let src: Vec<f32> = (0..257)
            .map(|_| rng.next_normal() * 10f32.powi((rng.below(10) as i32) - 5))
            .collect();
        for code in [FP16, BF16, FP32] {
            let want = qdq(&src, code);
            let mut dst = vec![f32::NAN; src.len()];
            qdq_into(&src, &mut dst, code);
            assert_eq!(
                dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "code {code}"
            );
            let mut inplace = src.clone();
            qdq_inplace(&mut inplace, code);
            assert_eq!(inplace, want, "in-place variant agrees (code {code})");
        }
    }

    #[test]
    fn qdq_is_idempotent() {
        let mut rng = Rng::new(7);
        for _ in 0..2000 {
            let v = rng.next_normal() * 10f32.powi((rng.below(12) as i32) - 6);
            for code in [FP16, BF16] {
                let once = qdq1(v, code);
                assert_eq!(qdq1(once, code), once, "code {code} v {v}");
            }
        }
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(9);
        for _ in 0..2000 {
            let v = rng.next_normal();
            let e16 = (f16_qdq(v) - v).abs();
            let eb = (bf16_qdq(v) - v).abs();
            // Relative ULP bounds: 2^-11 for fp16, 2^-8 for bf16.
            assert!(e16 <= v.abs() * 4.9e-4 + 1e-7, "fp16 {v} err {e16}");
            assert!(eb <= v.abs() * 4e-3 + 1e-7, "bf16 {v} err {eb}");
            // bf16 is coarser than fp16 in the normal range.
        }
    }

    #[test]
    fn roundtrip_monotone() {
        // Quantization must preserve ordering.
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let a = rng.next_normal();
            let b = rng.next_normal();
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(f16_qdq(lo) <= f16_qdq(hi));
            assert!(bf16_qdq(lo) <= bf16_qdq(hi));
        }
    }
}
