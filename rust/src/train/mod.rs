//! The trainer: binds the runtime session (any [`crate::runtime::Backend`]:
//! native reference executor or PJRT artifacts), the policy control
//! plane, the VRAM simulator, and the data pipeline into the paper's
//! training procedure (§4.1–§4.3): SGD+momentum, 5-epoch warmup +
//! cosine decay, per-epoch test evaluation, 3-axis metrics.
//!
//! The step loop talks to the control plane only through its
//! observation/decision interface: [`ControlPlane::plan_step`] decides
//! the step (batch size, codes, LR scales, loss scale, probe cadence),
//! the trainer feeds back observations (`observe_step`,
//! `observe_curvature`, `oom_event`), and `control_window` runs on the
//! `window_due` cadence. The trainer never reaches into an individual
//! policy.
//!
//! One `Trainer::run()` = one Table-1 cell at one seed.
//!
//! ## Panic propagation boundary
//!
//! The trainer holds no cross-job state: everything it owns (session,
//! control plane, VRAM sim, data iterators, metrics) is constructed
//! per run and dropped with it. A panic anywhere in the step loop —
//! including one injected through the telemetry sink by a fault plan
//! ([`crate::faults::PanicSink`]) — therefore unwinds cleanly out of
//! `run()` to the scheduler's supervisor, which catches it at the job
//! boundary (`catch_unwind` in [`crate::sched`]) and retries or
//! quarantines *that job only*. The compute pool is not part of the
//! unwind path: pool workers execute fixed work chunks and the
//! trainer's panic surfaces on the job's own thread. Simulated OOMs
//! are *not* panics — `oom_event` is an observation the control plane
//! adapts to (and OOM-storm faults kill the attempt in the supervisor,
//! before the trainer ever runs, so recorded results stay
//! bit-identical).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Config, Method};
use crate::data::{auto_source, BatchIter, Dataset, IMG_ELEMS};
use crate::manifest::FP32;
use crate::memsim::hostmem::{HostMeter, MemMeter};
use crate::memsim::{BudgetTrace, MemoryMonitor, SpeedModel, VramSim};
use crate::metrics::telemetry::{self, TelemetrySink};
use crate::metrics::{efficiency_score, EpochRecord, PrecisionMix, RunMetrics};
use crate::policy::{registry, ControlPlane};
use crate::runtime::Engine;
use crate::runtime::{Batch, Session, StepCtrl};
use crate::schedule::LrSchedule;

/// Condensed result of one run — the numbers a Table-1 cell needs.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub model_key: String,
    pub method: Method,
    pub seed: u64,
    pub test_acc_pct: f64,
    /// Wallclock s/epoch over the last 5 epochs (paper protocol).
    pub wall_s_per_epoch: f64,
    /// Accelerator-terms s/epoch from the analytic speed model.
    pub modeled_s_per_epoch: f64,
    pub peak_vram_gb: f64,
    /// Score on modeled time (the Table-1 comparable).
    pub eff_score: f64,
}

pub struct Trainer<'e> {
    pub cfg: Config,
    pub session: Session<'e>,
    pub controller: ControlPlane,
    pub memsim: VramSim,
    pub speed: SpeedModel,
    pub metrics: RunMetrics,
    /// The engine the session runs on — kept for the elastic replica
    /// path (`set_live_replicas` is an engine-level control; it never
    /// changes numerics on the replicated native backend).
    engine: &'e Engine,
    schedule: LrSchedule,
    train_iter: BatchIter,
    eval_ds: Box<dyn Dataset>,
    layer_flops: Vec<usize>,
    global_step: u64,
    steps_per_epoch_hint: usize,
    /// Optional streaming event sink (`step`/`oom`/`control_window`/
    /// `epoch` JSONL telemetry — see `metrics::telemetry`). `None`
    /// (the default) emits nothing and costs nothing.
    telemetry: Option<Box<dyn TelemetrySink>>,
    /// Real host-memory meter (`--mem-source host`): sampled only at
    /// control windows, where each reading is emitted as a `host_mem`
    /// telemetry event. Observational only — the §3.3/§3.4 policies
    /// always read the simulator's scalars, so the meter can never
    /// move a deterministic artifact. `None` (`mem_source = "sim"`,
    /// the default) skips the sampling entirely.
    host_meter: Option<Box<dyn MemMeter>>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: Config) -> Result<Trainer<'e>> {
        cfg.validate()?;
        let entry = engine.manifest.model(&cfg.model_key)?.clone();
        let min_eval_bucket = entry
            .eval_buckets
            .iter()
            .min()
            .copied()
            .context("model has no eval buckets")?;
        anyhow::ensure!(
            cfg.eval_examples % min_eval_bucket == 0,
            "eval_examples must be a multiple of the smallest eval bucket ({min_eval_bucket})"
        );
        // The greedy descending eval tiling in [`Self::evaluate`] covers
        // every multiple of the smallest bucket only when each bucket is
        // itself such a multiple — validate rather than assume.
        for &b in &entry.eval_buckets {
            anyhow::ensure!(
                b % min_eval_bucket == 0,
                "eval bucket {b} is not a multiple of the smallest ({min_eval_bucket})"
            );
        }
        // Replica shape must match the backend: a config asking for N
        // data-parallel replicas needs an engine actually holding N
        // engine instances (`Engine::native_replicated`).
        anyhow::ensure!(
            cfg.replicas <= engine.replica_capacity(),
            "config wants {} replicas but the engine holds {} — construct it with \
             Engine::native_replicated (CLI: --replicas)",
            cfg.replicas,
            engine.replica_capacity()
        );
        let session = Session::init(engine, &cfg.model_key, cfg.seed as i32)
            .context("initializing session")?;
        let controller = ControlPlane::new(&cfg, &entry);
        // Auto budget (paper's "strict single-GPU memory budget", scaled
        // per model): 1.05× the FP32 footprint at the initial batch, so
        // the static baselines just fit and the adaptive method has to
        // earn headroom via precision/batch moves.
        let budget_gb = if cfg.mem_budget_gb > 0.0 {
            cfg.mem_budget_gb
        } else {
            let mut probe = VramSim::new(&entry, 1e9, 0.0, cfg.seed);
            // Replicated runs budget for the full replica aggregate —
            // all replicas just fit at FP32, so a shrinking trace
            // forces the shed path. 1 replica is the pre-replica
            // budget bit-identically.
            probe.set_replicas(cfg.replicas);
            let fp32_codes = vec![crate::manifest::FP32; entry.num_layers];
            probe.usage(cfg.batch_init, &fp32_codes, false).total_gb * 1.05
        };
        let mut memsim = VramSim::new(&entry, budget_gb, cfg.mem_noise, cfg.seed);
        memsim.set_replicas(controller.replicas());
        // VRAM-pressure scenarios: a time-varying budget trace moves
        // MemMax under the controller's feet ("const" = the paper's
        // fixed strict budget, bit-identical to the untraced path).
        memsim.set_trace(BudgetTrace::parse(&cfg.mem_trace).context("mem_trace")?);
        let speed = SpeedModel::t4_like();
        let train_ds = auto_source(entry.num_classes, true, cfg.train_examples, cfg.seed);
        // Same seed as the train source: the class prototypes define the
        // task and must match; the train=false split flag already makes
        // the example streams disjoint.
        let eval_ds = auto_source(entry.num_classes, false, cfg.eval_examples, cfg.seed);
        let steps_per_epoch_hint = cfg
            .steps_per_epoch
            .unwrap_or_else(|| cfg.train_examples.div_ceil(cfg.batch_init).max(1));
        let total_steps = (steps_per_epoch_hint * cfg.epochs) as u64;
        let warmup_steps = (steps_per_epoch_hint * cfg.warmup_epochs) as u64;
        // Warmup can't exceed the whole run (short reduced-epoch runs).
        let warmup_steps = warmup_steps.min(total_steps / 2);
        let schedule = LrSchedule::new(cfg.base_lr, warmup_steps, total_steps);
        let layer_flops = entry.layers.iter().map(|l| l.flops).collect();
        // `--mem-source host`: real RSS/MemTotal readings replace the
        // simulator's scalars at control windows. Construction is the
        // opt-in; on a host without /proc the meter degrades to None
        // and the run behaves exactly like `sim`.
        let host_meter: Option<Box<dyn MemMeter>> = if cfg.mem_source == "host" {
            HostMeter::new().map(|m| Box::new(m) as Box<dyn MemMeter>)
        } else {
            None
        };
        Ok(Trainer {
            train_iter: BatchIter::new(train_ds, cfg.seed, true),
            eval_ds,
            session,
            controller,
            memsim,
            engine,
            speed,
            metrics: RunMetrics::default(),
            schedule,
            layer_flops,
            global_step: 0,
            steps_per_epoch_hint,
            telemetry: None,
            host_meter,
            cfg,
        })
    }

    /// Install (or replace) the control-window memory meter — the test
    /// hook for driving the host-source path with a deterministic
    /// [`crate::memsim::hostmem::FakeMeter`].
    pub fn set_mem_meter(&mut self, meter: Box<dyn MemMeter>) {
        self.host_meter = Some(meter);
    }

    /// Install a streaming telemetry sink: the trainer will emit one
    /// `step` event per optimizer step plus `oom`, `control_window`,
    /// and `epoch` events as they occur (schema in `docs/TELEMETRY.md`).
    pub fn set_telemetry(&mut self, sink: Box<dyn TelemetrySink>) {
        self.telemetry = Some(sink);
    }

    pub fn global_step(&self) -> u64 {
        self.global_step
    }

    /// One optimizer step, including the paper's control-loop hooks.
    /// Returns (loss, correct, batch size, modeled seconds).
    pub fn step(&mut self) -> Result<(f64, i64, usize, f64)> {
        // Advance the budget trace before any memory accounting: the
        // pressure scenarios move MemMax per step.
        self.memsim.set_step(self.global_step);
        // The decision half of the plane's interface: one bundle holds
        // everything this step needs.
        let plan = self.controller.plan_step(self.global_step);
        // Apply the plane's replica decision before compute or memory
        // accounting: the backend moves its live engine count
        // (numerics-neutral — canonical shards + ordered reduction),
        // the simulator aggregates over it. No-ops at 1 replica.
        self.engine.set_live_replicas(plan.replicas);
        self.memsim.set_replicas(plan.replicas);
        let b = plan.batch_size;
        let batch = self.train_iter.next_batch(b)?;
        let mut lr = self.schedule.lr_at(self.global_step);
        if self.cfg.lr_batch_scaling {
            // Linear scaling rule: keep per-example step size constant
            // as the elastic controller moves B(t).
            lr *= b as f32 / self.cfg.batch_init as f32;
        }
        let curv_due = plan.curvature_due;
        let ctrl = StepCtrl {
            codes: plan.codes,
            lr_scales: plan.lr_scales,
            lr,
            loss_scale: plan.loss_scale,
            weight_decay: self.cfg.weight_decay,
        };
        let out = self.session.train_step(&batch, &ctrl)?;
        self.controller.observe_step(&out.grad_var, out.overflow);
        if out.overflow {
            self.metrics.overflows += 1;
        }

        // VRAM accounting for this step (the §3.3 feedback signal). The
        // curvature probe is accounted separately below — it executes as
        // its own small-batch step (b_curv), not on top of this one.
        let usage = self.memsim.usage(b, &ctrl.codes, false);
        if usage.total_gb > self.memsim.mem_max_gb() {
            // Simulated OOM — the paper's motivating failure mode. The
            // elastic policy reacts with an emergency shrink; the
            // static baselines keep their batch (and the OOM counter
            // records that a real run would have crashed here).
            self.controller.oom_event(self.global_step);
            self.metrics.oom_events += 1;
            let max_gb = self.memsim.mem_max_gb();
            if let Some(sink) = self.telemetry.as_mut() {
                sink.emit(&telemetry::ev_oom(self.global_step, usage.total_gb, max_gb));
            }
        }

        // §3.2 curvature probe on its own cadence.
        if curv_due {
            let cb = self.session.entry.curv_batch;
            let cbatch = self.train_iter.next_batch(cb)?;
            let lambdas = self
                .session
                .curv_step(&cbatch, &ctrl.codes, self.cfg.seed ^ 0xCAFE)?;
            let rejected = self.controller.observe_curvature(&lambdas);
            if !rejected.is_empty() {
                self.session.reset_probes();
            }
            // Probe-step memory event: activations at b_curv plus the
            // u/Hu buffers. At the paper's geometry (b_curv=32 ≪ B=96)
            // this sits below the train step's peak; it only surfaces
            // when b_curv ≈ B (the CPU-scaled bench).
            let _ = self.memsim.usage(cb, &ctrl.codes, true);
            self.metrics.curv_firings += 1;
        }

        // §3.4 unified control window.
        if self.controller.window_due(self.global_step) {
            // The host meter (`--mem-source host`) is observational
            // only: every successful sample surfaces as a `host_mem`
            // telemetry event, but the control plane always sees the
            // simulator's scalars — live machine state must never
            // steer a deterministic artifact (docs/MEMORY.md). A
            // failed sample (no /proc) just skips the event.
            if let Some(m) = self.host_meter.as_mut() {
                if let Some(smp) = m.sample() {
                    let source = m.source();
                    if let Some(sink) = self.telemetry.as_mut() {
                        sink.emit(&telemetry::ev_host_mem(
                            self.global_step,
                            smp.used_gb,
                            smp.max_gb,
                            source,
                        ));
                    }
                }
            }
            let (used, max) = (self.memsim.mem_used_gb(), self.memsim.mem_max_gb());
            // Both fit predicates probe the same simulator; the plane
            // calls them sequentially, so a shared RefCell borrow is
            // never contended.
            let memsim = std::cell::RefCell::new(&mut self.memsim);
            let codes = ctrl.codes.clone();
            // Growth must leave the ρ_high shrink-band unviolated *and*
            // absorb a curvature-probe transient — otherwise the grown
            // batch immediately shrinks back and the spike sets the peak.
            let rho_high = self.cfg.rho_high;
            let curv_on = self.controller.curvature_active();
            let d = self.controller.control_window_replicated(
                self.global_step,
                used,
                max,
                |nb| memsim.borrow_mut().would_fit_within(nb, &codes, curv_on, rho_high),
                // Restoring replicas must keep the *current* batch
                // under the same band, at aggregate-VRAM accounting.
                |nr| memsim.borrow_mut().would_fit_replicas(nr, b, &codes, curv_on, rho_high),
            );
            self.metrics.promotions += d.promotions.len() as u64;
            if let Some(sink) = self.telemetry.as_mut() {
                sink.emit(&telemetry::ev_control_window(
                    self.global_step,
                    d.promotions.len(),
                    d.batch_size,
                    d.loss_scale as f64,
                    d.replicas,
                ));
            }
        }

        let modeled =
            self.speed
                .step_seconds_replicated(b, &ctrl.codes, &self.layer_flops, plan.replicas);
        self.metrics.record_batch(self.global_step, b);
        self.metrics.record_replicas(plan.replicas);
        if let Some(sink) = self.telemetry.as_mut() {
            sink.emit(&telemetry::ev_step(
                self.global_step,
                b,
                out.loss as f64,
                modeled,
                plan.replicas,
                usage.total_gb,
                self.memsim.mem_max_gb(),
            ));
        }
        self.global_step += 1;
        Ok((out.loss as f64, out.correct, b, modeled))
    }

    /// One epoch of `steps_per_epoch` steps (or a full pass in examples).
    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochRecord> {
        let mut consumed = 0usize;
        let mut steps = 0u64;
        let mut loss_sum = 0.0;
        let mut correct = 0i64;
        let mut modeled_s = 0.0;
        let budget_examples = self.cfg.train_examples;
        let fixed_steps = self.cfg.steps_per_epoch;
        // detlint: allow(d2) — wall_s is a measured-only epoch field,
        // excluded from digests and goldens (docs/TELEMETRY.md).
        let t0 = Instant::now();
        loop {
            let (loss, corr, b, modeled) = self.step()?;
            steps += 1;
            consumed += b;
            loss_sum += loss;
            correct += corr;
            modeled_s += modeled;
            let done = match fixed_steps {
                Some(n) => steps as usize >= n,
                None => consumed >= budget_examples,
            };
            if done {
                break;
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let (test_loss, test_acc) = self.evaluate()?;
        let peak = self.memsim.peak_gb();
        // Normalize modeled time to one *nominal* epoch so reduced-step
        // runs and elastic batch sizes compare like the paper's full
        // passes (time per 50k examples, not per step budget).
        let modeled_norm = modeled_s * self.cfg.train_examples as f64 / consumed as f64;
        let rec = EpochRecord {
            epoch,
            steps,
            train_loss: loss_sum / steps as f64,
            train_acc: 100.0 * correct as f64 / consumed as f64,
            test_loss,
            test_acc,
            examples: consumed,
            wall_s,
            modeled_s,
            modeled_s_norm: modeled_norm,
            peak_vram_gb: peak,
            mean_batch: consumed as f64 / steps as f64,
            mix: PrecisionMix::of(&self.controller.codes()),
            lr: self.schedule.lr_at(self.global_step.saturating_sub(1)) as f64,
            loss_scale: self.controller.scaler.scale() as f64,
            eff_score: efficiency_score(test_acc, modeled_norm, peak),
        };
        self.metrics.epochs.push(rec.clone());
        if let Some(sink) = self.telemetry.as_mut() {
            sink.emit(&telemetry::ev_epoch(&rec));
        }
        self.train_iter.next_epoch();
        let counts = self.controller.counts();
        self.metrics.precision_transitions = counts.precision_transitions;
        self.metrics.ctrl_windows = counts.windows;
        self.metrics.batch_decisions = counts.batch_decisions;
        self.metrics.replica_decisions = counts.replica_decisions;
        Ok(rec)
    }

    /// Full test-set evaluation at FP32 (paper's test protocol), tiled
    /// over the eval bucket ladder (largest buckets first).
    ///
    /// The example count is truncated to a multiple of the smallest
    /// eval bucket: when the dataset is smaller than `eval_examples`
    /// and not bucket-aligned, the old greedy tiling could strand a
    /// remainder below the smallest bucket and abort mid-eval. Each
    /// ladder bucket is a multiple of the smallest, so greedy
    /// descending tiling covers any truncated count exactly.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let buckets: Vec<usize> = {
            let mut b = self.session.entry.eval_buckets.clone();
            b.sort_unstable_by(|a, c| c.cmp(a)); // descending
            b
        };
        let &smallest = buckets.last().context("model has no eval buckets")?;
        let n = self.cfg.eval_examples.min(self.eval_ds.len());
        let n = n - n % smallest;
        anyhow::ensure!(
            n > 0,
            "eval set ({}) smaller than the smallest eval bucket ({smallest})",
            self.eval_ds.len()
        );
        let codes = vec![FP32; self.session.num_layers()];
        let mut pos = 0usize;
        let mut loss_sum = 0.0;
        let mut correct = 0i64;
        while pos < n {
            let remaining = n - pos;
            let &bs = buckets
                .iter()
                .find(|&&bsz| bsz <= remaining)
                .with_context(|| format!("no eval bucket fits remaining {remaining}"))?;
            let batch = self.eval_batch_at(pos, bs)?;
            let r = self.session.eval_batch(&batch, &codes)?;
            loss_sum += r.loss as f64 * bs as f64;
            correct += r.correct;
            pos += bs;
        }
        Ok((loss_sum / n as f64, 100.0 * correct as f64 / n as f64))
    }

    fn eval_batch_at(&self, pos: usize, n: usize) -> Result<Batch> {
        let mut x = vec![0f32; n * IMG_ELEMS];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let out = &mut x[i * IMG_ELEMS..(i + 1) * IMG_ELEMS];
            y[i] = self.eval_ds.example(pos + i, out);
        }
        Ok(Batch::new(x, y))
    }

    /// The full run: `epochs` epochs, returning the Table-1 numbers.
    pub fn run(&mut self) -> Result<RunSummary> {
        for epoch in 0..self.cfg.epochs {
            self.run_epoch(epoch)?;
        }
        Ok(self.summary())
    }

    pub fn summary(&self) -> RunSummary {
        let acc = self.metrics.final_test_acc();
        let wall = self.metrics.time_per_epoch(5, false);
        let modeled = self.metrics.time_per_epoch(5, true);
        let peak = self.metrics.peak_vram_gb();
        RunSummary {
            model_key: self.cfg.model_key.clone(),
            method: self.cfg.method,
            seed: self.cfg.seed,
            test_acc_pct: acc,
            wall_s_per_epoch: wall,
            modeled_s_per_epoch: modeled,
            peak_vram_gb: peak,
            eff_score: efficiency_score(acc, modeled, peak),
        }
    }

    /// Expected steps/epoch at the initial batch size (sizing hint for
    /// schedules and harnesses).
    pub fn steps_per_epoch_hint(&self) -> usize {
        self.steps_per_epoch_hint
    }

    /// Advance the training stream by one batch without training.
    /// Manual re-alignment for *version-1* checkpoints, which stored no
    /// stream position (only valid for fixed-batch runs — an elastic
    /// history changes the consumed-example count per batch). Current
    /// checkpoints restore the stream position automatically.
    pub fn skip_batch(&mut self) -> Result<()> {
        let b = self.controller.batch_size();
        let _ = self.train_iter.next_batch(b)?;
        Ok(())
    }

    /// Save the full optimizer state (params/momentum/BN state, live
    /// curvature probes, control-plane policy state, the data-stream
    /// position, and the step). The v3 header records the effective
    /// method key and the model-graph digest for resume-compatibility
    /// checks.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let mut ckpt = self.session.export(self.global_step)?;
        ckpt.method_key = registry::effective_key(&self.cfg);
        ckpt.ctrl = self.controller.export_state();
        let (epoch, pos) = self.train_iter.stream_state();
        ckpt.ctrl.push(("trainer/stream".into(), vec![epoch as f64, pos as f64]));
        ckpt.save(path)
    }

    /// Restore from a checkpoint saved by [`Self::save_checkpoint`];
    /// resumes the step counter (and thus the LR schedule position)
    /// *and* the controller (precision codes, variance/curvature EMAs,
    /// loss scale, batch-ladder position) — a resumed Tri-Accel run
    /// continues the saved policy instead of resetting to defaults.
    /// Version-1 checkpoints (no controller section) restore tensors
    /// only and keep the fresh controller.
    ///
    /// Exactness caveat: the VRAM simulator's allocator-noise RNG is
    /// *not* checkpointed, so bit-exact continuation holds only with
    /// `mem_noise = 0`. Under nonzero noise the resumed memory
    /// telemetry (a simulated transient by design) re-randomizes and
    /// batch decisions may diverge within the noise band.
    pub fn resume_from(&mut self, path: &std::path::Path) -> Result<u64> {
        let ckpt = crate::checkpoint::Checkpoint::load(path)?;
        // v3 headers carry the method the run trained with: policy
        // state is not transferable across methods, so a mismatch is
        // an error here, not a silently reset controller downstream.
        if !ckpt.method_key.is_empty() {
            let ours = registry::effective_key(&self.cfg);
            anyhow::ensure!(
                ckpt.method_key == ours,
                "checkpoint was trained with method `{}`, this run uses `{ours}` — \
                 resume with --method {} or start fresh",
                ckpt.method_key,
                ckpt.method_key
            );
        }
        let step = self.session.restore(&ckpt)?;
        if !ckpt.ctrl.is_empty() {
            self.controller
                .import_state(&ckpt.ctrl)
                .context("restoring controller state")?;
        }
        if let Some((_, v)) = ckpt.ctrl.iter().find(|(k, _)| k == "trainer/stream") {
            anyhow::ensure!(v.len() == 2, "trainer/stream arity");
            self.train_iter
                .seek(v[0] as u64, v[1] as usize)
                .context("restoring data-stream position")?;
        }
        self.global_step = step;
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticCifar;

    fn quick_cfg() -> Config {
        let mut cfg = Config::cell("tiny_cnn_c10", Method::Fp32, 0);
        cfg.epochs = 1;
        cfg.steps_per_epoch = Some(2);
        cfg.train_examples = 256;
        cfg.eval_examples = 256;
        cfg.batch_init = 16;
        cfg.t_curv = 0;
        cfg.warmup_epochs = 0;
        cfg.mem_budget_gb = 0.5;
        cfg.mem_noise = 0.0;
        cfg
    }

    #[test]
    fn evaluate_truncates_to_bucket_alignment() {
        // Regression (satellite #1): an eval set smaller than
        // `eval_examples` and not bucket-aligned used to strand a
        // remainder below the smallest bucket and abort with "no eval
        // bucket fits remaining". It must now truncate and succeed.
        let engine = Engine::native();
        let mut tr = Trainer::new(&engine, quick_cfg()).unwrap();
        // 40 examples with buckets {16, 128}: 40 -> 32 evaluated.
        tr.eval_ds = Box::new(SyntheticCifar::new(10, 40, false, 0));
        let (loss, acc) = tr.evaluate().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=100.0).contains(&acc));
    }

    #[test]
    fn evaluate_rejects_sub_bucket_dataset() {
        let engine = Engine::native();
        let mut tr = Trainer::new(&engine, quick_cfg()).unwrap();
        tr.eval_ds = Box::new(SyntheticCifar::new(10, 7, false, 0));
        let err = tr.evaluate().unwrap_err().to_string();
        assert!(err.contains("smaller than the smallest eval bucket"), "{err}");
    }

    #[test]
    fn eval_examples_must_align_to_smallest_bucket() {
        let engine = Engine::native();
        let mut cfg = quick_cfg();
        cfg.eval_examples = 250; // not a multiple of 16
        assert!(Trainer::new(&engine, cfg).is_err());
    }
}
