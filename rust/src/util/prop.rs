//! Property-based testing substrate (offline build — no proptest crate).
//!
//! A minimal QuickCheck-style runner over the in-tree [`Rng`]: N random
//! cases per property, deterministic per seed, with the failing case's
//! seed printed so a failure is reproducible with `PROP_SEED=<n>`.
//! No shrinking — generators are kept small-biased instead.

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200)
}

/// Run `f` over `cases` seeded inputs; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, mut f: F) {
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEFA17);
    let cases = if std::env::var("PROP_SEED").is_ok() { 1 } else { default_cases() };
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::stream(seed, 0x1E57);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at PROP_SEED={seed}: {msg}");
        }
    }
}

/// Small-biased usize in [lo, hi]: half the draws come from the bottom
/// decade, so boundary behaviour is exercised heavily.
pub fn small_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    let span = hi - lo + 1;
    if span == 1 {
        return lo;
    }
    if rng.bernoulli(0.5) {
        lo + rng.below(span.min(10) as u64) as usize
    } else {
        lo + rng.below(span as u64) as usize
    }
}

/// Log-uniform positive f64 in [10^lo_exp, 10^hi_exp] — matches the
/// scale-free quantities (gradient variances, curvatures) the
/// controllers consume.
pub fn log_uniform(rng: &mut Rng, lo_exp: f64, hi_exp: f64) -> f64 {
    let e = lo_exp + (hi_exp - lo_exp) * rng.next_f64();
    10f64.powf(e)
}

/// Uniform f64 in [lo, hi].
pub fn uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.next_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "PROP_SEED=")]
    fn check_reports_seed_on_failure() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn small_usize_in_bounds_and_hits_extremes() {
        let mut rng = Rng::new(1);
        let mut lo_hit = false;
        for _ in 0..2000 {
            let v = small_usize(&mut rng, 3, 40);
            assert!((3..=40).contains(&v));
            lo_hit |= v == 3;
        }
        assert!(lo_hit, "small bias should hit the lower bound");
        assert_eq!(small_usize(&mut rng, 7, 7), 7);
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut rng = Rng::new(2);
        let (mut lo, mut hi) = (f64::MAX, f64::MIN);
        for _ in 0..2000 {
            let v = log_uniform(&mut rng, -8.0, 2.0);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo < 1e-6 && hi > 1.0, "lo={lo} hi={hi}");
    }
}
