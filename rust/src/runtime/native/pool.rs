//! Deterministic worker pool for the native compute core.
//!
//! Design contract: **results never depend on the thread count.** Work
//! is split into *fixed* chunks whose boundaries depend only on the
//! problem size, each chunk owns a disjoint `&mut` slice of the output,
//! and any cross-chunk reduction is performed by the caller in chunk
//! order. The pool only decides *which thread* runs a chunk, never
//! *what* a chunk computes, so training output is bit-identical for
//! every `TRIACCEL_THREADS` value — the property the checkpoint-resume
//! and cross-thread determinism tests pin down.
//!
//! Implementation: `std::thread::scope` (no external deps, no unsafe).
//! Workers drain a mutex-guarded chunk iterator; the lock is held only
//! to pop the next chunk, never during compute. With one thread (or one
//! chunk, or `parallel == false`) everything runs inline on the caller
//! with zero spawn overhead, so the single-thread fast path is exactly
//! the serial kernel.

use std::sync::Mutex;

/// Hard cap on the auto-detected thread count (explicit
/// `TRIACCEL_THREADS` may exceed it).
const AUTO_MAX_THREADS: usize = 8;

/// Parse a `TRIACCEL_THREADS`-style value; `None`/invalid/0 fall back
/// to the capped machine parallelism.
pub fn resolve_threads(var: Option<&str>) -> usize {
    match var.and_then(|s| s.trim().parse::<usize>().ok()) {
        Some(t) if t >= 1 => t,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(AUTO_MAX_THREADS),
    }
}

/// Split a total worker budget across every concurrent compute lane:
/// `jobs` scheduler jobs × `replicas` data-parallel engines per job.
/// Each lane gets `floor(total / (jobs × replicas))`, at least 1, so
/// `jobs × replicas × threads ≤ total` whenever the budget covers the
/// lane count at all (the ≥ 1 floor keeps starved lanes making
/// progress rather than deadlocking the grid — see the
/// `thread_budget_never_oversubscribes` test for the exact guarantee).
/// The determinism contract makes the per-lane count a pure
/// performance knob.
pub fn budget_threads(total: usize, jobs: usize, replicas: usize) -> usize {
    let lanes = jobs.max(1) * replicas.max(1);
    (total / lanes).max(1)
}

/// Budget for non-replicated jobs: [`budget_threads`] with one replica
/// per job (kept as the name the scheduler historically used).
pub fn per_job_threads(total: usize, jobs: usize) -> usize {
    budget_threads(total, jobs, 1)
}

/// A fixed-width worker pool over scoped threads.
///
/// A `Pool` is a cheap, clonable *handle* (just the configured width —
/// workers are scoped per call, state-free). The scheduler exploits
/// this by building one `Pool` per job-pool worker and reusing the
/// handle — and the arena-carrying `Exec` around it — across every
/// job that worker runs ([`crate::runtime::Engine::native_with_pool`]),
/// so back-to-back jobs share warm scratch buffers instead of
/// re-growing an arena from empty.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool with an explicit thread count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Thread count from `TRIACCEL_THREADS`, else machine parallelism
    /// capped at 8.
    pub fn from_env() -> Pool {
        Pool::new(resolve_threads(std::env::var("TRIACCEL_THREADS").ok().as_deref()))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `data` into fixed `chunk_len`-element chunks and run
    /// `f(chunk_idx, chunk)` exactly once per chunk with exclusive
    /// access. Chunk boundaries depend only on `chunk_len`, so output
    /// written through `data` is identical for every thread count.
    /// `parallel == false` (or 1 thread, or ≤ 1 chunk) runs inline.
    pub fn for_each_chunk<T, F>(&self, data: &mut [T], chunk_len: usize, parallel: bool, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        if data.is_empty() {
            return;
        }
        let n_chunks = data.len().div_ceil(chunk_len);
        if !parallel || self.threads == 1 || n_chunks <= 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        let work = Mutex::new(data.chunks_mut(chunk_len).enumerate());
        let worker = || loop {
            // Pop under the lock, release, then compute outside it.
            let next = work.lock().unwrap().next();
            match next {
                Some((i, c)) => f(i, c),
                None => return,
            }
        };
        let spawned = self.threads.min(n_chunks) - 1;
        std::thread::scope(|s| {
            for _ in 0..spawned {
                s.spawn(&worker);
            }
            worker();
        });
    }
}

impl Default for Pool {
    fn default() -> Pool {
        Pool::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_job_threads_never_oversubscribes() {
        assert_eq!(per_job_threads(8, 4), 2);
        assert_eq!(per_job_threads(8, 1), 8);
        assert_eq!(per_job_threads(4, 8), 1, "floor at one thread per job");
        assert_eq!(per_job_threads(0, 3), 1);
        assert_eq!(per_job_threads(7, 0), 7, "jobs clamped to >= 1");
        for total in 1..=16usize {
            for jobs in 1..=16usize {
                assert!(per_job_threads(total, jobs) * jobs <= total.max(jobs));
            }
        }
    }

    #[test]
    fn thread_budget_never_oversubscribes() {
        assert_eq!(budget_threads(8, 2, 2), 2);
        assert_eq!(budget_threads(8, 2, 4), 1);
        assert_eq!(budget_threads(16, 2, 4), 2);
        assert_eq!(budget_threads(8, 1, 1), 8);
        assert_eq!(budget_threads(0, 2, 2), 1, "empty budget floors at one");
        assert_eq!(budget_threads(8, 0, 0), 8, "lanes clamped to >= 1");
        for total in 1..=16usize {
            for jobs in 1..=4usize {
                for replicas in 1..=4usize {
                    let per = budget_threads(total, jobs, replicas);
                    let lanes = jobs * replicas;
                    // Whenever the budget covers the lane count, the
                    // grid never oversubscribes; below that, every lane
                    // still gets its floor of exactly one thread.
                    if total >= lanes {
                        assert!(
                            per * lanes <= total,
                            "oversubscribed: {per} threads x {lanes} lanes > {total}"
                        );
                    } else {
                        assert_eq!(per, 1, "starved lanes floor at one thread");
                    }
                    assert_eq!(per_job_threads(total, jobs * replicas), per, "delegation");
                }
            }
        }
    }

    #[test]
    fn resolve_threads_parses_and_falls_back() {
        assert_eq!(resolve_threads(Some("3")), 3);
        assert_eq!(resolve_threads(Some(" 12 ")), 12, "explicit values exceed the auto cap");
        let auto = resolve_threads(None);
        assert!(auto >= 1 && auto <= AUTO_MAX_THREADS);
        assert_eq!(resolve_threads(Some("0")), auto, "0 means auto");
        assert_eq!(resolve_threads(Some("bogus")), auto);
    }

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = Pool::new(threads);
            let mut data = vec![0u32; 103]; // deliberately not a chunk multiple
            pool.for_each_chunk(&mut data, 10, true, |i, chunk| {
                for v in chunk.iter_mut() {
                    *v += 1 + i as u32;
                }
            });
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, 1 + (k / 10) as u32, "element {k} at threads={threads}");
            }
        }
    }

    #[test]
    fn chunk_boundaries_ignore_thread_count() {
        let run = |threads: usize| {
            let pool = Pool::new(threads);
            let mut data = vec![0f32; 1000];
            pool.for_each_chunk(&mut data, 64, true, |i, chunk| {
                // Value depends on (chunk idx, position) only.
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 1000 + j) as f32 * 0.5;
                }
            });
            data
        };
        let base = run(1);
        for t in [2usize, 3, 4, 8] {
            assert_eq!(run(t), base, "threads={t}");
        }
    }

    #[test]
    fn serial_flag_runs_inline() {
        let pool = Pool::new(4);
        let main_id = std::thread::current().id();
        let mut data = vec![0u8; 32];
        pool.for_each_chunk(&mut data, 8, false, |_, _| {
            assert_eq!(std::thread::current().id(), main_id);
        });
    }
}
