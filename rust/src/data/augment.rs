//! Training augmentations (paper §4.1: "augmented using random
//! horizontal flips and random crops"). Standard CIFAR recipe: flip with
//! p=0.5, then pad-4 reflect-free zero pad + random 32×32 crop.

use super::{IMG_C, IMG_H, IMG_W};
use crate::util::rng::Rng;

const PAD: usize = 4;

/// In-place flip + crop on one normalized NHWC image.
pub fn flip_crop(img: &mut [f32], rng: &mut Rng) {
    debug_assert_eq!(img.len(), IMG_H * IMG_W * IMG_C);
    if rng.bernoulli(0.5) {
        hflip(img);
    }
    // Offsets into the virtual (32+2·4)² padded canvas.
    let dy = rng.below((2 * PAD + 1) as u64) as isize - PAD as isize;
    let dx = rng.below((2 * PAD + 1) as u64) as isize - PAD as isize;
    if dy != 0 || dx != 0 {
        shift_zero_pad(img, dy, dx);
    }
}

/// Horizontal mirror.
pub fn hflip(img: &mut [f32]) {
    for y in 0..IMG_H {
        for x in 0..IMG_W / 2 {
            let xr = IMG_W - 1 - x;
            for c in 0..IMG_C {
                let a = (y * IMG_W + x) * IMG_C + c;
                let b = (y * IMG_W + xr) * IMG_C + c;
                img.swap(a, b);
            }
        }
    }
}

/// Translate by (dy, dx), filling exposed pixels with 0 (the padded
/// canvas is zero = per-channel mean after normalization).
pub fn shift_zero_pad(img: &mut [f32], dy: isize, dx: isize) {
    let src = img.to_vec();
    for y in 0..IMG_H as isize {
        for x in 0..IMG_W as isize {
            let (sy, sx) = (y + dy, x + dx);
            let dst = ((y as usize * IMG_W) + x as usize) * IMG_C;
            if (0..IMG_H as isize).contains(&sy) && (0..IMG_W as isize).contains(&sx) {
                let s = ((sy as usize * IMG_W) + sx as usize) * IMG_C;
                img[dst..dst + IMG_C].copy_from_slice(&src[s..s + IMG_C]);
            } else {
                img[dst..dst + IMG_C].fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::IMG_ELEMS;

    fn ramp() -> Vec<f32> {
        (0..IMG_ELEMS).map(|i| i as f32).collect()
    }

    #[test]
    fn hflip_is_involution() {
        let orig = ramp();
        let mut img = orig.clone();
        hflip(&mut img);
        assert_ne!(img, orig);
        hflip(&mut img);
        assert_eq!(img, orig);
    }

    #[test]
    fn hflip_mirrors_rows() {
        let mut img = ramp();
        hflip(&mut img);
        // Pixel (0,0) must now hold the old (0,31).
        for c in 0..IMG_C {
            assert_eq!(img[c], ((IMG_W - 1) * IMG_C + c) as f32);
        }
    }

    #[test]
    fn shift_moves_and_zero_fills() {
        let mut img = ramp();
        shift_zero_pad(&mut img, 1, 0); // read from row y+1
        // Bottom row (y=31) reads from y=32 → zero-filled.
        let last = (IMG_H - 1) * IMG_W * IMG_C;
        assert!(img[last..last + IMG_W * IMG_C].iter().all(|&v| v == 0.0));
        // Top row reads old row 1.
        assert_eq!(img[0], (IMG_W * IMG_C) as f32);
    }

    #[test]
    fn zero_shift_is_identity() {
        let orig = ramp();
        let mut img = orig.clone();
        shift_zero_pad(&mut img, 0, 0);
        assert_eq!(img, orig);
    }

    #[test]
    fn crop_offsets_bounded_by_pad() {
        // Over many draws, no shift may exceed ±PAD and both extremes
        // should be hit.
        let mut rng = Rng::new(11);
        let mut seen_neg = false;
        let mut seen_pos = false;
        for _ in 0..500 {
            let d = rng.below((2 * PAD + 1) as u64) as isize - PAD as isize;
            assert!(d.abs() <= PAD as isize);
            seen_neg |= d == -(PAD as isize);
            seen_pos |= d == PAD as isize;
        }
        assert!(seen_neg && seen_pos);
    }

    #[test]
    fn flip_crop_deterministic_per_rng_stream() {
        let mut a = ramp();
        let mut b = ramp();
        flip_crop(&mut a, &mut Rng::stream(5, 9));
        flip_crop(&mut b, &mut Rng::stream(5, 9));
        assert_eq!(a, b);
    }
}
