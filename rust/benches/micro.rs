//! Micro benchmarks (DESIGN.md P1): hot-path component latencies —
//! the native compute kernels (gemm / im2col / fused-qdq / conv3x3),
//! train-step per batch bucket and precision mix, eval, curvature
//! probe, pure controller overhead, memsim accounting, and the data
//! pipeline. The controller/memsim rows quantify the paper's
//! "negligible overhead" claim: control-loop work must be orders of
//! magnitude below a step.
//!
//! Output: the pretty table on stdout plus `BENCH_native.json` (via
//! `util::bench::BenchReport`), the machine-readable perf record
//! compared across PRs (`python/tools/bench_compare.py` diffs it
//! against the committed baseline). The GEMM case emits one row per
//! dispatch tier (`gemm(...)[scalar]` vs `[avx2]`/`[neon]`) plus
//! `speedup/<tier>` metadata, so the scalar-vs-SIMD ratio is tracked
//! in-repo. `-- --quick` runs every case once — the CI smoke mode
//! that keeps the kernels compiling and running; `-- --no-autotune`
//! skips the tuning pass and pins the default blocking.

use tri_accel::config::{Config, Method};
use tri_accel::coordinator::Controller;
use tri_accel::data::{synthetic::SyntheticCifar, BatchIter};
use tri_accel::manifest::{BF16, FP16, FP32};
use tri_accel::memsim::VramSim;
use tri_accel::policy::registry;
use tri_accel::runtime::native::{arena::Arena, autotune, gemm, ops, pool::Pool, simd};
use tri_accel::runtime::{Engine, Session, StepCtrl};
use tri_accel::train::Trainer;
use tri_accel::util::bench::{black_box, BenchReport, Bencher};
use tri_accel::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    if std::env::args().any(|a| a == "--no-autotune") {
        autotune::set_enabled(false);
    }
    let engine = Engine::native();
    let key = "tiny_cnn_c10";
    let entry = engine.manifest.model(key).unwrap().clone();
    let n_layers = entry.num_layers;
    let pool = Pool::from_env();

    let mut report = BenchReport::new("micro");
    report.meta_str("model", key);
    report.meta_str("mode", if quick { "quick" } else { "full" });
    report.meta_num("threads", pool.threads() as f64);
    report.meta_str("dispatch", simd::active().name());
    let tier_names: Vec<&str> = simd::available_tiers().iter().map(|t| t.name()).collect();
    report.meta_str("tiers", &tier_names.join(","));
    report.meta_str("autotune", if autotune::enabled() { "on" } else { "off" });

    println!(
        "== micro: L3 hot path ({key}, {} thread(s){}) ==",
        pool.threads(),
        if quick { ", quick" } else { "" }
    );
    let heavy = if quick { Bencher::smoke() } else { Bencher::heavy() };
    let quick_b = if quick { Bencher::smoke() } else { Bencher::default() };

    // -- compute kernels ----------------------------------------------------
    // conv2-shaped GEMM: M = 32·16·16 pixel rows, K = 9·16, N = 32.
    {
        let (m, k, n) = (8192usize, 144usize, 32usize);
        let mut rng = Rng::new(0xBE);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut c = vec![0f32; m * n];
        let mut arena = Arena::new();
        // Scalar-vs-SIMD rows: one per available tier, pinned through
        // gemm_with so the comparison isolates the micro-kernel. Full
        // mode autotunes the blocking first (and persists the cache);
        // quick/--no-autotune runs use whatever the cache already says.
        let mut scalar_mean = 0f64;
        for tier in simd::available_tiers() {
            if !quick && autotune::enabled() {
                let (cfg, err) = autotune::tune_and_save(&pool, &mut arena, tier, m, k, n, 3);
                if let Some(e) = err {
                    eprintln!("warning: could not save the tuning cache: {e}");
                }
                println!("tuned [{tier}] -> row_chunk {} nr {}", cfg.row_chunk, cfg.nr);
            }
            let cfg = autotune::lookup(tier, pool.threads(), m, k, n);
            let r = quick_b.run(&format!("gemm({m}x{k}x{n})[{tier}]"), || {
                gemm::gemm_with(tier, cfg, &pool, &mut arena, &a, &b, &mut c, m, k, n, false);
                black_box(c[0]);
            });
            let mean = r.mean.as_secs_f64();
            if tier == simd::Tier::Scalar {
                scalar_mean = mean;
            } else if scalar_mean > 0.0 && mean > 0.0 {
                let sp = scalar_mean / mean;
                report.meta_num(&format!("speedup/{tier}"), sp);
                println!("speedup [{tier}] vs scalar: {sp:.2}x");
            }
            report.push(&r);
        }
        // The dispatch row: active tier + tuned blocking, what the
        // trainer actually runs.
        report.push(&quick_b.run(&format!("gemm({m}x{k}x{n})"), || {
            gemm::gemm(&pool, &mut arena, &a, &b, &mut c, m, k, n, false);
            black_box(c[0]);
        }));
        let g: Vec<f32> = (0..m * n).map(|_| rng.next_normal()).collect();
        let mut dw = vec![0f32; k * n];
        report.push(&quick_b.run(&format!("gemm_at_b({m}x{k}x{n})"), || {
            gemm::gemm_at_b(&pool, &mut arena, &a, &g, &mut dw, m, k, n);
            black_box(dw[0]);
        }));
    }
    {
        // conv1-shaped im2col at B=32, plain and with fused fp16 qdq.
        let (n, h, w, cin) = (32usize, 32usize, 32usize, 3usize);
        let mut rng = Rng::new(0xC0);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.next_normal()).collect();
        let mut cols = vec![0f32; n * h * w * 9 * cin];
        report.push(&quick_b.run("im2col3x3(B=32, fp32)", || {
            gemm::im2col3x3_qdq(&pool, &x, n, h, w, cin, FP32, &mut cols);
            black_box(cols[0]);
        }));
        report.push(&quick_b.run("im2col3x3(B=32, fused fp16 qdq)", || {
            gemm::im2col3x3_qdq(&pool, &x, n, h, w, cin, FP16, &mut cols);
            black_box(cols[0]);
        }));
    }
    {
        // The acceptance rows: conv3x3 forward and backward, conv1 shape.
        let (n, h, w, cin, cout) = (16usize, 32usize, 32usize, 3usize, 16usize);
        let mut rng = Rng::new(0xC1);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.next_normal()).collect();
        let wt: Vec<f32> = (0..9 * cin * cout).map(|_| rng.next_normal()).collect();
        let g: Vec<f32> = (0..n * h * w * cout).map(|_| rng.next_normal()).collect();
        report.push(&quick_b.run("conv3x3_fwd(B=16, 32x32x3->16)", || {
            black_box(ops::conv3x3_fwd(&x, n, h, w, cin, &wt, cout));
        }));
        report.push(&quick_b.run("conv3x3_bwd(B=16, 32x32x3->16)", || {
            black_box(ops::conv3x3_bwd(&x, n, h, w, cin, &wt, cout, &g));
        }));
        report.push(&quick_b.run("conv3x3_fwd+bwd(B=16, 32x32x3->16)", || {
            black_box(ops::conv3x3_fwd(&x, n, h, w, cin, &wt, cout));
            black_box(ops::conv3x3_bwd(&x, n, h, w, cin, &wt, cout, &g));
        }));
    }
    {
        // Graph-grid kernels (resnet_mini / effnet_lite shapes): the
        // stride-2 downsampling conv, the 1×1 shortcut/pointwise conv,
        // and the depthwise conv — kernel regressions fail fast here.
        let (n, h, w, cin, cout) = (16usize, 32usize, 32usize, 8usize, 16usize);
        let (ho, wo) = (h / 2, w / 2);
        let mut rng = Rng::new(0xC2);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.next_normal()).collect();
        let wt3: Vec<f32> = (0..9 * cin * cout).map(|_| rng.next_normal()).collect();
        let gs2: Vec<f32> = (0..n * ho * wo * cout).map(|_| rng.next_normal()).collect();
        report.push(&quick_b.run("conv3x3s2_fwd+bwd(B=16, 32x32x8->16x16x16)", || {
            black_box(ops::conv_fwd(&x, n, h, w, cin, &wt3, cout, 3, 2));
            black_box(ops::conv_bwd(&x, n, h, w, cin, &wt3, cout, 3, 2, &gs2));
        }));
        let wt1: Vec<f32> = (0..cin * cout).map(|_| rng.next_normal()).collect();
        let g1: Vec<f32> = (0..n * h * w * cout).map(|_| rng.next_normal()).collect();
        report.push(&quick_b.run("conv1x1_fwd+bwd(B=16, 32x32x8->16)", || {
            black_box(ops::conv_fwd(&x, n, h, w, cin, &wt1, cout, 1, 1));
            black_box(ops::conv_bwd(&x, n, h, w, cin, &wt1, cout, 1, 1, &g1));
        }));
        let wtd: Vec<f32> = (0..9 * cin).map(|_| rng.next_normal()).collect();
        let gd: Vec<f32> = (0..n * h * w * cin).map(|_| rng.next_normal()).collect();
        report.push(&quick_b.run("dwconv3x3_fwd+bwd(B=16, 32x32x8)", || {
            black_box(ops::dwconv_fwd(&x, n, h, w, cin, 3, 1, &wtd));
            black_box(ops::dwconv_bwd(&x, n, h, w, cin, 3, 1, &wtd, &gd));
        }));
    }

    // -- data pipeline ----------------------------------------------------
    let ds = SyntheticCifar::new(10, 4096, true, 0);
    let mut it = BatchIter::new(Box::new(ds), 0, true);
    report.push(&quick_b.run("data/next_batch(B=32, augmented)", || {
        black_box(it.next_batch(32).unwrap());
    }));

    // -- train step per bucket ---------------------------------------------
    let mut session = Session::init(&engine, key, 0).unwrap();
    for &b in &[16usize, 32, 64, 96] {
        if !entry.train_buckets.contains(&b) {
            continue;
        }
        let batch = it.next_batch(b).unwrap();
        let ctrl = StepCtrl::uniform(n_layers, BF16, 0.05, 5e-4);
        report.push(&heavy.run(&format!("train_step(B={b}, bf16)"), || {
            black_box(session.train_step(&batch, &ctrl).unwrap());
        }));
    }

    // -- data-parallel replica scaling --------------------------------------
    // One train-step row per replica count on the replicated backend,
    // each engine budgeted so replicas × threads stays within the pool
    // width. The rows share a batch and precision mix, so the
    // `speedup/replicasN` metadata is the pure shard-parallel return —
    // and because replication is numerics-neutral, any loss drift
    // across these rows is a bug, not noise.
    {
        use tri_accel::runtime::native::pool::budget_threads;
        let batch = it.next_batch(32).unwrap();
        let ctrl = StepCtrl::uniform(n_layers, BF16, 0.05, 5e-4);
        let mut single_mean = 0f64;
        for replicas in [1usize, 2, 4] {
            let threads_each = budget_threads(pool.threads(), 1, replicas);
            let eng = Engine::native_replicated(replicas, threads_each);
            let mut s = Session::init(&eng, key, 0).unwrap();
            let r = heavy.run(&format!("train_step(B=32, bf16, replicas={replicas})"), || {
                black_box(s.train_step(&batch, &ctrl).unwrap());
            });
            let mean = r.mean.as_secs_f64();
            if replicas == 1 {
                single_mean = mean;
            } else if single_mean > 0.0 && mean > 0.0 {
                let sp = single_mean / mean;
                report.meta_num(&format!("speedup/replicas{replicas}"), sp);
                println!("speedup [replicas={replicas}] vs 1: {sp:.2}x");
            }
            report.push(&r);
        }
    }

    // -- graph-grid architectures: one train-step row each ------------------
    for key in ["resnet_mini_c10", "effnet_lite_c10"] {
        let e = engine.manifest.model(key).unwrap().clone();
        let mut s = Session::init(&engine, key, 0).unwrap();
        let batch = it.next_batch(32).unwrap();
        let ctrl = StepCtrl::uniform(e.num_layers, BF16, 0.05, 5e-4);
        report.push(&heavy.run(&format!("train_step({key}, B=32, bf16)"), || {
            black_box(s.train_step(&batch, &ctrl).unwrap());
        }));
    }

    // -- precision mix sensitivity at fixed B -------------------------------
    let batch = it.next_batch(32).unwrap();
    for (name, code) in [("fp16", FP16), ("bf16", BF16), ("fp32", FP32)] {
        let ctrl = StepCtrl::uniform(n_layers, code, 0.05, 5e-4);
        report.push(&heavy.run(&format!("train_step(B=32, uniform {name})"), || {
            black_box(session.train_step(&batch, &ctrl).unwrap());
        }));
    }

    // -- eval + curvature ---------------------------------------------------
    let eval_b = it.next_batch(16).unwrap();
    let codes = vec![FP32; n_layers];
    report.push(&heavy.run("eval_batch(B=16)", || {
        black_box(session.eval_batch(&eval_b, &codes).unwrap());
    }));
    let curv_b = it.next_batch(entry.curv_batch).unwrap();
    report.push(&heavy.run(&format!("curv_step(B={})", entry.curv_batch), || {
        black_box(session.curv_step(&curv_b, &codes, 7).unwrap());
    }));

    // -- controller-only overhead (the paper's "negligible" claim) ----------
    let mut cfg = Config::cell(key, Method::TriAccel, 0);
    cfg.t_ctrl = 1;
    let mut ctl = Controller::new(&cfg, &entry);
    let vars: Vec<f32> = (0..n_layers).map(|i| 1e-6 * (i + 1) as f32).collect();
    report.push(&quick_b.run("controller/observe_step", || {
        ctl.observe_step(black_box(&vars), false);
    }));
    let mut step = 0u64;
    report.push(&quick_b.run("controller/control_window", || {
        step += 1;
        black_box(ctl.control_window(step, 0.8, 1.0, |_| true));
    }));

    // -- per-method policy-decision counts (registry sweep) ------------------
    // A short fixed-budget run per registry method; the decision
    // counters land in BENCH_native.json metadata so the cross-PR
    // bench trajectory captures control-plane overhead per method.
    for method_key in ["fp32", "amp_static", "tri_accel", "greedy_batch"] {
        let spec = registry::resolve(method_key).unwrap();
        let mut cfg = Config::cell(key, spec.family, 0);
        registry::apply(&mut cfg, spec);
        cfg.epochs = 1;
        cfg.steps_per_epoch = Some(10);
        cfg.train_examples = 256;
        cfg.eval_examples = 128;
        cfg.batch_init = 16;
        cfg.t_ctrl = 2;
        cfg.t_curv = 5;
        cfg.curv_warmup = 1;
        cfg.batch_cooldown = 2;
        cfg.warmup_epochs = 0;
        cfg.mem_budget_gb = 0.06;
        cfg.mem_noise = 0.0;
        let mut tr = Trainer::new(&engine, cfg).unwrap();
        tr.run_epoch(0).unwrap();
        let c = tr.controller.counts();
        report.meta_num(&format!("policy/{method_key}/windows"), c.windows as f64);
        report.meta_num(
            &format!("policy/{method_key}/precision_transitions"),
            c.precision_transitions as f64,
        );
        report.meta_num(
            &format!("policy/{method_key}/batch_decisions"),
            c.batch_decisions as f64,
        );
        report.meta_num(
            &format!("policy/{method_key}/curv_firings"),
            c.curv_firings as f64,
        );
        println!(
            "policy decisions [{method_key:<12}] windows {:>3}  precision {:>3}  batch {:>3}  curv {:>3}",
            c.windows, c.precision_transitions, c.batch_decisions, c.curv_firings
        );
    }

    // -- memsim accounting ---------------------------------------------------
    let mut sim = VramSim::new(&entry, 0.45, 0.01, 0);
    let codes2: Vec<i32> = (0..n_layers).map(|i| (i % 3) as i32).collect();
    report.push(&quick_b.run("memsim/usage", || {
        black_box(sim.usage(96, &codes2, false));
    }));
    report.push(&quick_b.run("memsim/would_fit", || {
        black_box(sim.would_fit(128, &codes2, false));
    }));

    let out = std::path::Path::new("BENCH_native.json");
    match report.write(out) {
        Ok(()) => println!("\nwrote {} rows to {}", report.len(), out.display()),
        Err(e) => eprintln!("\nwarning: could not write {}: {e}", out.display()),
    }
    println!("(controller+memsim rows are the per-step control overhead;");
    println!(" compare against the train_step rows — expect ≥1000× headroom.)");
}
