//! Property-based tests on the coordinator state machines (DESIGN.md
//! deliverable c): randomized measurement streams must never violate the
//! §3 invariants, whatever the history.

use tri_accel::config::{Ablation, Config, Method};
use tri_accel::coordinator::batch::{BatchConfig, BatchController, BatchMove};
use tri_accel::coordinator::curvature::{CurvatureConfig, CurvatureScheduler};
use tri_accel::coordinator::precision::{LossScaler, PrecisionConfig, PrecisionController};
use tri_accel::coordinator::Controller;
use tri_accel::manifest::{LayerSpec, ModelEntry, BF16, FP16, FP32};
use tri_accel::util::prop::{check, log_uniform, small_usize, uniform};
use tri_accel::util::rng::Rng;

fn entry(num_layers: usize, buckets: Vec<usize>) -> ModelEntry {
    ModelEntry {
        key: "prop".into(),
        model: "prop".into(),
        num_classes: 10,
        num_layers,
        param_count: 0,
        layers: (0..num_layers)
            .map(|i| LayerSpec {
                name: format!("l{i}"),
                kind: "conv".into(),
                param_elems: 100,
                act_elems: 10,
                flops: 1000,
            })
            .collect(),
        params: vec![],
        nodes: vec![],
        state_shapes: vec![],
        train_buckets: buckets,
        eval_buckets: vec![16],
        curv_batch: 8,
        artifacts: Default::default(),
    }
}

fn random_ladder(rng: &mut Rng) -> Vec<usize> {
    let len = small_usize(rng, 1, 7);
    let mut v: Vec<usize> = (0..len).map(|_| small_usize(rng, 1, 256)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

// ---------------------------------------------------------------- batch

#[test]
fn prop_batch_stays_on_ladder() {
    check("batch size is always an AOT bucket", |rng| {
        let ladder = random_ladder(rng);
        let cfg = BatchConfig {
            rho_low: uniform(rng, 0.2, 0.6),
            rho_high: uniform(rng, 0.65, 0.99),
            cooldown: small_usize(rng, 0, 20) as u64,
        };
        let init = small_usize(rng, 1, 256);
        let mut c = BatchController::new(ladder.clone(), init, cfg);
        for step in 0..200u64 {
            let used = uniform(rng, 0.0, 1.2);
            let fits = rng.bernoulli(0.7);
            c.update(step, used, 1.0, |_| fits);
            if rng.bernoulli(0.05) {
                c.force_shrink(step);
            }
            if !c.buckets().contains(&c.current()) {
                return Err(format!("B={} not in {:?}", c.current(), c.buckets()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batch_never_grows_past_veto() {
    check("vetoed growth leaves B unchanged", |rng| {
        let ladder = random_ladder(rng);
        let cfg = BatchConfig { rho_low: 0.7, rho_high: 0.9, cooldown: 0 };
        let mut c = BatchController::new(ladder, 64, cfg);
        for step in 0..100u64 {
            let before = c.current();
            let m = c.update(step, uniform(rng, 0.0, 0.69), 1.0, |_| false);
            if m == BatchMove::Grow {
                return Err("grew despite universal veto".into());
            }
            if c.current() != before {
                return Err(format!("moved {}→{} without fit", before, c.current()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batch_monotone_under_pressure() {
    check("sustained over-budget usage is non-increasing in B", |rng| {
        let ladder = random_ladder(rng);
        let cfg = BatchConfig { rho_low: 0.3, rho_high: 0.8, cooldown: small_usize(rng, 0, 5) as u64 };
        let mut c = BatchController::new(ladder, 256, cfg);
        let mut prev = c.current();
        for step in 0..50u64 {
            c.update(step, uniform(rng, 0.81, 2.0), 1.0, |_| true);
            if c.current() > prev {
                return Err(format!("grew under pressure {}→{}", prev, c.current()));
            }
            prev = c.current();
        }
        Ok(())
    });
}

// ------------------------------------------------------------ precision

fn pcfg(rng: &mut Rng) -> PrecisionConfig {
    let lo = log_uniform(rng, -8.0, -3.0);
    PrecisionConfig {
        beta: uniform(rng, 0.0, 0.99),
        tau_low: lo,
        tau_high: lo * log_uniform(rng, 0.5, 3.0),
        auto_threshold: false,
        default_code: BF16,
    }
}

#[test]
fn prop_precision_codes_always_valid() {
    check("codes ∈ {FP16, BF16, FP32} under arbitrary streams", |rng| {
        let layers = small_usize(rng, 1, 12);
        let mut pc = PrecisionController::new(layers, pcfg(rng));
        for _ in 0..100 {
            let vars: Vec<f32> = (0..layers)
                .map(|_| {
                    if rng.bernoulli(0.05) {
                        f32::NAN
                    } else {
                        log_uniform(rng, -10.0, 1.0) as f32
                    }
                })
                .collect();
            pc.observe(&vars);
            if rng.bernoulli(0.3) {
                pc.control_window();
            }
            if rng.bernoulli(0.1) {
                pc.promote(small_usize(rng, 0, layers - 1));
            }
            if !pc.codes().iter().all(|c| [FP16, BF16, FP32].contains(c)) {
                return Err(format!("invalid codes {:?}", pc.codes()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_precision_moves_at_most_one_rung_per_window() {
    check("one rung per control window", |rng| {
        let layers = small_usize(rng, 1, 8);
        let mut pc = PrecisionController::new(layers, pcfg(rng));
        let rung = |c: i32| [FP16, BF16, FP32].iter().position(|&x| x == c).unwrap() as i64;
        for _ in 0..60 {
            let vars: Vec<f32> =
                (0..layers).map(|_| log_uniform(rng, -10.0, 1.0) as f32).collect();
            pc.observe(&vars);
            let before: Vec<i64> = pc.codes().iter().map(|&c| rung(c)).collect();
            pc.control_window();
            for (l, (&b, &a)) in before
                .iter()
                .zip(pc.codes().iter().map(|&c| rung(c)).collect::<Vec<_>>().iter())
                .enumerate()
            {
                // Promotions (not exercised here) may jump; pure variance
                // moves must be |Δ| ≤ 1.
                if (a - b).abs() > 1 {
                    return Err(format!("layer {l} jumped {b}→{a}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_promotion_always_yields_fp32() {
    check("promote() pins FP32 immediately", |rng| {
        let layers = small_usize(rng, 1, 8);
        let mut pc = PrecisionController::new(layers, pcfg(rng));
        for _ in 0..30 {
            let vars: Vec<f32> =
                (0..layers).map(|_| log_uniform(rng, -10.0, -2.0) as f32).collect();
            pc.observe(&vars);
            pc.control_window();
            let l = small_usize(rng, 0, layers - 1);
            pc.promote(l);
            if pc.codes()[l] != FP32 {
                return Err(format!("layer {l} is {} after promote", pc.codes()[l]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_loss_scaler_positive_and_bounded() {
    check("loss scale ∈ [1, 65536] and halves on overflow", |rng| {
        let mut ls = LossScaler::new(2f32.powi(small_usize(rng, 0, 16) as i32), small_usize(rng, 1, 50) as u64);
        for _ in 0..300 {
            let before = ls.scale();
            let overflow = rng.bernoulli(0.15);
            ls.update(overflow);
            let s = ls.scale();
            if !(1.0..=65536.0).contains(&s) {
                return Err(format!("scale {s} out of bounds"));
            }
            if overflow && before > 1.0 && s != before * 0.5 {
                return Err(format!("overflow: {before} → {s}, expected halving"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------ curvature

#[test]
fn prop_lr_scales_in_unit_interval() {
    check("η_l/η₀ ∈ (0, 1] for any λ stream", |rng| {
        let layers = small_usize(rng, 1, 10);
        let cfg = CurvatureConfig {
            t_curv: 10,
            alpha: uniform(rng, 0.01, 5.0) as f32,
            tau_curv: log_uniform(rng, -1.0, 3.0),
            warmup: small_usize(rng, 0, 3) as u64,
            beta: uniform(rng, 0.0, 0.9),
        };
        let mut cs = CurvatureScheduler::new(layers, cfg);
        for _ in 0..30 {
            let lams: Vec<f32> = (0..layers)
                .map(|_| {
                    let mag = log_uniform(rng, -3.0, 4.0) as f32;
                    if rng.bernoulli(0.3) {
                        -mag
                    } else if rng.bernoulli(0.05) {
                        f32::INFINITY
                    } else {
                        mag
                    }
                })
                .collect();
            cs.observe(&lams);
            for (l, &s) in cs.lr_scales().iter().enumerate() {
                if !(s > 0.0 && s <= 1.0) {
                    return Err(format!("layer {l}: scale {s}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lr_scale_antitone_in_lambda() {
    check("larger λ never yields larger η", |rng| {
        let cfg = CurvatureConfig {
            t_curv: 10,
            alpha: uniform(rng, 0.01, 5.0) as f32,
            tau_curv: 1e9,
            warmup: 0,
            beta: 0.0,
        };
        let mut cs = CurvatureScheduler::new(2, cfg);
        let a = log_uniform(rng, -3.0, 3.0) as f32;
        let b = log_uniform(rng, -3.0, 3.0) as f32;
        cs.observe(&[a.min(b), a.max(b)]);
        let s = cs.lr_scales();
        if s[0] < s[1] - 1e-6 {
            return Err(format!("λ=({},{}) → η=({},{})", a.min(b), a.max(b), s[0], s[1]));
        }
        Ok(())
    });
}

// ----------------------------------------------------- unified controller

#[test]
fn prop_controller_respects_method_contracts() {
    check("baselines stay pinned; Tri-Accel stays on the ladder", |rng| {
        let layers = small_usize(rng, 1, 6);
        let buckets = vec![16, 32, 64, 96, 128];
        let e = entry(layers, buckets.clone());
        let method = match small_usize(rng, 0, 2) {
            0 => Method::Fp32,
            1 => Method::AmpStatic,
            _ => Method::TriAccel,
        };
        let mut cfg = Config::default();
        cfg.method = method;
        cfg.ablation = Ablation {
            dynamic_precision: rng.bernoulli(0.5),
            dynamic_batch: rng.bernoulli(0.5),
            curvature: rng.bernoulli(0.5),
        };
        cfg.t_ctrl = small_usize(rng, 1, 10) as u64;
        cfg.auto_threshold = rng.bernoulli(0.5);
        cfg.batch_cooldown = small_usize(rng, 0, 5) as u64;
        let mut ctl = Controller::new(&cfg, &e);
        for step in 1..=120u64 {
            let vars: Vec<f32> =
                (0..layers).map(|_| log_uniform(rng, -9.0, 0.0) as f32).collect();
            ctl.observe_step(&vars, rng.bernoulli(0.05));
            if ctl.curvature_due(step) {
                let lams: Vec<f32> =
                    (0..layers).map(|_| log_uniform(rng, -2.0, 3.0) as f32).collect();
                ctl.observe_curvature(&lams);
            }
            if ctl.window_due(step) {
                let fits = rng.bernoulli(0.8);
                ctl.control_window(step, uniform(rng, 0.0, 1.1), 1.0, |_| fits);
            }
            match method {
                Method::Fp32 => {
                    if ctl.codes().iter().any(|&c| c != FP32) {
                        return Err("FP32 baseline drifted".into());
                    }
                    if ctl.batch_size() != 96 {
                        return Err("FP32 baseline batch moved".into());
                    }
                    if ctl.loss_scale() != 1.0 {
                        return Err("FP32 baseline has a loss scale".into());
                    }
                }
                Method::AmpStatic => {
                    if ctl.codes().iter().any(|&c| c != BF16) {
                        return Err("AMP static drifted".into());
                    }
                    if ctl.batch_size() != 96 {
                        return Err("AMP static batch moved".into());
                    }
                }
                Method::TriAccel => {
                    if !buckets.contains(&ctl.batch_size()) {
                        return Err(format!("B={} off ladder", ctl.batch_size()));
                    }
                    if !cfg.ablation.dynamic_batch && ctl.batch_size() != 96 {
                        return Err("batch moved with dynamic_batch=off".into());
                    }
                }
            }
            let scales = ctl.lr_scales();
            if scales.len() != layers || scales.iter().any(|&s| !(s > 0.0 && s <= 1.0)) {
                return Err(format!("bad lr scales {scales:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_controller_loss_scale_only_with_fp16() {
    check("loss scale ≠ 1 implies an FP16 layer exists", |rng| {
        let layers = small_usize(rng, 1, 6);
        let e = entry(layers, vec![32, 96]);
        let mut cfg = Config::default();
        cfg.method = Method::TriAccel;
        cfg.t_ctrl = 5;
        cfg.auto_threshold = false;
        let mut ctl = Controller::new(&cfg, &e);
        for step in 1..=80u64 {
            let vars: Vec<f32> =
                (0..layers).map(|_| log_uniform(rng, -12.0, -1.0) as f32).collect();
            ctl.observe_step(&vars, rng.bernoulli(0.1));
            if ctl.window_due(step) {
                ctl.control_window(step, 0.8, 1.0, |_| true);
            }
            if ctl.loss_scale() != 1.0 && !ctl.codes().contains(&FP16) {
                return Err(format!(
                    "scale {} without FP16 in {:?}",
                    ctl.loss_scale(),
                    ctl.codes()
                ));
            }
        }
        Ok(())
    });
}
