//! Checkpointing substrate: save/restore a training session (params,
//! momentum, BN state, curvature probes, controller state, step) to a
//! single binary file, so long table-regeneration runs survive
//! interruption and runs can be resumed or evaluated offline.
//!
//! Format (little-endian, versioned):
//!
//! ```text
//! magic "TRIACCEL"  u32 version  u32 model_key_len  model_key bytes
//! (v3) u32 method_len  method bytes  u64 graph_digest
//! u64 step  u32 n_tensors  then per tensor:
//!   u32 name_len  name  u32 ndim  u64 dims[ndim]  f32 data[prod(dims)]
//! (v2) u32 n_ctrl  then per entry:
//!   u32 name_len  name  u32 len  f64 data[len]
//! u64 crc  (FNV-1a over everything before it)
//! ```
//!
//! Tensors are stored by *role/index* name (`param/3`, `mom/3`,
//! `state/1`, `probe/3`), validated against the manifest on load —
//! loading a checkpoint into a different model is an error, not a
//! crash. The `ctrl` section (v2+) holds the control-plane policy
//! state (precision codes + variance EMAs, curvature EMAs, loss scale,
//! batch-ladder position) as named f64 vectors — namespaced
//! `policy/<name>/…` since the policy refactor, with the legacy
//! un-namespaced keys still importable — so a resumed run continues
//! with the policy the saved run had, not the defaults.
//!
//! The v3 header additionally pins *compatibility*: `method` is the
//! registry key the run trained with (resuming under a different
//! method is an error — policy state is not transferable), and
//! `graph_digest` fingerprints the manifest entry's geometry and node
//! graph ([`crate::manifest::ModelEntry::digest`]) so a checkpoint
//! written before a model definition changed fails loudly at load
//! instead of as a downstream shape/state mismatch. Version-1 files
//! (no ctrl section) and version-2 files (no compat header) still
//! load, with empty `ctrl` / empty method / zero digest.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

const MAGIC: &[u8; 8] = b"TRIACCEL";
const VERSION: u32 = 3;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<u64>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model_key: String,
    /// Registry method key the run trained with ("" for v1/v2 files —
    /// no method check possible).
    pub method_key: String,
    /// [`crate::manifest::ModelEntry::digest`] of the model the run
    /// trained on (0 for v1/v2 files — no graph check possible).
    pub graph_digest: u64,
    pub step: u64,
    pub tensors: Vec<Tensor>,
    /// Controller state: named f64 vectors (empty for v1 files and for
    /// checkpoints saved without a controller).
    pub ctrl: Vec<(String, Vec<f64>)>,
}

/// FNV-1a over a byte stream (substrate — no crc crates offline).
/// Shared with [`crate::manifest::ModelEntry::digest`].
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        let key = self.model_key.as_bytes();
        buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
        buf.extend_from_slice(key);
        let method = self.method_key.as_bytes();
        buf.extend_from_slice(&(method.len() as u32).to_le_bytes());
        buf.extend_from_slice(method);
        buf.extend_from_slice(&self.graph_digest.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            let name = t.name.as_bytes();
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name);
            buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            let elems: u64 = t.dims.iter().product();
            anyhow::ensure!(
                elems as usize == t.data.len(),
                "tensor {}: dims {:?} vs data {}",
                t.name,
                t.dims,
                t.data.len()
            );
            for &v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        buf.extend_from_slice(&(self.ctrl.len() as u32).to_le_bytes());
        for (name, vals) in &self.ctrl {
            let name = name.as_bytes();
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name);
            buf.extend_from_slice(&(vals.len() as u32).to_le_bytes());
            for &v in vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut bytes)?;
        anyhow::ensure!(bytes.len() > 8 + 4 + 4 + 8 + 4 + 8, "checkpoint truncated");
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(crc_bytes.try_into().unwrap());
        anyhow::ensure!(fnv1a(body) == want, "checkpoint CRC mismatch (corrupt file)");

        let mut r = Reader { b: body, i: 0 };
        anyhow::ensure!(r.take(8)? == MAGIC, "bad magic — not a Tri-Accel checkpoint");
        let version = r.u32()?;
        anyhow::ensure!(
            (1..=VERSION).contains(&version),
            "unsupported checkpoint version {version}"
        );
        let key_len = r.u32()? as usize;
        let model_key = String::from_utf8(r.take(key_len)?.to_vec()).context("model key utf8")?;
        let (method_key, graph_digest) = if version >= 3 {
            let method_len = r.u32()? as usize;
            let method =
                String::from_utf8(r.take(method_len)?.to_vec()).context("method key utf8")?;
            (method, r.u64()?)
        } else {
            (String::new(), 0)
        };
        let step = r.u64()?;
        let n = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec()).context("tensor name")?;
            let ndim = r.u32()? as usize;
            anyhow::ensure!(ndim <= 8, "implausible ndim {ndim}");
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u64()?);
            }
            let elems: u64 = dims.iter().product();
            let raw = r.take(elems as usize * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(Tensor { name, dims, data });
        }
        let mut ctrl = Vec::new();
        if version >= 2 {
            let n_ctrl = r.u32()? as usize;
            for _ in 0..n_ctrl {
                let name_len = r.u32()? as usize;
                let name =
                    String::from_utf8(r.take(name_len)?.to_vec()).context("ctrl name")?;
                let len = r.u32()? as usize;
                let raw = r.take(len * 8)?;
                let vals = raw
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                ctrl.push((name, vals));
            }
        }
        anyhow::ensure!(r.i == body.len(), "trailing bytes in checkpoint");
        Ok(Checkpoint { model_key, method_key, graph_digest, step, tensors, ctrl })
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("checkpoint has no tensor `{name}`"))
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.i + n <= self.b.len(), "checkpoint truncated");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            model_key: "tiny_cnn_c10".into(),
            method_key: "tri_accel".into(),
            graph_digest: 0xDEAD_BEEF_CAFE_F00D,
            step: 1234,
            tensors: vec![
                Tensor { name: "param/0".into(), dims: vec![2, 3], data: vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25] },
                Tensor { name: "mom/0".into(), dims: vec![6], data: vec![0.5; 6] },
                Tensor { name: "state/0".into(), dims: vec![], data: vec![3.25] }, // scalar
            ],
            ctrl: vec![
                ("precision/codes".into(), vec![0.0, 1.0, 2.0]),
                ("scaler/state".into(), vec![1024.0, 17.0, 3.0]),
            ],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("triaccel_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_bitexact() {
        let c = sample();
        let p = tmp("rt");
        c.save(&p).unwrap();
        let d = Checkpoint::load(&p).unwrap();
        assert_eq!(d.model_key, c.model_key);
        assert_eq!(d.method_key, "tri_accel");
        assert_eq!(d.graph_digest, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(d.step, 1234);
        assert_eq!(d.tensors.len(), 3);
        for (a, b) in c.tensors.iter().zip(&d.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.dims, b.dims);
            assert_eq!(a.data, b.data, "f32 payload must be bit-exact");
        }
        assert_eq!(d.ctrl, c.ctrl, "controller state must be bit-exact (f64)");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_files_load_with_empty_ctrl() {
        // Hand-build a version-1 byte stream (no ctrl section).
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        let key = b"m";
        buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
        buf.extend_from_slice(key);
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        let name = b"param/0";
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&1u32.to_le_bytes()); // ndim
        buf.extend_from_slice(&2u64.to_le_bytes()); // dims [2]
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&(-2.0f32).to_le_bytes());
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        let p = tmp("v1");
        std::fs::write(&p, &buf).unwrap();
        let c = Checkpoint::load(&p).unwrap();
        assert_eq!(c.model_key, "m");
        assert_eq!(c.step, 7);
        assert!(c.ctrl.is_empty());
        assert!(c.method_key.is_empty() && c.graph_digest == 0, "v1: no compat header");
        assert_eq!(c.tensors[0].data, vec![1.5, -2.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_files_load_without_compat_header() {
        // Hand-build a version-2 byte stream: ctrl section present, no
        // method/digest header.
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&2u32.to_le_bytes());
        let key = b"m2";
        buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
        buf.extend_from_slice(key);
        buf.extend_from_slice(&11u64.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // no tensors
        buf.extend_from_slice(&1u32.to_le_bytes()); // one ctrl entry
        let name = b"scaler/state";
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&3u32.to_le_bytes());
        for v in [512.0f64, 4.0, 1.0] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        let p = tmp("v2");
        std::fs::write(&p, &buf).unwrap();
        let c = Checkpoint::load(&p).unwrap();
        assert_eq!(c.model_key, "m2");
        assert_eq!(c.step, 11);
        assert!(c.method_key.is_empty() && c.graph_digest == 0);
        assert_eq!(c.ctrl, vec![("scaler/state".to_string(), vec![512.0, 4.0, 1.0])]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unknown_version_rejected() {
        let c = sample();
        let p = tmp("ver");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Patch the version field and re-stamp the CRC.
        bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
        let n = bytes.len();
        let crc = fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_detected() {
        let c = sample();
        let p = tmp("crc");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).unwrap_err().to_string().contains("CRC"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncation_detected() {
        let c = sample();
        let p = tmp("trunc");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 20]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dims_data_mismatch_rejected_on_save() {
        let mut c = sample();
        c.tensors[0].dims = vec![7];
        assert!(c.save(&tmp("mismatch")).is_err());
    }

    #[test]
    fn tensor_lookup() {
        let c = sample();
        assert!(c.tensor("mom/0").is_ok());
        assert!(c.tensor("nope").is_err());
    }
}
