//! Elastic data-parallel replica control under VRAM pressure.
//!
//! The replica count is the one memory lever that never touches
//! training numerics: the native replicated backend computes over
//! *canonical* batch shards with an ordered reduction, so shedding or
//! restoring replicas changes aggregate VRAM (each live replica holds
//! its own params/grads/workspace) while the parameter trajectory
//! stays bit-identical (see `runtime::native::replica`). The batch
//! controller, by contrast, changes B(t) — a different trajectory.
//!
//! The control rule mirrors §3.3's feedback form over the replica
//! ladder (powers of two up to the configured ceiling):
//!
//! ```text
//! R(t+1) = R(t) · 2   if MemUsage(t) < ρ_low · MemMax and the
//!                     restored count is predicted to fit
//!          R(t) / 2   if MemUsage(t) > ρ_high · MemMax
//!          R(t)       otherwise
//! ```
//!
//! The plane orders the two memory levers: replicas shed *before* the
//! batch shrinks (free memory without touching the trajectory first),
//! and an actual OOM force-sheds a replica rung before it drops a
//! batch bucket. Like batch growth, restoring replicas is vetoed by a
//! predictive fit check so the controller never causes the OOM it
//! exists to avoid.

use super::ckpt_lookup_opt;

/// Outcome of one replica decision (telemetry / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaMove {
    /// Dropped one ladder rung (halved the live count).
    Shed,
    /// Climbed one ladder rung (doubled the live count).
    Restore,
    Hold,
    /// Restore was indicated but vetoed by the fit predictor.
    VetoedRestore,
}

/// Thresholds and damping for the replica feedback rule (shared with
/// the §3.3 batch controller: one pressure vocabulary).
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    pub rho_low: f64,
    pub rho_high: f64,
    /// Minimum steps between moves.
    pub cooldown: u64,
}

impl ReplicaConfig {
    pub fn from_cfg(cfg: &crate::config::Config) -> ReplicaConfig {
        ReplicaConfig {
            rho_low: cfg.rho_low,
            rho_high: cfg.rho_high,
            cooldown: cfg.batch_cooldown,
        }
    }
}

/// The replica-count policy: elastic (the feedback rule above) or
/// fixed (every non-replica method — the count never moves). One type
/// covers both so the plane always has a replica axis; the fixed mode
/// is inert and exports no state.
pub struct ReplicaController {
    cfg: ReplicaConfig,
    /// Ascending power-of-two ladder up to the configured ceiling.
    ladder: Vec<usize>,
    /// Index into `ladder`.
    idx: usize,
    elastic: bool,
    last_move_step: u64,
    sheds: u64,
    restores: u64,
    vetoes: u64,
}

impl ReplicaController {
    /// `capacity` is the configured replica ceiling (`--replicas`);
    /// elastic controllers start at full capacity and shed downward
    /// under pressure. A fixed controller pins the count at capacity.
    pub fn new(capacity: usize, elastic: bool, cfg: ReplicaConfig) -> ReplicaController {
        let cap = capacity.max(1);
        let mut ladder = Vec::new();
        let mut v = 1usize;
        while v <= cap {
            ladder.push(v);
            v *= 2;
        }
        // detlint: allow(d6) — the loop above always pushes 1 first
        // (cap >= 1 by the clamp), so the ladder is never empty.
        if *ladder.last().unwrap() != cap {
            ladder.push(cap); // defensive: config validation pins 1|2|4
        }
        let idx = ladder.len() - 1;
        ReplicaController {
            cfg,
            ladder,
            idx,
            elastic,
            last_move_step: 0,
            sheds: 0,
            restores: 0,
            vetoes: 0,
        }
    }

    /// Policy name (checkpoint namespace / telemetry).
    pub fn name(&self) -> &'static str {
        if self.elastic {
            "replica.elastic"
        } else {
            "replica.fixed"
        }
    }

    /// Is the elastic path active (vs a pinned count)?
    pub fn elastic(&self) -> bool {
        self.elastic
    }

    /// Live replica count.
    pub fn current(&self) -> usize {
        self.ladder[self.idx]
    }

    /// The configured ceiling (top of the ladder).
    pub fn capacity(&self) -> usize {
        // detlint: allow(d6) — the constructor guarantees a nonempty
        // ladder (it always pushes at least 1).
        *self.ladder.last().unwrap()
    }

    /// The ascending replica ladder.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// One feedback decision. `mem_used`/`mem_max` in GiB; `fits(n)` is
    /// the predictive OOM veto for running the *current* batch at `n`
    /// live replicas (aggregate accounting, from `VramSim`).
    pub fn update<F: FnMut(usize) -> bool>(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        mut fits: F,
    ) -> ReplicaMove {
        if !self.elastic {
            return ReplicaMove::Hold;
        }
        let frac = mem_used / mem_max;
        // Pressure shed bypasses the cooldown, like the batch
        // controller's shrink: reacting late defeats the purpose.
        if frac > self.cfg.rho_high {
            if self.idx > 0 {
                self.idx -= 1;
                self.last_move_step = step;
                self.sheds += 1;
                return ReplicaMove::Shed;
            }
            return ReplicaMove::Hold; // already down to one replica
        }
        if step.saturating_sub(self.last_move_step) < self.cfg.cooldown {
            return ReplicaMove::Hold;
        }
        if frac < self.cfg.rho_low && self.idx + 1 < self.ladder.len() {
            if fits(self.ladder[self.idx + 1]) {
                self.idx += 1;
                self.last_move_step = step;
                self.restores += 1;
                return ReplicaMove::Restore;
            }
            self.vetoes += 1;
            return ReplicaMove::VetoedRestore;
        }
        ReplicaMove::Hold
    }

    /// Emergency shed on an actual OOM signal: drop one rung
    /// immediately. The plane tries this *before* a batch shrink —
    /// replicas are the lever that costs no trajectory change.
    pub fn force_shed(&mut self, step: u64) -> bool {
        if !self.elastic || self.idx == 0 {
            return false;
        }
        self.idx -= 1;
        self.last_move_step = step;
        self.sheds += 1;
        true
    }

    /// Moves + vetoes (controller-overhead telemetry).
    pub fn decisions(&self) -> u64 {
        self.sheds + self.restores + self.vetoes
    }

    /// Serialize (current count, cooldown anchor, shed/restore/veto
    /// counters). Fixed controllers export nothing — the count is
    /// config, not state.
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        if !self.elastic {
            return Vec::new();
        }
        vec![(
            "policy/replica.elastic/state".into(),
            vec![
                self.current() as f64,
                self.last_move_step as f64,
                self.sheds as f64,
                self.restores as f64,
                self.vetoes as f64,
            ],
        )]
    }

    /// Restore state written by [`Self::export_state`]. Checkpoints
    /// predating the replica axis carry no key — the controller keeps
    /// its fresh (full-capacity) position, matching how those runs
    /// actually trained. Fixed controllers ignore any saved state.
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        if !self.elastic {
            return Ok(());
        }
        let Some(v) = ckpt_lookup_opt(kv, &["policy/replica.elastic/state"]) else {
            return Ok(());
        };
        anyhow::ensure!(v.len() == 5, "replica state arity");
        let count = v[0] as usize;
        let idx = self.ladder.iter().position(|&r| r == count).ok_or_else(|| {
            anyhow::anyhow!(
                "checkpoint replica count {count} is not on this ladder {:?}",
                self.ladder
            )
        })?;
        self.idx = idx;
        self.last_move_step = v[1] as u64;
        self.sheds = v[2] as u64;
        self.restores = v[3] as u64;
        self.vetoes = v[4] as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ReplicaConfig {
        ReplicaConfig { rho_low: 0.7, rho_high: 0.9, cooldown: 5 }
    }

    fn ctl() -> ReplicaController {
        ReplicaController::new(4, true, cfg())
    }

    #[test]
    fn ladder_is_powers_of_two_starting_live_at_capacity() {
        let c = ctl();
        assert_eq!(c.ladder(), &[1, 2, 4]);
        assert_eq!(c.current(), 4, "elastic starts at full capacity");
        assert_eq!(c.capacity(), 4);
        let two = ReplicaController::new(2, true, cfg());
        assert_eq!(two.ladder(), &[1, 2]);
        let one = ReplicaController::new(1, true, cfg());
        assert_eq!(one.ladder(), &[1]);
        assert_eq!(one.current(), 1);
    }

    #[test]
    fn sheds_under_pressure_bypassing_cooldown() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.95, 1.0, |_| true), ReplicaMove::Shed);
        assert_eq!(c.current(), 2);
        // Immediately again — shed ignores the cooldown.
        assert_eq!(c.update(11, 0.95, 1.0, |_| true), ReplicaMove::Shed);
        assert_eq!(c.current(), 1);
        assert_eq!(c.update(12, 0.95, 1.0, |_| true), ReplicaMove::Hold, "floor");
    }

    #[test]
    fn restores_with_headroom_and_a_passing_fit_check() {
        let mut c = ctl();
        c.force_shed(0);
        c.force_shed(0);
        assert_eq!(c.current(), 1);
        assert_eq!(c.update(10, 0.2, 1.0, |_| true), ReplicaMove::Restore);
        assert_eq!(c.current(), 2);
        assert_eq!(c.update(12, 0.2, 1.0, |_| true), ReplicaMove::Hold, "cooling down");
        assert_eq!(c.update(20, 0.2, 1.0, |_| true), ReplicaMove::Restore);
        assert_eq!(c.current(), 4);
        assert_eq!(c.update(30, 0.2, 1.0, |_| true), ReplicaMove::Hold, "ceiling");
    }

    #[test]
    fn veto_blocks_unfit_restore() {
        let mut c = ctl();
        c.force_shed(0);
        let mut asked = Vec::new();
        let m = c.update(10, 0.2, 1.0, |n| {
            asked.push(n);
            false
        });
        assert_eq!(m, ReplicaMove::VetoedRestore);
        assert_eq!(c.current(), 2);
        assert_eq!(asked, vec![4], "predictive check sees the candidate count");
        assert_eq!(c.decisions(), 2, "one shed + one veto");
    }

    #[test]
    fn holds_in_the_band() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.8, 1.0, |_| true), ReplicaMove::Hold);
        assert_eq!(c.current(), 4);
    }

    #[test]
    fn fixed_controller_is_inert() {
        let mut c = ReplicaController::new(2, false, cfg());
        assert_eq!(c.current(), 2, "pinned at the configured count");
        assert_eq!(c.update(10, 0.99, 1.0, |_| true), ReplicaMove::Hold);
        assert!(!c.force_shed(10));
        assert_eq!(c.current(), 2);
        assert_eq!(c.decisions(), 0);
        assert!(c.export_state().is_empty());
        c.import_state(&[("policy/replica.elastic/state".into(), vec![1.0; 5])]).unwrap();
        assert_eq!(c.current(), 2, "saved elastic state ignored when fixed");
    }

    #[test]
    fn state_roundtrips_and_tolerates_absence() {
        let mut c = ctl();
        c.update(10, 0.95, 1.0, |_| true);
        c.update(20, 0.2, 1.0, |_| false);
        let saved = c.export_state();
        assert_eq!(saved[0].0, "policy/replica.elastic/state");
        let mut fresh = ctl();
        fresh.import_state(&saved).unwrap();
        assert_eq!(fresh.current(), c.current());
        assert_eq!(fresh.decisions(), c.decisions());
        // Continued evolution matches.
        assert_eq!(
            fresh.update(26, 0.2, 1.0, |_| true),
            c.update(26, 0.2, 1.0, |_| true)
        );
        // A pre-replica checkpoint has no key: fresh position kept.
        let mut old = ctl();
        old.import_state(&[("policy/batch.elastic/state".into(), vec![0.0; 4])]).unwrap();
        assert_eq!(old.current(), 4);
        // Off-ladder counts fail loudly.
        let mut bad = ctl();
        let err = bad
            .import_state(&[("policy/replica.elastic/state".into(), vec![3.0, 0.0, 0.0, 0.0, 0.0])])
            .unwrap_err();
        assert!(err.to_string().contains("not on this ladder"));
    }
}
