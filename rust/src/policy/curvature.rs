//! §3.2 Sparse Second-Order Signals.
//!
//! The expensive part — one power-iteration step of per-layer
//! block-diagonal HVPs — runs inside the AOT `curv` graph
//! (`Session::curv_step`); this module is the *scheduler and consumer*:
//!
//! * decides when a probe fires (`every T_curv steps`, paper §4.3),
//! * smooths the per-layer Rayleigh quotients λ_l across firings
//!   (power iteration is amortized: one step per firing, warm-started
//!   probe vectors persisted in the session),
//! * turns λ into per-layer step-size scales
//!   `η_l = η₀ / (1 + α·max λ)` (§3.2, "Step size scaling"),
//! * flags layers whose λ exceeds τ_curv for precision promotion
//!   (§3.2, "Precision promotion").
//!
//! Two [`CurvaturePolicy`](super::CurvaturePolicy) impls live here:
//! [`CurvatureScheduler`] (the amortized probe loop above) and
//! [`NoCurvature`] (baselines / the curvature-off ablation — never
//! due, unit LR scales, no promotions).

use crate::util::stats::Ema;

use super::{ckpt_lookup, CurvaturePolicy};

#[derive(Debug, Clone)]
pub struct CurvatureConfig {
    /// Probe cadence in optimizer steps (paper: 200).
    pub t_curv: u64,
    /// Step-size scaling coefficient α.
    pub alpha: f32,
    /// Promotion threshold τ_curv on λ_max.
    pub tau_curv: f64,
    /// Firings before λ is trusted (power iteration convergence).
    pub warmup: u64,
    /// EMA smoothing across firings.
    pub beta: f64,
}

impl CurvatureConfig {
    pub fn from_cfg(cfg: &crate::config::Config) -> CurvatureConfig {
        CurvatureConfig {
            t_curv: cfg.t_curv,
            alpha: cfg.alpha,
            tau_curv: cfg.tau_curv,
            warmup: cfg.curv_warmup,
            beta: 0.5,
        }
    }
}

pub struct CurvatureScheduler {
    cfg: CurvatureConfig,
    /// Smoothed |λ_max| per layer.
    lambdas: Vec<Ema>,
    firings: u64,
    /// Telemetry: probes that produced non-finite λ (reset events).
    rejected: u64,
}

impl CurvatureScheduler {
    pub fn new(num_layers: usize, cfg: CurvatureConfig) -> CurvatureScheduler {
        CurvatureScheduler {
            lambdas: (0..num_layers).map(|_| Ema::new(cfg.beta)).collect(),
            cfg,
            firings: 0,
            rejected: 0,
        }
    }

    /// Should the trainer run a curvature probe at `step`?
    pub fn due(&self, step: u64) -> bool {
        self.cfg.t_curv > 0 && step > 0 && step % self.cfg.t_curv == 0
    }

    /// Ingest one probe's per-layer Rayleigh quotients. Non-finite
    /// entries (diverged probe) are rejected; the caller should reset
    /// that probe vector. Returns the indices of rejected layers.
    pub fn observe(&mut self, lambdas: &[f32]) -> Vec<usize> {
        assert_eq!(lambdas.len(), self.lambdas.len(), "lambda arity");
        self.firings += 1;
        let mut bad = Vec::new();
        for (l, (ema, &lam)) in self.lambdas.iter_mut().zip(lambdas).enumerate() {
            if lam.is_finite() {
                // The loss surface can be locally concave; the step-size
                // rule uses curvature *magnitude*.
                ema.update(lam.abs() as f64);
            } else {
                bad.push(l);
            }
        }
        self.rejected += bad.len() as u64;
        bad
    }

    /// True once enough firings have happened to trust λ (§ warmup).
    pub fn warmed_up(&self) -> bool {
        self.firings >= self.cfg.warmup
    }

    /// Per-layer learning-rate scales `1 / (1 + α·λ_l)`; all-ones until
    /// warmed up (so the early schedule matches the baselines exactly).
    pub fn lr_scales(&self) -> Vec<f32> {
        if !self.warmed_up() {
            return vec![1.0; self.lambdas.len()];
        }
        self.lambdas
            .iter()
            .map(|e| 1.0 / (1.0 + self.cfg.alpha as f64 * e.get()) as f32)
            .collect()
    }

    /// Layers whose smoothed λ exceeds τ_curv → precision promotion.
    pub fn promotions(&self) -> Vec<usize> {
        if !self.warmed_up() {
            return Vec::new();
        }
        self.lambdas
            .iter()
            .enumerate()
            .filter(|(_, e)| e.get() > self.cfg.tau_curv)
            .map(|(l, _)| l)
            .collect()
    }

    pub fn lambda(&self, l: usize) -> f64 {
        self.lambdas[l].get()
    }

    pub fn lambdas(&self) -> Vec<f64> {
        self.lambdas.iter().map(|e| e.get()).collect()
    }

    pub fn firings(&self) -> u64 {
        self.firings
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Serialize the λ EMAs and firing counters for checkpointing.
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        let mut vals = Vec::with_capacity(self.lambdas.len());
        let mut steps = Vec::with_capacity(self.lambdas.len());
        for e in &self.lambdas {
            let (v, s) = e.raw();
            vals.push(v);
            steps.push(s as f64);
        }
        vec![
            (key("lam_values"), vals),
            (key("lam_steps"), steps),
            (key("counters"), vec![self.firings as f64, self.rejected as f64]),
        ]
    }

    /// Restore state written by [`Self::export_state`] (or the legacy
    /// `curvature/…` keys of pre-policy checkpoints).
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        let vals = ckpt_lookup(kv, &[&key("lam_values"), "curvature/lam_values"])?;
        let steps = ckpt_lookup(kv, &[&key("lam_steps"), "curvature/lam_steps"])?;
        let counters = ckpt_lookup(kv, &[&key("counters"), "curvature/counters"])?;
        anyhow::ensure!(
            vals.len() == self.lambdas.len() && steps.len() == self.lambdas.len(),
            "curvature state arity mismatch ({} layers)",
            self.lambdas.len()
        );
        anyhow::ensure!(counters.len() == 2, "curvature counters arity");
        for (ema, (&v, &s)) in self.lambdas.iter_mut().zip(vals.iter().zip(steps.iter())) {
            ema.set_raw(v, s as u64);
        }
        self.firings = counters[0] as u64;
        self.rejected = counters[1] as u64;
        Ok(())
    }
}

const NAME: &str = "curvature.amortized";

fn key(field: &str) -> String {
    format!("policy/{NAME}/{field}")
}

impl CurvaturePolicy for CurvatureScheduler {
    fn name(&self) -> &'static str {
        NAME
    }

    fn active(&self) -> bool {
        true
    }

    fn due(&self, step: u64) -> bool {
        CurvatureScheduler::due(self, step)
    }

    fn observe(&mut self, lambdas: &[f32]) -> Vec<usize> {
        CurvatureScheduler::observe(self, lambdas)
    }

    fn lr_scales(&self, num_layers: usize) -> Vec<f32> {
        debug_assert_eq!(num_layers, self.lambdas.len(), "lr_scales arity");
        CurvatureScheduler::lr_scales(self)
    }

    fn promotions(&self) -> Vec<usize> {
        CurvatureScheduler::promotions(self)
    }

    fn firings(&self) -> u64 {
        CurvatureScheduler::firings(self)
    }

    fn lambdas(&self) -> Vec<f64> {
        CurvatureScheduler::lambdas(self)
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        CurvatureScheduler::export_state(self)
    }

    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        CurvatureScheduler::import_state(self, kv)
    }
}

/// Curvature disabled: the baselines and the curvature-off ablation.
/// Never due, unit LR scales, no promotions, no state.
pub struct NoCurvature;

impl CurvaturePolicy for NoCurvature {
    fn name(&self) -> &'static str {
        "curvature.off"
    }

    fn active(&self) -> bool {
        false
    }

    fn due(&self, _step: u64) -> bool {
        false
    }

    fn observe(&mut self, _lambdas: &[f32]) -> Vec<usize> {
        Vec::new()
    }

    fn lr_scales(&self, num_layers: usize) -> Vec<f32> {
        vec![1.0; num_layers]
    }

    fn promotions(&self) -> Vec<usize> {
        Vec::new()
    }

    fn firings(&self) -> u64 {
        0
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        Vec::new()
    }

    fn import_state(&mut self, _kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CurvatureConfig {
        CurvatureConfig { t_curv: 10, alpha: 0.5, tau_curv: 4.0, warmup: 2, beta: 0.0 }
    }

    #[test]
    fn cadence() {
        let cs = CurvatureScheduler::new(1, cfg());
        assert!(!cs.due(0), "never at step 0");
        assert!(cs.due(10));
        assert!(!cs.due(11));
        assert!(cs.due(20));
    }

    #[test]
    fn cadence_disabled_when_zero() {
        let mut c = cfg();
        c.t_curv = 0;
        let cs = CurvatureScheduler::new(1, c);
        assert!(!cs.due(10) && !cs.due(200));
    }

    #[test]
    fn lr_scales_flat_until_warmup() {
        let mut cs = CurvatureScheduler::new(2, cfg());
        cs.observe(&[8.0, 0.0]);
        assert_eq!(cs.lr_scales(), vec![1.0, 1.0], "1 firing < warmup 2");
        cs.observe(&[8.0, 0.0]);
        let s = cs.lr_scales();
        assert!((s[0] - 1.0 / 5.0).abs() < 1e-6, "1/(1+0.5·8) = 0.2, got {}", s[0]);
        assert_eq!(s[1], 1.0);
    }

    #[test]
    fn high_curvature_shrinks_lr_monotonically() {
        let mut cs = CurvatureScheduler::new(3, cfg());
        cs.observe(&[0.0, 2.0, 20.0]);
        cs.observe(&[0.0, 2.0, 20.0]);
        let s = cs.lr_scales();
        assert!(s[0] > s[1] && s[1] > s[2]);
        assert!(s.iter().all(|&x| x > 0.0 && x <= 1.0));
    }

    #[test]
    fn promotions_above_tau() {
        let mut cs = CurvatureScheduler::new(3, cfg());
        cs.observe(&[1.0, 5.0, 3.9]);
        assert!(cs.promotions().is_empty(), "not warmed up");
        cs.observe(&[1.0, 5.0, 3.9]);
        assert_eq!(cs.promotions(), vec![1]);
    }

    #[test]
    fn negative_lambda_uses_magnitude() {
        let mut cs = CurvatureScheduler::new(1, cfg());
        cs.observe(&[-8.0]);
        cs.observe(&[-8.0]);
        assert!((cs.lambda(0) - 8.0).abs() < 1e-9);
        assert_eq!(cs.promotions(), vec![0]);
    }

    #[test]
    fn non_finite_rejected() {
        let mut cs = CurvatureScheduler::new(2, cfg());
        let bad = cs.observe(&[f32::NAN, 1.0]);
        assert_eq!(bad, vec![0]);
        assert_eq!(cs.rejected(), 1);
        assert_eq!(cs.lambda(0), 0.0, "rejected probe leaves EMA untouched");
        assert!((cs.lambda(1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ema_smooths_across_firings() {
        let mut c = cfg();
        c.beta = 0.5;
        c.warmup = 1;
        let mut cs = CurvatureScheduler::new(1, c);
        cs.observe(&[10.0]);
        cs.observe(&[0.0]);
        let lam = cs.lambda(0);
        assert!(lam > 0.0 && lam < 10.0, "smoothed, got {lam}");
    }

    #[test]
    fn no_curvature_is_inert() {
        let mut nc = NoCurvature;
        assert!(!nc.active());
        assert!(!CurvaturePolicy::due(&nc, 200));
        assert!(CurvaturePolicy::observe(&mut nc, &[1.0, 2.0]).is_empty());
        assert_eq!(CurvaturePolicy::lr_scales(&nc, 3), vec![1.0; 3]);
        assert!(CurvaturePolicy::promotions(&nc).is_empty());
        assert!(CurvaturePolicy::export_state(&nc).is_empty());
        nc.import_state(&[]).unwrap();
    }

    #[test]
    fn scheduler_state_roundtrips_with_legacy_keys() {
        let mut cs = CurvatureScheduler::new(2, cfg());
        cs.observe(&[3.0, f32::NAN]);
        cs.observe(&[2.0, 1.0]);
        let saved = CurvatureScheduler::export_state(&cs);
        assert!(saved.iter().all(|(k, _)| k.starts_with("policy/curvature.amortized/")));
        let legacy: Vec<(String, Vec<f64>)> = saved
            .iter()
            .map(|(k, v)| {
                (k.replace("policy/curvature.amortized/", "curvature/"), v.clone())
            })
            .collect();
        for kv in [&saved, &legacy] {
            let mut fresh = CurvatureScheduler::new(2, cfg());
            fresh.import_state(kv).unwrap();
            assert_eq!(fresh.lambdas(), cs.lambdas());
            assert_eq!(fresh.firings(), cs.firings());
            assert_eq!(fresh.rejected(), cs.rejected());
        }
    }
}
