"""EfficientNet-lite — the paper's second architecture, compound-scaled
down to CIFAR 32×32 (DESIGN.md §5 substitution: the paper runs B0 at
224×224 from pretrained weights; we keep the architectural ingredients that
matter for per-layer precision/curvature dynamics — MBConv inverted
bottlenecks, depthwise convs, squeeze-excite — at a CPU-trainable size).

Stem 3×3 s1 → MBConv stages (expansion 1/6, SE ¼) → 1×1 head conv →
GAP → dense. SE squeeze convs stay fp32 (tiny, numerically sensitive —
same policy AMP applies to softmax-adjacent ops).
"""

from __future__ import annotations

import jax.nn
import jax.numpy as jnp

from . import common as C

NAME = "effnet_lite"

# (expansion, features, num_blocks, stride)
STAGES = (
    (1, 16, 1, 1),
    (6, 24, 2, 2),  # 16x16
    (6, 40, 2, 2),  # 8x8
    (6, 80, 2, 2),  # 4x4
)
HEAD_FEATURES = 192


def _se(store: C.Store, name: str, x, reduced: int):
    """Squeeze-excite. Uses precision layers for its 1×1 convs (they are
    cheap but real layers — the controller may still retune them)."""
    s = jnp.mean(x, axis=(1, 2), keepdims=True)
    s = C.conv2d(store, f"{name}/reduce", s, reduced, kernel=1)
    s = jax.nn.relu(s)
    s = C.conv2d(store, f"{name}/expand", s, x.shape[-1], kernel=1)
    return x * jax.nn.sigmoid(s)


def _mbconv(store: C.Store, name: str, x, expansion: int, features: int, stride: int):
    cin = x.shape[-1]
    mid = cin * expansion
    out = x
    if expansion != 1:
        out = C.conv2d(store, f"{name}/expand", out, mid, kernel=1)
        out = C.batchnorm(store, f"{name}/bn_expand", out)
        out = jax.nn.relu(out)
    out = C.conv2d(store, f"{name}/dw", out, mid, kernel=3, stride=stride, groups=mid)
    out = C.batchnorm(store, f"{name}/bn_dw", out)
    out = jax.nn.relu(out)
    out = _se(store, f"{name}/se", out, max(1, cin // 4))
    out = C.conv2d(store, f"{name}/project", out, features, kernel=1)
    out = C.batchnorm(store, f"{name}/bn_project", out)
    if stride == 1 and cin == features:
        out = out + x
    return out


def make_forward(num_classes: int):
    def forward(store: C.Store, x):
        x = C.conv2d(store, "stem", x, 32, kernel=3)
        x = C.batchnorm(store, "bn_stem", x)
        x = jax.nn.relu(x)
        for si, (exp, feat, nblocks, stride) in enumerate(STAGES):
            for bi in range(nblocks):
                s = stride if bi == 0 else 1
                x = _mbconv(store, f"stage{si}/block{bi}", x, exp, feat, s)
        x = C.conv2d(store, "head_conv", x, HEAD_FEATURES, kernel=1)
        x = C.batchnorm(store, "bn_head", x)
        x = jax.nn.relu(x)
        x = C.global_avg_pool(x)
        return C.dense(store, "head", x, num_classes)

    return forward


def build(num_classes: int = 10, seed: int = 0) -> C.Model:
    return C.build_model(NAME, num_classes, make_forward(num_classes), seed=seed)
