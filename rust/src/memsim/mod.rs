//! VRAM simulator — the substitution for CUDA memory telemetry
//! (DESIGN.md §5). The paper's controller consumes two scalars,
//! `MemUsage(t)` and `MemMax`; this module produces them from an analytic
//! allocator model driven by the manifest's per-layer tensor shapes, the
//! live precision map, and the live batch size.
//!
//! The model is deliberately structural, not fitted: every term is the
//! byte count of a real allocation the PyTorch/Triton stack would make,
//! so the *functional form* of memory vs (B, precision) — which is what
//! the feedback controller's dynamics depend on — is preserved.

// Enforced as an error by the docs CI job (`cargo doc` with
// `RUSTDOCFLAGS=-D warnings`); kept at `warn` here so tier-1
// `cargo build`/`cargo test` never hard-fails on a doc regression.
#![warn(missing_docs)]

pub mod hostmem;
pub mod scenarios;
pub mod tracefile;

use crate::manifest::{precision_bytes, ModelEntry};
use crate::util::rng::Rng;

/// Hardware-agnostic memory telemetry (the abstraction the paper's §4.5
/// names as future work). `VramSim` is the simulator backend; a CUDA/TPU
/// backend would implement the same trait from vendor APIs.
pub trait MemoryMonitor {
    /// Current usage in GiB (most recent step).
    fn mem_used_gb(&self) -> f64;
    /// Capacity / budget in GiB (MemMax).
    fn mem_max_gb(&self) -> f64;
    /// High-water mark over the run.
    fn peak_gb(&self) -> f64;
}

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// A time-varying budget: the VRAM-pressure scenarios. `MemMax` is no
/// longer necessarily a constant — a co-tenant spinning up, a shrinking
/// cgroup allocation, or a periodic neighbor all move the ceiling the
/// §3.3 controller must live under. The synthetic traces multiply the
/// base budget by a step-indexed factor in (0, 1]; a [`Self::Replay`]
/// trace instead *replaces* `MemMax` with a recorded absolute series
/// (see [`tracefile`]).
#[derive(Debug, Clone, PartialEq)]
pub enum BudgetTrace {
    /// Fixed budget (the paper's strict single-GPU setting).
    Constant,
    /// Step function: full budget before `at`, `frac`·budget from `at`
    /// on — a co-tenant that arrives and stays.
    Step { at: u64, frac: f64 },
    /// Linear shrink from 1.0 at `start` to `floor` at `end` (clamped
    /// after) — a draining allocation.
    Ramp { start: u64, end: u64, floor: f64 },
    /// Sawtooth contention: a periodic co-tenant that claims memory
    /// linearly over each period, then releases. Factor falls from 1.0
    /// toward `1 - depth` across each `period`-step cycle.
    Sawtooth { period: u64, depth: f64 },
    /// A recorded absolute `MemMax` series (GiB), loaded from a
    /// versioned trace file and played back by step index — no wall
    /// clock, no base-budget scaling. Past the end of the series the
    /// last value holds. `path` is kept for spec round-tripping.
    Replay {
        /// The trace file the series was loaded from.
        path: String,
        /// Absolute `MemMax` in GiB at step `i` (never empty).
        gb: Vec<f64>,
    },
    /// A named adversarial scenario from the library — a closed-form
    /// deterministic factor curve (see [`scenarios`]).
    Scenario(scenarios::ScenarioKind),
}

impl BudgetTrace {
    /// Parse a trace spec: `const` | `step:FRAC@STEP` |
    /// `ramp:START:END:FLOOR` | `saw:PERIOD:DEPTH` |
    /// `replay:FILE[#DIGEST]` | `scenario:NAME`.
    ///
    /// `replay:` loads and validates the trace file eagerly, so a
    /// malformed file fails here (CLI arg / config validation), never
    /// mid-grid. The optional `#DIGEST` suffix (16 hex digits) pins the
    /// file's content digest — [`Self::to_spec`] always emits it — so
    /// the spec string, and with it every config fingerprint built
    /// from it, changes whenever the trace *content* changes.
    pub fn parse(spec: &str) -> anyhow::Result<BudgetTrace> {
        let t = match spec {
            "" | "const" | "none" => BudgetTrace::Constant,
            s if s.starts_with("step:") => {
                let body = &s[5..];
                let (frac, at) = body
                    .split_once('@')
                    .ok_or_else(|| anyhow::anyhow!("step trace wants FRAC@STEP, got `{body}`"))?;
                BudgetTrace::Step {
                    at: at.parse().map_err(|_| anyhow::anyhow!("bad step `{at}`"))?,
                    frac: frac.parse().map_err(|_| anyhow::anyhow!("bad frac `{frac}`"))?,
                }
            }
            s if s.starts_with("ramp:") => {
                let parts: Vec<&str> = s[5..].split(':').collect();
                anyhow::ensure!(parts.len() == 3, "ramp trace wants START:END:FLOOR");
                BudgetTrace::Ramp {
                    start: parts[0].parse().map_err(|_| anyhow::anyhow!("bad start"))?,
                    end: parts[1].parse().map_err(|_| anyhow::anyhow!("bad end"))?,
                    floor: parts[2].parse().map_err(|_| anyhow::anyhow!("bad floor"))?,
                }
            }
            s if s.starts_with("saw:") => {
                let parts: Vec<&str> = s[4..].split(':').collect();
                anyhow::ensure!(parts.len() == 2, "saw trace wants PERIOD:DEPTH");
                BudgetTrace::Sawtooth {
                    period: parts[0].parse().map_err(|_| anyhow::anyhow!("bad period"))?,
                    depth: parts[1].parse().map_err(|_| anyhow::anyhow!("bad depth"))?,
                }
            }
            s if s.starts_with("replay:") => {
                let body = &s[7..];
                // `#DIGEST` pin: exactly 16 trailing hex digits after
                // the last `#`; anything else is part of the path.
                let (path, want) = match body.rsplit_once('#') {
                    Some((p, d)) if d.len() == 16 => match u64::from_str_radix(d, 16) {
                        Ok(w) => (p, Some(w)),
                        Err(_) => (body, None),
                    },
                    _ => (body, None),
                };
                anyhow::ensure!(!path.is_empty(), "replay trace wants a file path");
                let tf = tracefile::TraceFile::load(std::path::Path::new(path))?;
                if let Some(w) = want {
                    let got = tf.digest();
                    anyhow::ensure!(
                        got == w,
                        "replay trace `{path}` content digest {got:016x} does not match the \
                         pinned {w:016x} — the file changed since this spec was written"
                    );
                }
                BudgetTrace::Replay { path: path.to_string(), gb: tf.gb }
            }
            s if s.starts_with("scenario:") => {
                BudgetTrace::Scenario(scenarios::ScenarioKind::parse(&s[9..])?)
            }
            other => anyhow::bail!(
                "unknown budget trace `{other}` (const|step:FRAC@STEP|ramp:START:END:FLOOR\
                 |saw:PERIOD:DEPTH|replay:FILE[#DIGEST]|scenario:NAME)"
            ),
        };
        t.validate()?;
        Ok(t)
    }

    fn validate(&self) -> anyhow::Result<()> {
        match *self {
            BudgetTrace::Constant | BudgetTrace::Scenario(_) => {}
            BudgetTrace::Step { frac, .. } => {
                anyhow::ensure!(frac > 0.0 && frac <= 1.0, "step frac in (0,1]");
            }
            BudgetTrace::Ramp { start, end, floor } => {
                anyhow::ensure!(start < end, "ramp start < end");
                anyhow::ensure!(floor > 0.0 && floor <= 1.0, "ramp floor in (0,1]");
            }
            BudgetTrace::Sawtooth { period, depth } => {
                anyhow::ensure!(period > 0, "saw period > 0");
                anyhow::ensure!((0.0..1.0).contains(&depth), "saw depth in [0,1)");
            }
            BudgetTrace::Replay { ref gb, .. } => tracefile::validate_series(gb)?,
        }
        Ok(())
    }

    /// Render the canonical spec string [`Self::parse`] accepts — the
    /// inverse of `parse`, used wherever a trace flows into a config
    /// (`Config::mem_trace`), so grid identity always hashes the
    /// canonical form. For [`Self::Replay`] the emitted spec pins the
    /// content digest: `replay:PATH#DIGEST`.
    pub fn to_spec(&self) -> String {
        match self {
            BudgetTrace::Constant => "const".to_string(),
            BudgetTrace::Step { at, frac } => format!("step:{frac}@{at}"),
            BudgetTrace::Ramp { start, end, floor } => format!("ramp:{start}:{end}:{floor}"),
            BudgetTrace::Sawtooth { period, depth } => format!("saw:{period}:{depth}"),
            BudgetTrace::Replay { path, gb } => {
                format!("replay:{path}#{:016x}", tracefile::series_digest(gb))
            }
            BudgetTrace::Scenario(k) => format!("scenario:{}", k.name()),
        }
    }

    /// Absolute `MemMax` level (GiB) at `step`, for traces that carry
    /// one ([`Self::Replay`] — clamped to the last recorded step).
    /// `None` for the factor-based traces, which scale a base budget
    /// instead.
    pub fn level_gb(&self, step: u64) -> Option<f64> {
        match self {
            BudgetTrace::Replay { gb, .. } => {
                // `gb` is never empty (validated at parse/load time).
                Some(gb[(step as usize).min(gb.len() - 1)])
            }
            _ => None,
        }
    }

    /// Budget multiplier at `step`, in (0, 1]. For [`Self::Replay`]
    /// the factor is unused ([`Self::level_gb`] replaces the budget
    /// outright) and reads as 1.0.
    pub fn factor(&self, step: u64) -> f64 {
        match *self {
            BudgetTrace::Constant | BudgetTrace::Replay { .. } => 1.0,
            BudgetTrace::Step { at, frac } => {
                if step >= at {
                    frac
                } else {
                    1.0
                }
            }
            BudgetTrace::Ramp { start, end, floor } => {
                if step <= start {
                    1.0
                } else if step >= end {
                    floor
                } else {
                    let t = (step - start) as f64 / (end - start) as f64;
                    1.0 + t * (floor - 1.0)
                }
            }
            BudgetTrace::Sawtooth { period, depth } => {
                let phase = (step % period) as f64 / period as f64;
                1.0 - depth * phase
            }
            BudgetTrace::Scenario(k) => k.factor(step),
        }
    }
}

/// Fraction of the base budget left during an injected OOM storm —
/// small enough that not even a batch-1 FP32 step fits any built-in
/// model's budget, so a stormed attempt always dies.
pub const STORM_FRAC: f64 = 0.001;

/// The budget trace an injected OOM storm installs (see
/// `faults::simulated_oom_storm`): a co-tenant burst that claims
/// essentially the whole device from step 0 — a [`BudgetTrace::Step`]
/// at the [`STORM_FRAC`] floor. Kept here (with the other traces) so
/// the fault injector and the pressure scenarios share one vocabulary.
pub fn storm_trace() -> BudgetTrace {
    BudgetTrace::Step { at: 0, frac: STORM_FRAC }
}

/// Fixed runtime overhead: context, cuDNN/Triton handles, streams.
const BASE_OVERHEAD_BYTES: f64 = 48.0 * 1024.0 * 1024.0;
/// Allocator block rounding / fragmentation factor.
const FRAG_FACTOR: f64 = 1.05;

/// Byte accounting of one simulated train step, split by allocation
/// class (all GiB).
#[derive(Debug, Clone)]
pub struct StepUsage {
    /// Master FP32 weights plus BN/statistics state.
    pub params_gb: f64,
    /// Low-precision compute copies of quantized layers.
    pub compute_copies_gb: f64,
    /// Gradients in compute precision (plus FP32 BN grads).
    pub grads_gb: f64,
    /// SGD momentum buffers (FP32).
    pub momentum_gb: f64,
    /// Saved activations for backward, scaled by the batch size.
    pub activations_gb: f64,
    /// Convolution / reduction scratch workspace.
    pub workspace_gb: f64,
    /// Curvature-probe u/Hu buffers (probe steps only).
    pub transient_gb: f64,
    /// Grand total including fragmentation, noise, and base overhead.
    pub total_gb: f64,
}

/// The analytic VRAM simulator: produces `MemUsage(t)`/`MemMax` for
/// the §3.3 feedback controller from the manifest's tensor shapes, the
/// live precision map, and the live batch size. Supports time-varying
/// budgets ([`BudgetTrace`]) for the VRAM-pressure scenarios.
pub struct VramSim {
    /// Base budget; the live `MemMax` is `budget_gb · trace.factor(step)`
    /// — except under a [`BudgetTrace::Replay`], whose recorded
    /// absolute series replaces the ceiling entirely.
    budget_gb: f64,
    trace: BudgetTrace,
    /// Current trainer step (drives the trace). Advanced by
    /// [`Self::set_step`]; constant traces ignore it.
    step: u64,
    /// Live data-parallel replica count: [`Self::usage`] accounts the
    /// *aggregate* across replicas (each holds its own weights /
    /// grads / workspace; activations split). 1 = the pre-replica
    /// model, bit-identically.
    replicas: usize,
    noise_frac: f64,
    rng: Rng,
    // static per-model quantities (elements)
    param_elems_total: usize,
    layer_param_elems: Vec<usize>,
    layer_act_elems: Vec<usize>,
    state_elems: usize,
    /// Workspace sizing units: the largest per-sample layer tile,
    /// weighted by kind — depthwise convs run direct (no shared im2col
    /// panel) and materialize a quantized input copy alongside the
    /// output tile, so they charge 2× their activation extent; im2col
    /// kinds (conv/dense) share the GEMM workspace already counted at
    /// 1×.
    ws_units: f64,
    last: f64,
    peak: f64,
    oom_events: u64,
}

impl VramSim {
    /// Build a simulator for one model entry: `budget_gb` is the base
    /// `MemMax`, `noise_frac` the allocator-transient noise band, and
    /// `seed` drives the (deterministic) noise stream.
    pub fn new(entry: &ModelEntry, budget_gb: f64, noise_frac: f64, seed: u64) -> VramSim {
        VramSim {
            budget_gb,
            trace: BudgetTrace::Constant,
            step: 0,
            replicas: 1,
            noise_frac,
            rng: Rng::stream(seed, 0x4D454D),
            param_elems_total: entry.param_count,
            layer_param_elems: entry.layers.iter().map(|l| l.param_elems).collect(),
            layer_act_elems: entry.layers.iter().map(|l| l.act_elems).collect(),
            state_elems: entry.state_elems(),
            ws_units: entry
                .layers
                .iter()
                .map(|l| l.act_elems as f64 * if l.kind == "dwconv" { 2.0 } else { 1.0 })
                .fold(0.0, f64::max),
            last: BASE_OVERHEAD_BYTES / GIB,
            peak: BASE_OVERHEAD_BYTES / GIB,
            oom_events: 0,
        }
    }

    /// Byte accounting for one train step at batch size `b` with the live
    /// per-layer precision `codes`. `curv_active` charges the curvature
    /// probe's extra HVP buffers on probe steps.
    pub fn usage(&mut self, b: usize, codes: &[i32], curv_active: bool) -> StepUsage {
        assert_eq!(codes.len(), self.layer_param_elems.len(), "codes arity");
        let f = |elems: usize, bytes: usize| (elems * bytes) as f64;

        // Master weights + momentum + BN state: always fp32.
        let params = f(self.param_elems_total + self.state_elems, 4);
        let momentum = f(self.param_elems_total, 4);

        // Low-precision compute copies & gradients per layer.
        let mut copies = 0.0;
        let mut grads = 0.0;
        let mut acts = 0.0;
        for ((&pe, &ae), &c) in self
            .layer_param_elems
            .iter()
            .zip(self.layer_act_elems.iter())
            .zip(codes.iter())
        {
            let by = precision_bytes(c);
            // A quantized weight copy only exists when compute ≠ fp32.
            if by != 4 {
                copies += f(pe, by);
            }
            grads += f(pe, by.max(2)); // grads live in compute precision
            acts += f(ae, by) * b as f64; // saved activations for backward
        }
        // Non-layer (BN) grads, fp32.
        let bn_elems = self.param_elems_total
            - self.layer_param_elems.iter().sum::<usize>();
        grads += f(bn_elems, 4);

        // Workspace: conv scratch ~ one layer's input+output tile at the
        // live precision, plus the loss/reduction buffers (kind-weighted
        // — see `ws_units`).
        let ws_bytes = self.ws_units
            * b as f64
            * codes.iter().map(|&c| precision_bytes(c)).max().unwrap_or(4) as f64;
        let workspace = ws_bytes * 0.5;

        // Curvature probes (§3.2 block-diagonal): the power iteration
        // walks layer blocks, so u/Hu buffers are sized by the largest
        // layer, not the whole network (×2 for u and Hu, fp32).
        let max_layer = self.layer_param_elems.iter().copied().max().unwrap_or(0);
        let transient = if curv_active { f(max_layer, 4) * 2.0 } else { 0.0 };

        // Aggregate across live data-parallel replicas: every replica
        // device holds its own master weights, momentum, compute
        // copies, gradients, and workspace — plus its own runtime base
        // overhead — while the saved activations split across replicas
        // (each holds 1/N of the batch, so the aggregate activation
        // bytes are unchanged). The curvature probe runs on one
        // replica, so its transient is unscaled too. `replicas = 1` is
        // bit-identical to the pre-replica model (×1.0 is exact and
        // the addition order is preserved).
        let r = self.replicas.max(1) as f64;
        let noise = 1.0 + self.noise_frac * (2.0 * self.rng.next_f64() - 1.0);
        let total_bytes = ((params + momentum + copies + grads) * r + acts + workspace * r
            + transient)
            * FRAG_FACTOR
            * noise
            + BASE_OVERHEAD_BYTES * r;

        let u = StepUsage {
            params_gb: params * r / GIB,
            compute_copies_gb: copies * r / GIB,
            grads_gb: grads * r / GIB,
            momentum_gb: momentum * r / GIB,
            activations_gb: acts / GIB,
            workspace_gb: workspace * r / GIB,
            transient_gb: transient / GIB,
            total_gb: total_bytes / GIB,
        };
        self.last = u.total_gb;
        if u.total_gb > self.peak {
            self.peak = u.total_gb;
        }
        if u.total_gb > self.mem_max_gb() {
            self.oom_events += 1;
        }
        u
    }

    /// Advance the budget trace to the trainer's current step. Constant
    /// traces (the default, and every paper table) are unaffected.
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Install a time-varying budget trace (VRAM-pressure scenarios).
    pub fn set_trace(&mut self, trace: BudgetTrace) {
        self.trace = trace;
    }

    /// Set the live data-parallel replica count the accounting
    /// aggregates over (clamped to ≥ 1).
    pub fn set_replicas(&mut self, n: usize) {
        self.replicas = n.max(1);
    }

    /// The live replica count the accounting aggregates over.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Predictive fit at a *candidate* replica count: would a step at
    /// (b, codes) with `n` live replicas stay under `frac·budget`?
    /// Probes without mutating the live count, peak, or noise stream —
    /// the replica controller's restore veto.
    pub fn would_fit_replicas(
        &mut self,
        n: usize,
        b: usize,
        codes: &[i32],
        curv_active: bool,
        frac: f64,
    ) -> bool {
        let live = self.replicas;
        self.replicas = n.max(1);
        let ok = self.would_fit_within(b, codes, curv_active, frac);
        self.replicas = live;
        ok
    }

    /// The installed budget trace ([`BudgetTrace::Constant`] default).
    pub fn trace(&self) -> &BudgetTrace {
        &self.trace
    }

    /// The base (trace-free) budget.
    pub fn base_budget_gb(&self) -> f64 {
        self.budget_gb
    }

    /// Would a step at (b, codes) exceed the budget? Used by the batch
    /// controller to veto growth before attempting it (OOM avoidance).
    pub fn would_fit(&mut self, b: usize, codes: &[i32], curv_active: bool) -> bool {
        self.would_fit_within(b, codes, curv_active, 1.0)
    }

    /// Predictive fit against `frac·budget`. Growing only while the
    /// *predicted* usage stays under ρ_high·MemMax keeps the controller
    /// from spiking the peak with a grow-then-shrink oscillation — the
    /// grown batch would immediately trip the §3.3 shrink rule.
    pub fn would_fit_within(
        &mut self,
        b: usize,
        codes: &[i32],
        curv_active: bool,
        frac: f64,
    ) -> bool {
        // Probe without mutating peak/last: run on a cloned accounting.
        let saved = (self.last, self.peak, self.oom_events, self.rng.clone());
        let u = self.usage(b, codes, curv_active);
        self.last = saved.0;
        self.peak = saved.1;
        self.oom_events = saved.2;
        self.rng = saved.3;
        u.total_gb <= self.mem_max_gb() * frac
    }

    /// Simulated OOM count: steps whose usage exceeded the live budget.
    pub fn oom_events(&self) -> u64 {
        self.oom_events
    }

    /// Reset the high-water mark to the most recent step's usage.
    pub fn reset_peak(&mut self) {
        self.peak = self.last;
    }
}

impl MemoryMonitor for VramSim {
    fn mem_used_gb(&self) -> f64 {
        self.last
    }

    fn mem_max_gb(&self) -> f64 {
        // A replayed trace carries the absolute ceiling; the base
        // budget does not enter (that is what makes replay portable
        // across models and budgets).
        if let Some(gb) = self.trace.level_gb(self.step) {
            return gb;
        }
        match self.trace {
            BudgetTrace::Constant => self.budget_gb,
            _ => self.budget_gb * self.trace.factor(self.step),
        }
    }

    fn peak_gb(&self) -> f64 {
        self.peak
    }
}

/// Analytic accelerator-time model: translates measured step counts into
/// "GPU-terms" seconds for the Table-1 time column (DESIGN.md §5). Uses
/// MAC counts from the manifest with per-precision throughput factors
/// (T4-class: half precision ≈ 1.8× fp32 effective, memory-bound tail
/// keeps it below the 8× tensor-core peak).
#[derive(Debug, Clone)]
pub struct SpeedModel {
    /// Effective FP32 throughput (TFLOP/s).
    pub fp32_tflops: f64,
    /// Effective speedup factor for half-precision layers.
    pub half_speedup: f64,
    /// Per-step launch/host overhead (seconds).
    pub fixed_overhead_s: f64,
}

impl SpeedModel {
    /// T4-class parameters (the paper's single-GPU setting).
    pub fn t4_like() -> SpeedModel {
        SpeedModel { fp32_tflops: 8.1, half_speedup: 1.8, fixed_overhead_s: 2.0e-3 }
    }

    /// Modeled seconds for one fwd+bwd step (bwd ≈ 2× fwd FLOPs). The
    /// per-layer MAC counts come from the manifest at call time, so the
    /// model carries no per-entry state.
    pub fn step_seconds(&self, b: usize, codes: &[i32], layer_flops: &[usize]) -> f64 {
        let total: f64 = layer_flops
            .iter()
            .zip(codes.iter())
            .map(|(&fl, &c)| {
                let speed = if precision_bytes(c) == 2 { self.half_speedup } else { 1.0 };
                (fl as f64 * 2.0) / speed
            })
            .sum();
        let flops = total * 3.0 * b as f64; // fwd + 2×fwd for bwd
        flops / (self.fp32_tflops * 1e12) + self.fixed_overhead_s
    }

    /// Modeled seconds for one *replicated* fwd+bwd step: `replicas`
    /// engines each execute 1/N of the batch concurrently, with a 5%
    /// per-extra-replica synchronization/reduction tax on the compute
    /// term (the ordered gradient reduction is serial in N). The
    /// per-step launch overhead is not divided — every replica step
    /// still pays it once. `replicas = 1` is [`Self::step_seconds`]
    /// bit-identically.
    pub fn step_seconds_replicated(
        &self,
        b: usize,
        codes: &[i32],
        layer_flops: &[usize],
        replicas: usize,
    ) -> f64 {
        if replicas <= 1 {
            return self.step_seconds(b, codes, layer_flops);
        }
        let n = replicas as f64;
        let compute = self.step_seconds(b, codes, layer_flops) - self.fixed_overhead_s;
        compute / n * (1.0 + 0.05 * (n - 1.0)) + self.fixed_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{LayerSpec, ModelEntry, BF16, FP16, FP32};
    use std::collections::BTreeMap;

    fn toy_entry() -> ModelEntry {
        ModelEntry {
            key: "toy".into(),
            model: "toy".into(),
            num_classes: 10,
            num_layers: 2,
            param_count: 1_000_000,
            layers: vec![
                LayerSpec {
                    name: "a".into(),
                    kind: "conv".into(),
                    param_elems: 600_000,
                    act_elems: 100_000,
                    flops: 10_000_000,
                },
                LayerSpec {
                    name: "b".into(),
                    kind: "dense".into(),
                    param_elems: 300_000,
                    act_elems: 10,
                    flops: 300_000,
                },
            ],
            params: vec![],
            nodes: vec![],
            state_shapes: vec![],
            train_buckets: vec![32, 64],
            eval_buckets: vec![16],
            curv_batch: 32,
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn memory_grows_with_batch() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        let u32_ = sim.usage(32, &[FP32, FP32], false);
        let u64_ = sim.usage(64, &[FP32, FP32], false);
        assert!(u64_.total_gb > u32_.total_gb);
        assert!(u64_.activations_gb > 1.9 * u32_.activations_gb);
    }

    #[test]
    fn half_precision_saves_memory() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        let hi = sim.usage(64, &[FP32, FP32], false);
        let lo = sim.usage(64, &[FP16, BF16], false);
        assert!(lo.total_gb < hi.total_gb);
        assert!(lo.activations_gb < 0.6 * hi.activations_gb);
    }

    #[test]
    fn peak_tracks_high_water() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        sim.usage(64, &[FP32, FP32], false);
        let peak_hi = sim.peak_gb();
        sim.usage(32, &[FP16, FP16], false);
        assert_eq!(sim.peak_gb(), peak_hi, "peak must not decrease");
        assert!(sim.mem_used_gb() < peak_hi);
    }

    #[test]
    fn curvature_charges_transient() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        let base = sim.usage(32, &[FP32, FP32], false);
        let probe = sim.usage(32, &[FP32, FP32], true);
        assert!(probe.transient_gb > 0.0 && probe.total_gb > base.total_gb);
    }

    #[test]
    fn would_fit_does_not_mutate(){
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 0.08, 0.0, 0);
        let before = sim.peak_gb();
        let fits = sim.would_fit(64, &[FP32, FP32], false);
        assert!(!fits, "64×fp32 should blow a 0.08GB budget");
        assert_eq!(sim.peak_gb(), before);
        assert_eq!(sim.oom_events(), 0);
    }

    #[test]
    fn paper_geometry_probe_hides_under_activation_headroom() {
        // §3.2/§4.3 geometry: train at B=96, curvature probe at
        // b_curv=32. The probe's u/Hu buffers must sit below the train
        // step's activation peak, so Tri-Accel's peak equals AMP's —
        // the Table-1 "Tri-Accel ≤ AMP" shape. (When b_curv ≈ B, as in
        // the CPU-scaled bench, the probe surfaces in the peak; see
        // EXPERIMENTS.md.)
        let mut layers = Vec::new();
        for i in 0..8 {
            layers.push(LayerSpec {
                name: format!("l{i}"),
                kind: "conv".into(),
                param_elems: 1_400_000,
                act_elems: 500_000, // CIFAR ResNet-scale per-sample acts
                flops: 0,
            });
        }
        let e = ModelEntry {
            key: "rn".into(),
            model: "rn".into(),
            num_classes: 10,
            num_layers: 8,
            param_count: 11_200_000,
            layers,
            params: vec![],
            nodes: vec![],
            state_shapes: vec![],
            train_buckets: vec![32, 96],
            eval_buckets: vec![16],
            curv_batch: 32,
            artifacts: BTreeMap::new(),
        };
        let codes = vec![BF16; 8];
        // AMP peak: train step at B=96.
        let mut amp = VramSim::new(&e, 10.0, 0.0, 0);
        let amp_peak = {
            amp.usage(96, &codes, false);
            amp.peak_gb()
        };
        // Tri-Accel: same train steps + separate probe events at b=32.
        let mut tri = VramSim::new(&e, 10.0, 0.0, 0);
        tri.usage(96, &codes, false);
        tri.usage(32, &codes, true); // probe step
        tri.usage(96, &codes, false);
        assert!(
            tri.peak_gb() <= amp_peak + 1e-9,
            "probe surfaced in the peak: tri {} vs amp {amp_peak}",
            tri.peak_gb()
        );
    }

    #[test]
    fn dwconv_layers_charge_wider_workspace() {
        // Two entries identical except the dominant layer's kind: the
        // depthwise variant runs direct (quantized input copy + output
        // tile), so its workspace — and only its workspace — doubles.
        let mk = |kind: &str| {
            let mut e = toy_entry();
            e.layers[0].kind = kind.into();
            e
        };
        let mut conv = VramSim::new(&mk("conv"), 10.0, 0.0, 0);
        let mut dw = VramSim::new(&mk("dwconv"), 10.0, 0.0, 0);
        let codes = [BF16, BF16];
        let uc = conv.usage(64, &codes, false);
        let ud = dw.usage(64, &codes, false);
        assert!((ud.workspace_gb - 2.0 * uc.workspace_gb).abs() < 1e-12);
        assert_eq!(uc.activations_gb, ud.activations_gb, "acts unchanged");
        assert!(ud.total_gb > uc.total_gb);
    }

    #[test]
    fn budget_trace_parse_and_factor() {
        assert_eq!(BudgetTrace::parse("const").unwrap(), BudgetTrace::Constant);
        let st = BudgetTrace::parse("step:0.6@100").unwrap();
        assert_eq!(st, BudgetTrace::Step { at: 100, frac: 0.6 });
        assert_eq!(st.factor(99), 1.0);
        assert_eq!(st.factor(100), 0.6);
        let ramp = BudgetTrace::parse("ramp:10:20:0.5").unwrap();
        assert_eq!(ramp.factor(10), 1.0);
        assert!((ramp.factor(15) - 0.75).abs() < 1e-12);
        assert_eq!(ramp.factor(25), 0.5);
        let saw = BudgetTrace::parse("saw:10:0.4").unwrap();
        assert_eq!(saw.factor(0), 1.0);
        assert!((saw.factor(5) - 0.8).abs() < 1e-12);
        assert_eq!(saw.factor(10), 1.0, "period boundary releases");
        for bad in ["step:1.5@4", "ramp:9:9:0.5", "saw:0:0.2", "wobble", "saw:5:1.0"] {
            assert!(BudgetTrace::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn to_spec_round_trips_every_variant() {
        // Synthetic traces: parse(to_spec(t)) == t, and the canonical
        // string is a fixed point of the round trip.
        for spec in ["const", "step:0.6@100", "ramp:10:20:0.5", "saw:10:0.4"] {
            let t = BudgetTrace::parse(spec).unwrap();
            assert_eq!(t.to_spec(), spec, "canonical specs are fixed points");
            assert_eq!(BudgetTrace::parse(&t.to_spec()).unwrap(), t);
        }
        assert_eq!(BudgetTrace::parse("").unwrap().to_spec(), "const");
        for k in scenarios::ALL {
            let t = BudgetTrace::Scenario(k);
            assert_eq!(BudgetTrace::parse(&t.to_spec()).unwrap(), t);
        }
        // Replay: to_spec pins the content digest; parse verifies it.
        let dir = std::env::temp_dir().join(format!("triaccel_memsim_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round.json");
        tracefile::TraceFile::new("unit", vec![0.5, 0.25, 0.125]).unwrap().save(&path).unwrap();
        let t = BudgetTrace::parse(&format!("replay:{}", path.display())).unwrap();
        let spec = t.to_spec();
        assert!(spec.contains('#'), "replay spec pins the digest: {spec}");
        assert_eq!(BudgetTrace::parse(&spec).unwrap(), t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_trace_replaces_the_ceiling_and_clamps() {
        let dir = std::env::temp_dir().join(format!("triaccel_memsim_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("levels.json");
        tracefile::TraceFile::new("unit", vec![2.0, 0.04, 0.5]).unwrap().save(&path).unwrap();
        let t = BudgetTrace::parse(&format!("replay:{}", path.display())).unwrap();
        assert_eq!(t.level_gb(0), Some(2.0));
        assert_eq!(t.level_gb(1), Some(0.04));
        assert_eq!(t.level_gb(9), Some(0.5), "holds the last value past the end");
        assert_eq!(t.factor(1), 1.0, "factor is unused under replay");

        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        sim.set_trace(t);
        sim.set_step(0);
        assert_eq!(sim.mem_max_gb(), 2.0, "absolute series ignores the base budget");
        sim.usage(32, &[FP32, FP32], false);
        assert_eq!(sim.oom_events(), 0);
        sim.set_step(1);
        assert_eq!(sim.mem_max_gb(), 0.04);
        sim.usage(32, &[FP32, FP32], false);
        assert_eq!(sim.oom_events(), 1, "squeezed recorded step OOMs");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_spec_rejects_bad_files_and_stale_digests() {
        let dir = std::env::temp_dir().join(format!("triaccel_memsim_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Missing file fails at parse time, not mid-grid.
        let missing = dir.join("nope.json");
        assert!(BudgetTrace::parse(&format!("replay:{}", missing.display())).is_err());
        assert!(BudgetTrace::parse("replay:").is_err(), "empty path rejected");
        // Malformed content fails at parse time too.
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[0.5,-1.0]}").unwrap();
        assert!(BudgetTrace::parse(&format!("replay:{}", bad.display())).is_err());
        // A pinned digest catches content drift.
        let path = dir.join("pin.json");
        tracefile::TraceFile::new("unit", vec![0.5]).unwrap().save(&path).unwrap();
        let spec = BudgetTrace::parse(&format!("replay:{}", path.display())).unwrap().to_spec();
        tracefile::TraceFile::new("unit", vec![0.25]).unwrap().save(&path).unwrap();
        let err = BudgetTrace::parse(&spec).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        for f in [&bad, &path] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn scenario_trace_drives_the_budget() {
        let t = BudgetTrace::parse("scenario:spike").unwrap();
        assert_eq!(t, BudgetTrace::Scenario(scenarios::ScenarioKind::Spike));
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        sim.set_trace(t);
        sim.set_step(0);
        assert_eq!(sim.mem_max_gb(), 1.0);
        sim.set_step(8);
        assert!((sim.mem_max_gb() - 0.45).abs() < 1e-12, "burst squeezes the ceiling");
        assert!(BudgetTrace::parse("scenario:surge").is_err(), "unknown names rejected");
    }

    #[test]
    fn storm_trace_starves_even_batch_one() {
        let t = storm_trace();
        t.validate().unwrap();
        assert_eq!(t.factor(0), STORM_FRAC, "storm hits from the first step");
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        sim.set_trace(storm_trace());
        sim.set_step(0);
        let codes = vec![FP32; e.layers.len()];
        let used = sim.usage(1, &codes, false).total_gb;
        assert!(used > sim.mem_max_gb(), "batch 1 must not fit a stormed budget");
    }

    #[test]
    fn trace_moves_mem_max_and_ooms() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 1.0, 0.0, 0);
        sim.set_trace(BudgetTrace::parse("step:0.01@50").unwrap());
        sim.set_step(0);
        assert_eq!(sim.mem_max_gb(), 1.0);
        let u = sim.usage(32, &[FP32, FP32], false);
        assert_eq!(sim.oom_events(), 0, "fits the full budget ({} GB)", u.total_gb);
        sim.set_step(50);
        assert!((sim.mem_max_gb() - 0.01).abs() < 1e-12);
        sim.usage(32, &[FP32, FP32], false);
        assert_eq!(sim.oom_events(), 1, "same step OOMs under the squeezed budget");
        assert!(!sim.would_fit(32, &[FP32, FP32], false));
    }

    #[test]
    fn constant_trace_is_bit_identical_to_untraced() {
        let e = toy_entry();
        let mut a = VramSim::new(&e, 0.5, 0.01, 7);
        let mut b = VramSim::new(&e, 0.5, 0.01, 7);
        b.set_trace(BudgetTrace::Constant);
        for step in 0..20u64 {
            b.set_step(step);
            let ua = a.usage(32, &[BF16, FP16], step % 5 == 0);
            let ub = b.usage(32, &[BF16, FP16], step % 5 == 0);
            assert_eq!(ua.total_gb.to_bits(), ub.total_gb.to_bits());
        }
        assert_eq!(a.peak_gb().to_bits(), b.peak_gb().to_bits());
        assert_eq!(a.oom_events(), b.oom_events());
    }

    #[test]
    fn oom_counted_when_over_budget() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 0.05, 0.0, 0);
        sim.usage(64, &[FP32, FP32], false);
        assert_eq!(sim.oom_events(), 1);
    }

    #[test]
    fn replicas_scale_weights_not_activations() {
        let e = toy_entry();
        let mut a = VramSim::new(&e, 10.0, 0.0, 0);
        let mut b = VramSim::new(&e, 10.0, 0.0, 0);
        b.set_replicas(2);
        assert_eq!(b.replicas(), 2);
        let u1 = a.usage(64, &[FP32, FP32], false);
        let u2 = b.usage(64, &[FP32, FP32], false);
        assert_eq!(u2.params_gb, 2.0 * u1.params_gb);
        assert_eq!(u2.momentum_gb, 2.0 * u1.momentum_gb);
        assert_eq!(u2.grads_gb, 2.0 * u1.grads_gb);
        assert_eq!(u2.workspace_gb, 2.0 * u1.workspace_gb);
        assert_eq!(u2.activations_gb, u1.activations_gb, "acts split across replicas");
        assert!(u2.total_gb > u1.total_gb && u2.total_gb < 2.0 * u1.total_gb + 1.0);
    }

    #[test]
    fn one_replica_is_bit_identical_to_the_pre_replica_model() {
        let e = toy_entry();
        let mut a = VramSim::new(&e, 0.5, 0.01, 7);
        let mut b = VramSim::new(&e, 0.5, 0.01, 7);
        b.set_replicas(1);
        for step in 0..10u64 {
            let ua = a.usage(32, &[BF16, FP16], step % 3 == 0);
            let ub = b.usage(32, &[BF16, FP16], step % 3 == 0);
            assert_eq!(ua.total_gb.to_bits(), ub.total_gb.to_bits());
            assert_eq!(ua.workspace_gb.to_bits(), ub.workspace_gb.to_bits());
        }
        assert_eq!(a.peak_gb().to_bits(), b.peak_gb().to_bits());
    }

    #[test]
    fn would_fit_replicas_probes_without_mutating() {
        let e = toy_entry();
        let mut sim = VramSim::new(&e, 0.1, 0.0, 0);
        let fits1 = sim.would_fit_replicas(1, 32, &[FP16, FP16], false, 1.0);
        let fits4 = sim.would_fit_replicas(4, 32, &[FP16, FP16], false, 1.0);
        assert!(fits1, "one replica fits the 0.1 GiB budget");
        assert!(!fits4, "four replicas' aggregate weights must not");
        assert_eq!(sim.replicas(), 1, "probe restores the live count");
        assert_eq!(sim.oom_events(), 0);
        assert_eq!(sim.peak_gb(), BASE_OVERHEAD_BYTES / GIB, "peak untouched");
    }

    #[test]
    fn replicated_speed_scales_sublinearly() {
        let e = toy_entry();
        let sm = SpeedModel::t4_like();
        let fl: Vec<usize> = e.layers.iter().map(|l| l.flops).collect();
        let codes = [FP32, FP32];
        let t1 = sm.step_seconds_replicated(96, &codes, &fl, 1);
        assert_eq!(
            t1.to_bits(),
            sm.step_seconds(96, &codes, &fl).to_bits(),
            "one replica is the plain model, bit-identically"
        );
        let t2 = sm.step_seconds_replicated(96, &codes, &fl, 2);
        let t4 = sm.step_seconds_replicated(96, &codes, &fl, 4);
        assert!(t2 < t1 && t4 < t2, "more replicas is faster");
        let c1 = t1 - sm.fixed_overhead_s;
        let c4 = t4 - sm.fixed_overhead_s;
        assert!(c4 > c1 / 4.0, "sync tax keeps the scaling sublinear");
    }

    #[test]
    fn speed_model_prefers_half() {
        let e = toy_entry();
        let sm = SpeedModel::t4_like();
        let fl: Vec<usize> = e.layers.iter().map(|l| l.flops).collect();
        let t32 = sm.step_seconds(96, &[FP32, FP32], &fl);
        let t16 = sm.step_seconds(96, &[FP16, FP16], &fl);
        assert!(t16 < t32);
        assert!(t32 < 1.0, "sane magnitude: {t32}");
    }
}
