//! The detlint rule set: D1–D6 line rules over scanned source.
//!
//! Each rule encodes one clause of the repo's determinism/safety
//! contract (`docs/DETERMINISM.md` carries the full table and
//! rationale). Rules match against the scanner's comment-stripped
//! `code` channel only, scoped by relative path, and are suppressed by
//! a justified `// detlint: allow(<rule>)` pragma (D4 additionally by
//! `// detlint: ordered`). The schema-drift rule D7 lives in
//! [`super::schema`] because it digests file contents instead of
//! matching lines.

use super::scan::SourceFile;

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (`d1`..`d7`, or `pragma` for malformed pragmas).
    pub rule: String,
    /// Path relative to the lint root.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// What is wrong and how to fix or justify it.
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

/// Static metadata for one rule (report/doc rendering).
pub struct RuleInfo {
    /// Rule id (`d1`..`d7`).
    pub id: &'static str,
    /// One-line title.
    pub title: &'static str,
    /// Where the rule applies.
    pub scope: &'static str,
}

/// The rule table, in id order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "d1",
        title: "no HashMap/HashSet in deterministic subsystems",
        scope: "runtime/ sched/ policy/ metrics/ checkpoint/ memsim/ (non-test)",
    },
    RuleInfo {
        id: "d2",
        title: "no wall-clock or host-environment reads outside the allowlist",
        scope: "all library code (non-test)",
    },
    RuleInfo {
        id: "d3",
        title: "no thread creation outside the deterministic pools",
        scope: "all library code except runtime/native/pool.rs and sched/mod.rs (non-test)",
    },
    RuleInfo {
        id: "d4",
        title: "float reductions must pin their order",
        scope: "runtime/native/ and data/ (tests included)",
    },
    RuleInfo {
        id: "d5",
        title: "every `unsafe` needs a `// SAFETY:` comment",
        scope: "all code (tests included)",
    },
    RuleInfo {
        id: "d6",
        title: "no unwrap()/expect() in library code",
        scope: "all library code (non-test)",
    },
    RuleInfo {
        id: "d7",
        title: "serialized schema drift requires a version bump",
        scope: "metrics/telemetry.rs and sched/ledger.rs field keys",
    },
];

/// Subsystems whose in-memory iteration order reaches artifacts.
const D1_DIRS: &[&str] = &["runtime/", "sched/", "policy/", "metrics/", "checkpoint/", "memsim/"];

/// Modules whose reductions feed golden traces and gradchecks.
const D4_DIRS: &[&str] = &["runtime/native/", "data/"];

/// Files allowed to create threads: the deterministic compute pool and
/// the scheduler's job pool (both reduce in fixed order).
const D3_ALLOWED: &[&str] = &["runtime/native/pool.rs", "sched/mod.rs"];

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d))
}

/// Order-independent reduction operators exempt from D4.
const D4_EXEMPT: &[&str] = &["f32::max", "f32::min", "f64::max", "f64::min"];

/// Infallible-by-construction idioms exempt from D6: a poisoned lock
/// means another thread already panicked (propagating the panic is the
/// correct response), and `try_into` on a length-checked slice cannot
/// fail.
const D6_EXEMPT: &[&str] = &[".lock().unwrap()", ".try_into().unwrap()"];

/// Run every line rule over one scanned file.
pub fn check_file(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (lineno, msg) in &sf.pragma_errors {
        out.push(finding(sf, "pragma", *lineno, msg));
    }
    for (i, line) in sf.lines.iter().enumerate() {
        let lineno = i + 1;
        let code = line.code.as_str();
        if code.trim().is_empty() {
            continue;
        }
        let allowed = |rule: &str| sf.file_allows.contains(rule) || line.allows.contains(rule);

        // D1 — nondeterministic-iteration collections.
        if !line.in_test
            && in_dirs(&sf.rel, D1_DIRS)
            && (code.contains("HashMap") || code.contains("HashSet"))
            && !allowed("d1")
        {
            out.push(finding(
                sf,
                "d1",
                lineno,
                "HashMap/HashSet iteration order is nondeterministic — use BTreeMap/BTreeSet \
                 in deterministic subsystems",
            ));
        }

        // D2 — wall-clock reads, plus host-environment reads: a
        // `/proc/` path is live machine state (RSS, MemTotal) and must
        // not feed deterministic paths. The code channel blanks string
        // literal contents, so the path is matched on the raw line;
        // comment-only lines never reach this point, and a prose
        // mention in a trailing comment does not count.
        // detlint: allow(d2) — the rule's own matcher must name the pattern
        let proc_read = sf.raw[i].contains("/proc/") && !line.comment.contains("/proc/");
        if !line.in_test
            && (code.contains("Instant::now") || code.contains("SystemTime") || proc_read)
            && !allowed("d2")
        {
            out.push(finding(
                sf,
                "d2",
                lineno,
                "wall-clock or host-environment read outside the timing allowlist — \
                 deterministic paths must not observe time or live machine state",
            ));
        }

        // D3 — thread creation.
        if !line.in_test
            && !D3_ALLOWED.contains(&sf.rel.as_str())
            && (code.contains("thread::spawn") || code.contains("thread::scope"))
            && !allowed("d3")
        {
            out.push(finding(
                sf,
                "d3",
                lineno,
                "thread creation outside the deterministic worker pools (pool.rs / sched) — \
                 ad-hoc threads break the ordered-reduction contract",
            ));
        }

        // D4 — unordered float reductions in kernel/hot-path modules.
        if in_dirs(&sf.rel, D4_DIRS)
            && is_reduction(code)
            && !D4_EXEMPT.iter().any(|p| code.contains(p))
            && !line.ordered
            && !allowed("d4")
        {
            out.push(finding(
                sf,
                "d4",
                lineno,
                "float reduction without a pinned order — state it with \
                 `// detlint: ordered — <order>`",
            ));
        }

        // D5 — unsafe without SAFETY. The comment may sit on the line
        // itself or anywhere in the contiguous comment block directly
        // above it (no blank or code line in between).
        if contains_word(code, "unsafe") && !allowed("d5") {
            let mut documented = line.comment.contains("SAFETY:");
            let mut j = i;
            while !documented && j > 0 {
                j -= 1;
                let above = &sf.lines[j];
                // A comment line has no code but nonblank raw text (a
                // bare `//` spacer counts); anything else ends the block.
                if !above.code.trim().is_empty() || sf.raw[j].trim().is_empty() {
                    break;
                }
                documented = above.comment.contains("SAFETY:");
            }
            if !documented {
                out.push(finding(
                    sf,
                    "d5",
                    lineno,
                    "`unsafe` without a `// SAFETY:` comment on or directly above the block",
                ));
            }
        }

        // D6 — unwrap/expect in library code.
        if !line.in_test && (code.contains(".unwrap()") || code.contains(".expect(")) {
            let exempt = D6_EXEMPT.iter().any(|p| code.contains(p));
            if !exempt && !allowed("d6") {
                out.push(finding(
                    sf,
                    "d6",
                    lineno,
                    "unwrap()/expect() in library code — propagate with anyhow \
                     (`?` / `.context(...)`)",
                ));
            }
        }
    }
    out
}

/// Reduction shapes D4 watches: iterator sums and folds over floats,
/// plus the SIMD fused-multiply-add intrinsics (each `fmadd` chains a
/// lane accumulator — the pragma must state the lane-order argument:
/// which axis the lanes span and why the per-element chain is pinned).
fn is_reduction(code: &str) -> bool {
    code.contains(".sum::<f32>()")
        || code.contains(".sum::<f64>()")
        || code.contains(".sum()")
        || code.contains(".fold(")
        || code.contains("_mm256_fmadd_ps(")
        || code.contains("vfmaq_f32(")
}

/// `needle` present as a standalone word (no identifier chars around).
fn contains_word(code: &str, needle: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(at) = code[from..].find(needle) {
        let start = from + at;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident(bytes[start - 1]);
        let post_ok = end == bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn finding(sf: &SourceFile, rule: &str, lineno: usize, message: &str) -> Finding {
    let snippet = sf
        .raw
        .get(lineno - 1)
        .map(|l| l.trim().chars().take(120).collect())
        .unwrap_or_default();
    Finding {
        rule: rule.to_string(),
        path: sf.rel.clone(),
        line: lineno,
        message: message.to_string(),
        snippet,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan_source;

    fn check(rel: &str, src: &str) -> Vec<Finding> {
        check_file(&scan_source(rel, src))
    }

    #[test]
    fn d1_scoped_to_deterministic_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(check("policy/x.rs", src).len(), 1);
        assert!(check("util/x.rs", src).is_empty(), "util is out of scope");
    }

    #[test]
    fn d5_safety_comment_block_must_be_contiguous() {
        let ok = "// SAFETY: prefix initialized\n// (multi-line)\n//\nunsafe { v.set_len(n) };\n";
        assert!(check("util/x.rs", ok).is_empty());
        let gap = "// SAFETY: detached by a blank line\n\nunsafe { v.set_len(n) };\n";
        assert_eq!(check("util/x.rs", gap).len(), 1);
        let code_between = "// SAFETY: detached by code\nlet a = 1;\nunsafe { v.set_len(n) };\n";
        assert_eq!(check("util/x.rs", code_between).len(), 1);
    }

    #[test]
    fn d2_flags_proc_reads_despite_literal_blanking() {
        let src = "let t = std::fs::read_to_string(\"/proc/self/statm\");\n";
        let hits = check("memsim/x.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "d2");
        let commented = "let a = 1; // docs mention /proc/meminfo\n";
        assert!(check("memsim/x.rs", commented).is_empty(), "prose mention in a comment");
        let pragma = "// detlint: allow(d2) — host meter by design\n\
                      let t = std::fs::read_to_string(\"/proc/self/statm\");\n";
        assert!(check("memsim/x.rs", pragma).is_empty(), "justified pragma suppresses");
    }

    #[test]
    fn d6_exempts_lock_and_try_into() {
        let src = "let g = m.lock().unwrap();\nlet a: [u8; 4] = b.try_into().unwrap();\n";
        assert!(check("util/x.rs", src).is_empty());
    }

    #[test]
    fn word_boundary_matching() {
        assert!(contains_word("unsafe { }", "unsafe"));
        assert!(!contains_word("unsafely()", "unsafe"));
        assert!(!contains_word("an_unsafe_name", "unsafe"));
    }
}
