//! The grid ledger: `runs/<grid-id>/ledger.json`.
//!
//! One ledger records one grid — its structure (cells, per-cell job
//! keys) plus one entry per *completed* job, keyed by the job key and
//! guarded by the (model-graph digest, method key, seed, config
//! fingerprint) quadruple. Rerunning the same grid command loads the
//! ledger, skips every recorded job, and re-aggregates the persisted
//! per-seed results — so a killed grid resumes mid-way and produces
//! bit-identical artifacts (aggregation reads the JSON-roundtripped
//! values in fixed job-key order, never the in-memory floats of
//! whichever jobs happened to run this time).
//!
//! Since schema 2 the file is JSONL: line 1 is a sealed `header`
//! record (grid structure), then one sealed `job` record per
//! completion, appended as jobs finish. "Sealed" means every record
//! carries a `crc` — an FNV-1a-64 digest of its own serialization
//! without the `crc` key (recomputable exactly because
//! [`Json::to_string_compact`] is deterministic). Appends go through
//! the [`ArtifactIo`] seam, so crash-recovery is tested against
//! injected torn and failed writes (`docs/FAULTS.md`); a torn tail is
//! detected by the checksum on load and dropped, costing exactly the
//! affected job(s) instead of the grid. Format reference:
//! `docs/TELEMETRY.md`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::checkpoint::fnv1a;
use crate::faults::ArtifactIo;
use crate::harness::SeedResult;
use crate::util::json::Json;

use super::{GridSpec, Job};

/// Ledger format version (`"schema"` in `ledger.json`). Bump only on
/// breaking changes; additive fields keep the version. Version 2 is
/// the sealed-JSONL format (v1 was a single atomically-rewritten JSON
/// document without per-record checksums).
pub const LEDGER_SCHEMA_VERSION: u64 = 2;

/// One completed job: identity quadruple + persisted result.
#[derive(Debug, Clone)]
pub struct LedgerEntry {
    /// Job key (`<cell>_<model>_<method>_s<seed>`).
    pub key: String,
    /// Manifest model key.
    pub model: String,
    /// Effective method key ([`crate::policy::registry::effective_key`]).
    pub method_key: String,
    /// Training seed.
    pub seed: u64,
    /// Model-graph digest ([`crate::manifest::ModelEntry::digest`]).
    pub digest: u64,
    /// Config fingerprint ([`crate::config::Config::fingerprint`]).
    pub config_hash: u64,
    /// The persisted per-seed result.
    pub result: SeedResult,
    /// Wall-clock seconds the job took (informational; the one field
    /// that differs across reruns and is never rendered into the
    /// deterministic artifacts).
    pub wall_s: f64,
}

/// One grid cell's structure: which jobs aggregate into which row.
#[derive(Debug, Clone)]
pub struct CellMeta {
    /// Manifest model key.
    pub model: String,
    /// Row label (Table-1 method name / Table-2 configuration).
    pub label: String,
    /// Effective method key.
    pub method_key: String,
    /// Budget trace spec the cell ran under (`const` outside pressure).
    pub trace: String,
    /// Seeds, normalized (sorted, deduplicated).
    pub seeds: Vec<u64>,
    /// Job keys in aggregation order (one per seed).
    pub job_keys: Vec<String>,
}

/// The grid ledger: structure + completed-job entries.
#[derive(Debug, Clone)]
pub struct Ledger {
    /// Format version of the loaded/created file.
    pub schema: u64,
    /// Content-derived grid id (also the directory name).
    pub grid_id: String,
    /// Grid kind (`table1`/`table2`/`fig`/`pressure`).
    pub kind: String,
    /// Cell structure in presentation/aggregation order.
    pub cells: Vec<CellMeta>,
    /// Completed jobs by job key.
    pub entries: BTreeMap<String, LedgerEntry>,
}

/// Relaxed load outcome ([`Ledger::load_relaxed`]): recovery callers
/// (grid resume) decide how much damage is survivable.
pub enum Loaded {
    /// The header parsed and sealed correctly. `dropped` counts
    /// invalid/torn trailing job records that were discarded — their
    /// jobs simply rerun.
    Usable {
        /// The recovered ledger (valid prefix of the file).
        ledger: Ledger,
        /// Discarded trailing record count (0 on a clean file).
        dropped: usize,
    },
    /// The header line itself is unreadable (empty file, torn first
    /// line, or a pre-v2 document): nothing is recoverable.
    Corrupt {
        /// Human-readable diagnosis.
        reason: String,
    },
}

fn hex_u64(j: &Json, key: &str) -> Result<u64> {
    let s = j.req(key)?.as_str().with_context(|| format!("ledger `{key}` not a string"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("ledger `{key}`: bad hex `{s}`"))
}

/// Seal a record in place: set `crc` to the FNV-1a-64 digest of the
/// record's compact serialization without the `crc` key.
fn seal(m: &mut BTreeMap<String, Json>) {
    m.remove("crc");
    let unsealed = Json::Obj(m.clone()).to_string_compact();
    m.insert("crc".into(), Json::Str(format!("{:016x}", fnv1a(unsealed.as_bytes()))));
}

/// Verify a record's seal: recompute the digest over the record minus
/// `crc` and compare. A record without `crc` never verifies.
fn seal_ok(m: &BTreeMap<String, Json>) -> bool {
    let Some(stored) = m.get("crc").and_then(Json::as_str) else {
        return false;
    };
    let mut unsealed = m.clone();
    unsealed.remove("crc");
    let crc = fnv1a(Json::Obj(unsealed).to_string_compact().as_bytes());
    stored == format!("{crc:016x}")
}

impl Ledger {
    /// Fresh ledger for a grid about to run (no completed jobs yet).
    pub fn new(grid_id: &str, spec: &GridSpec, jobs: &[Job]) -> Ledger {
        let mut cells = Vec::with_capacity(spec.cells.len());
        for (ci, c) in spec.cells.iter().enumerate() {
            cells.push(CellMeta {
                model: c.model_key.clone(),
                label: c.label.clone(),
                method_key: c.method_key.clone(),
                trace: c.base.mem_trace.clone(),
                seeds: c.seeds.clone(),
                job_keys: jobs
                    .iter()
                    .filter(|j| j.cell == ci)
                    .map(|j| j.key.clone())
                    .collect(),
            });
        }
        Ledger {
            schema: LEDGER_SCHEMA_VERSION,
            grid_id: grid_id.to_string(),
            kind: spec.kind.name().to_string(),
            cells,
            entries: BTreeMap::new(),
        }
    }

    /// Has this job already completed?
    pub fn is_done(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    /// Record one completed job.
    pub fn insert(&mut self, entry: LedgerEntry) {
        self.entries.insert(entry.key.clone(), entry);
    }

    /// Check a loaded ledger against the jobs the current command
    /// expects: same grid id, and every recorded entry must match its
    /// job's digest + config fingerprint. A mismatch means the code or
    /// config changed under an existing grid directory — stale results
    /// must never be silently re-aggregated.
    pub fn validate_against(&self, grid_id: &str, jobs: &[Job]) -> Result<()> {
        anyhow::ensure!(
            self.grid_id == grid_id,
            "ledger grid id `{}` does not match this command (`{grid_id}`) — \
             delete the grid directory to start over",
            self.grid_id
        );
        let by_key: BTreeMap<&str, &Job> =
            jobs.iter().map(|j| (j.key.as_str(), j)).collect();
        for (key, e) in &self.entries {
            let job = by_key.get(key.as_str()).with_context(|| {
                format!("ledger records job `{key}` which this grid does not contain")
            })?;
            anyhow::ensure!(
                e.digest == job.digest && e.config_hash == job.config_hash,
                "ledger entry `{key}` was produced by a different model/config \
                 (digest {:016x} vs {:016x}, config {:016x} vs {:016x}) — \
                 delete the grid directory to rerun",
                e.digest,
                job.digest,
                e.config_hash,
                job.config_hash
            );
        }
        Ok(())
    }

    /// Per-cell seed results in canonical (cell, job-key) order.
    /// Errors if any cell's job is missing — callers resume the grid
    /// first, then aggregate.
    pub fn cell_results(&self) -> Result<Vec<Vec<SeedResult>>> {
        let mut out = Vec::with_capacity(self.cells.len());
        for c in &self.cells {
            let mut rs = Vec::with_capacity(c.job_keys.len());
            for k in &c.job_keys {
                let e = self.entries.get(k).with_context(|| {
                    format!(
                        "grid incomplete: job `{k}` has no ledger entry — \
                         rerun the grid command to resume"
                    )
                })?;
                rs.push(e.result.clone());
            }
            out.push(rs);
        }
        Ok(out)
    }

    /// The sealed header record (line 1 of the file).
    fn header_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("record".into(), Json::Str("header".to_string()));
        root.insert("schema".into(), Json::Num(self.schema as f64));
        root.insert("grid_id".into(), Json::Str(self.grid_id.clone()));
        root.insert("kind".into(), Json::Str(self.kind.clone()));
        root.insert(
            "cells".into(),
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("model".into(), Json::Str(c.model.clone()));
                        m.insert("label".into(), Json::Str(c.label.clone()));
                        m.insert("method_key".into(), Json::Str(c.method_key.clone()));
                        m.insert("trace".into(), Json::Str(c.trace.clone()));
                        // Decimal strings, not JSON numbers: u64 seeds
                        // past 2^53 must survive the round trip.
                        m.insert(
                            "seeds".into(),
                            Json::Arr(
                                c.seeds.iter().map(|s| Json::Str(s.to_string())).collect(),
                            ),
                        );
                        m.insert(
                            "job_keys".into(),
                            Json::Arr(
                                c.job_keys.iter().map(|k| Json::Str(k.clone())).collect(),
                            ),
                        );
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        seal(&mut root);
        Json::Obj(root)
    }

    /// One sealed `job` record (a completion line).
    fn entry_json(e: &LedgerEntry) -> Json {
        let mut m = BTreeMap::new();
        m.insert("record".into(), Json::Str("job".to_string()));
        m.insert("key".into(), Json::Str(e.key.clone()));
        m.insert("model".into(), Json::Str(e.model.clone()));
        m.insert("method_key".into(), Json::Str(e.method_key.clone()));
        m.insert("seed".into(), Json::Str(e.seed.to_string()));
        m.insert("digest".into(), Json::Str(format!("{:016x}", e.digest)));
        m.insert("config_hash".into(), Json::Str(format!("{:016x}", e.config_hash)));
        m.insert("wall_s".into(), Json::Num(e.wall_s));
        m.insert("result".into(), e.result.to_json());
        seal(&mut m);
        Json::Obj(m)
    }

    /// Serialize the whole ledger as sealed JSONL (header + entries in
    /// job-key order).
    pub fn to_jsonl(&self) -> String {
        let mut out = self.header_json().to_string_compact();
        out.push('\n');
        for e in self.entries.values() {
            out.push_str(&Self::entry_json(e).to_string_compact());
            out.push('\n');
        }
        out
    }

    fn parse_header(line: &str) -> Result<Ledger> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("header line: {e}"))?;
        let m = j.as_obj().context("header line not an object")?;
        let record = j.get("record").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(
            record == "header",
            "first line is not a `header` record (a pre-v2 ledger is rebuilt from scratch)"
        );
        anyhow::ensure!(seal_ok(m), "header record failed its checksum");
        let schema = j.req("schema")?.as_i64().context("ledger schema")? as u64;
        anyhow::ensure!(
            schema == LEDGER_SCHEMA_VERSION,
            "unsupported ledger schema {schema} (this build reads {LEDGER_SCHEMA_VERSION})"
        );
        let grid_id = j.req("grid_id")?.as_str().context("ledger grid_id")?.to_string();
        let kind = j.req("kind")?.as_str().context("ledger kind")?.to_string();
        let mut cells = Vec::new();
        for c in j.req("cells")?.as_arr().context("ledger cells")? {
            cells.push(CellMeta {
                model: c.req("model")?.as_str().context("cell model")?.to_string(),
                label: c.req("label")?.as_str().context("cell label")?.to_string(),
                method_key: c
                    .req("method_key")?
                    .as_str()
                    .context("cell method_key")?
                    .to_string(),
                trace: c.req("trace")?.as_str().context("cell trace")?.to_string(),
                seeds: c
                    .req("seeds")?
                    .as_arr()
                    .context("cell seeds")?
                    .iter()
                    .map(|s| -> Result<u64> {
                        s.as_str()
                            .context("cell seed not a string")?
                            .parse()
                            .context("cell seed not a u64")
                    })
                    .collect::<Result<_>>()?,
                job_keys: c
                    .req("job_keys")?
                    .as_arr()
                    .context("cell job_keys")?
                    .iter()
                    .map(|k| k.as_str().map(str::to_string).context("cell job key"))
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Ledger { schema, grid_id, kind, cells, entries: BTreeMap::new() })
    }

    fn parse_entry(line: &str) -> Result<LedgerEntry> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("job line: {e}"))?;
        let m = j.as_obj().context("job line not an object")?;
        anyhow::ensure!(
            j.get("record").and_then(Json::as_str) == Some("job"),
            "not a `job` record"
        );
        anyhow::ensure!(seal_ok(m), "job record failed its checksum");
        let key = j.req("key")?.as_str().context("job key")?.to_string();
        Ok(LedgerEntry {
            key: key.clone(),
            model: j.req("model")?.as_str().context("job model")?.to_string(),
            method_key: j
                .req("method_key")?
                .as_str()
                .context("job method_key")?
                .to_string(),
            seed: j
                .req("seed")?
                .as_str()
                .context("job seed not a string")?
                .parse()
                .context("job seed not a u64")?,
            digest: hex_u64(&j, "digest")?,
            config_hash: hex_u64(&j, "config_hash")?,
            wall_s: j.req("wall_s")?.as_f64().context("job wall_s")?,
            result: SeedResult::from_json(j.req("result")?)
                .with_context(|| format!("job `{key}` result"))?,
        })
    }

    /// Load with crash recovery: parse the valid sealed prefix of the
    /// file and report — rather than fail on — damage a mid-write kill
    /// can cause. A torn, truncated, or checksum-failing record ends
    /// the prefix; it and everything after it is counted in `dropped`
    /// (truncation only ever damages the tail, so later lines cannot
    /// be trusted more than the first bad one). Duplicate job keys
    /// keep the last record. Errors only if the file cannot be read at
    /// all.
    pub fn load_relaxed(path: &Path) -> Result<Loaded> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut lines = text.lines();
        let Some(first) = lines.next() else {
            return Ok(Loaded::Corrupt { reason: "empty ledger file".to_string() });
        };
        let mut ledger = match Self::parse_header(first) {
            Ok(l) => l,
            Err(e) => return Ok(Loaded::Corrupt { reason: format!("{e:#}") }),
        };
        let mut dropped = 0usize;
        let mut tail_bad = false;
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            if tail_bad {
                dropped += 1;
                continue;
            }
            match Self::parse_entry(line) {
                Ok(e) => ledger.insert(e),
                Err(_) => {
                    tail_bad = true;
                    dropped += 1;
                }
            }
        }
        Ok(Loaded::Usable { ledger, dropped })
    }

    /// Load a ledger file, warning (not failing) about a recoverable
    /// torn tail — the affected jobs rerun on resume. Errors if the
    /// header itself is unreadable; grid resume treats that case as
    /// "no ledger" and rebuilds, while read-only consumers surface it.
    pub fn load(path: &Path) -> Result<Ledger> {
        match Self::load_relaxed(path)? {
            Loaded::Usable { ledger, dropped } => {
                if dropped > 0 {
                    eprintln!(
                        "warning: {}: dropped {dropped} torn/invalid trailing record(s) — \
                         the affected job(s) rerun on resume",
                        path.display()
                    );
                }
                Ok(ledger)
            }
            Loaded::Corrupt { reason } => Err(anyhow::anyhow!(
                "{}: {reason} — rerun the grid command to rebuild, or delete the grid \
                 directory to start over",
                path.display()
            )),
        }
    }

    /// Rewrite the whole file atomically (temp + rename) through the
    /// artifact-IO seam. Used at grid creation, when healing a torn
    /// tail, and as the fallback when [`Self::append_entry`] fails.
    pub fn save(&self, path: &Path, io: &dyn ArtifactIo) -> Result<()> {
        io.write_atomic(path, &self.to_jsonl())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Append one sealed completion record. The fast path after each
    /// job: O(1) in grid size, and a kill mid-append costs at most
    /// this one record (the checksum catches the torn line on load).
    pub fn append_entry(entry: &LedgerEntry, path: &Path, io: &dyn ArtifactIo) -> Result<()> {
        let mut line = Self::entry_json(entry).to_string_compact();
        line.push('\n');
        io.append(path, &line)
            .with_context(|| format!("appending job `{}` to {}", entry.key, path.display()))
    }
}
