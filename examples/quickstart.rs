//! Quickstart: train a small CNN on (synthetic) CIFAR-10 with the full
//! Tri-Accel loop and print what the controller is doing.
//!
//!     cargo run --release --example quickstart
//!
//! Runs hermetically on the native backend (`tiny_cnn_c10`, built-in
//! manifest — no artifacts, no Python) in ~a minute on CPU.

use anyhow::Result;

use tri_accel::config::{Config, Method};
use tri_accel::manifest::precision_name;
use tri_accel::policy::{BatchPolicy, PrecisionPolicy};
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

fn main() -> Result<()> {
    let engine = Engine::native();
    println!("backend: {}", engine.platform());

    // The full adaptive method on a laptop-scale budget.
    let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 0);
    cfg.epochs = 3;
    cfg.steps_per_epoch = Some(40);
    cfg.train_examples = 4096;
    cfg.eval_examples = 512;
    cfg.batch_init = 32;
    cfg.t_ctrl = 10;
    cfg.t_curv = 20;
    cfg.warmup_epochs = 1;
    cfg.mem_budget_gb = 0.06; // tight budget so the elastic controller works

    let mut tr = Trainer::new(&engine, cfg)?;
    println!(
        "model: {} layers, buckets {:?}",
        tr.session.num_layers(),
        tr.controller.batch.ladder()
    );

    for epoch in 0..3 {
        let r = tr.run_epoch(epoch)?;
        let codes = tr.controller.codes();
        let names: Vec<&str> = codes.iter().map(|&c| precision_name(c)).collect();
        println!(
            "epoch {}  train_loss {:.4}  test_acc {:.1}%  peak {:.4}GB  B̄ {:.0}  codes {:?}",
            r.epoch, r.train_loss, r.test_acc, r.peak_vram_gb, r.mean_batch, names
        );
    }

    let s = tr.summary();
    println!(
        "\nsummary: acc {:.2}%  modeled {:.3}s/epoch  wall {:.2}s/epoch  peak {:.4}GB  eff-score {:.2}",
        s.test_acc_pct, s.modeled_s_per_epoch, s.wall_s_per_epoch, s.peak_vram_gb, s.eff_score
    );
    println!(
        "controller: {} precision transitions, {} promotions, {} batch decisions, {} OOM events",
        tr.controller.precision.transitions(),
        tr.metrics.promotions,
        tr.controller.batch.decisions(),
        tr.metrics.oom_events
    );
    Ok(())
}
