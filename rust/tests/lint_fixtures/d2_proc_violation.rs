fn resident_pages() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/statm").ok()?;
    text.split_whitespace().nth(1)?.parse().ok()
}
