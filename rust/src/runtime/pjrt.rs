//! PJRT/XLA backend (`--features pjrt`) — loads the AOT artifacts (HLO
//! text lowered by `make artifacts`) and executes them on the CPU PJRT
//! client. This is the only module that touches the external `xla`
//! crate; enabling the feature requires *adding* that crate to
//! `[dependencies]` (vendored path or git dep — it is intentionally
//! undeclared so the hermetic default build never resolves it; see
//! README "Backends").
//!
//! Executables are compiled on first use and cached by (model key,
//! artifact name): the batch-bucket ladder means the elastic controller
//! can request a new bucket mid-run and pay the compile exactly once.
//!
//! The backend uploads the session's host state to device literals per
//! call and downloads the outputs — simple and correct; a
//! device-resident state cache is a later optimization once a real
//! accelerator backend lands.

// detlint: allow-file(d1, d6) — feature-gated PJRT shim, outside the
// determinism contract: the HashMap is a compile-cache keyed by lookup
// (never iterated into artifacts), and the unwraps sit on xla-crate
// invariants the artifact contract upholds. The hermetic default build
// never compiles this module.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::backend::{Backend, ModelState};
use super::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::manifest::{Manifest, ModelEntry};

pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Fetch (compile-on-miss) the executable for `entry`'s artifact.
    fn executable(&self, entry: &ModelEntry, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}::{}", entry.key, name);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(entry, name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn batch_literals(batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let x = xla::Literal::vec1(&batch.x).reshape(&[batch.n as i64, 32, 32, 3])?;
        let y = xla::Literal::vec1(&batch.y);
        Ok((x, y))
    }

    fn tensor_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Execute with borrowed literals and flatten the single tuple result.
    fn run_refs(
        exe: &xla::PjRtLoadedExecutable,
        refs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<&xla::Literal>(refs)?;
        anyhow::ensure!(out.len() == 1 && out[0].len() == 1, "expected 1x1 output");
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }

    fn supports(&self, entry: &ModelEntry) -> bool {
        entry.artifacts.contains_key("init")
    }

    fn init(&self, entry: &ModelEntry, seed: i32) -> Result<ModelState> {
        let exe = self.executable(entry, "init")?;
        let seed_lit = xla::Literal::scalar(seed);
        let outs = Self::run_refs(&exe, &[&seed_lit])?;
        let n = entry.params.len();
        let s = entry.state_shapes.len();
        anyhow::ensure!(outs.len() == n + s, "init output arity {} != {}", outs.len(), n + s);
        let mut params = Vec::with_capacity(n);
        let mut state = Vec::with_capacity(s);
        for (i, lit) in outs.into_iter().enumerate() {
            let v = lit.to_vec::<f32>()?;
            if i < n {
                params.push(v);
            } else {
                state.push(v);
            }
        }
        let mom = entry.params.iter().map(|p| vec![0f32; p.elems]).collect();
        Ok(ModelState { params, mom, state })
    }

    fn train_step(
        &self,
        entry: &ModelEntry,
        st: &mut ModelState,
        batch: &Batch,
        ctrl: &StepCtrl,
    ) -> Result<TrainOutputs> {
        let exe = self.executable(entry, &format!("train_b{}", batch.n))?;
        let (x, y) = Self::batch_literals(batch)?;
        let mut holders: Vec<xla::Literal> = Vec::new();
        for (p, spec) in st.params.iter().zip(&entry.params) {
            holders.push(Self::tensor_literal(p, &spec.shape)?);
        }
        for (m, spec) in st.mom.iter().zip(&entry.params) {
            holders.push(Self::tensor_literal(m, &spec.shape)?);
        }
        for (s, shape) in st.state.iter().zip(&entry.state_shapes) {
            holders.push(Self::tensor_literal(s, shape)?);
        }
        let codes = xla::Literal::vec1(&ctrl.codes);
        let lr_scales = xla::Literal::vec1(&ctrl.lr_scales);
        let lr = xla::Literal::scalar(ctrl.lr);
        let ls = xla::Literal::scalar(ctrl.loss_scale);
        let wd = xla::Literal::scalar(ctrl.weight_decay);
        let mut refs: Vec<&xla::Literal> = holders.iter().collect();
        refs.push(&x);
        refs.push(&y);
        refs.push(&codes);
        refs.push(&lr_scales);
        refs.push(&lr);
        refs.push(&ls);
        refs.push(&wd);
        let outs = Self::run_refs(&exe, &refs)?;
        let n = entry.params.len();
        let s = entry.state_shapes.len();
        anyhow::ensure!(outs.len() == 2 * n + s + 5, "train output arity {}", outs.len());
        let mut it = outs.into_iter();
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            params.push(it.next().unwrap().to_vec::<f32>()?);
        }
        let mut mom = Vec::with_capacity(n);
        for _ in 0..n {
            mom.push(it.next().unwrap().to_vec::<f32>()?);
        }
        let mut state = Vec::with_capacity(s);
        for _ in 0..s {
            state.push(it.next().unwrap().to_vec::<f32>()?);
        }
        let loss = it.next().unwrap().get_first_element::<f32>()?;
        let correct = it.next().unwrap().get_first_element::<i32>()? as i64;
        let grad_var = it.next().unwrap().to_vec::<f32>()?;
        let grad_norm = it.next().unwrap().to_vec::<f32>()?;
        let overflow = it.next().unwrap().get_first_element::<i32>()? != 0;
        st.params = params;
        st.mom = mom;
        st.state = state;
        Ok(TrainOutputs { loss, correct, grad_var, grad_norm, overflow })
    }

    fn eval_batch(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        codes: &[i32],
    ) -> Result<EvalResult> {
        let exe = self.executable(entry, &format!("eval_b{}", batch.n))?;
        let (x, y) = Self::batch_literals(batch)?;
        let mut holders: Vec<xla::Literal> = Vec::new();
        for (p, spec) in st.params.iter().zip(&entry.params) {
            holders.push(Self::tensor_literal(p, &spec.shape)?);
        }
        for (s, shape) in st.state.iter().zip(&entry.state_shapes) {
            holders.push(Self::tensor_literal(s, shape)?);
        }
        let codes_l = xla::Literal::vec1(codes);
        let mut refs: Vec<&xla::Literal> = holders.iter().collect();
        refs.push(&x);
        refs.push(&y);
        refs.push(&codes_l);
        let outs = Self::run_refs(&exe, &refs)?;
        anyhow::ensure!(outs.len() == 2, "eval output arity");
        Ok(EvalResult {
            loss: outs[0].get_first_element::<f32>()?,
            correct: outs[1].get_first_element::<i32>()? as i64,
            total: batch.n,
        })
    }

    fn curv_step(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        probes: &mut [Vec<f32>],
        codes: &[i32],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(entry, "curv")?;
        let (x, y) = Self::batch_literals(batch)?;
        let mut holders: Vec<xla::Literal> = Vec::new();
        for (p, spec) in st.params.iter().zip(&entry.params) {
            holders.push(Self::tensor_literal(p, &spec.shape)?);
        }
        for (s, shape) in st.state.iter().zip(&entry.state_shapes) {
            holders.push(Self::tensor_literal(s, shape)?);
        }
        let head = holders.len();
        let mut refs: Vec<&xla::Literal> = holders[..head].iter().collect();
        let (xr, yr) = (&x, &y);
        refs.push(xr);
        refs.push(yr);
        let probe_lits: Vec<xla::Literal> = probes
            .iter()
            .zip(&entry.params)
            .map(|(u, spec)| Self::tensor_literal(u, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        for lit in probe_lits.iter() {
            refs.push(lit);
        }
        let codes_l = xla::Literal::vec1(codes);
        refs.push(&codes_l);
        let outs = Self::run_refs(&exe, &refs)?;
        let n = entry.params.len();
        anyhow::ensure!(outs.len() == n + 1, "curv output arity");
        let mut it = outs.into_iter();
        for u in probes.iter_mut() {
            *u = it.next().unwrap().to_vec::<f32>()?;
        }
        let lambdas = it.next().unwrap().to_vec::<f32>()?;
        Ok(lambdas)
    }
}
