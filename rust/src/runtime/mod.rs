//! PJRT runtime — loads the AOT artifacts (HLO text) and executes them on
//! the CPU PJRT client. This is the only module that touches the `xla`
//! crate; everything above it deals in plain `f32` host vectors.
//!
//! Python never runs here: the artifacts were lowered once at build time
//! (`make artifacts`) and the binary is self-contained afterwards.

mod engine;
mod session;

pub use engine::Engine;
pub use session::{Batch, EvalResult, Session, StepCtrl, TrainOutputs};
