//! PJRT client + lazy executable cache.
//!
//! Executables are compiled on first use and cached by (model key,
//! artifact name) — the batch-bucket ladder means the elastic controller
//! can request a new bucket mid-run and pay the compile exactly once
//! (mirrors Triton's per-shape JIT cache in the paper's stack).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::manifest::{Manifest, ModelEntry};

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    compile_log: RefCell<Vec<(String, f64)>>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compile-on-miss) the executable for `entry`'s artifact
    /// `name` (e.g. "train_b96", "eval_b128", "curv", "init").
    pub fn executable(
        &self,
        entry: &ModelEntry,
        name: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}::{}", entry.key, name);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(entry, name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((key.clone(), dt));
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// True if the executable is already compiled (used by the batch
    /// controller to prefer warm buckets when latency matters).
    pub fn is_warm(&self, entry: &ModelEntry, name: &str) -> bool {
        self.cache
            .borrow()
            .contains_key(&format!("{}::{}", entry.key, name))
    }

    /// (artifact, seconds) pairs for every compile performed so far.
    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.borrow().clone()
    }

    /// Run a compiled executable over host literals and flatten the
    /// single tuple result into its leaves.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(inputs)?;
        anyhow::ensure!(
            out.len() == 1 && out[0].len() == 1,
            "expected single tuple output, got {}x{}",
            out.len(),
            out.first().map(|v| v.len()).unwrap_or(0)
        );
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}
