//! Memory-elastic batch scaling demo (paper §3.3): run the same model
//! under three VRAM budgets and watch B(t) find the largest batch that
//! fits — including the OOM-avoidance path when the budget is so tight
//! the initial batch doesn't fit at all.
//!
//!     cargo run --release --example elastic_demo

use anyhow::Result;

use tri_accel::config::{Config, Method};
use tri_accel::memsim::MemoryMonitor;
use tri_accel::policy::BatchPolicy;
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

fn main() -> Result<()> {
    let engine = Engine::native();

    for &(label, budget_gb) in
        &[("roomy", 0.500f64), ("paper-like", 0.065), ("starved", 0.050)]
    {
        let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 0);
        cfg.epochs = 2;
        cfg.steps_per_epoch = Some(60);
        cfg.train_examples = 4096;
        cfg.eval_examples = 256;
        cfg.batch_init = 32;
        cfg.t_ctrl = 5;
        cfg.batch_cooldown = 5;
        cfg.t_curv = 0; // isolate the batch controller
        cfg.warmup_epochs = 1;
        cfg.mem_budget_gb = budget_gb;

        let mut tr = Trainer::new(&engine, cfg)?;
        for e in 0..2 {
            tr.run_epoch(e)?;
        }
        let trace: Vec<String> = tr
            .metrics
            .batch_trace
            .iter()
            .map(|(s, b)| format!("@{s}→{b}"))
            .collect();
        println!(
            "budget {:>9} ({:.3}GB): peak {:.4}GB  util {:>5.1}%  ladder decisions {}  OOM {}  trace [{}]",
            label,
            budget_gb,
            tr.memsim.peak_gb(),
            100.0 * tr.memsim.peak_gb() / tr.memsim.mem_max_gb(),
            tr.controller.batch.decisions(),
            tr.metrics.oom_events,
            trace.join(" ")
        );
    }

    println!("\nThe controller grows B under roomy budgets, holds near the");
    println!("utilization band in the paper-like case, and shrinks (without");
    println!("crashing) when starved — the §3.3 feedback behaviour.");
    Ok(())
}
