"""L2 init graph — seed-parameterized parameter initialization.

Lowered once per (model, classes); the Rust runtime executes it with a
runtime seed scalar to materialize (params, state) device-side for each of
the 3-seed protocol's runs. This keeps weight blobs out of the artifact
set entirely — initialization is itself an XLA computation (threefry).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models import common as C


def make_init(model_builder, num_classes: int, forward_factory):
    """Returns init(seed: i32[]) -> (params..., state...)."""

    forward = forward_factory(num_classes)

    def init(seed):
        store = C.Store(rng=jax.random.PRNGKey(seed), train=True)
        x = jnp.zeros((1, 32, 32, 3), jnp.float32)
        forward(store, x)
        return tuple(store.params), tuple(store.state_in)

    return init


def example_args():
    return (jax.ShapeDtypeStruct((), jnp.int32),)
