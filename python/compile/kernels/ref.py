"""Pure-jnp reference oracle for every L1 Pallas kernel.

These are the ground-truth semantics the Pallas kernels must match
bit-for-bit (modulo fp32 accumulation order, tested with allclose).
The Rust side never sees this file; it exists so pytest + hypothesis can
pin the kernels down before AOT lowering.

Precision codes (shared contract with the Rust coordinator — see
rust/src/coordinator/precision.rs and artifacts/manifest.json):
    0 = FP16, 1 = BF16, 2 = FP32.
"""

from __future__ import annotations

import jax.numpy as jnp

# Precision-code contract. Keep in sync with rust/src/coordinator/precision.rs.
FP16 = 0
BF16 = 1
FP32 = 2
PRECISION_NAMES = {FP16: "fp16", BF16: "bf16", FP32: "fp32"}

# Bytes per element charged by the memory model for each code.
PRECISION_BYTES = {FP16: 2, BF16: 2, FP32: 4}


def qdq_ref(x: jnp.ndarray, code) -> jnp.ndarray:
    """Quantize-dequantize `x` (f32) through the precision named by `code`.

    FP16 models IEEE half: overflow saturates to inf, subnormals flush per
    the hardware convert; BF16 is round-to-nearest-even on the top 16 bits.
    FP32 is the identity. `code` may be a traced scalar.
    """
    x = x.astype(jnp.float32)
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    b16 = x.astype(jnp.bfloat16).astype(jnp.float32)
    code = jnp.asarray(code, dtype=jnp.int32)
    return jnp.where(code == FP16, f16, jnp.where(code == BF16, b16, x))


def mp_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, code) -> jnp.ndarray:
    """Mixed-precision matmul: inputs rounded to `code`, fp32 accumulate."""
    xq = qdq_ref(x, code)
    wq = qdq_ref(w, code)
    return jnp.matmul(xq, wq, preferred_element_type=jnp.float32)


def grad_stats_ref(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(mean, biased variance) over all elements of `g`, fp32."""
    g = g.astype(jnp.float32).reshape(-1)
    mean = jnp.mean(g)
    var = jnp.mean(jnp.square(g)) - jnp.square(mean)
    # Clamp tiny negative round-off so downstream log/thresholds are safe.
    return mean, jnp.maximum(var, 0.0)


SGD_MOMENTUM = 0.9


def sgd_update_ref(p, m, g, lr_eff, wd, apply_mask):
    """Fused SGD+momentum update (see kernels/sgd_update.py).

    g_eff = (g + wd·p)·mask;  m' = μ·m + g_eff (held when mask=0);
    p' = p − lr_eff·mask·m'.
    """
    p = p.astype(jnp.float32)
    m = m.astype(jnp.float32)
    g = g.astype(jnp.float32)
    lr_eff = jnp.asarray(lr_eff, jnp.float32)
    wd = jnp.asarray(wd, jnp.float32)
    apply_mask = jnp.asarray(apply_mask, jnp.float32)
    g_eff = (g + wd * p) * apply_mask
    m_new = SGD_MOMENTUM * m + g_eff
    m_out = jnp.where(apply_mask > 0.5, m_new, m)
    p_out = p - lr_eff * apply_mask * m_out
    return p_out, m_out


def sr_qdq_ref(x: jnp.ndarray, noise: jnp.ndarray, code) -> jnp.ndarray:
    """Stochastic-rounding qdq (paper §4.5 extension).

    `noise` is uniform [0,1) of x's shape. For BF16 we round down/up to the
    two nearest representable values with probability proportional to the
    distance to each; FP16 falls back to round-to-nearest (the hardware
    convert); FP32 passes through.
    """
    x = x.astype(jnp.float32)
    noise = noise.astype(jnp.float32)
    code = jnp.asarray(code, dtype=jnp.int32)

    # Stochastic rounding to bf16: truncate mantissa to get the lower
    # representable value, add one bf16-ULP for the upper, pick by noise.
    bits = x.view(jnp.uint32)
    lo_bits = bits & jnp.uint32(0xFFFF0000)
    lo = lo_bits.view(jnp.float32)
    hi = (lo_bits + jnp.uint32(0x00010000)).view(jnp.float32)
    span = hi - lo
    frac = jnp.where(span != 0, (x - lo) / jnp.where(span != 0, span, 1.0), 0.0)
    sr_b16 = jnp.where(noise < frac, hi, lo)
    # Exactly-representable values and non-finite inputs pass through.
    sr_b16 = jnp.where(jnp.isfinite(x), sr_b16, x)

    f16 = x.astype(jnp.float16).astype(jnp.float32)
    return jnp.where(code == FP16, f16, jnp.where(code == BF16, sr_b16, x))
