//! Integration tests over the runtime layer: manifest → engine →
//! session, exercising the native reference backend end-to-end.
//! Hermetic: no artifacts, no Python — `Engine::native()` serves the
//! built-in manifest. Uses `tiny_cnn_c10`, the CI-speed model.

use tri_accel::data::{synthetic::SyntheticCifar, BatchIter, Dataset};
use tri_accel::manifest::{BF16, FP16, FP32};
use tri_accel::runtime::{Engine, Session, StepCtrl};

fn engine() -> Engine {
    Engine::native()
}

fn batch(n: usize, seed: u64) -> tri_accel::runtime::Batch {
    let ds = SyntheticCifar::new(10, 512, true, seed);
    BatchIter::new(Box::new(ds), seed, false).next_batch(n).unwrap()
}

#[test]
fn builtin_manifest_lists_models() {
    let e = engine();
    for key in ["tiny_cnn_c10", "tiny_cnn_c100"] {
        let m = e.manifest.model(key).unwrap();
        assert!(m.num_layers > 0);
        assert!(!m.train_buckets.is_empty());
        assert!(!m.eval_buckets.is_empty());
        assert!(m.curv_batch > 0);
        assert!(e.backend().supports(m), "{key} must run natively");
    }
    assert!(e.manifest.model("resnet18_c10").is_err(), "artifact-only model");
}

#[test]
fn session_rejects_unknown_model() {
    let e = engine();
    assert!(Session::init(&e, "resnet18_c10", 0).is_err());
}

#[test]
fn init_is_deterministic_per_seed() {
    let e = engine();
    let s1 = Session::init(&e, "tiny_cnn_c10", 7).unwrap();
    let s2 = Session::init(&e, "tiny_cnn_c10", 7).unwrap();
    let s3 = Session::init(&e, "tiny_cnn_c10", 8).unwrap();
    for i in 0..3 {
        assert_eq!(s1.param_norm(i).unwrap(), s2.param_norm(i).unwrap());
    }
    let diff = (0..3).any(|i| s1.param_norm(i).unwrap() != s3.param_norm(i).unwrap());
    assert!(diff, "different seeds must give different inits");
}

#[test]
fn train_step_updates_params_and_reports_stats() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let before: Vec<f64> = (0..n).map(|i| s.param_norm(i).unwrap()).collect();
    let b = batch(16, 0);
    let ctrl = StepCtrl::uniform(n, FP32, 0.05, 5e-4);
    let out = s.train_step(&b, &ctrl).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0, "loss {}", out.loss);
    assert!((0..=16).contains(&out.correct));
    assert_eq!(out.grad_var.len(), n);
    assert!(out.grad_var.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(out.grad_norm.iter().all(|g| g.is_finite() && *g >= 0.0));
    assert!(!out.overflow);
    let after: Vec<f64> = (0..n).map(|i| s.param_norm(i).unwrap()).collect();
    assert_ne!(before, after, "params must move");
}

#[test]
fn train_step_rejects_non_bucket_batch() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let b = batch(13, 0); // 13 is not a bucket
    let ctrl = StepCtrl::uniform(n, FP32, 0.05, 0.0);
    assert!(s.train_step(&b, &ctrl).is_err());
}

#[test]
fn train_step_rejects_bad_arity() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let b = batch(16, 0);
    let ctrl = StepCtrl::uniform(2, FP32, 0.05, 0.0); // wrong layer count
    if s.num_layers() != 2 {
        assert!(s.train_step(&b, &ctrl).is_err());
    }
}

#[test]
fn training_is_bitwise_reproducible() {
    let e = engine();
    let run = || {
        let mut s = Session::init(&e, "tiny_cnn_c10", 3).unwrap();
        let n = s.num_layers();
        let ctrl = StepCtrl::uniform(n, BF16, 0.05, 5e-4);
        let mut losses = Vec::new();
        for i in 0..3 {
            let b = batch(16, 100 + i);
            losses.push(s.train_step(&b, &ctrl).unwrap().loss);
        }
        (losses, s.params_host().unwrap())
    };
    let (l1, p1) = run();
    let (l2, p2) = run();
    assert_eq!(l1, l2, "loss trajectory must be bit-identical");
    assert_eq!(p1, p2, "parameters must be bit-identical");
}

#[test]
fn precision_codes_change_numerics_but_stay_close() {
    let e = engine();
    let run_at = |code: i32| {
        let mut s = Session::init(&e, "tiny_cnn_c10", 1).unwrap();
        let ctrl = StepCtrl::uniform(s.num_layers(), code, 0.05, 0.0);
        let b = batch(16, 9);
        let out = s.train_step(&b, &ctrl).unwrap();
        (out.loss, out.grad_var)
    };
    let (l32, v32) = run_at(FP32);
    let (l16, v16) = run_at(FP16);
    let (lbf, vbf) = run_at(BF16);
    // The quantization must actually perturb the computation. The
    // scalar loss can coincidentally round identically, so the robust
    // check is on the gradient statistics, which integrate rounding
    // error across every parameter.
    assert_ne!(v32, v16, "fp16 emulation must perturb gradients");
    assert_ne!(v32, vbf, "bf16 emulation must perturb gradients");
    // ... but only slightly: same loss to 10%, grad variance same scale.
    assert!((l32 - l16).abs() / l32 < 0.1, "fp16 loss far off: {l32} vs {l16}");
    assert!((l32 - lbf).abs() / l32 < 0.1, "bf16 loss far off: {l32} vs {lbf}");
    for (a, b) in v32.iter().zip(&v16) {
        assert!((a / b).max(b / a) < 2.0, "fp16 grad_var off-scale: {a} vs {b}");
    }
}

#[test]
fn eval_counts_correct_within_batch() {
    let e = engine();
    let s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let codes = vec![FP32; s.num_layers()];
    let ds = SyntheticCifar::new(10, 512, false, 4);
    let mut x = vec![0f32; 16 * 32 * 32 * 3];
    let mut y = vec![0i32; 16];
    for i in 0..16 {
        y[i] = ds.example(i, &mut x[i * 3072..(i + 1) * 3072]);
    }
    let b = tri_accel::runtime::Batch::new(x, y);
    let r = s.eval_batch(&b, &codes).unwrap();
    assert!(r.loss.is_finite() && r.loss > 0.0);
    assert!((0..=16).contains(&r.correct));
    assert_eq!(r.total, 16);
}

#[test]
fn curvature_probe_stabilizes_on_dominant_layer() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let codes = vec![FP32; n];
    let cb = s.entry.curv_batch;
    let b = batch(cb, 5);
    let mut last = Vec::new();
    for _ in 0..6 {
        last = s.curv_step(&b, &codes, 11).unwrap();
        assert_eq!(last.len(), n);
        assert!(last.iter().all(|l| l.is_finite()), "λ not finite: {last:?}");
    }
    let next = s.curv_step(&b, &codes, 11).unwrap();
    // Power iteration on a fixed batch: the dominant-curvature layer's
    // Rayleigh quotient must be near-converged after 7 steps. (Layers
    // with near-zero curvature keep jittering around zero — their
    // absolute magnitude is what the controller consumes.)
    let dom = (0..n)
        .max_by(|&a, &b_| last[a].abs().partial_cmp(&last[b_].abs()).unwrap())
        .unwrap();
    let denom = last[dom].abs().max(1e-3);
    assert!(
        (last[dom] - next[dom]).abs() / denom < 0.25,
        "dominant λ jitter {} → {}",
        last[dom],
        next[dom]
    );
    assert!(last[dom].abs() > 0.05, "dominant curvature should be visible");
}

#[test]
fn curvature_probe_is_deterministic_and_resettable() {
    let e = engine();
    let codes = vec![FP32; 4];
    let run = |resets: bool| {
        let mut s = Session::init(&e, "tiny_cnn_c10", 2).unwrap();
        let b = batch(s.entry.curv_batch, 3);
        let mut lams = Vec::new();
        for i in 0..4 {
            if resets && i == 2 {
                s.reset_probes();
            }
            lams.push(s.curv_step(&b, &codes, 17).unwrap());
        }
        lams
    };
    assert_eq!(run(false), run(false), "probe sequence is deterministic");
    let with_reset = run(true);
    let without = run(false);
    // Resetting re-seeds the probe with the same stream, so iteration
    // 2 after a reset equals iteration 0.
    assert_eq!(with_reset[2], without[0]);
}

#[test]
fn loss_scale_is_value_neutral_for_fp32() {
    // The backward pass divides the scale back out — an FP32 run with
    // scale 1024 must match scale 1 bit-for-bit (no fp16 rounding).
    let e = engine();
    let run = |scale: f32| {
        let mut s = Session::init(&e, "tiny_cnn_c10", 2).unwrap();
        let n = s.num_layers();
        let mut ctrl = StepCtrl::uniform(n, FP32, 0.05, 0.0);
        ctrl.loss_scale = scale;
        let b = batch(16, 77);
        let out = s.train_step(&b, &ctrl).unwrap();
        (out.loss, s.params_host().unwrap())
    };
    let (l1, p1) = run(1.0);
    let (l2, p2) = run(1024.0);
    assert_eq!(l1, l2);
    // Gradients go through *2^k scaling — exact in binary fp.
    assert_eq!(p1, p2, "2^k loss scaling must be exact for fp32");
}

#[test]
fn backend_reports_platform() {
    let e = engine();
    assert_eq!(e.platform(), "native-cpu");
    // The compatibility constructor falls back to native when no
    // artifacts exist (the default hermetic build).
    let e2 = Engine::new(std::path::Path::new("artifacts")).unwrap();
    assert_eq!(e2.platform(), "native-cpu");
}
