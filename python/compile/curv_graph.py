"""L2 curvature probe — one amortized power-iteration step (§3.2).

Computes a Hessian-vector product Hu of the training loss at the current
params (curvature batch b_curv ≪ B_train) via forward-over-reverse, then
per precision layer l:

    λ_l = ⟨u_l, (Hu)_l⟩ / ⟨u_l, u_l⟩          (Rayleigh quotient)
    u'_l = (Hu)_l / ‖(Hu)_l‖                  (next probe, unit per layer)

The Rust curvature scheduler persists u between firings (every T_curv
steps), so the iteration converges across firings at one-HVP cost each —
amortized power iteration (DESIGN.md §6.6).

Approximation note (documented in DESIGN.md): the paper's block-diagonal
H_l is approximated by the layer-slice of the full HVP. Cross-layer terms
perturb the iterate, but the control law only consumes max-λ magnitude,
and the §4.3 protocol's λ are themselves power-iteration estimates. The
strict per-block variant (L masked HVPs) is available for tiny models as
`make_curv_probe(strict_block=True)` and is used by pytest to bound the
approximation error.

Precision codes: the probe runs with the *current* codes, so λ reflects
the loss surface the optimizer actually walks (quantization included).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import api
from .models import common as C

EPS = 1e-12

# The HVP needs forward-over-reverse differentiation, but the Pallas
# kernels carry custom_vjp rules (no jvp). The probe therefore traces the
# model through the pure-jnp reference kernels — pytest pins those to the
# Pallas kernels bit-for-bit, and astype/dot are differentiable at any
# order. The probe is its own artifact, so the train step keeps the real
# kernels.


def _group_by_layer(model, vecs):
    """Yield (layer_idx, [tensor...]) for precision layers."""
    groups: dict[int, list] = {}
    for spec, v in zip(model.param_specs, vecs):
        if spec.layer_idx >= 0:
            groups.setdefault(spec.layer_idx, []).append(v)
    return groups


def make_curv_probe(model, strict_block: bool = False):
    """Returns curv_probe(params, state, x, y, u, codes) -> (u', lambdas)."""

    def loss_only(params, state, x, y, codes):
        with api.backend("ref"):
            logits, _ = model.apply(params, state, x, codes, train=True)
        return C.cross_entropy(logits, y)

    def hvp(params, state, x, y, codes, u):
        g_fn = lambda p: jax.grad(loss_only)(p, state, x, y, codes)
        _, hu = jax.jvp(g_fn, (params,), (u,))
        return hu

    def curv_probe(params, state, x, y, u, codes):
        params = tuple(params)
        state = tuple(state)
        u = tuple(u)
        L = model.num_layers

        if strict_block:
            # L masked HVPs: zero the tangent outside layer l — exact
            # block-diagonal power iteration (test/reference path only).
            hu_parts = []
            for li in range(L):
                masked = tuple(
                    v if s.layer_idx == li else jnp.zeros_like(v)
                    for s, v in zip(model.param_specs, u)
                )
                hu_l = hvp(params, state, x, y, codes, masked)
                hu_parts.append(hu_l)
            hu = tuple(
                hu_parts[s.layer_idx][pi] if s.layer_idx >= 0 else jnp.zeros_like(u[pi])
                for pi, s in enumerate(model.param_specs)
            )
        else:
            hu = hvp(params, state, x, y, codes, u)

        groups = _group_by_layer(model, list(range(len(u))))
        lambdas = [jnp.float32(0.0)] * L
        norms = {}
        for li, idxs in groups.items():
            num = jnp.float32(0.0)
            den = jnp.float32(0.0)
            hn = jnp.float32(0.0)
            for pi in idxs:
                num += jnp.vdot(u[pi], hu[pi])
                den += jnp.vdot(u[pi], u[pi])
                hn += jnp.vdot(hu[pi], hu[pi])
            lambdas[li] = num / (den + EPS)
            norms[li] = jnp.sqrt(hn) + EPS

        u_next = []
        for pi, spec in enumerate(model.param_specs):
            li = spec.layer_idx
            if li < 0:
                u_next.append(jnp.zeros_like(u[pi]))
            else:
                u_next.append(hu[pi] / norms[li])
        return tuple(u_next), jnp.stack(lambdas)

    return curv_probe


def example_args(model, batch: int):
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    params = tuple(sds(p.shape, f32) for p in model.params)
    state = tuple(sds(s.shape, f32) for s in model.state)
    x = sds((batch, 32, 32, 3), f32)
    y = sds((batch,), jnp.int32)
    u = tuple(sds(p.shape, f32) for p in model.params)
    codes = sds((model.num_layers,), jnp.int32)
    return (params, state, x, y, u, codes)
