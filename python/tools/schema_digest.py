#!/usr/bin/env python3
"""Python twin of detlint rule D7's schema digest (stdlib only).

Usage: schema_digest.py <file.rs> <VERSION_CONST> [<file.rs> <VERSION_CONST> ...]
       schema_digest.py --scenarios

Recomputes, for each schema-pinned Rust source file, the (version,
digest) pair that `rust/src/lint/schema.rs` pins: the FNV-1a-64 hash of
the sorted, comma-joined set of serialized-field-key string literals —
the first argument of `insert("…")` / `num(&mut m, "…")` /
`s(&mut m, "…")` calls on non-test code lines. The extraction is a
faithful port of the Rust scanner's code channel (string contents and
comments blanked, `#[cfg(test)]` regions tracked by brace depth), so
the numbers printed here are the numbers `tri-accel lint` computes.

Use it when bumping a schema version without a local Rust toolchain:
run it on the edited file, then update the matching PINS entry in
`rust/src/lint/schema.rs`. Validate the port itself by running it on
an unmodified pinned file and comparing against the pinned digest.

Prints one line per file: `<file> version=<v> digest=0x<16 hex>`.

`--scenarios` instead prints the pressure-scenario factor-series
digests pinned in `rust/src/memsim/scenarios.rs`: FNV-1a-64 over the
little-endian f64 bits of `factor(step)` for steps 0..256, one line
per scenario. The formulas here are a faithful port of `ScenarioKind::
factor` (pure rational arithmetic — bit-identical across languages);
re-pin the Rust test values from this output after any deliberate
formula change.
"""

import struct
import sys

KEY_MARKERS = ['insert("', 'num(&mut m, "', 's(&mut m, "']


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def split_code_lines(text):
    """Per-line code channel: comments removed, string/char literal
    contents blanked with delimiters kept (port of lint/scan.rs
    split_channels, code side only)."""
    chars = text
    code_lines = []
    code = []
    state = "code"  # code | line_comment | block_comment | str | raw_str
    depth = 0  # block-comment nesting
    hashes = 0  # raw-string hash count
    i = 0
    n = len(chars)
    while i < n:
        c = chars[i]
        if c == "\n":
            code_lines.append("".join(code))
            code = []
            if state == "line_comment":
                state = "code"
            i += 1
            continue
        if state == "code":
            nxt = chars[i + 1] if i + 1 < n else ""
            if c == "/" and nxt == "/":
                state = "line_comment"
                i += 2
            elif c == "/" and nxt == "*":
                state = "block_comment"
                depth = 1
                i += 2
            elif c == '"':
                code.append('"')
                state = "str"
                i += 1
            elif c == "r" or (c == "b" and nxt == "r"):
                j = i + (2 if c == "b" else 1)
                h = 0
                while j < n and chars[j] == "#":
                    h += 1
                    j += 1
                if j < n and chars[j] == '"':
                    code.append('"')
                    state = "raw_str"
                    hashes = h
                    i = j + 1
                else:
                    code.append(c)
                    i += 1
            elif c == "b" and nxt == '"':
                code.append('"')
                state = "str"
                i += 2
            elif c == "'" or (c == "b" and nxt == "'"):
                q = i + 1 if c == "b" else i
                if q + 1 < n and chars[q + 1] == "\\":
                    j = q + 2
                    while j < n and chars[j] != "'":
                        j += 1
                    code.append("'")
                    i = j + 1
                elif q + 2 < n and chars[q + 2] == "'" and chars[q + 1] != "'":
                    code.append("'")
                    i = q + 3
                else:
                    code.append(c)
                    i += 1
            else:
                code.append(c)
                i += 1
        elif state == "line_comment":
            i += 1
        elif state == "block_comment":
            nxt = chars[i + 1] if i + 1 < n else ""
            if c == "/" and nxt == "*":
                depth += 1
                i += 2
            elif c == "*" and nxt == "/":
                depth -= 1
                if depth == 0:
                    state = "code"
                i += 2
            else:
                i += 1
        elif state == "str":
            if c == "\\" and not (i + 1 < n and chars[i + 1] == "\n"):
                i += 2
            elif c == '"':
                code.append('"')
                state = "code"
                i += 1
            else:
                i += 1
        else:  # raw_str
            if c == '"' and all(
                i + k < n and chars[i + k] == "#" for k in range(1, hashes + 1)
            ):
                code.append('"')
                state = "code"
                i += 1 + hashes
            else:
                i += 1
    code_lines.append("".join(code))
    # Align with str::lines() semantics (drop the stray final element
    # when the text ends in a newline).
    want = len(text.splitlines())
    del code_lines[want:]
    while len(code_lines) < want:
        code_lines.append("")
    return code_lines


def test_regions(code_lines):
    """Mark lines covered by a #[cfg(test)] item (port of lint/scan.rs
    test_regions, brace-depth tracking)."""
    out = [False] * len(code_lines)
    depth = 0
    pending_attr = False
    region_floor = None
    for idx, code in enumerate(code_lines):
        trimmed = code.strip()
        if region_floor is None and trimmed.startswith("#[cfg(test)]"):
            pending_attr = True
        if pending_attr or region_floor is not None:
            out[idx] = True
        depth_before = depth
        first_open_depth = None
        for ch in trimmed:
            if ch == "{":
                depth += 1
                if first_open_depth is None:
                    first_open_depth = depth
            elif ch == "}":
                depth -= 1
        if pending_attr and trimmed and not trimmed.startswith("#["):
            pending_attr = False
            if first_open_depth is not None:
                region_floor = first_open_depth
            elif not trimmed.endswith(";"):
                region_floor = depth_before + 1
        if region_floor is not None and depth < region_floor:
            region_floor = None
    return out


def extract(src, version_const):
    """(version, sorted key list) — port of lint/schema.rs extract."""
    raw = src.splitlines()
    code_lines = split_code_lines(src)
    in_test = test_regions(code_lines)
    keys = set()
    version = None
    needle = f"const {version_const}: u64 ="
    for i, code in enumerate(code_lines):
        if in_test[i]:
            continue
        if needle in code:
            at = raw[i].find(needle)
            if at >= 0:
                tail = raw[i][at + len(needle):].lstrip()
                digits = ""
                for ch in tail:
                    if ch.isdigit():
                        digits += ch
                    else:
                        break
                if digits:
                    version = int(digits)
        for marker in KEY_MARKERS:
            if marker not in code:
                continue
            at = raw[i].find(marker)
            if at >= 0:
                tail = raw[i][at + len(marker):]
                end = tail.find('"')
                if end >= 0:
                    keys.add(tail[:end])
    return version, sorted(keys)


def digest_keys(keys):
    return fnv1a64(",".join(keys).encode("utf-8"))


def scenario_factor(name, step):
    """Port of memsim/scenarios.rs ScenarioKind::factor."""
    if name == "spike":
        p = step % 23
        if 8 <= p < 11:
            return 0.45
        if step % 37 == 18:
            return 0.3
        return 1.0
    if name == "frag":
        return 1.0 - 0.045 * float(min(step // 6, 9))
    if name == "leak":
        f = 1.0 - 0.004 * float(step)
        return 0.5 if f < 0.5 else f
    raise ValueError(f"unknown scenario `{name}`")


def scenario_digests():
    """One (name, digest) pair per scenario: FNV-1a-64 over the
    little-endian f64 bits of factor(0..256)."""
    out = []
    for name in ("spike", "frag", "leak"):
        series = b"".join(
            struct.pack("<d", scenario_factor(name, step)) for step in range(256)
        )
        out.append((name, fnv1a64(series)))
    return out


def main(argv):
    args = argv[1:]
    if args == ["--scenarios"]:
        for name, digest in scenario_digests():
            print(f"{name} digest=0x{digest:016x}")
        return 0
    if not args or len(args) % 2 != 0:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path, const in zip(args[::2], args[1::2]):
        with open(path, encoding="utf-8") as f:
            src = f.read()
        version, keys = extract(src, const)
        v = "?" if version is None else str(version)
        print(f"{path} version={v} digest=0x{digest_keys(keys):016x} keys={len(keys)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
