//! Property tests on the policy control plane (alongside
//! `prop_coordinator.rs`, which covers the individual state machines):
//! checkpoint round-trips taken *mid-control-window* must leave every
//! policy's subsequent decisions bit-identical, for every registry
//! method, under arbitrary measurement histories.

use tri_accel::config::Config;
use tri_accel::manifest::{LayerSpec, ModelEntry};
use tri_accel::policy::{registry, ControlPlane};
use tri_accel::util::prop::{check, log_uniform, small_usize, uniform};
use tri_accel::util::rng::Rng;

fn entry(num_layers: usize) -> ModelEntry {
    ModelEntry {
        key: "prop_policy".into(),
        model: "prop_policy".into(),
        num_classes: 10,
        num_layers,
        param_count: 0,
        layers: (0..num_layers)
            .map(|i| LayerSpec {
                name: format!("l{i}"),
                kind: "conv".into(),
                param_elems: 100,
                act_elems: 10,
                flops: 1000,
            })
            .collect(),
        params: vec![],
        nodes: vec![],
        state_shapes: vec![],
        train_buckets: vec![16, 32, 64, 96, 128],
        eval_buckets: vec![16],
        curv_batch: 8,
        artifacts: Default::default(),
    }
}

/// Feed one step of a random measurement stream into a plane —
/// observations, probes, occasional OOMs, the control window on its
/// cadence. The stream is a pure function of `rng`, so replaying the
/// same draws drives two planes identically.
fn drive(ctl: &mut ControlPlane, step: u64, rng: &mut Rng) {
    let layers = ctl.codes().len();
    let vars: Vec<f32> = (0..layers).map(|_| log_uniform(rng, -9.0, 0.0) as f32).collect();
    ctl.observe_step(&vars, rng.bernoulli(0.08));
    if ctl.curvature_due(step) {
        let lams: Vec<f32> =
            (0..layers).map(|_| log_uniform(rng, -2.0, 3.0) as f32).collect();
        ctl.observe_curvature(&lams);
    }
    if rng.bernoulli(0.05) {
        ctl.oom_event(step);
    }
    if ctl.window_due(step) {
        let fits = rng.bernoulli(0.7);
        ctl.control_window(step, uniform(rng, 0.0, 1.2), 1.0, |_| fits);
    }
}

fn random_cfg(rng: &mut Rng) -> Config {
    let specs = registry::registry();
    let spec = &specs[small_usize(rng, 0, specs.len() - 1)];
    let mut cfg = Config::default();
    registry::apply(&mut cfg, spec);
    cfg.t_ctrl = small_usize(rng, 1, 7) as u64;
    cfg.t_curv = small_usize(rng, 1, 9) as u64;
    cfg.curv_warmup = small_usize(rng, 0, 2) as u64;
    cfg.batch_cooldown = small_usize(rng, 0, 4) as u64;
    cfg.auto_threshold = rng.bernoulli(0.5);
    cfg.tau_curv = log_uniform(rng, 0.0, 3.0);
    cfg
}

#[test]
fn prop_mid_window_roundtrip_is_bit_identical() {
    check("export/import at an arbitrary step is decision-transparent", |rng| {
        let layers = small_usize(rng, 1, 6);
        let e = entry(layers);
        let cfg = random_cfg(rng);
        let mut live = ControlPlane::new(&cfg, &e);

        // Arbitrary history — deliberately not aligned to t_ctrl, so
        // the snapshot lands mid-control-window most of the time.
        let snap_at = small_usize(rng, 1, 60) as u64;
        for step in 1..=snap_at {
            drive(&mut live, step, rng);
        }

        let saved = live.export_state();
        let mut resumed = ControlPlane::new(&cfg, &e);
        resumed.import_state(&saved).map_err(|err| format!("import: {err:#}"))?;

        // Continue both under an identical input stream: every decision
        // surface must match bit for bit, step for step.
        for step in snap_at + 1..=snap_at + 40 {
            let vars: Vec<f32> =
                (0..layers).map(|_| log_uniform(rng, -9.0, 0.0) as f32).collect();
            let overflow = rng.bernoulli(0.08);
            live.observe_step(&vars, overflow);
            resumed.observe_step(&vars, overflow);

            if live.curvature_due(step) != resumed.curvature_due(step) {
                return Err(format!("step {step}: curvature cadence diverged"));
            }
            if live.curvature_due(step) {
                let lams: Vec<f32> =
                    (0..layers).map(|_| log_uniform(rng, -2.0, 3.0) as f32).collect();
                let ra = live.observe_curvature(&lams);
                let rb = resumed.observe_curvature(&lams);
                if ra != rb {
                    return Err(format!("step {step}: probe rejections diverged"));
                }
            }
            if rng.bernoulli(0.05) {
                let a = live.oom_event(step);
                let b = resumed.oom_event(step);
                if a != b {
                    return Err(format!("step {step}: OOM shed diverged"));
                }
            }
            if live.window_due(step) {
                let used = uniform(rng, 0.0, 1.2);
                let fits = rng.bernoulli(0.7);
                let a = live.control_window(step, used, 1.0, |_| fits);
                let b = resumed.control_window(step, used, 1.0, |_| fits);
                if a.batch_move != b.batch_move
                    || a.batch_size != b.batch_size
                    || a.promotions != b.promotions
                    || a.precision_changed != b.precision_changed
                    || a.loss_scale.to_bits() != b.loss_scale.to_bits()
                {
                    return Err(format!("step {step}: window decisions diverged"));
                }
            }

            if live.codes() != resumed.codes() {
                return Err(format!(
                    "step {step}: codes {:?} vs {:?}",
                    live.codes(),
                    resumed.codes()
                ));
            }
            if live.batch_size() != resumed.batch_size() {
                return Err(format!("step {step}: batch diverged"));
            }
            if live.loss_scale().to_bits() != resumed.loss_scale().to_bits() {
                return Err(format!("step {step}: loss scale diverged"));
            }
            let (sa, sb) = (live.lr_scales(), resumed.lr_scales());
            if sa.iter().map(|v| v.to_bits()).ne(sb.iter().map(|v| v.to_bits())) {
                return Err(format!("step {step}: lr scales diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_reexport_after_roundtrip_is_stable() {
    // export → import → export must be a fixed point: nothing about a
    // plane's checkpointable state is lost or mutated by the trip.
    check("double export is a fixed point", |rng| {
        let layers = small_usize(rng, 1, 6);
        let e = entry(layers);
        let cfg = random_cfg(rng);
        let mut live = ControlPlane::new(&cfg, &e);
        let steps = small_usize(rng, 1, 50) as u64;
        for step in 1..=steps {
            drive(&mut live, step, rng);
        }
        let first = live.export_state();
        let mut resumed = ControlPlane::new(&cfg, &e);
        resumed.import_state(&first).map_err(|err| format!("import: {err:#}"))?;
        let second = resumed.export_state();
        if first.len() != second.len() {
            return Err(format!("entry count {} vs {}", first.len(), second.len()));
        }
        for ((ka, va), (kb, vb)) in first.iter().zip(second.iter()) {
            if ka != kb {
                return Err(format!("key order changed: {ka} vs {kb}"));
            }
            if va.iter().map(|v| v.to_bits()).ne(vb.iter().map(|v| v.to_bits())) {
                return Err(format!("state `{ka}` not bit-stable"));
            }
        }
        Ok(())
    });
}
