//! Source scanner for the detlint pass.
//!
//! A small line/token scanner — deliberately not a parser (`syn` would
//! drag a proc-macro stack into the hermetic vendor tree). One pass
//! classifies every line of a file into *code* (string-literal
//! contents and comments blanked out, quote delimiters kept) and
//! *comment* text; a second pass tracks `#[cfg(test)]` regions by
//! brace depth; a third collects `// detlint:` pragmas. Rules only
//! ever match against the `code` channel, so a `HashMap` mentioned in
//! a doc comment or a string literal can never fire a finding.
//!
//! Pragma grammar (justifications are mandatory — an allowlist entry
//! without a stated reason is itself a finding):
//!
//! ```text
//! // detlint: allow(d1, d6) — <why this line is exempt>
//! // detlint: allow-file(d2) — <why this whole file is exempt>
//! // detlint: ordered — <statement of the reduction order>
//! ```
//!
//! A pragma on a line with code applies to that line; a pragma on a
//! comment-only line applies to the next line that has code.

use std::collections::BTreeSet;

/// One scanned source line.
#[derive(Debug)]
pub struct Line {
    /// Source text with comments removed and string/char literal
    /// contents blanked (delimiters kept), so needle matching never
    /// fires inside prose.
    pub code: String,
    /// Comment text on this line (line, block, and doc comments).
    pub comment: String,
    /// Inside a `#[cfg(test)]` item (the attribute line included).
    pub in_test: bool,
    /// Rules allowlisted at this line via `detlint: allow(...)`.
    pub allows: BTreeSet<String>,
    /// A `detlint: ordered` pragma covers this line.
    pub ordered: bool,
}

/// A fully scanned file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the lint root, forward slashes.
    pub rel: String,
    /// Raw source lines (for finding snippets).
    pub raw: Vec<String>,
    /// Scanned lines, parallel to `raw`.
    pub lines: Vec<Line>,
    /// Rules allowlisted file-wide via `detlint: allow-file(...)`.
    pub file_allows: BTreeSet<String>,
    /// Malformed pragmas found while scanning: `(1-based line, message)`.
    pub pragma_errors: Vec<(usize, String)>,
}

/// The rule ids a pragma may name.
pub const RULE_IDS: &[&str] = &["d1", "d2", "d3", "d4", "d5", "d6", "d7"];

/// Scan one file's source text.
pub fn scan_source(rel: &str, text: &str) -> SourceFile {
    let raw: Vec<String> = text.lines().map(str::to_string).collect();
    let (code_lines, comment_lines) = split_channels(text);
    let in_test = test_regions(&code_lines);
    let mut sf = SourceFile {
        rel: rel.to_string(),
        raw,
        lines: Vec::with_capacity(code_lines.len()),
        file_allows: BTreeSet::new(),
        pragma_errors: Vec::new(),
    };
    for (i, code) in code_lines.iter().enumerate() {
        sf.lines.push(Line {
            code: code.clone(),
            comment: comment_lines[i].clone(),
            in_test: in_test[i],
            allows: BTreeSet::new(),
            ordered: false,
        });
    }
    apply_pragmas(&mut sf);
    sf
}

/// Lexer state for [`split_channels`]. Strings and block comments span
/// lines, so the state must survive line boundaries.
enum St {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Split source text into per-line code and comment channels.
fn split_channels(text: &str) -> (Vec<String>, Vec<String>) {
    let chars: Vec<char> = text.chars().collect();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut com));
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' || (c == 'b' && next == Some('r')) {
                    // Possible raw string: r"..." / r#"..."# / br"...".
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        code.push('"');
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == 'b' && next == Some('"') {
                    code.push('"');
                    st = St::Str;
                    i += 2;
                } else if c == '\'' || (c == 'b' && next == Some('\'')) {
                    // Char/byte literal vs lifetime tick.
                    let q = if c == 'b' { i + 1 } else { i };
                    if chars.get(q + 1) == Some(&'\\') {
                        let mut j = q + 2;
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        code.push('\'');
                        i = j + 1;
                    } else if chars.get(q + 2) == Some(&'\'') && chars.get(q + 1) != Some(&'\'') {
                        code.push('\'');
                        i = q + 3;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                com.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    if depth == 1 {
                        st = St::Code;
                    } else {
                        st = St::BlockComment(depth - 1);
                    }
                    i += 2;
                } else {
                    com.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && chars.get(i + 1) != Some(&'\n') {
                    // Skip the escaped char; an escaped newline falls
                    // through so the line accounting above sees it.
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let h = hashes as usize;
                    let closed = (1..=h).all(|k| chars.get(i + k) == Some(&'#'));
                    if closed {
                        code.push('"');
                        st = St::Code;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    code_lines.push(code);
    comment_lines.push(com);
    // Keep the channel vectors aligned with `str::lines()` (the final
    // push is a stray empty line when the text ends in a newline).
    let n = text.lines().count();
    code_lines.truncate(n);
    comment_lines.truncate(n);
    while code_lines.len() < n {
        code_lines.push(String::new());
        comment_lines.push(String::new());
    }
    (code_lines, comment_lines)
}

/// Mark lines covered by a `#[cfg(test)]` item (brace-depth tracking).
fn test_regions(code_lines: &[String]) -> Vec<bool> {
    let mut out = vec![false; code_lines.len()];
    let mut depth: i64 = 0;
    let mut pending_attr = false;
    // Active while `depth >= region_floor`.
    let mut region_floor: Option<i64> = None;
    for (idx, code) in code_lines.iter().enumerate() {
        let trimmed = code.trim();
        if region_floor.is_none() && trimmed.starts_with("#[cfg(test)]") {
            pending_attr = true;
        }
        if pending_attr || region_floor.is_some() {
            out[idx] = true;
        }
        let depth_before = depth;
        let mut first_open_depth: Option<i64> = None;
        for ch in trimmed.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if first_open_depth.is_none() {
                        first_open_depth = Some(depth);
                    }
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if pending_attr && !trimmed.is_empty() && !trimmed.starts_with("#[") {
            // The item the attribute covers starts here.
            pending_attr = false;
            if let Some(open) = first_open_depth {
                region_floor = Some(open);
            } else if !trimmed.ends_with(';') {
                // Item signature continues onto later lines; its body
                // opens at one past the depth the item started at.
                region_floor = Some(depth_before + 1);
            }
        }
        if let Some(floor) = region_floor {
            if depth < floor {
                region_floor = None;
            }
        }
    }
    out
}

/// A parsed `detlint:` directive.
enum Directive {
    Allow(Vec<String>),
    AllowFile(Vec<String>),
    Ordered,
}

/// Parse the directive out of one comment, validating rule names and
/// the mandatory justification text.
fn parse_directive(comment: &str) -> Option<Result<Directive, String>> {
    let at = comment.find("detlint:")?;
    // Only a directive at the start of the comment counts — prose that
    // *mentions* a pragma (docs, this file) is not a pragma.
    if !comment[..at].chars().all(|c| matches!(c, '/' | ' ' | '\t')) {
        return None;
    }
    let rest = comment[at + "detlint:".len()..].trim_start();
    let (dir, tail) = if let Some(t) = rest.strip_prefix("allow-file(") {
        match rule_list(t) {
            Ok((rules, tail)) => (Directive::AllowFile(rules), tail),
            Err(e) => return Some(Err(e)),
        }
    } else if let Some(t) = rest.strip_prefix("allow(") {
        match rule_list(t) {
            Ok((rules, tail)) => (Directive::Allow(rules), tail),
            Err(e) => return Some(Err(e)),
        }
    } else if let Some(t) = rest.strip_prefix("ordered") {
        (Directive::Ordered, t.to_string())
    } else {
        let head = rest.split_whitespace().next().unwrap_or("");
        return Some(Err(format!(
            "unknown detlint directive `{head}` (allow | allow-file | ordered)"
        )));
    };
    if !justified(&tail) {
        return Some(Err("detlint pragma needs a `— <justification>` suffix".to_string()));
    }
    Some(Ok(dir))
}

/// Parse `d1, d6) tail` into validated rule ids + the remaining text.
fn rule_list(t: &str) -> Result<(Vec<String>, String), String> {
    let close = t.find(')').ok_or_else(|| "unclosed rule list in detlint pragma".to_string())?;
    let mut rules = Vec::new();
    for part in t[..close].split(',') {
        let r = part.trim().to_ascii_lowercase();
        if !RULE_IDS.contains(&r.as_str()) {
            return Err(format!("unknown detlint rule `{r}` (d1..d7)"));
        }
        rules.push(r);
    }
    Ok((rules, t[close + 1..].to_string()))
}

/// Justifications follow an em-dash/hyphen separator and are nonempty.
fn justified(tail: &str) -> bool {
    let t = tail.trim_start();
    let stripped = t
        .strip_prefix('—')
        .or_else(|| t.strip_prefix("--"))
        .or_else(|| t.strip_prefix('-'));
    match stripped {
        Some(rest) => !rest.trim().is_empty(),
        None => false,
    }
}

/// Attach pragmas to lines (same-line, or carried to the next code line).
fn apply_pragmas(sf: &mut SourceFile) {
    let mut pending_allows: BTreeSet<String> = BTreeSet::new();
    let mut pending_ordered = false;
    for (i, line) in sf.lines.iter_mut().enumerate() {
        let has_code = !line.code.trim().is_empty();
        match parse_directive(&line.comment) {
            Some(Ok(Directive::AllowFile(rules))) => sf.file_allows.extend(rules),
            Some(Ok(Directive::Allow(rules))) => {
                if has_code {
                    line.allows.extend(rules);
                } else {
                    pending_allows.extend(rules);
                }
            }
            Some(Ok(Directive::Ordered)) => {
                if has_code {
                    line.ordered = true;
                } else {
                    pending_ordered = true;
                }
            }
            Some(Err(msg)) => sf.pragma_errors.push((i + 1, msg)),
            None => {}
        }
        if has_code && (!pending_allows.is_empty() || pending_ordered) {
            line.allows.append(&mut pending_allows);
            if pending_ordered {
                line.ordered = true;
                pending_ordered = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_stripped_from_code() {
        let sf = scan_source(
            "x.rs",
            "let a = \"HashMap\"; // HashMap in comment\nlet b = 1; /* HashMap */ let c = 2;\n",
        );
        assert!(!sf.lines[0].code.contains("HashMap"));
        assert!(sf.lines[0].comment.contains("HashMap"));
        assert!(!sf.lines[1].code.contains("HashMap"));
        assert!(sf.lines[1].code.contains("let c = 2;"));
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let sf = scan_source(
            "x.rs",
            "let a = r#\"unsafe {\"#;\nlet b = '\\'';\nlet c: &'static str = \"x\";\n",
        );
        assert!(!sf.lines[0].code.contains("unsafe"));
        assert!(!sf.lines[1].code.contains("\\'"));
        assert!(sf.lines[2].code.contains("&'static str"));
    }

    #[test]
    fn multiline_strings_span_lines() {
        let sf = scan_source("x.rs", "let a = \"one\ntwo unsafe {\nthree\";\nlet b = 1;\n");
        assert!(!sf.lines[1].code.contains("unsafe"));
        assert!(sf.lines[3].code.contains("let b"));
    }

    #[test]
    fn cfg_test_region_tracked_by_depth() {
        let src = "fn a() {}\n#[cfg(test)]\nmod t {\n fn t() { x.unwrap(); }\n}\nfn z() {}\n";
        let sf = scan_source("x.rs", src);
        assert!(!sf.lines[0].in_test);
        assert!(sf.lines[1].in_test, "attribute line is test");
        assert!(sf.lines[3].in_test);
        assert!(!sf.lines[5].in_test, "region closes with the brace");
    }

    #[test]
    fn pragmas_attach_to_code_lines() {
        let src = "// detlint: allow(d6) — infallible by construction\nlet a = x.unwrap();\n\
                   let b = y.unwrap(); // detlint: allow(d6) — same line\n";
        let sf = scan_source("x.rs", src);
        assert!(sf.lines[1].allows.contains("d6"));
        assert!(sf.lines[2].allows.contains("d6"));
        assert!(sf.pragma_errors.is_empty());
    }

    #[test]
    fn prose_mentions_are_not_pragmas() {
        let sf = scan_source("x.rs", "// see the detlint: allow(d1) docs\nlet a = 1;\n");
        assert!(sf.pragma_errors.is_empty());
        assert!(sf.lines[1].allows.is_empty());
    }

    #[test]
    fn unjustified_or_unknown_pragmas_error() {
        let sf = scan_source("x.rs", "// detlint: allow(d6)\nlet a = 1;\n");
        assert_eq!(sf.pragma_errors.len(), 1);
        let sf = scan_source("x.rs", "// detlint: allow(d99) — nope\nlet a = 1;\n");
        assert_eq!(sf.pragma_errors.len(), 1);
        let sf = scan_source("x.rs", "// detlint: frobnicate — eh\nlet a = 1;\n");
        assert_eq!(sf.pragma_errors.len(), 1);
    }

    #[test]
    fn allow_file_is_file_wide() {
        let sf = scan_source("x.rs", "// detlint: allow-file(d2) — bench module\nfn f() {}\n");
        assert!(sf.file_allows.contains("d2"));
    }
}
