//! The experiment scheduler: decomposes the paper's grids
//! (`table1`/`table2`/`fig`/`pressure`) into independent [`Job`]s —
//! one per (model × method × seed × trace) cell-seed — and executes
//! them concurrently on a dedicated *job pool*, streaming per-step
//! telemetry and persisting every result into a resumable grid ledger.
//!
//! Two thread pools, one budget: the job pool (`--jobs N`) runs whole
//! training runs side by side, while each job's *compute* pool (the
//! deterministic [`crate::runtime::native::pool::Pool`]) gets
//! `budget_threads(total, jobs, replicas)` workers — so
//! `jobs × replicas × threads` never oversubscribes the machine. When
//! a grid's configs carry `replicas > 1` (the `--replicas` flag), each
//! worker holds a replicated engine ([`Engine::native_replicated`])
//! whose per-replica pools split that worker's share. Because the
//! compute core is bit-identical for every thread count *and* every
//! replica count, `--jobs` is a pure wall-clock knob (a `--jobs 4`
//! grid produces byte-identical artifacts to a `--jobs 1` run), and
//! `--replicas` is numerics-neutral: it changes the config identity
//! (grid id, job keys gain an `_rN` suffix) and the telemetry
//! `replicas` field, but every loss, parameter, and policy decision
//! matches the single-replica trajectory bit for bit.
//!
//! Everything a grid produces lands in `runs/<grid-id>/`:
//!
//! ```text
//! runs/table1-1a2b3c4d/
//! ├── ledger.json              completed jobs + grid structure (resume state)
//! ├── events/<job>.jsonl       schema-versioned per-step telemetry
//! ├── table1.md                deterministic report artifact (by kind)
//! └── BENCH_grid.json          decision-count / modeled-time summary
//! ```
//!
//! The grid id is a content hash of every job's (key, model-graph
//! digest, config fingerprint), so the same command always maps to
//! the same directory, and *any* change to model, method, seed list,
//! or hyperparameters maps to a new one. Rerunning a killed grid
//! skips the jobs its ledger already records and re-aggregates the
//! persisted results in fixed job-key order — resumption is
//! bit-identical by construction, not by luck. See
//! `docs/ARCHITECTURE.md` (subsystem tour) and `docs/TELEMETRY.md`
//! (event + ledger formats).
//!
//! Every job runs under a *supervisor*: the attempt is isolated with
//! `catch_unwind`, a failed or panicking attempt is retried up to
//! [`SchedOptions::retries`] times with deterministic exponential
//! backoff on a virtual clock (pure step counting — no wall-time
//! reads, so retry behavior is reproducible and detlint-clean), and a
//! job that exhausts its retries is *quarantined*: the grid keeps
//! going, finishes every other job, and renders a partial report that
//! marks the quarantined cells instead of aborting
//! ([`report::render_partial`]). Ledger and telemetry writes go
//! through the [`crate::faults::ArtifactIo`] seam; when
//! [`SchedOptions::faults`] carries a seeded [`FaultSpec`], the
//! supervisor runs the grid under injected OOM storms, IO errors,
//! panics, and torn ledger writes — and the `chaos` subcommand
//! verifies the artifacts still come out bit-identical
//! (`docs/FAULTS.md`).

// Enforced as an error by the docs CI job (`cargo doc` with
// `RUSTDOCFLAGS=-D warnings`); kept at `warn` here so tier-1
// `cargo build`/`cargo test` never hard-fails on a doc regression.
#![warn(missing_docs)]

pub mod ledger;
pub mod replay;
pub mod report;

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Config, Method};
use crate::faults::{ArtifactIo, FaultPlan, FaultSpec, FaultyIo, PanicSink, RealIo};
use crate::harness::{self, SeedResult};
use crate::manifest::Manifest;
use crate::metrics::telemetry::{self, JsonlWriter, SharedSink, TelemetrySink};
use crate::policy::registry;
use crate::runtime::native::pool::{budget_threads, resolve_threads, Pool};
use crate::runtime::Engine;

pub use ledger::{CellMeta, Ledger, LedgerEntry, Loaded, LEDGER_SCHEMA_VERSION};

/// Which paper artifact a grid regenerates (drives the report
/// renderer and the row layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridKind {
    /// Methods × models (paper Table 1).
    Table1,
    /// Ablation rows for one model (paper Table 2).
    Table2,
    /// The adaptive-behaviour trace (paper Fig. 3).
    Fig,
    /// Method sweep under a moving VRAM budget (the pressure scenario).
    Pressure,
}

impl GridKind {
    /// Stable lowercase name (ledger `"kind"` field, grid-id prefix).
    pub fn name(&self) -> &'static str {
        match self {
            GridKind::Table1 => "table1",
            GridKind::Table2 => "table2",
            GridKind::Fig => "fig",
            GridKind::Pressure => "pressure",
        }
    }
}

/// One grid cell: a (model, method composition) pair swept over seeds.
/// `base` is the fully-tweaked config (budget, trace, ablation);
/// per-seed jobs differ from it only in the `seed` field.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Manifest model key.
    pub model_key: String,
    /// Row label (Table-1 method name / Table-2 configuration).
    pub label: String,
    /// Effective method key ([`registry::effective_key`] of `base`).
    pub method_key: String,
    /// Seeds, normalized (sorted, deduplicated).
    pub seeds: Vec<u64>,
    /// The cell's config at seed 0 (seed overridden per job).
    pub base: Config,
}

/// A whole grid: kind + cells in presentation order.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Which artifact this grid regenerates.
    pub kind: GridKind,
    /// Cells in presentation/aggregation order.
    pub cells: Vec<CellSpec>,
}

/// One schedulable unit: a single (model, method, seed, config) run.
#[derive(Debug, Clone)]
pub struct Job {
    /// Index into [`GridSpec::cells`].
    pub cell: usize,
    /// Training seed.
    pub seed: u64,
    /// Filename-safe job key: `<cell>_<model>_<method>[_rN]_s<seed>`
    /// (the `_rN` segment appears only when `cfg.replicas > 1`).
    pub key: String,
    /// The fully-resolved config this job trains.
    pub cfg: Config,
    /// [`Config::fingerprint`] of `cfg` (ledger identity).
    pub config_hash: u64,
    /// Model-graph digest (ledger identity).
    pub digest: u64,
    /// Manifest model key (denormalized for telemetry/ledger).
    pub model_key: String,
    /// Effective method key (denormalized for telemetry/ledger).
    pub method_key: String,
}

/// Replace any character that isn't filename-safe (the synthesized
/// method keys contain `[`/`&`/`=`) with `-`.
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.') { c } else { '-' })
        .collect()
}

impl GridSpec {
    /// Decompose into jobs — one per (cell, seed), in (cell, seed)
    /// order. Validates every model key against the manifest and
    /// stamps each job with its model-graph digest.
    pub fn jobs(&self, manifest: &Manifest) -> Result<Vec<Job>> {
        let mut jobs = Vec::new();
        for (ci, cell) in self.cells.iter().enumerate() {
            let digest = manifest.model(&cell.model_key)?.digest();
            for &seed in &cell.seeds {
                let mut cfg = cell.base.clone();
                cfg.seed = seed;
                cfg.validate()
                    .with_context(|| format!("cell {ci} ({})", cell.label))?;
                // Replicated configs are a different workload shape, so
                // the key says so: `_rN` keeps a `--replicas 2` grid's
                // event files from shadowing the single-replica ones.
                let rep = if cfg.replicas > 1 { format!("_r{}", cfg.replicas) } else { String::new() };
                let key = format!(
                    "{ci:02}_{}_{}{rep}_s{seed}",
                    sanitize(&cell.model_key),
                    sanitize(&cell.method_key)
                );
                jobs.push(Job {
                    cell: ci,
                    seed,
                    config_hash: cfg.fingerprint(),
                    digest,
                    key,
                    cfg,
                    model_key: cell.model_key.clone(),
                    method_key: cell.method_key.clone(),
                });
            }
        }
        anyhow::ensure!(!jobs.is_empty(), "grid has no jobs (empty cells or seed lists)");
        Ok(jobs)
    }

    /// Content-derived grid id: `<kind>-<hash8>` over every job's
    /// (key, digest, config fingerprint). The same command always maps
    /// to the same id; any change to models, methods, seeds, or
    /// hyperparameters maps to a fresh one.
    pub fn grid_id(&self, jobs: &[Job]) -> String {
        let mut desc = String::from(self.kind.name());
        for j in jobs {
            desc.push_str(&format!("|{}:{:016x}:{:016x}", j.key, j.digest, j.config_hash));
        }
        let h = crate::checkpoint::fnv1a(desc.as_bytes());
        format!("{}-{:08x}", self.kind.name(), (h ^ (h >> 32)) as u32)
    }
}

/// Scheduler knobs (the CLI's `--jobs`/`--threads`/`--out` flags).
#[derive(Debug, Clone)]
pub struct SchedOptions {
    /// Concurrent jobs on the job pool (`--jobs`, default 1).
    pub jobs: usize,
    /// Total compute-thread budget shared by all concurrent jobs
    /// (`--threads`; 0 = auto: `TRIACCEL_THREADS`, else machine
    /// parallelism capped at 8). The scheduler caps concurrent
    /// workers at this budget and gives each one
    /// [`budget_threads`]`(total, workers, replicas)` compute threads
    /// per replica, so `workers × replicas × threads` never exceeds
    /// the budget.
    pub total_threads: usize,
    /// Base output directory (`--out`, default `runs`); the grid
    /// writes into `<out>/<grid-id>/`.
    pub out_dir: PathBuf,
    /// Test hook: stop after this many *newly executed* jobs, leaving
    /// the grid incomplete — simulates a mid-grid kill for the
    /// resume property suite. `None` (the default) runs to completion.
    pub job_limit: Option<usize>,
    /// Suppress per-job progress lines.
    pub quiet: bool,
    /// Supervisor retries per job (`--retries`, default 2): a failed
    /// or panicking attempt reruns up to this many extra times (with
    /// deterministic virtual-clock backoff) before the job is
    /// quarantined.
    pub retries: usize,
    /// Fault plan to run the grid under (`--faults`; `None` or an
    /// empty spec injects nothing).
    pub faults: Option<FaultSpec>,
}

impl Default for SchedOptions {
    fn default() -> SchedOptions {
        SchedOptions {
            jobs: 1,
            total_threads: 0,
            out_dir: PathBuf::from("runs"),
            job_limit: None,
            quiet: false,
            retries: 2,
            faults: None,
        }
    }
}

/// A job that exhausted its supervisor retries. The grid completes
/// around it; [`report::render_partial`] marks its cell.
#[derive(Debug, Clone)]
pub struct Quarantine {
    /// Job key.
    pub key: String,
    /// Attempts made (initial try + retries).
    pub attempts: usize,
    /// The last attempt's failure, rendered.
    pub error: String,
}

/// What one `run_grid` call did.
#[derive(Debug)]
pub struct GridOutcome {
    /// Content-derived grid id.
    pub grid_id: String,
    /// `out_dir/<grid_id>` — ledger, events, and report artifacts.
    pub grid_dir: PathBuf,
    /// Jobs executed by this call.
    pub executed: usize,
    /// Jobs skipped because the ledger already recorded them.
    pub reused: usize,
    /// Total jobs in the grid.
    pub total: usize,
    /// Did every job complete? (False only under [`SchedOptions::job_limit`].)
    pub complete: bool,
    /// Per-cell seed results in canonical order, re-read from the
    /// persisted ledger (empty unless `complete`).
    pub cells: Vec<Vec<SeedResult>>,
    /// The completed grid's ledger as re-read from disk (`None`
    /// unless `complete`) — feed it to [`report::cell_rows`] /
    /// [`report::pressure_rows`] so stdout tables aggregate through
    /// exactly the same path as the rendered artifacts.
    pub ledger: Option<Ledger>,
    /// Report artifacts rendered into `grid_dir`. Empty unless the
    /// grid is `complete` — or partially complete with quarantined
    /// jobs, in which case this holds the partial report.
    pub artifacts: Vec<PathBuf>,
    /// Jobs that exhausted their retries this call (sorted by key).
    /// Non-empty implies `complete == false`.
    pub quarantined: Vec<Quarantine>,
}

/// Execute one job attempt: open its telemetry stream, run the seed,
/// persist the `run_started`/`run_finished` envelope, and build the
/// ledger entry. `panic_fault` optionally wraps the trainer's sink in
/// a [`PanicSink`] so an injected panic unwinds from inside the step
/// loop.
fn run_job(
    engine: &Engine,
    job: &Job,
    grid_dir: &Path,
    io: &Arc<dyn ArtifactIo>,
    panic_fault: Option<(Arc<FaultPlan>, String)>,
) -> Result<LedgerEntry> {
    let events_path = grid_dir.join("events").join(format!("{}.jsonl", job.key));
    let sink = SharedSink::new(JsonlWriter::create_with_io(&events_path, io.clone())?);
    sink.post(&telemetry::ev_run_started(
        &job.key,
        &job.model_key,
        &job.method_key,
        job.seed,
        job.digest,
        job.config_hash,
    ));
    let trainer_sink: Box<dyn TelemetrySink> = match panic_fault {
        Some((plan, id)) => Box::new(PanicSink::new(Box::new(sink.clone()), plan, id)),
        None => Box::new(sink.clone()),
    };
    // detlint: allow(d2) — measured wall_s is observability-only: it
    // rides in telemetry/ledger but is excluded from result digests and
    // every golden comparison (docs/TELEMETRY.md "determinism").
    let t0 = Instant::now();
    let result = harness::run_seed(engine, job.cfg.clone(), Some(trainer_sink))?;
    let wall_s = t0.elapsed().as_secs_f64();
    sink.post(&telemetry::ev_run_finished(&job.key, result.to_json(), wall_s));
    sink.flush()?;
    Ok(LedgerEntry {
        key: job.key.clone(),
        model: job.model_key.clone(),
        method_key: job.method_key.clone(),
        seed: job.seed,
        digest: job.digest,
        config_hash: job.config_hash,
        result,
        wall_s,
    })
}

/// The supervisor's backoff clock: pure step accounting, no wall-time
/// reads. Attempt `i` "waits" `2^i` virtual ticks before the next try
/// — deterministic, reproducible, and free (simulated time costs
/// nothing, exactly like the simulated VRAM budget).
struct VirtualClock {
    ticks: u64,
}

impl VirtualClock {
    fn new() -> VirtualClock {
        VirtualClock { ticks: 0 }
    }

    /// Account the backoff for a failed attempt; returns the delay in
    /// virtual ticks.
    fn backoff(&mut self, attempt: usize) -> u64 {
        let delay = 1u64 << attempt.min(16);
        self.ticks += delay;
        delay
    }
}

/// How one supervised job ended.
enum JobVerdict {
    /// An attempt succeeded.
    Done(Box<LedgerEntry>),
    /// Every attempt failed; the job is quarantined.
    Quarantined(Quarantine),
}

/// Render a `catch_unwind` payload (panics carry `&str` or `String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one job under supervision: up to `1 + retries` isolated
/// attempts, exponential virtual-clock backoff between failures, and a
/// [`Quarantine`] verdict when they are exhausted. Scheduled faults
/// (OOM storms, panics) are consulted per attempt, so a job whose
/// plan hits H attempts recovers on attempt H+1 — within the retry
/// budget — or quarantines beyond it.
fn supervise_job(
    engine: &Engine,
    job: &Job,
    grid_dir: &Path,
    io: &Arc<dyn ArtifactIo>,
    plan: Option<&Arc<FaultPlan>>,
    manifest: &Manifest,
    opts: &SchedOptions,
) -> JobVerdict {
    let mut clock = VirtualClock::new();
    let mut last_err = String::new();
    let mut attempts = 0;
    for attempt in 0..=opts.retries {
        attempts = attempt + 1;
        // A scheduled OOM storm kills the attempt before it trains:
        // the live budget is crushed by a simulated co-tenant burst
        // and not even batch 1 fits. Running the storm *outside* the
        // trainer is deliberate — the retry trains fault-free, so the
        // recorded result (and the grid artifacts) stay bit-identical
        // to an unstormed run.
        let storm = plan.and_then(|p| {
            let id = p.oom_due(&job.key, attempt)?;
            p.fire(&id, "oom", &job.key).then_some(id)
        });
        if storm.is_some() {
            last_err = match manifest.model(&job.model_key) {
                Ok(entry) => format!("{:#}", crate::faults::simulated_oom_storm(entry, &job.cfg)),
                Err(e) => format!("injected OOM storm (model lookup failed: {e:#})"),
            };
        } else {
            let panic_fault =
                plan.and_then(|p| p.panic_due(&job.key, attempt).map(|id| (Arc::clone(p), id)));
            // AssertUnwindSafe: a panicking attempt's state is all
            // attempt-local (trainer, sink, scratch); the shared
            // engine only queues closures on its compute pool and the
            // unwind happens on this worker thread, never inside a
            // pool task — nothing shared is left mid-mutation.
            let caught =
                catch_unwind(AssertUnwindSafe(|| run_job(engine, job, grid_dir, io, panic_fault)));
            match caught {
                Ok(Ok(entry)) => return JobVerdict::Done(Box::new(entry)),
                Ok(Err(e)) => last_err = format!("{e:#}"),
                Err(payload) => last_err = format!("panic: {}", panic_message(payload.as_ref())),
            }
        }
        if attempt < opts.retries {
            let delay = clock.backoff(attempt);
            if !opts.quiet {
                eprintln!(
                    "  job {} attempt {attempts} failed ({last_err}); retrying after \
                     {delay} virtual tick(s)",
                    job.key
                );
            }
        }
    }
    JobVerdict::Quarantined(Quarantine { key: job.key.clone(), attempts, error: last_err })
}

/// Run (or resume) a grid: skip ledger-recorded jobs, execute the rest
/// on the job pool, persist each completion atomically, and — once the
/// grid is whole — re-aggregate from the ledger and render the report
/// artifacts. Aggregation always reads the persisted (JSON-roundtripped)
/// values in job-key order, so interrupted-and-resumed grids, fresh
/// grids, and any `--jobs` width all produce bit-identical artifacts.
pub fn run_grid(spec: &GridSpec, opts: &SchedOptions) -> Result<GridOutcome> {
    anyhow::ensure!(opts.jobs >= 1, "--jobs must be at least 1");
    let manifest = crate::runtime::native::builtin_manifest();
    let jobs = spec.jobs(&manifest)?;
    let grid_id = spec.grid_id(&jobs);
    let grid_dir = opts.out_dir.join(&grid_id);
    std::fs::create_dir_all(grid_dir.join("events"))
        .with_context(|| format!("creating {}", grid_dir.display()))?;

    // Arm the fault plan (if any) against the *full* job-key set, so
    // targeting is identical on resume, and route runtime artifact
    // writes through the fault-injecting IO seam. Recovery writes
    // (healing a torn ledger, the initial header) use the real
    // filesystem: they repair damage, they are not job activity.
    let plan: Option<Arc<FaultPlan>> = match &opts.faults {
        Some(fspec) if !fspec.is_empty() => {
            let keys: Vec<String> = jobs.iter().map(|j| j.key.clone()).collect();
            let p = FaultPlan::arm(fspec, &grid_dir, &keys)?;
            if !opts.quiet {
                println!("  fault plan armed: {} (log: {})", fspec.render(), p.log_path().display());
            }
            Some(p)
        }
        _ => None,
    };
    let io: Arc<dyn ArtifactIo> = match &plan {
        Some(p) => Arc::new(FaultyIo::new(Arc::clone(p))),
        None => Arc::new(RealIo),
    };

    let ledger_path = grid_dir.join("ledger.json");
    let mut led = if ledger_path.exists() {
        match Ledger::load_relaxed(&ledger_path)? {
            Loaded::Usable { ledger, dropped } => {
                ledger.validate_against(&grid_id, &jobs)?;
                if dropped > 0 {
                    if !opts.quiet {
                        eprintln!(
                            "  recovered {}: dropped {dropped} torn/invalid trailing \
                             record(s); the affected job(s) rerun",
                            ledger_path.display()
                        );
                    }
                    // Heal: rewrite the valid prefix atomically so the
                    // torn tail never has to be re-skipped.
                    ledger.save(&ledger_path, &RealIo)?;
                }
                ledger
            }
            Loaded::Corrupt { reason } => {
                if !opts.quiet {
                    eprintln!(
                        "  rebuilding {}: {reason}; every job reruns",
                        ledger_path.display()
                    );
                }
                Ledger::new(&grid_id, spec, &jobs)
            }
        }
    } else {
        Ledger::new(&grid_id, spec, &jobs)
    };
    // The file on disk always starts with a valid sealed header — even
    // before the first job completes.
    led.save(&ledger_path, &RealIo)?;

    let mut pending: Vec<Job> =
        jobs.iter().filter(|j| !led.is_done(&j.key)).cloned().collect();
    let reused = jobs.len() - pending.len();
    if let Some(k) = opts.job_limit {
        pending.truncate(k);
    }
    let executed = pending.len();

    let mut quarantined: Vec<Quarantine> = Vec::new();
    if !pending.is_empty() {
        let total_threads = if opts.total_threads > 0 {
            opts.total_threads
        } else {
            resolve_threads(std::env::var("TRIACCEL_THREADS").ok().as_deref())
        };
        // Concurrent workers never exceed the pending work *or* the
        // thread budget (more jobs than threads would oversubscribe no
        // matter how the budget is split), and each worker's compute
        // pool(s) get an equal share of the whole budget — so
        // `workers × replicas × threads_each ≤ total_threads` always,
        // and a resume with one pending job still uses the full
        // budget. Replicated configs shrink the worker cap too: every
        // live replica holds its own pool, so a worker "costs"
        // `replicas` pool slots out of the budget.
        let replicas_max = pending.iter().map(|j| j.cfg.replicas).max().unwrap_or(1).max(1);
        let workers = opts
            .jobs
            .min(pending.len())
            .min((total_threads / replicas_max).max(1))
            .max(1);
        let threads_each = budget_threads(total_threads, workers, replicas_max);
        let queue = Mutex::new(VecDeque::from(pending));
        let led_mutex = Mutex::new(&mut led);
        let quarantine_sink: Mutex<Vec<Quarantine>> = Mutex::new(Vec::new());
        // The failure latch aborts the grid — reserved for ledger
        // persistence failures (a completion we cannot record is not a
        // per-job problem). Job failures never land here: the
        // supervisor retries, then quarantines.
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let grid_dir_ref = &grid_dir;
        let ledger_path_ref = &ledger_path;
        let manifest_ref = &manifest;
        let plan_ref = &plan;
        let io_ref = &io;
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    // One engine per worker, reused across every job it
                    // runs: the pool handles and the warm scratch
                    // arenas behind it survive job boundaries. A
                    // replicated grid gets a replicated engine sized to
                    // the widest job; narrower jobs just leave the
                    // extra replicas parked.
                    let engine = if replicas_max > 1 {
                        Engine::native_replicated(replicas_max, threads_each)
                    } else {
                        Engine::native_with_pool(Pool::new(threads_each))
                    };
                    loop {
                        if failure.lock().unwrap().is_some() {
                            return;
                        }
                        let job = queue.lock().unwrap().pop_front();
                        let Some(job) = job else { return };
                        let verdict = supervise_job(
                            &engine,
                            &job,
                            grid_dir_ref,
                            io_ref,
                            plan_ref.as_ref(),
                            manifest_ref,
                            opts,
                        );
                        match verdict {
                            JobVerdict::Done(entry) => {
                                if !opts.quiet {
                                    println!(
                                        "  job {:<44} {:>7.2}s  acc {:5.1}%",
                                        entry.key, entry.wall_s, entry.result.test_acc_pct
                                    );
                                }
                                let entry = *entry;
                                let mut l = led_mutex.lock().unwrap();
                                l.insert(entry.clone());
                                // Fast path: append one sealed record.
                                // If the append fails (transient IO
                                // fault, torn write), fall back to a
                                // full atomic rewrite; only when both
                                // fail is the grid aborted.
                                let saved = Ledger::append_entry(
                                    &entry,
                                    ledger_path_ref,
                                    io_ref.as_ref(),
                                )
                                .or_else(|append_err| {
                                    l.save(ledger_path_ref, io_ref.as_ref()).with_context(
                                        || format!("after failed append ({append_err:#})"),
                                    )
                                });
                                if let Err(e) = saved {
                                    let mut f = failure.lock().unwrap();
                                    if f.is_none() {
                                        *f = Some(e.context(format!(
                                            "persisting job `{}`",
                                            entry.key
                                        )));
                                    }
                                    return;
                                }
                            }
                            JobVerdict::Quarantined(q) => {
                                if !opts.quiet {
                                    eprintln!(
                                        "  job {:<44} QUARANTINED after {} attempt(s): {}",
                                        q.key, q.attempts, q.error
                                    );
                                }
                                quarantine_sink.lock().unwrap().push(q);
                            }
                        }
                    }
                });
            }
        });
        let first_failure = failure.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(e) = first_failure {
            return Err(e);
        }
        quarantined =
            quarantine_sink.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        quarantined.sort_by(|a, b| a.key.cmp(&b.key));
    }

    let complete = jobs.iter().all(|j| led.is_done(&j.key));
    let mut outcome = GridOutcome {
        grid_id,
        grid_dir: grid_dir.clone(),
        executed,
        reused,
        total: jobs.len(),
        complete,
        cells: Vec::new(),
        ledger: None,
        artifacts: Vec::new(),
        quarantined: Vec::new(),
    };
    if complete {
        // Reload from disk so aggregation consumes exactly the
        // persisted bits — the same inputs a later resume or `report`
        // invocation would read.
        let led = Ledger::load(&ledger_path)?;
        outcome.cells = led.cell_results()?;
        outcome.artifacts = report::render(&grid_dir, &led)?;
        outcome.ledger = Some(led);
    } else if !quarantined.is_empty() {
        // Quarantined jobs must not silently erase the rest of the
        // grid's work: render a partial report that marks their cells.
        // (Plain `job_limit` incompleteness still renders nothing —
        // that is a simulated kill, not a supervised failure.)
        let led = Ledger::load(&ledger_path)?;
        outcome.artifacts = report::render_partial(&grid_dir, &led, &quarantined)?;
        outcome.quarantined = quarantined;
    }
    Ok(outcome)
}

// ---------------------------------------------------------------------------
// Grid builders: the CLI subcommands' decompositions.
// ---------------------------------------------------------------------------

fn cell(model_key: &str, label: &str, seeds: &[u64], base: Config) -> CellSpec {
    CellSpec {
        model_key: model_key.to_string(),
        label: label.to_string(),
        method_key: registry::effective_key(&base),
        seeds: harness::normalize_seeds(seeds),
        base,
    }
}

/// Table 1: every model × the paper's three method columns.
pub fn table1_spec(models: &[&str], seeds: &[u64], tweak: &dyn Fn(&mut Config)) -> GridSpec {
    let mut cells = Vec::new();
    for model in models {
        for method in [Method::Fp32, Method::AmpStatic, Method::TriAccel] {
            let mut base = Config::cell(model, method, 0);
            tweak(&mut base);
            cells.push(cell(model, method.name(), seeds, base));
        }
    }
    GridSpec { kind: GridKind::Table1, cells }
}

/// Table 2: the four ablation rows ([`harness::TABLE2_ROWS`]) for one
/// model.
pub fn table2_spec(model: &str, seeds: &[u64], tweak: &dyn Fn(&mut Config)) -> GridSpec {
    let mut cells = Vec::new();
    for (label, method, ablation) in harness::TABLE2_ROWS {
        let mut base = Config::cell(model, method, 0);
        base.ablation = ablation;
        tweak(&mut base);
        cells.push(cell(model, label, seeds, base));
    }
    GridSpec { kind: GridKind::Table2, cells }
}

/// The adaptive-behaviour figure: one Tri-Accel run at one seed.
pub fn fig_spec(model: &str, seed: u64, tweak: &dyn Fn(&mut Config)) -> GridSpec {
    let mut base = Config::cell(model, Method::TriAccel, 0);
    tweak(&mut base);
    GridSpec {
        kind: GridKind::Fig,
        cells: vec![cell(model, "Tri-Accel", &[seed], base)],
    }
}

/// The VRAM-pressure sweep: registry methods × one model under a
/// time-varying budget trace. Method keys and the trace spec are
/// validated here, before any training burns time.
pub fn pressure_spec(
    model: &str,
    method_keys: &[&str],
    seeds: &[u64],
    trace: &str,
    tweak: &dyn Fn(&mut Config),
) -> Result<GridSpec> {
    // Canonicalize through parse→to_spec so a `replay:` trace carries
    // its content digest into every config fingerprint (and thus the
    // grid id): swapping the trace file's bytes maps to a new grid.
    let trace = crate::memsim::BudgetTrace::parse(trace)?.to_spec();
    let specs: Vec<&registry::MethodSpec> = method_keys
        .iter()
        .map(|k| registry::resolve(k.trim()))
        .collect::<Result<_>>()?;
    let mut cells = Vec::new();
    for spec in specs {
        let mut base = Config::cell(model, spec.family, 0);
        registry::apply(&mut base, spec);
        tweak(&mut base);
        base.mem_trace = trace.clone();
        cells.push(cell(model, spec.label, seeds, base));
    }
    Ok(GridSpec { kind: GridKind::Pressure, cells })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_tweak() -> impl Fn(&mut Config) {
        |cfg: &mut Config| {
            cfg.steps_per_epoch = Some(2);
            cfg.epochs = 1;
            cfg.train_examples = 256;
            cfg.eval_examples = 128;
            cfg.batch_init = 32;
            cfg.warmup_epochs = 0;
            cfg.mem_budget_gb = 0.0;
        }
    }

    #[test]
    fn jobs_are_cell_seed_ordered_and_keyed() {
        let manifest = crate::runtime::native::builtin_manifest();
        let spec = table1_spec(&["tiny_cnn_c10"], &[1, 0], &tiny_tweak());
        let jobs = spec.jobs(&manifest).unwrap();
        assert_eq!(jobs.len(), 6, "3 methods x 2 seeds");
        assert_eq!(jobs[0].key, "00_tiny_cnn_c10_fp32_s0", "seeds normalized ascending");
        assert_eq!(jobs[1].key, "00_tiny_cnn_c10_fp32_s1");
        assert_eq!(jobs[4].cell, 2);
        assert_eq!(jobs[4].method_key, "tri_accel");
        assert_eq!(jobs[4].cfg.seed, 0);
        let with_dup = table1_spec(&["tiny_cnn_c10"], &[0, 0, 1], &tiny_tweak());
        assert_eq!(with_dup.jobs(&manifest).unwrap().len(), 6, "duplicate seeds collapse");
    }

    #[test]
    fn grid_id_tracks_content() {
        let manifest = crate::runtime::native::builtin_manifest();
        let a = table1_spec(&["tiny_cnn_c10"], &[0], &tiny_tweak());
        let id_a = a.grid_id(&a.jobs(&manifest).unwrap());
        let id_a2 = a.grid_id(&a.jobs(&manifest).unwrap());
        assert_eq!(id_a, id_a2, "same spec, same id");
        assert!(id_a.starts_with("table1-"), "{id_a}");
        let b = table1_spec(&["tiny_cnn_c10"], &[0, 1], &tiny_tweak());
        assert_ne!(id_a, b.grid_id(&b.jobs(&manifest).unwrap()), "seed list changes id");
        let c = table1_spec(&["tiny_cnn_c100"], &[0], &tiny_tweak());
        assert_ne!(id_a, c.grid_id(&c.jobs(&manifest).unwrap()), "model changes id");
    }

    #[test]
    fn replicated_grids_get_suffixed_keys_and_fresh_ids() {
        let manifest = crate::runtime::native::builtin_manifest();
        let plain = table1_spec(&["tiny_cnn_c10"], &[0], &tiny_tweak());
        let tweak = tiny_tweak();
        let spec = table1_spec(&["tiny_cnn_c10"], &[0], &|cfg: &mut Config| {
            tweak(cfg);
            cfg.replicas = 2;
        });
        let jobs = spec.jobs(&manifest).unwrap();
        assert_eq!(jobs[0].key, "00_tiny_cnn_c10_fp32_r2_s0");
        assert!(jobs.iter().all(|j| j.cfg.replicas == 2));
        assert_ne!(
            spec.grid_id(&jobs),
            plain.grid_id(&plain.jobs(&manifest).unwrap()),
            "replica count is part of the grid identity"
        );
    }

    #[test]
    fn unknown_model_fails_at_decomposition() {
        let manifest = crate::runtime::native::builtin_manifest();
        let spec = table1_spec(&["resnet18_c10"], &[0], &tiny_tweak());
        assert!(spec.jobs(&manifest).is_err());
    }

    #[test]
    fn pressure_spec_validates_inputs_early() {
        assert!(pressure_spec("tiny_cnn_c10", &["nope"], &[0], "const", &tiny_tweak()).is_err());
        assert!(
            pressure_spec("tiny_cnn_c10", &["fp32"], &[0], "wobble:3", &tiny_tweak()).is_err()
        );
        let methods = ["fp32", "greedy_batch"];
        let ok = pressure_spec("tiny_cnn_c10", &methods, &[0], "ramp:1:4:0.6", &tiny_tweak())
            .unwrap();
        assert_eq!(ok.cells.len(), 2);
        assert_eq!(ok.cells[0].base.mem_trace, "ramp:1:4:0.6");
        let sc = pressure_spec("tiny_cnn_c10", &["fp32"], &[0], "scenario:spike", &tiny_tweak())
            .unwrap();
        assert_eq!(sc.cells[0].base.mem_trace, "scenario:spike", "scenarios are canonical specs");
    }

    #[test]
    fn table2_cells_map_to_effective_keys() {
        let spec = table2_spec("tiny_cnn_c10", &[0], &tiny_tweak());
        let keys: Vec<&str> = spec.cells.iter().map(|c| c.method_key.as_str()).collect();
        assert_eq!(keys[0], "fp32");
        assert_eq!(keys[1], "greedy_batch", "+ Dynamic Batch is the elasticity-only spec");
        assert!(keys[2].starts_with("tri_accel[p1b0c0"), "unnamed composition: {}", keys[2]);
        assert_eq!(keys[3], "tri_accel");
    }

    #[test]
    fn sanitize_makes_filename_safe_keys() {
        assert_eq!(sanitize("tri_accel[p1b0c0&pin=auto]"), "tri_accel-p1b0c0-pin-auto-");
        assert_eq!(sanitize("ok_name-1.2"), "ok_name-1.2");
    }
}
