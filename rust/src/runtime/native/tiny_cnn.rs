//! The `tiny_cnn` model for the native backend — a pure-Rust port of
//! `python/compile/models/tiny_cnn.py` + `train_graph.py` semantics:
//!
//! * forward: [conv3×3 → BN → ReLU → maxpool2]×2 → conv3×3 → BN → ReLU
//!   → global-avg-pool → dense head; each conv/dense consumes one entry
//!   of the runtime `codes` vector (weights + input activations rounded
//!   through qdq / mp_matmul, BN always fp32);
//! * backward: hand-written reverse pass with the Pallas kernels' VJP
//!   contract (cotangents re-quantized at each precision layer);
//! * train step: loss-scaled grads, overflow detection (any non-finite
//!   grad skips the whole update and holds BN state), per-layer
//!   grad-variance/norm stats, fused SGD+momentum with weight decay and
//!   per-layer LR scales;
//! * curv step: block-diagonal Hessian-vector products via per-layer
//!   central-difference of the gradient (one power-iteration step per
//!   firing, probe vectors normalized per layer) — the strict-block
//!   variant of `curv_graph.py`.
//!
//! Compute substrate (PR 2): every conv executes as fused-qdq im2col +
//! tiled GEMM and every dense as GEMM (`gemm.rs`), parallelized by the
//! deterministic worker pool (`pool.rs`); every scratch buffer — im2col
//! panels, forward caches, cotangents, gradients, even the BN running-
//! stat updates — comes from the [`Exec`]'s arena, so a warm train step
//! performs zero buffer allocations (pinned by the test below). The
//! only steady-state allocations left are the 4-float stat vectors the
//! Backend API returns and the parameter clones inside the (amortized)
//! curvature probe.
//!
//! Parameter order (the manifest contract): conv{1,2,3}/w, bn{1,2,3}
//! gamma+beta interleaved per block, then head/w, head/b. BN state is
//! [rm, rv] per block, zeros/ones initialized.

#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::gemm;
use super::ops;
use super::qdq;
use super::Exec;
use crate::manifest::ModelEntry;
use crate::runtime::backend::ModelState;
use crate::runtime::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::util::rng::Rng;

/// Conv-block output channels.
pub const CHANNELS: [usize; 3] = [16, 32, 64];
/// Spatial side length at the input of each conv block.
const DIMS: [usize; 3] = [32, 16, 8];
/// Dense-head input features (= last conv channels after GAP).
const FEATURES: usize = 64;
/// SGD momentum (kernels/ref.py::SGD_MOMENTUM).
const MOMENTUM: f32 = 0.9;
/// Number of flat parameter tensors.
const N_PARAMS: usize = 11;

/// Forward-pass caches consumed by [`backward`]. Every buffer is
/// arena-backed; [`release_fwd`] checks them back in.
struct Fwd {
    /// Quantized im2col panels per conv block (rows × 9·cin) — both the
    /// GEMM A-operand and the `x_colsᵀ·g` weight-gradient operand.
    cols: [Vec<f32>; 3],
    /// Quantized conv weights, per conv block.
    wq: [Vec<f32>; 3],
    /// Conv outputs (BN inputs), per conv block.
    conv_out: [Vec<f32>; 3],
    /// BN batch statistics, per conv block.
    bn_mean: [Vec<f32>; 3],
    bn_inv: [Vec<f32>; 3],
    /// BN outputs (ReLU pre-activations), per conv block.
    bn_out: [Vec<f32>; 3],
    /// Max-pool argmax maps for blocks 0 and 1.
    arg: [Vec<u8>; 2],
    /// Quantized dense input / weight.
    head_xq: Vec<f32>,
    head_wq: Vec<f32>,
    /// Cotangent of the (unscaled) mean loss w.r.t. the logits.
    dlogits: Vec<f32>,
    /// Updated BN running stats (train mode), [rm, rv] per block.
    new_state: [Vec<f32>; 6],
    loss: f32,
    correct: i64,
}

/// Return every forward cache to the arena.
fn release_fwd(ex: &mut Exec, fwd: Fwd) {
    let Fwd {
        cols,
        wq,
        conv_out,
        bn_mean,
        bn_inv,
        bn_out,
        arg,
        head_xq,
        head_wq,
        dlogits,
        new_state,
        ..
    } = fwd;
    ex.arena.put_all(cols);
    ex.arena.put_all(wq);
    ex.arena.put_all(conv_out);
    ex.arena.put_all(bn_mean);
    ex.arena.put_all(bn_inv);
    ex.arena.put_all(bn_out);
    for a in arg {
        ex.arena.put_u8(a);
    }
    ex.arena.put(head_xq);
    ex.arena.put(head_wq);
    ex.arena.put(dlogits);
    ex.arena.put_all(new_state);
}

fn forward(
    ex: &mut Exec,
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    n: usize,
    codes: &[i32],
    train: bool,
) -> Fwd {
    debug_assert_eq!(params.len(), N_PARAMS);
    let Exec { pool, arena } = ex;
    let classes = entry.num_classes;
    let mut cols: [Vec<f32>; 3] = Default::default();
    let mut wq: [Vec<f32>; 3] = Default::default();
    let mut conv_out: [Vec<f32>; 3] = Default::default();
    let mut bn_mean: [Vec<f32>; 3] = Default::default();
    let mut bn_inv: [Vec<f32>; 3] = Default::default();
    let mut bn_out: [Vec<f32>; 3] = Default::default();
    let mut arg: [Vec<u8>; 2] = Default::default();
    let mut new_state: [Vec<f32>; 6] = Default::default();

    // `cur` owns the activation flowing between blocks (None = batch.x).
    let mut cur: Option<Vec<f32>> = None;
    let mut cin = 3usize;
    for li in 0..3 {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let code = codes[li];
        let rows = n * dim * dim;
        let k9 = 9 * cin;

        // im2col with the qdq round-trip fused into the pack — the only
        // place input activations are rounded, and no quantized copy of
        // the activation tensor is ever materialized.
        let mut c_buf = arena.take(rows * k9);
        {
            let src: &[f32] = cur.as_deref().unwrap_or(x);
            gemm::im2col3x3_qdq(pool, src, n, dim, dim, cin, code, &mut c_buf);
        }
        let mut w_buf = arena.take(9 * cin * cout);
        qdq::qdq_into(&params[li * 3], &mut w_buf, code);
        let mut conv = arena.take(rows * cout);
        gemm::gemm(pool, arena, &c_buf, &w_buf, &mut conv, rows, k9, cout, false);

        let mut bnout = arena.take(rows * cout);
        let mut nrm = arena.take(cout);
        let mut nrv = arena.take(cout);
        let mut mean = arena.take(cout);
        let mut inv = arena.take(cout);
        ops::bn_fwd_into(
            &conv,
            rows,
            cout,
            &params[li * 3 + 1],
            &params[li * 3 + 2],
            &state[li * 2],
            &state[li * 2 + 1],
            train,
            &mut bnout,
            &mut nrm,
            &mut nrv,
            &mut mean,
            &mut inv,
        );
        new_state[li * 2] = nrm;
        new_state[li * 2 + 1] = nrv;

        // ReLU on a copy — bn_out stays cached as the pre-activation.
        let mut r = arena.take(rows * cout);
        r.copy_from_slice(&bnout);
        ops::relu_inplace(&mut r);
        let next = if li < 2 {
            let (ho, wo) = (dim / 2, dim / 2);
            let mut p_out = arena.take(n * ho * wo * cout);
            let mut a_buf = arena.take_u8(n * ho * wo * cout);
            ops::maxpool2_fwd_into(&r, n, dim, dim, cout, &mut p_out, &mut a_buf);
            arg[li] = a_buf;
            p_out
        } else {
            let mut g_out = arena.take(n * cout);
            ops::gap_fwd_into(&r, n, dim, dim, cout, &mut g_out);
            g_out
        };
        arena.put(r);
        if let Some(old) = cur.take() {
            arena.put(old);
        }
        cur = Some(next);

        cols[li] = c_buf;
        wq[li] = w_buf;
        conv_out[li] = conv;
        bn_mean[li] = mean;
        bn_inv[li] = inv;
        bn_out[li] = bnout;
        cin = cout;
    }

    // Dense head: bias-preloaded GEMM (mp_matmul operand quantization).
    let code = codes[3];
    let h_act = cur.take().expect("three conv blocks ran");
    let mut head_xq = arena.take(n * FEATURES);
    qdq::qdq_into(&h_act, &mut head_xq, code);
    arena.put(h_act);
    let mut head_wq = arena.take(params[9].len());
    qdq::qdq_into(&params[9], &mut head_wq, code);
    let mut logits = arena.take(n * classes);
    for r in 0..n {
        logits[r * classes..(r + 1) * classes].copy_from_slice(&params[10]);
    }
    gemm::gemm(pool, arena, &head_xq, &head_wq, &mut logits, n, FEATURES, classes, true);
    let mut dlogits = arena.take(n * classes);
    let (loss, correct) = ops::softmax_ce_into(&logits, y, n, classes, &mut dlogits);
    arena.put(logits);

    Fwd {
        cols,
        wq,
        conv_out,
        bn_mean,
        bn_inv,
        bn_out,
        arg,
        head_xq,
        head_wq,
        dlogits,
        new_state,
        loss,
        correct,
    }
}

/// Reverse pass: returns the 11 parameter gradients of the *unscaled*
/// mean loss (the loss-scale round-trip is exact for 2^k scales).
/// Gradients are arena buffers; the caller checks them back in.
fn backward(
    ex: &mut Exec,
    entry: &ModelEntry,
    fwd: &Fwd,
    params: &[Vec<f32>],
    codes: &[i32],
    loss_scale: f32,
    n: usize,
) -> [Vec<f32>; N_PARAMS] {
    let Exec { pool, arena } = ex;
    let classes = entry.num_classes;
    let mut grads: [Vec<f32>; N_PARAMS] = Default::default();

    // Seed with the cotangent of the scaled loss.
    let mut g_logits = arena.take(n * classes);
    for (d, &v) in g_logits.iter_mut().zip(fwd.dlogits.iter()) {
        *d = v * loss_scale;
    }

    // Dense head (mp_matmul VJP): dx/dw see the quantized cotangent,
    // the bias grad sits outside the kernel and sees the raw one.
    let mut gq = arena.take(n * classes);
    qdq::qdq_into(&g_logits, &mut gq, codes[3]);
    let mut dx_head = arena.take(n * FEATURES);
    gemm::gemm_a_bt(pool, arena, &gq, &fwd.head_wq, &mut dx_head, n, classes, FEATURES, false);
    let mut dw_head = arena.take(FEATURES * classes);
    gemm::gemm_at_b(pool, arena, &fwd.head_xq, &gq, &mut dw_head, n, FEATURES, classes);
    arena.put(gq);
    let mut db = arena.take(classes);
    for bi in 0..n {
        for (d, &v) in db.iter_mut().zip(g_logits[bi * classes..(bi + 1) * classes].iter()) {
            *d += v;
        }
    }
    arena.put(g_logits);
    grads[9] = dw_head;
    grads[10] = db;

    let mut g = dx_head;
    for li in (0..3).rev() {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let cin = if li == 0 { 3 } else { CHANNELS[li - 1] };
        let rows = n * dim * dim;
        let k9 = 9 * cin;

        let mut gs = arena.take(rows * cout);
        if li == 2 {
            ops::gap_bwd_into(&g, n, dim, dim, cout, &mut gs);
        } else {
            ops::maxpool2_bwd_into(&g, &fwd.arg[li], n, dim, dim, cout, &mut gs);
        }
        arena.put(g);
        ops::relu_bwd_inplace(&mut gs, &fwd.bn_out[li]);

        let mut dxbn = arena.take(rows * cout);
        let mut dgamma = arena.take(cout);
        let mut dbeta = arena.take(cout);
        ops::bn_bwd_into(
            &fwd.conv_out[li],
            &gs,
            rows,
            cout,
            &params[li * 3 + 1],
            &fwd.bn_mean[li],
            &fwd.bn_inv[li],
            &mut dxbn,
            &mut dgamma,
            &mut dbeta,
        );
        arena.put(gs);

        // Conv backward: dw = x_colsᵀ·g (ordered-reduction GEMM), then
        // dx = col2im(g·Wᵀ); qdq VJP rounds both outgoing cotangents.
        let mut dw = arena.take(k9 * cout);
        gemm::gemm_at_b(pool, arena, &fwd.cols[li], &dxbn, &mut dw, rows, k9, cout);
        qdq::qdq_inplace(&mut dw, codes[li]);
        g = if li == 0 {
            // The cotangent w.r.t. the input images is never consumed —
            // skip its GEMM + col2im entirely (the seed kernels paid
            // for it on every step).
            arena.put(dxbn);
            Vec::new()
        } else {
            let mut dcols = arena.take(rows * k9);
            gemm::gemm_a_bt(pool, arena, &dxbn, &fwd.wq[li], &mut dcols, rows, cout, k9, false);
            arena.put(dxbn);
            let mut dx = arena.take(rows * cin);
            gemm::col2im3x3(pool, &dcols, n, dim, dim, cin, &mut dx);
            arena.put(dcols);
            qdq::qdq_inplace(&mut dx, codes[li]);
            dx
        };

        grads[li * 3] = dw;
        grads[li * 3 + 1] = dgamma;
        grads[li * 3 + 2] = dbeta;
    }
    arena.put(g); // empty after block 0 (zero-capacity puts are no-ops)

    // Unscale (exact for power-of-two loss scales).
    let inv = 1.0 / loss_scale;
    for gvec in grads.iter_mut() {
        for v in gvec.iter_mut() {
            *v *= inv;
        }
    }
    grads
}

/// Per-precision-layer (variance, Σg²) of the parameter gradients,
/// mirroring `train_graph._per_layer_grad_stats`. NaN/inf gradients
/// propagate into the stats (the controller ignores non-finite values).
fn layer_stats(entry: &ModelEntry, grads: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let l_count = entry.num_layers;
    let mut sum = vec![0f64; l_count];
    let mut sq = vec![0f64; l_count];
    let mut count = vec![0usize; l_count];
    for (spec, g) in entry.params.iter().zip(grads) {
        if spec.layer_idx < 0 {
            continue;
        }
        let li = spec.layer_idx as usize;
        for &v in g {
            sum[li] += v as f64;
            sq[li] += (v as f64) * (v as f64);
        }
        count[li] += g.len();
    }
    let mut var = Vec::with_capacity(l_count);
    let mut norm = Vec::with_capacity(l_count);
    for li in 0..l_count {
        let cnt = count[li].max(1) as f64;
        let mean = sum[li] / cnt;
        let raw = sq[li] / cnt - mean * mean;
        // Clamp round-off below zero but let NaN through (overflow
        // steps must not report a fake zero variance).
        let v = if raw.is_nan() { f64::NAN } else { raw.max(0.0) };
        var.push(v as f32);
        norm.push(sq[li] as f32);
    }
    (var, norm)
}

/// Seed-deterministic parameter/state materialization (he-normal convs,
/// kaiming-uniform dense, unit gammas, zero betas/bias; BN running
/// stats start at (0, 1)). Each tensor draws from its own RNG stream,
/// so the init is independent of evaluation order.
pub fn init(entry: &ModelEntry, seed: i32) -> Result<ModelState> {
    let base = seed as i64 as u64;
    let mut params = Vec::with_capacity(entry.params.len());
    for (i, spec) in entry.params.iter().enumerate() {
        let mut rng = Rng::stream(base, 0x1817 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let v: Vec<f32> = if spec.shape.len() == 4 {
            // conv kernel: he_normal, fan_in = k*k*cin.
            let fan_in = (spec.shape[0] * spec.shape[1] * spec.shape[2]).max(1);
            let s = (2.0 / fan_in as f64).sqrt() as f32;
            (0..spec.elems).map(|_| rng.next_normal() * s).collect()
        } else if spec.shape.len() == 2 {
            // dense kernel: uniform(-1/sqrt(fan_in), 1/sqrt(fan_in)).
            let bound = 1.0 / (spec.shape[0].max(1) as f32).sqrt();
            (0..spec.elems)
                .map(|_| -bound + rng.next_f32() * (2.0 * bound))
                .collect()
        } else if spec.name.ends_with("gamma") {
            vec![1.0; spec.elems]
        } else {
            vec![0.0; spec.elems] // beta / bias
        };
        params.push(v);
    }
    let mom = entry.params.iter().map(|p| vec![0f32; p.elems]).collect();
    // BN state interleaves [running_mean, running_var] per block.
    let state = entry
        .state_shapes
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let elems: usize = shape.iter().product();
            if i % 2 == 0 {
                vec![0f32; elems]
            } else {
                vec![1f32; elems]
            }
        })
        .collect();
    Ok(ModelState { params, mom, state })
}

/// One fused SGD+momentum training step (train_graph.py semantics).
pub fn train_step(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &mut ModelState,
    batch: &Batch,
    ctrl: &StepCtrl,
) -> Result<TrainOutputs> {
    let n = batch.n;
    let mut fwd = forward(
        ex,
        entry,
        &st.params,
        &st.state,
        &batch.x,
        &batch.y,
        n,
        &ctrl.codes,
        true,
    );
    let grads = backward(ex, entry, &fwd, &st.params, &ctrl.codes, ctrl.loss_scale, n);
    let overflow = grads.iter().any(|g| g.iter().any(|v| !v.is_finite()));
    let (grad_var, grad_norm) = layer_stats(entry, &grads);

    // Fused update with the overflow gate as a runtime mask: an
    // overflowed step leaves params, momentum, and BN state untouched.
    let mask = if overflow { 0f32 } else { 1f32 };
    for (i, spec) in entry.params.iter().enumerate() {
        let scale = if spec.layer_idx >= 0 {
            ctrl.lr_scales[spec.layer_idx as usize]
        } else {
            1.0
        };
        let lr_eff = ctrl.lr * scale;
        let p = &mut st.params[i];
        let m = &mut st.mom[i];
        let g = &grads[i];
        for k in 0..p.len() {
            let g_eff = (g[k] + ctrl.weight_decay * p[k]) * mask;
            let m_new = MOMENTUM * m[k] + g_eff;
            let m_out = if mask > 0.5 { m_new } else { m[k] };
            p[k] -= lr_eff * mask * m_out;
            m[k] = m_out;
        }
    }
    if !overflow {
        // Swap the arena-backed running stats in; the displaced old
        // state vectors ride back to the arena through `new_state`.
        for (dst, src) in st.state.iter_mut().zip(fwd.new_state.iter_mut()) {
            std::mem::swap(dst, src);
        }
    }
    let (loss, correct) = (fwd.loss, fwd.correct);
    ex.arena.put_all(grads);
    release_fwd(ex, fwd);
    Ok(TrainOutputs { loss, correct, grad_var, grad_norm, overflow })
}

/// Eval with running-stat BN (codes honoured, state untouched).
pub fn eval_batch(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    codes: &[i32],
) -> Result<EvalResult> {
    let fwd = forward(ex, entry, &st.params, &st.state, &batch.x, &batch.y, batch.n, codes, false);
    let (loss, correct) = (fwd.loss, fwd.correct);
    release_fwd(ex, fwd);
    Ok(EvalResult { loss, correct, total: batch.n })
}

/// Relative step size of the central-difference HVP probe.
const FD_EPS_REL: f64 = 1e-2;

/// Gradients of the unscaled train-mode loss at `params` (arena-backed;
/// the caller returns them).
fn grad_at(
    ex: &mut Exec,
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    batch: &Batch,
    codes: &[i32],
) -> [Vec<f32>; N_PARAMS] {
    let fwd = forward(ex, entry, params, state, &batch.x, &batch.y, batch.n, codes, true);
    let grads = backward(ex, entry, &fwd, params, codes, 1.0, batch.n);
    release_fwd(ex, fwd);
    grads
}

/// One amortized power-iteration step per precision layer:
/// block-diagonal HVP `H_l u_l` via a per-layer central difference of
/// the gradient, Rayleigh quotient `λ_l`, and normalized next probe
/// written back into `probes` (curv_graph.py strict-block semantics).
/// The two perturbed parameter sets are plain clones — the parameter
/// footprint is tiny next to the activation scratch, and curvature
/// fires on the amortized control cadence, not every step.
pub fn curv_step(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    probes: &mut [Vec<f32>],
    codes: &[i32],
) -> Result<Vec<f32>> {
    let l_count = entry.num_layers;
    let mut lambdas = vec![0f32; l_count];
    for li in 0..l_count {
        let idxs: Vec<usize> = entry
            .params
            .iter()
            .enumerate()
            .filter(|(_, s)| s.layer_idx == li as i64)
            .map(|(i, _)| i)
            .collect();
        let un: f64 = idxs
            .iter()
            .map(|&i| probes[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt();
        if un < 1e-12 {
            continue; // degenerate probe — λ stays 0, probe untouched
        }
        let tn: f64 = idxs
            .iter()
            .map(|&i| st.params[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt();
        let eps = (FD_EPS_REL * (tn + 1.0) / un) as f32;

        let mut pp = st.params.clone();
        let mut pm = st.params.clone();
        for &i in &idxs {
            for k in 0..pp[i].len() {
                let d = eps * probes[i][k];
                pp[i][k] += d;
                pm[i][k] -= d;
            }
        }
        let gp = grad_at(ex, entry, &pp, &st.state, batch, codes);
        let gm = grad_at(ex, entry, &pm, &st.state, batch, codes);

        let inv2e = 1.0 / (2.0 * eps);
        let mut num = 0f64;
        let mut den = 0f64;
        let mut hn2 = 0f64;
        let mut hu: Vec<(usize, Vec<f32>)> = Vec::with_capacity(idxs.len());
        for &i in &idxs {
            let mut h = ex.arena.take(gp[i].len());
            for (hv, (&a, &b)) in h.iter_mut().zip(gp[i].iter().zip(gm[i].iter())) {
                *hv = (a - b) * inv2e;
            }
            for (k, &hv) in h.iter().enumerate() {
                num += probes[i][k] as f64 * hv as f64;
                den += (probes[i][k] as f64) * (probes[i][k] as f64);
                hn2 += (hv as f64) * (hv as f64);
            }
            hu.push((i, h));
        }
        let hn = hn2.sqrt() + 1e-12;
        lambdas[li] = (num / (den + 1e-12)) as f32;
        for (i, h) in hu {
            for (p, &hv) in probes[i].iter_mut().zip(h.iter()) {
                *p = (hv as f64 / hn) as f32;
            }
            ex.arena.put(h);
        }
        ex.arena.put_all(gp);
        ex.arena.put_all(gm);
    }
    Ok(lambdas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{BF16, FP16, FP32};
    use crate::runtime::native::builtin_manifest;

    fn entry() -> ModelEntry {
        builtin_manifest().model("tiny_cnn_c10").unwrap().clone()
    }

    fn rand_batch(n: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        Batch::new(x, y)
    }

    #[test]
    fn init_shapes_match_manifest() {
        let e = entry();
        let st = init(&e, 3).unwrap();
        assert_eq!(st.params.len(), e.params.len());
        for (p, spec) in st.params.iter().zip(&e.params) {
            assert_eq!(p.len(), spec.elems, "{}", spec.name);
        }
        assert_eq!(st.state.len(), e.state_shapes.len());
        // gammas one, betas zero, running stats (0, 1).
        assert!(st.params[1].iter().all(|&v| v == 1.0), "gamma");
        assert!(st.params[2].iter().all(|&v| v == 0.0), "beta");
        assert!(st.state[0].iter().all(|&v| v == 0.0), "rm");
        assert!(st.state[1].iter().all(|&v| v == 1.0), "rv");
        // conv weights have he-normal-ish spread.
        let w0 = &st.params[0];
        let norm: f64 = w0.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        assert!(norm > 1.0 && norm < 100.0, "conv1 norm² {norm}");
    }

    #[test]
    fn whole_model_gradcheck_fp32() {
        let e = entry();
        let mut ex = Exec::from_env();
        let mut st = init(&e, 7).unwrap();
        let b = rand_batch(4, 1);
        let codes = vec![FP32; 4];
        let grads = grad_at(&mut ex, &e, &st.params, &st.state, &b, &codes);
        let loss_at = |ex: &mut Exec, params: &[Vec<f32>], st: &ModelState| -> f64 {
            let fwd = forward(ex, &e, params, &st.state, &b.x, &b.y, b.n, &codes, true);
            let loss = fwd.loss as f64;
            release_fwd(ex, fwd);
            loss
        };
        let mut rng = Rng::new(0xFD);
        // Spot-check a few components of every parameter tensor.
        for pi in 0..st.params.len() {
            for _ in 0..4 {
                let k = rng.below(st.params[pi].len() as u64) as usize;
                let eps = 5e-3f32;
                let orig = st.params[pi][k];
                st.params[pi][k] = orig + eps;
                let lp = loss_at(&mut ex, &st.params, &st);
                st.params[pi][k] = orig - eps;
                let lm = loss_at(&mut ex, &st.params, &st);
                st.params[pi][k] = orig;
                let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let analytic = grads[pi][k];
                let diff = (numeric - analytic).abs();
                let scale = numeric.abs().max(analytic.abs()).max(3e-2);
                assert!(
                    diff / scale < 0.15,
                    "param {pi}[{k}]: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn overfits_one_batch() {
        let e = entry();
        let mut ex = Exec::from_env();
        let mut st = init(&e, 1).unwrap();
        let b = rand_batch(8, 5);
        let ctrl = StepCtrl::uniform(4, FP32, 0.1, 0.0);
        let mut first = 0f32;
        let mut last = TrainOutputs {
            loss: 0.0,
            correct: 0,
            grad_var: vec![],
            grad_norm: vec![],
            overflow: false,
        };
        for step in 0..40 {
            last = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
            if step == 0 {
                first = last.loss;
            }
        }
        assert!(
            last.loss < 0.5 && last.loss < first * 0.5,
            "no memorization: {first} -> {}",
            last.loss
        );
        assert_eq!(last.correct, 8, "one batch must be memorized");
    }

    #[test]
    fn overflow_masks_the_update() {
        let e = entry();
        let mut ex = Exec::from_env();
        let mut st = init(&e, 2).unwrap();
        let before = st.clone();
        let b = rand_batch(8, 9);
        let mut ctrl = StepCtrl::uniform(4, FP16, 0.05, 0.0);
        ctrl.loss_scale = 1e30; // cotangents overflow binary16 -> inf
        let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert!(out.overflow, "1e30 scale through fp16 must overflow");
        assert_eq!(st.params, before.params, "params held on overflow");
        assert_eq!(st.mom, before.mom, "momentum held on overflow");
        assert_eq!(st.state, before.state, "BN state held on overflow");
        // A sane scale on the same batch recovers immediately.
        ctrl.loss_scale = 1024.0;
        let ok = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert!(!ok.overflow);
        assert_ne!(st.params, before.params, "clean step updates params");
    }

    #[test]
    fn grad_stats_have_layer_arity_and_scale() {
        let e = entry();
        let mut ex = Exec::from_env();
        let mut st = init(&e, 4).unwrap();
        let b = rand_batch(16, 2);
        let ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
        let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert_eq!(out.grad_var.len(), 4);
        assert_eq!(out.grad_norm.len(), 4);
        assert!(out.grad_var.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(out.grad_norm.iter().all(|v| v.is_finite() && *v >= 0.0));
        // The dense head sees the largest per-element gradients at init.
        assert!(out.grad_var[3] > out.grad_var[1]);
    }

    #[test]
    fn warm_train_step_performs_zero_buffer_allocs() {
        let e = entry();
        let mut ex = Exec::from_env();
        let mut st = init(&e, 6).unwrap();
        let b = rand_batch(16, 13);
        let ctrl = StepCtrl::uniform(4, BF16, 0.05, 5e-4);
        for _ in 0..2 {
            train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        }
        let warm_allocs = ex.arena.fresh_allocs();
        let warm_pooled = ex.arena.pooled();
        for _ in 0..4 {
            train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
            assert_eq!(
                ex.arena.fresh_allocs(),
                warm_allocs,
                "steady-state train step allocated a buffer"
            );
            assert_eq!(
                ex.arena.pooled(),
                warm_pooled,
                "buffer leak: a take without a matching put"
            );
        }
    }

    #[test]
    fn train_bits_identical_across_thread_counts() {
        let e = entry();
        let b = rand_batch(16, 21);
        let run = |threads: usize| {
            let mut ex = Exec::new(threads);
            let mut st = init(&e, 9).unwrap();
            let mut ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
            ctrl.codes = vec![FP16, BF16, FP32, BF16];
            let mut trace = Vec::new();
            for _ in 0..3 {
                let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
                trace.push(out.loss.to_bits());
                trace.extend(out.grad_var.iter().map(|v| v.to_bits()));
            }
            for p in &st.params {
                trace.extend(p.iter().map(|v| v.to_bits()));
            }
            trace
        };
        let t1 = run(1);
        assert_eq!(t1, run(2), "2 threads must match 1");
        assert_eq!(t1, run(4), "4 threads must match 1");
    }
}
