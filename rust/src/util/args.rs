//! Tiny CLI argument parser (substrate — no clap in the offline build).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]...`
//! Unknown keys are an error (catches typos in experiment scripts).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        if i < argv.len() && !argv[i].starts_with("--") {
            out.subcommand = Some(argv[i].clone());
            i += 1;
        }
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --option, got `{a}`"))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.kv.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                out.flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse `{v}`")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after all gets: errors on any option the program never read.
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let seen = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = Args::parse(&argv("train --model resnet18 --epochs 3 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("resnet18"));
        assert_eq!(a.parse_or("epochs", 0usize).unwrap(), 3);
        assert!(a.flag("verbose"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("run")).unwrap();
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.parse_or("n", 7i32).unwrap(), 7);
        assert!(!a.flag("f"));
    }

    #[test]
    fn unknown_rejected() {
        let a = Args::parse(&argv("run --oops 1")).unwrap();
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&argv("run --n abc")).unwrap();
        assert!(a.parse_or("n", 0usize).is_err());
    }
}
