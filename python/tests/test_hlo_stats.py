"""hlo_stats parser against a hand-written HLO snippet and (if present)
a real artifact."""

import pathlib
import textwrap

from compile.hlo_stats import ArtifactStats, elems, parse_shape

SNIPPET = textwrap.dedent(
    """\
    HloModule test

    ENTRY main.1 {
      Arg_0.1 = f32[8,32,32,3]{3,2,1,0} parameter(0)
      Arg_1.1 = f32[3,3,3,16]{3,2,1,0} parameter(1)
      Arg_2.1 = f32[256,10]{1,0} parameter(2)
      convolution.1 = f32[8,32,32,16]{3,2,1,0} convolution(Arg_0.1, Arg_1.1), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
      reshape.1 = f32[8,256]{1,0} reshape(convolution.1)
      dot.1 = f32[8,10]{1,0} dot(reshape.1, Arg_2.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      add.1 = f32[8,10]{1,0} add(dot.1, dot.1)
      ROOT tuple.1 = (f32[8,10]{1,0}) tuple(add.1)
    }
    """
)


def write_snippet(tmp_path):
    p = tmp_path / "snippet.hlo.txt"
    p.write_text(SNIPPET)
    return p


def test_parse_shape():
    dt, dims, _ = parse_shape("f32[8,32,32,3]{3,2,1,0}")
    assert dt == "f32" and dims == [8, 32, 32, 3]
    dt, dims, _ = parse_shape("s32[] parameter(0)")
    assert dt == "s32" and dims == []
    assert elems([2, 3, 4]) == 24
    assert elems([]) == 1


def test_op_histogram(tmp_path):
    s = ArtifactStats(write_snippet(tmp_path))
    assert s.ops["convolution"] == 1
    assert s.ops["dot"] == 1
    assert s.ops["add"] == 1
    assert s.ops["reshape"] == 1


def test_conv_and_dot_flops(tmp_path):
    s = ArtifactStats(write_snippet(tmp_path))
    # conv: 2 * prod(8,32,32,16) * (3*3*3*16)/16 = 2*131072*27
    conv = 2 * (8 * 32 * 32 * 16) * (3 * 3 * 3)
    # dot: 2 * prod(8,10) * (prod(8,256)/8) = 2*80*256
    dot = 2 * 80 * 256
    assert s.flops == conv + dot


def test_param_and_output_bytes(tmp_path):
    s = ArtifactStats(write_snippet(tmp_path))
    want_params = 4 * (8 * 32 * 32 * 3 + 3 * 3 * 3 * 16 + 256 * 10)
    assert s.param_bytes == want_params
    assert s.out_bytes == 4 * 8 * 10
    assert s.intensity > 0


def test_no_duplicate_smell_in_snippet(tmp_path):
    s = ArtifactStats(write_snippet(tmp_path))
    assert s.duplicate_convs() == {}


def test_real_artifact_if_present():
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    f = art / "tiny_cnn_c10_train_b32.hlo.txt"
    if not f.exists():
        return  # artifacts not built in this checkout
    s = ArtifactStats(f)
    assert s.ops["convolution"] >= 3, "tiny_cnn has 3 convs in fwd alone"
    assert s.flops > 1e6
    assert s.total_ops > 100
    report = s.report()
    assert "estFLOPs" in report
