"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import api, grad_stats, mp_matmul, qdq, ref, sr_qdq  # noqa: F401
