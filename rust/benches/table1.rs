//! Table-1 regeneration bench (DESIGN.md T1): 12 cells of
//! (CIFAR-10/100 × ResNet-18/EffNet-lite × FP32/AMP/Tri-Accel) at a
//! reduced budget. Full-budget reproduction: `cargo run --release
//! --example reproduce_tables -- --steps 100 --epochs 5`.
//!
//! Env knobs: T1_STEPS, T1_EPOCHS, T1_SEEDS, T1_MODELS.

use tri_accel::harness;
use tri_accel::runtime::Engine;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let engine = Engine::native();
    let steps = env_usize("T1_STEPS", 6);
    let epochs = env_usize("T1_EPOCHS", 1);
    let seeds: Vec<u64> = std::env::var("T1_SEEDS")
        .unwrap_or_else(|_| "0".into())
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let models_env = std::env::var("T1_MODELS")
        .unwrap_or_else(|_| "tiny_cnn_c10,tiny_cnn_c100".into()); // artifact models via T1_MODELS + --features pjrt
    let keys: Vec<&str> = models_env.split(',').collect();

    println!("== bench table1: {steps} steps × {epochs} epochs × {} seed(s) ==", seeds.len());
    let t0 = std::time::Instant::now();
    let rows = harness::table1(&engine, &keys, &seeds, &harness::quick_budget(steps, epochs))
        .expect("table1 run");
    harness::print_table1(&rows);
    println!("\nshape checks vs paper Table 1:");
    for chunk in rows.chunks(3) {
        let (fp32, amp, tri) = (&chunk[0], &chunk[1], &chunk[2]);
        // Robust shape: both reduced-precision methods strictly below
        // FP32. Tri-Accel vs AMP is regime-dependent (paper's 3%
        // advantage needs a net batch shrink; our band holds) — the
        // delta is reported alongside rather than asserted.
        let mem_ok = amp.peak_gb.mean() < fp32.peak_gb.mean()
            && tri.peak_gb.mean() < fp32.peak_gb.mean();
        let tri_vs_amp =
            100.0 * (tri.peak_gb.mean() - amp.peak_gb.mean()) / amp.peak_gb.mean();
        let time_ok = tri.modeled_s.mean() < fp32.modeled_s.mean();
        let score_ok = tri.score.mean() > fp32.score.mean();
        println!(
            "  {:<18} mem order {}  time order {}  score order {}  tri-vs-amp mem {:+.1}% (paper −3%..0%)   [{}]",
            fp32.model_key,
            if mem_ok { "OK " } else { "MISS" },
            if time_ok { "OK " } else { "MISS" },
            if score_ok { "OK " } else { "MISS" },
            tri_vs_amp,
            harness::headline(fp32, tri)
        );
    }
    println!("total bench wallclock: {:.1}s", t0.elapsed().as_secs_f64());
}
