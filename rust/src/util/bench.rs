//! Micro-benchmark harness (substrate — no criterion in the offline
//! build). `cargo bench` targets use `harness = false` and call into this.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean ± std
//! and p50/p90 per iteration.
//!
//! Besides the pretty table, results can be collected into a
//! [`BenchReport`] and written as machine-readable JSON
//! (`BENCH_native.json`), so the repo's perf trajectory is comparable
//! across PRs (`util/json.rs` is both the writer and the reader).

// detlint: allow-file(d2) — this IS the wall-clock module: measuring
// latency is its whole job, and bench output never feeds deterministic
// artifacts (BENCH_*.json is observability, not a golden file).

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{percentile, Welford};
use crate::faults::{ArtifactIo, RealIo};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub p50: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters   mean {:>12?}   std {:>10?}   p50 {:>12?}   p90 {:>12?}",
            self.name, self.iters, self.mean, self.std, self.p50, self.p90
        )
    }

    /// Machine-readable form (seconds as f64) for [`BenchReport`].
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("mean_s".into(), Json::Num(self.mean.as_secs_f64()));
        m.insert("std_s".into(), Json::Num(self.std.as_secs_f64()));
        m.insert("p50_s".into(), Json::Num(self.p50.as_secs_f64()));
        m.insert("p90_s".into(), Json::Num(self.p90.as_secs_f64()));
        Json::Obj(m)
    }
}

/// Accumulates [`BenchResult`]s plus free-form metadata and writes them
/// as one JSON document — the cross-PR perf record.
pub struct BenchReport {
    suite: String,
    meta: std::collections::BTreeMap<String, Json>,
    results: Vec<Json>,
}

impl BenchReport {
    pub fn new(suite: &str) -> BenchReport {
        BenchReport {
            suite: suite.to_string(),
            meta: std::collections::BTreeMap::new(),
            results: Vec::new(),
        }
    }

    /// Attach a metadata string (model key, mode, …).
    pub fn meta_str(&mut self, key: &str, value: &str) {
        self.meta.insert(key.to_string(), Json::Str(value.to_string()));
    }

    /// Attach a metadata number (thread count, batch size, …).
    pub fn meta_num(&mut self, key: &str, value: f64) {
        self.meta.insert(key.to_string(), Json::Num(value));
    }

    /// Record one benchmark result.
    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(r.to_json());
    }

    /// Record one pre-built result row. The grid scheduler's
    /// `BENCH_grid.json` reuses this report container for its
    /// per-cell modeled-time/decision-count rows, so every
    /// machine-readable bench artifact shares one envelope shape
    /// (`{suite, ...meta, results: [...]}`).
    pub fn push_json(&mut self, row: Json) {
        self.results.push(row);
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut m = self.meta.clone();
        m.insert("suite".into(), Json::Str(self.suite.clone()));
        m.insert("results".into(), Json::Arr(self.results.clone()));
        Json::Obj(m)
    }

    /// Write the report to `path` as compact JSON, through the
    /// crash-safe temp+rename seam — a killed bench run (or the chaos
    /// harness's byte-compare) can never observe a torn
    /// `BENCH_*.json`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        RealIo.write_atomic(path, &self.to_json().to_string_compact())
    }
}

pub struct Bencher {
    pub warmup: u32,
    pub min_iters: u64,
    pub min_time: Duration,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 2,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000_000,
        }
    }
}

impl Bencher {
    /// Preset for expensive end-to-end cases (train steps etc.).
    /// `min_iters` is 8: with the nearest-rank percentile, p90 over n
    /// samples degenerates to the max for every n ≤ 6 (round(0.9·(n-1))
    /// = n-1), so ≥ 7 samples are needed before the reported p90 is a
    /// real order statistic rather than the worst outlier — the former
    /// `min_iters: 3` made every heavy p90 a max.
    pub fn heavy() -> Self {
        Bencher { warmup: 1, min_iters: 8, min_time: Duration::from_millis(100), max_iters: 40 }
    }

    /// Smoke preset (`--quick`): one measured iteration per case, just
    /// enough to prove the kernels compile and run — the CI guard.
    pub fn smoke() -> Self {
        Bencher { warmup: 0, min_iters: 1, min_time: Duration::ZERO, max_iters: 1 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::default();
        let mut samples = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (iters < self.min_iters || start.elapsed() < self.min_time)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed();
            w.push(dt.as_secs_f64());
            samples.push(dt.as_secs_f64());
            iters += 1;
        }
        samples.sort_by(f64::total_cmp);
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(w.mean()),
            std: Duration::from_secs_f64(w.std()),
            p50: Duration::from_secs_f64(percentile(&samples, 0.5)),
            p90: Duration::from_secs_f64(percentile(&samples, 0.9)),
        };
        println!("{}", res.row());
        res
    }
}

/// Prevents the optimizer from eliding a computed value (ptr read fence).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher { warmup: 1, min_iters: 5, min_time: Duration::from_millis(1), max_iters: 50 };
        let mut acc = 0u64;
        let r = b.run("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean >= Duration::ZERO);
    }

    #[test]
    fn heavy_p90_is_not_the_max() {
        // 8+ samples make round(0.9·(n-1)) < n-1, so the reported p90
        // is a real order statistic (the min_iters:3 regression).
        let n = Bencher::heavy().min_iters as usize;
        assert!(n >= 7, "need ≥7 samples for a non-degenerate p90");
        let samples: Vec<f64> = (0..n).map(|i| i as f64).collect();
        assert!(percentile(&samples, 0.9) < samples[n - 1]);
    }

    #[test]
    fn report_roundtrips_through_json() {
        let b = Bencher::smoke();
        let r = b.run("case_a", || {
            black_box(2 + 2);
        });
        let mut rep = BenchReport::new("unit");
        rep.meta_str("mode", "test");
        rep.meta_num("threads", 4.0);
        rep.push(&r);
        assert_eq!(rep.len(), 1);
        assert!(!rep.is_empty());
        let j = rep.to_json();
        assert_eq!(j.get("suite").unwrap().as_str(), Some("unit"));
        assert_eq!(j.get("threads").unwrap().as_f64(), Some(4.0));
        let rows = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("case_a"));
        assert!(rows[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        // Serialized form parses back (what a cross-PR comparator reads).
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("suite").unwrap().as_str(), Some("unit"));
    }

    #[test]
    fn report_writes_to_disk() {
        let mut rep = BenchReport::new("disk");
        rep.meta_str("k", "v");
        let p = std::env::temp_dir().join(format!(
            "triaccel_bench_report_{}.json",
            std::process::id()
        ));
        rep.write(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::remove_file(&p).ok();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("suite").unwrap().as_str(), Some("disk"));
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 0);
    }
}
