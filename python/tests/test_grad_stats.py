"""grad_stats fused reduction vs oracle + moment invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import grad_stats as gs_mod
from compile.kernels import ref
from compile.kernels.grad_stats import grad_stats


def _rand(shape, seed=0, scale=1.0, loc=0.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal(shape, dtype=np.float32) * scale + loc
    )


@pytest.mark.parametrize(
    "shape", [(1,), (5,), (1024,), (3, 5, 7), (64, 3, 3, 64), (100001,)]
)
def test_grad_stats_matches_ref(shape):
    g = _rand(shape, seed=hash(shape) % 2**31, scale=3.0, loc=-1.0)
    m_k, v_k = grad_stats(g)
    m_r, v_r = ref.grad_stats_ref(g)
    np.testing.assert_allclose(float(m_k), float(m_r), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=1e-4, atol=1e-7)


def test_grad_stats_multiblock_tail():
    n = gs_mod.BLOCK * 3 + 777
    g = _rand((n,), seed=42, scale=2.0, loc=0.5)
    m_k, v_k = grad_stats(g)
    m_np = float(np.mean(np.asarray(g)))
    v_np = float(np.var(np.asarray(g)))
    np.testing.assert_allclose(float(m_k), m_np, rtol=1e-4)
    np.testing.assert_allclose(float(v_k), v_np, rtol=1e-3)


def test_constant_tensor_zero_variance():
    g = jnp.full((4096,), 2.5, jnp.float32)
    m, v = grad_stats(g)
    assert abs(float(m) - 2.5) < 1e-6
    assert float(v) >= 0.0 and float(v) < 1e-6


def test_variance_nonnegative_after_cancellation():
    # Catastrophic-cancellation regime: huge mean, tiny variance.
    g = jnp.full((8192,), 1e4, jnp.float32) + _rand((8192,), seed=1, scale=1e-3)
    _, v = grad_stats(g)
    assert float(v) >= 0.0


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 5000),
    scale=st.floats(1e-3, 1e3),
    loc=st.floats(-10, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_stats_hypothesis(n, scale, loc, seed):
    g = _rand((n,), seed=seed, scale=scale, loc=loc)
    m_k, v_k = grad_stats(g)
    m_r, v_r = ref.grad_stats_ref(g)
    np.testing.assert_allclose(float(m_k), float(m_r), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=1e-3, atol=1e-6)


def test_scaling_law():
    # Var[c·g] = c²·Var[g] — the invariant the precision controller's
    # loss-scale compensation relies on.
    g = _rand((2048,), seed=3)
    _, v1 = grad_stats(g)
    _, v4 = grad_stats(4.0 * g)
    np.testing.assert_allclose(float(v4), 16.0 * float(v1), rtol=1e-4)
