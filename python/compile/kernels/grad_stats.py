"""`grad_stats` — fused two-moment reduction Pallas kernel.

Computes (mean, biased variance) of a gradient tensor in one pass: each
grid step accumulates the block's sum and sum-of-squares into a 2-element
VMEM accumulator; the final moments are formed on the way out. This is the
per-layer `Var[∇_l(t)]` the paper's precision controller consumes every
step (§3.1) — it has to be cheap enough to be "negligible overhead", hence
one fused pass instead of mean-then-var.

The count is carried statically (the tensor size is known at lowering
time), so the kernel only reduces sums.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128 * 1024


def _stats_kernel(x_ref, acc_ref):
    x = x_ref[...]
    s = jnp.sum(x)
    sq = jnp.sum(x * x)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[0] = s
        acc_ref[1] = sq

    @pl.when(pl.program_id(0) != 0)
    def _accum():
        acc_ref[0] += s
        acc_ref[1] += sq


def grad_stats(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(mean, biased variance) over all elements of `g`.

    Matches `ref.grad_stats_ref` (allclose; block accumulation order).
    Not differentiated — callers wrap in stop_gradient.
    """
    g_flat = jax.lax.stop_gradient(g).astype(jnp.float32).reshape(-1)
    n = g_flat.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        # Zero padding is moment-safe: we divide by the true n below.
        g_flat = jnp.concatenate([g_flat, jnp.zeros((pad,), jnp.float32)])
    np_ = g_flat.shape[0]
    block = BLOCK if np_ >= BLOCK else np_
    acc = pl.pallas_call(
        _stats_kernel,
        grid=(np_ // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        interpret=True,
    )(g_flat)
    inv_n = 1.0 / float(n)
    mean = acc[0] * inv_n
    var = acc[1] * inv_n - mean * mean
    return mean, jnp.maximum(var, 0.0)
