//! Record→replay verification: compare two completed grid directories
//! for deterministic equivalence, ignoring only the fields that are
//! *allowed* to differ between a recording run and its replay.
//!
//! This is the proof obligation behind `mem_trace=replay:FILE`: a
//! budget squeeze recorded from one run and replayed onto another must
//! reproduce every policy decision, loss value, and OOM count bit for
//! bit. The comparator loads both grids' ledgers, matches jobs by key
//! (job keys carry no hashes, so they survive config changes by
//! design), and diffs both the persisted results and the full
//! telemetry event streams after normalization:
//!
//! * top-level `crc` (reseals over changed content), `wall_s`
//!   (measured time), and `config_hash` (the replay grid carries a
//!   different `mem_trace` spec by construction) are dropped;
//! * `wall_s` nested inside a `run_finished` record's `result` object
//!   is dropped for the same reason;
//! * everything else — every step event, every policy decision, every
//!   loss bit — must match exactly.
//!
//! Used by the `trace --verify` subcommand and the record→replay
//! property suite (`tests/prop_memsim.rs`); the CI smoke job fails on
//! a non-empty mismatch list. See `docs/MEMORY.md` for the replay
//! determinism contract.

#![warn(missing_docs)]

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::ledger::{Ledger, LedgerEntry};

/// Mismatches rendered in full before [`CompareReport::render`] elides
/// the rest; also the per-job cap on reported line diffs (one bad
/// window desynchronizes every later line, so more adds only noise).
const MISMATCH_CAP: usize = 20;

/// The outcome of a grid comparison: counts plus a human-readable
/// mismatch list (empty means the grids are replay-equivalent).
#[derive(Debug)]
pub struct CompareReport {
    /// Jobs compared (present in both ledgers).
    pub jobs: usize,
    /// Telemetry lines compared across all jobs.
    pub lines: usize,
    /// Rendered mismatches, in job-key order. Empty means equivalent.
    pub mismatches: Vec<String>,
}

impl CompareReport {
    /// Did every job identity, result, and normalized telemetry line
    /// match?
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }

    /// One-paragraph summary for CLI output.
    pub fn render(&self) -> String {
        if self.ok() {
            return format!(
                "replay-equivalent: {} job(s), {} telemetry line(s) match after normalization",
                self.jobs, self.lines
            );
        }
        let mut s = format!(
            "{} mismatch(es) across {} job(s), {} telemetry line(s):",
            self.mismatches.len(),
            self.jobs,
            self.lines
        );
        for m in self.mismatches.iter().take(MISMATCH_CAP) {
            s.push_str("\n  - ");
            s.push_str(m);
        }
        if self.mismatches.len() > MISMATCH_CAP {
            s.push_str(&format!("\n  … and {} more", self.mismatches.len() - MISMATCH_CAP));
        }
        s
    }
}

/// Strip the fields that legitimately differ between a recording run
/// and its replay from one telemetry/ledger JSONL line, and return the
/// canonical compact re-serialization. Fails on a non-JSON line — a
/// torn artifact is a real mismatch, not something to normalize away.
pub fn normalize_line(line: &str) -> Result<String> {
    let mut v = Json::parse(line).map_err(|e| anyhow::anyhow!("non-JSON line: {e}"))?;
    if let Json::Obj(m) = &mut v {
        m.remove("crc");
        m.remove("wall_s");
        m.remove("config_hash");
        if let Some(Json::Obj(r)) = m.get_mut("result") {
            r.remove("wall_s");
        }
    }
    Ok(v.to_string_compact())
}

/// The ledger entry's persisted result with wall time stripped, as a
/// canonical compact string (everything else in [`SeedResult`]
/// participates in the replay contract).
///
/// [`SeedResult`]: crate::harness::SeedResult
fn result_minus_wall(e: &LedgerEntry) -> String {
    let mut v = e.result.to_json();
    if let Json::Obj(m) = &mut v {
        m.remove("wall_s");
    }
    v.to_string_compact()
}

/// Read one job's event stream and normalize every line.
fn normalized_events(grid_dir: &Path, key: &str) -> Result<Vec<String>> {
    let path = grid_dir.join("events").join(format!("{key}.jsonl"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .enumerate()
        .map(|(i, l)| normalize_line(l).with_context(|| format!("{key}.jsonl line {}", i + 1)))
        .collect()
}

/// Compare two completed grid directories (each holding `ledger.json`
/// and `events/`) for replay equivalence. Jobs are matched by key;
/// keys present in only one grid, diverging job identities (model,
/// method, seed, model digest), diverging wall-free results, and any
/// diverging normalized telemetry line are all reported. The call
/// itself only fails when a directory is unreadable or a ledger is
/// unparseable — content differences land in the report.
pub fn compare_grids(a_dir: &Path, b_dir: &Path) -> Result<CompareReport> {
    let a = Ledger::load(&a_dir.join("ledger.json"))
        .with_context(|| format!("grid A ({})", a_dir.display()))?;
    let b = Ledger::load(&b_dir.join("ledger.json"))
        .with_context(|| format!("grid B ({})", b_dir.display()))?;
    let a_keys: BTreeSet<&String> = a.entries.keys().collect();
    let b_keys: BTreeSet<&String> = b.entries.keys().collect();
    let mut mismatches = Vec::new();
    for k in a_keys.difference(&b_keys) {
        mismatches.push(format!("job `{k}` recorded only in grid A"));
    }
    for k in b_keys.difference(&a_keys) {
        mismatches.push(format!("job `{k}` recorded only in grid B"));
    }
    let mut lines = 0usize;
    let shared: Vec<&String> = a_keys.intersection(&b_keys).copied().collect();
    for key in &shared {
        let ea = &a.entries[*key];
        let eb = &b.entries[*key];
        let ida = (&ea.model, &ea.method_key, ea.seed, ea.digest);
        let idb = (&eb.model, &eb.method_key, eb.seed, eb.digest);
        if ida != idb {
            mismatches.push(format!("job `{key}`: identity differs ({ida:?} vs {idb:?})"));
        }
        let (ra, rb) = (result_minus_wall(ea), result_minus_wall(eb));
        if ra != rb {
            mismatches.push(format!("job `{key}`: result differs\n      A: {ra}\n      B: {rb}"));
        }
        let (la, lb) = match (normalized_events(a_dir, key), normalized_events(b_dir, key)) {
            (Ok(la), Ok(lb)) => (la, lb),
            (Err(e), _) | (_, Err(e)) => {
                mismatches.push(format!("job `{key}`: {e:#}"));
                continue;
            }
        };
        lines += la.len().max(lb.len());
        if la.len() != lb.len() {
            mismatches
                .push(format!("job `{key}`: event count differs ({} vs {})", la.len(), lb.len()));
        }
        let mut reported = 0usize;
        for (i, (x, y)) in la.iter().zip(lb.iter()).enumerate() {
            if x != y {
                mismatches.push(format!("job `{key}` line {}:\n      A: {x}\n      B: {y}", i + 1));
                reported += 1;
                if reported >= MISMATCH_CAP {
                    mismatches.push(format!("job `{key}`: further line diffs elided"));
                    break;
                }
            }
        }
    }
    Ok(CompareReport { jobs: shared.len(), lines, mismatches })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::RealIo;
    use crate::harness::SeedResult;
    use std::collections::BTreeMap;

    fn result(seed: u64, wall_s: f64, score: f64) -> SeedResult {
        SeedResult {
            seed,
            test_acc_pct: 61.5,
            wall_s,
            modeled_s: 1.25,
            peak_gb: 0.5,
            score,
            oom_events: 1,
            batch_decisions: 3,
            ctrl_windows: 4,
            precision_transitions: 0,
            curv_firings: 2,
            min_batch: 16,
            replica_decisions: 0,
            min_replicas: 1,
        }
    }

    fn entry(key: &str, wall_s: f64, score: f64) -> LedgerEntry {
        LedgerEntry {
            key: key.to_string(),
            model: "tiny_cnn_c10".to_string(),
            method_key: "fp32".to_string(),
            seed: 7,
            digest: 0xabcd,
            config_hash: (wall_s * 1e6) as u64,
            result: result(7, wall_s, score),
            wall_s,
        }
    }

    /// Write a one-job grid dir: sealed ledger plus one events file.
    fn write_grid(dir: &Path, key: &str, wall_s: f64, score: f64, loss: f64) {
        std::fs::create_dir_all(dir.join("events")).unwrap();
        let mut entries = BTreeMap::new();
        entries.insert(key.to_string(), entry(key, wall_s, score));
        let led = Ledger {
            schema: crate::sched::LEDGER_SCHEMA_VERSION,
            grid_id: "pressure-00000000".to_string(),
            kind: "pressure".to_string(),
            cells: Vec::new(),
            entries,
        };
        led.save(&dir.join("ledger.json"), &RealIo).unwrap();
        let step = format!(r#"{{"crc":"x","kind":"step","loss":{loss},"wall_s":{wall_s}}}"#);
        let fin =
            format!(r#"{{"kind":"run_finished","result":{{"score":{score},"wall_s":{wall_s}}}}}"#);
        std::fs::write(dir.join("events").join(format!("{key}.jsonl")), format!("{step}\n{fin}\n"))
            .unwrap();
    }

    fn temp_root(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("triaccel_replay_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn normalize_drops_only_the_volatile_fields() {
        let line = concat!(
            r#"{"config_hash":"00ff","crc":"aa","kind":"run_finished","#,
            r#""result":{"score":1.25,"seed":"7","wall_s":2.5},"wall_s":1.5}"#
        );
        assert_eq!(
            normalize_line(line).unwrap(),
            r#"{"kind":"run_finished","result":{"score":1.25,"seed":"7"}}"#
        );
        // Non-envelope fields survive untouched, bit for bit.
        let step = r#"{"crc":"bb","kind":"step","loss":0.30000000000000004,"used_gb":0.25}"#;
        assert_eq!(
            normalize_line(step).unwrap(),
            r#"{"kind":"step","loss":0.30000000000000004,"used_gb":0.25}"#
        );
        assert!(normalize_line("not json").is_err(), "torn lines are mismatches, not noise");
    }

    #[test]
    fn equivalent_grids_compare_clean_despite_wall_and_hash_drift() {
        let root = temp_root("ok");
        let (a, b) = (root.join("a"), root.join("b"));
        write_grid(&a, "00_job_s7", 1.0, 2.5, 0.125);
        write_grid(&b, "00_job_s7", 9.0, 2.5, 0.125); // wall_s + config_hash differ
        let rep = compare_grids(&a, &b).unwrap();
        assert!(rep.ok(), "mismatches: {:?}", rep.mismatches);
        assert_eq!(rep.jobs, 1);
        assert_eq!(rep.lines, 2);
        assert!(rep.render().contains("replay-equivalent"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn content_drift_is_reported_per_job_and_per_line() {
        let root = temp_root("bad");
        let (a, b) = (root.join("a"), root.join("b"));
        write_grid(&a, "00_job_s7", 1.0, 2.5, 0.125);
        write_grid(&b, "00_job_s7", 1.0, 9.75, 0.5); // score + loss differ
        let rep = compare_grids(&a, &b).unwrap();
        assert!(!rep.ok());
        assert!(
            rep.mismatches.iter().any(|m| m.contains("result differs")),
            "{:?}",
            rep.mismatches
        );
        assert!(rep.mismatches.iter().any(|m| m.contains("line 1")), "{:?}", rep.mismatches);
        assert!(rep.render().contains("mismatch(es)"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn disjoint_job_sets_are_mismatches() {
        let root = temp_root("keys");
        let (a, b) = (root.join("a"), root.join("b"));
        write_grid(&a, "00_job_s7", 1.0, 2.5, 0.125);
        write_grid(&b, "00_other_s7", 1.0, 2.5, 0.125);
        let rep = compare_grids(&a, &b).unwrap();
        assert_eq!(rep.jobs, 0);
        assert_eq!(rep.mismatches.len(), 2, "{:?}", rep.mismatches);
        let _ = std::fs::remove_dir_all(&root);
    }
}
