//! Property suite for deterministic data-parallel replication: the
//! replica count is a pure wall-clock knob. A batch is always split
//! into the same fixed canonical shards and every cross-shard
//! reduction folds in ascending canonical order, so N = 1, 2, and 4
//! replicas walk bit-identical parameter trajectories — same per-step
//! loss bits, same controller state, byte-identical checkpoints — and
//! a run checkpointed at one replica count resumes at another without
//! perturbing a single bit. Modeled time is the one legitimate
//! difference (replication exists to buy wall-clock), so it is the one
//! thing these tests never compare.

use tri_accel::config::{Config, Method};
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

/// Quick Tri-Accel config at a given replica count. The budget is
/// deliberately generous: aggregate usage stays far below the control
/// band at every replica count, so the policy plane makes the same
/// decisions in every run and the trajectories are comparable step
/// for step.
fn cfg(replicas: usize, seed: u64) -> Config {
    let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, seed);
    cfg.epochs = 1;
    cfg.steps_per_epoch = Some(18);
    cfg.train_examples = 2048;
    cfg.eval_examples = 256;
    cfg.batch_init = 32;
    cfg.t_ctrl = 4;
    cfg.t_curv = 8;
    cfg.curv_warmup = 1;
    cfg.batch_cooldown = 3;
    cfg.warmup_epochs = 0;
    cfg.mem_budget_gb = 100.0;
    cfg.mem_noise = 0.0;
    cfg.replicas = replicas;
    cfg
}

/// The engine whose capacity matches the config's replica count.
fn engine_for(replicas: usize) -> Engine {
    if replicas > 1 {
        Engine::native_replicated(replicas, 1)
    } else {
        Engine::native()
    }
}

/// Run `steps` optimizer steps and return every per-step loss, bitwise.
fn loss_bits(tr: &mut Trainer, steps: usize) -> Vec<u64> {
    (0..steps).map(|_| tr.step().unwrap().0.to_bits()).collect()
}

#[test]
fn prop_replica_count_is_bit_invariant_step_for_step() {
    for seed in [0u64, 3] {
        let e1 = engine_for(1);
        let mut t1 = Trainer::new(&e1, cfg(1, seed)).unwrap();
        let base = loss_bits(&mut t1, 18);
        let ctrl1 = t1.controller.export_state();
        for replicas in [2usize, 4] {
            let en = engine_for(replicas);
            let mut tn = Trainer::new(&en, cfg(replicas, seed)).unwrap();
            let got = loss_bits(&mut tn, 18);
            assert_eq!(
                got, base,
                "seed {seed}: per-step loss bits diverged at {replicas} replicas"
            );
            assert_eq!(
                tn.controller.export_state(),
                ctrl1,
                "seed {seed}: controller state diverged at {replicas} replicas"
            );
        }
    }
}

#[test]
fn prop_checkpoints_are_byte_identical_across_replica_counts() {
    // Checkpoints carry params, momentum, BN state, probes, and policy
    // state — none of which may know the replica count. Saving the same
    // trajectory from a 1-replica and a 2-replica run must produce the
    // same file, byte for byte.
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let mut files = Vec::new();
    for replicas in [1usize, 2] {
        let e = engine_for(replicas);
        let mut tr = Trainer::new(&e, cfg(replicas, 1)).unwrap();
        for _ in 0..10 {
            tr.step().unwrap();
        }
        let p = dir.join(format!("triaccel_prop_replicas_{pid}_r{replicas}.bin"));
        tr.save_checkpoint(&p).unwrap();
        files.push(std::fs::read(&p).unwrap());
        std::fs::remove_file(&p).ok();
    }
    assert_eq!(files[0], files[1], "checkpoint bytes depend on the replica count");
}

#[test]
fn prop_resume_at_a_different_replica_count_continues_bit_identically() {
    // Checkpoint at N=2 replicas mid-run, resume at N=4: the
    // continuation must reproduce the tail of an uninterrupted
    // 1-replica run bit for bit, and the final checkpoints must match
    // byte for byte. Elasticity across restarts is free when the
    // replica count never touches the numbers.
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let mid = dir.join(format!("triaccel_prop_replicas_{pid}_mid.bin"));
    let end_a = dir.join(format!("triaccel_prop_replicas_{pid}_end_a.bin"));
    let end_b = dir.join(format!("triaccel_prop_replicas_{pid}_end_b.bin"));

    // Uninterrupted reference at 1 replica.
    let e1 = engine_for(1);
    let mut full = Trainer::new(&e1, cfg(1, 2)).unwrap();
    let full_losses = loss_bits(&mut full, 16);
    full.save_checkpoint(&end_a).unwrap();

    // First half at 2 replicas, checkpoint, second half at 4.
    let e2 = engine_for(2);
    let mut first = Trainer::new(&e2, cfg(2, 2)).unwrap();
    let head = loss_bits(&mut first, 8);
    assert_eq!(head, full_losses[..8], "head diverged before the handoff");
    first.save_checkpoint(&mid).unwrap();

    let e4 = engine_for(4);
    let mut second = Trainer::new(&e4, cfg(4, 2)).unwrap();
    assert_eq!(second.resume_from(&mid).unwrap(), 8);
    let tail = loss_bits(&mut second, 8);
    assert_eq!(tail, full_losses[8..], "tail diverged after the replica-count switch");
    second.save_checkpoint(&end_b).unwrap();

    let a = std::fs::read(&end_a).unwrap();
    let b = std::fs::read(&end_b).unwrap();
    assert_eq!(a, b, "final checkpoints differ across the 2→4 replica handoff");
    for p in [&mid, &end_a, &end_b] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn prop_elastic_replica_moves_never_change_the_numbers() {
    // The elastic composition at a capacity the roomy budget will keep
    // fully restored vs the same composition pinned: live-replica moves
    // (including the initial full-capacity state and any veto churn)
    // must be invisible to the loss stream.
    let seed = 4;
    let e1 = engine_for(1);
    let mut pinned = Trainer::new(&e1, cfg(1, seed)).unwrap();
    let base = loss_bits(&mut pinned, 18);

    let e = engine_for(4);
    let mut c = cfg(4, seed);
    c.elastic_replicas = true;
    let mut elastic = Trainer::new(&e, c).unwrap();
    let got = loss_bits(&mut elastic, 18);
    assert_eq!(got, base, "an elastic replica decision leaked into the numerics");
}
