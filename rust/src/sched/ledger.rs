//! The grid ledger: `runs/<grid-id>/ledger.json`.
//!
//! One ledger records one grid — its structure (cells, per-cell job
//! keys) plus one entry per *completed* job, keyed by the job key and
//! guarded by the (model-graph digest, method key, seed, config
//! fingerprint) quadruple. Rerunning the same grid command loads the
//! ledger, skips every recorded job, and re-aggregates the persisted
//! per-seed results — so a killed grid resumes mid-way and produces
//! bit-identical artifacts (aggregation reads the JSON-roundtripped
//! values in fixed job-key order, never the in-memory floats of
//! whichever jobs happened to run this time).
//!
//! The file is written atomically (temp file + rename) after every
//! job completion, so a kill at any instant leaves either the old or
//! the new ledger — never a torn one. Format reference:
//! `docs/TELEMETRY.md`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::harness::SeedResult;
use crate::util::json::Json;

use super::{GridSpec, Job};

/// Ledger format version (`"schema"` in `ledger.json`). Bump only on
/// breaking changes; additive fields keep the version.
pub const LEDGER_SCHEMA_VERSION: u64 = 1;

/// One completed job: identity quadruple + persisted result.
#[derive(Debug, Clone)]
pub struct LedgerEntry {
    /// Job key (`<cell>_<model>_<method>_s<seed>`).
    pub key: String,
    /// Manifest model key.
    pub model: String,
    /// Effective method key ([`crate::policy::registry::effective_key`]).
    pub method_key: String,
    /// Training seed.
    pub seed: u64,
    /// Model-graph digest ([`crate::manifest::ModelEntry::digest`]).
    pub digest: u64,
    /// Config fingerprint ([`crate::config::Config::fingerprint`]).
    pub config_hash: u64,
    /// The persisted per-seed result.
    pub result: SeedResult,
    /// Wall-clock seconds the job took (informational; the one field
    /// that differs across reruns and is never rendered into the
    /// deterministic artifacts).
    pub wall_s: f64,
}

/// One grid cell's structure: which jobs aggregate into which row.
#[derive(Debug, Clone)]
pub struct CellMeta {
    /// Manifest model key.
    pub model: String,
    /// Row label (Table-1 method name / Table-2 configuration).
    pub label: String,
    /// Effective method key.
    pub method_key: String,
    /// Budget trace spec the cell ran under (`const` outside pressure).
    pub trace: String,
    /// Seeds, normalized (sorted, deduplicated).
    pub seeds: Vec<u64>,
    /// Job keys in aggregation order (one per seed).
    pub job_keys: Vec<String>,
}

/// The grid ledger: structure + completed-job entries.
#[derive(Debug, Clone)]
pub struct Ledger {
    /// Format version of the loaded/created file.
    pub schema: u64,
    /// Content-derived grid id (also the directory name).
    pub grid_id: String,
    /// Grid kind (`table1`/`table2`/`fig`/`pressure`).
    pub kind: String,
    /// Cell structure in presentation/aggregation order.
    pub cells: Vec<CellMeta>,
    /// Completed jobs by job key.
    pub entries: BTreeMap<String, LedgerEntry>,
}

fn hex_u64(j: &Json, key: &str) -> Result<u64> {
    let s = j.req(key)?.as_str().with_context(|| format!("ledger `{key}` not a string"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("ledger `{key}`: bad hex `{s}`"))
}

impl Ledger {
    /// Fresh ledger for a grid about to run (no completed jobs yet).
    pub fn new(grid_id: &str, spec: &GridSpec, jobs: &[Job]) -> Ledger {
        let mut cells = Vec::with_capacity(spec.cells.len());
        for (ci, c) in spec.cells.iter().enumerate() {
            cells.push(CellMeta {
                model: c.model_key.clone(),
                label: c.label.clone(),
                method_key: c.method_key.clone(),
                trace: c.base.mem_trace.clone(),
                seeds: c.seeds.clone(),
                job_keys: jobs
                    .iter()
                    .filter(|j| j.cell == ci)
                    .map(|j| j.key.clone())
                    .collect(),
            });
        }
        Ledger {
            schema: LEDGER_SCHEMA_VERSION,
            grid_id: grid_id.to_string(),
            kind: spec.kind.name().to_string(),
            cells,
            entries: BTreeMap::new(),
        }
    }

    /// Has this job already completed?
    pub fn is_done(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    /// Record one completed job.
    pub fn insert(&mut self, entry: LedgerEntry) {
        self.entries.insert(entry.key.clone(), entry);
    }

    /// Check a loaded ledger against the jobs the current command
    /// expects: same grid id, and every recorded entry must match its
    /// job's digest + config fingerprint. A mismatch means the code or
    /// config changed under an existing grid directory — stale results
    /// must never be silently re-aggregated.
    pub fn validate_against(&self, grid_id: &str, jobs: &[Job]) -> Result<()> {
        anyhow::ensure!(
            self.grid_id == grid_id,
            "ledger grid id `{}` does not match this command (`{grid_id}`) — \
             delete the grid directory to start over",
            self.grid_id
        );
        let by_key: BTreeMap<&str, &Job> =
            jobs.iter().map(|j| (j.key.as_str(), j)).collect();
        for (key, e) in &self.entries {
            let job = by_key.get(key.as_str()).with_context(|| {
                format!("ledger records job `{key}` which this grid does not contain")
            })?;
            anyhow::ensure!(
                e.digest == job.digest && e.config_hash == job.config_hash,
                "ledger entry `{key}` was produced by a different model/config \
                 (digest {:016x} vs {:016x}, config {:016x} vs {:016x}) — \
                 delete the grid directory to rerun",
                e.digest,
                job.digest,
                e.config_hash,
                job.config_hash
            );
        }
        Ok(())
    }

    /// Per-cell seed results in canonical (cell, job-key) order.
    /// Errors if any cell's job is missing — callers resume the grid
    /// first, then aggregate.
    pub fn cell_results(&self) -> Result<Vec<Vec<SeedResult>>> {
        let mut out = Vec::with_capacity(self.cells.len());
        for c in &self.cells {
            let mut rs = Vec::with_capacity(c.job_keys.len());
            for k in &c.job_keys {
                let e = self.entries.get(k).with_context(|| {
                    format!(
                        "grid incomplete: job `{k}` has no ledger entry — \
                         rerun the grid command to resume"
                    )
                })?;
                rs.push(e.result.clone());
            }
            out.push(rs);
        }
        Ok(out)
    }

    /// Serialize the whole ledger.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Num(self.schema as f64));
        root.insert("grid_id".into(), Json::Str(self.grid_id.clone()));
        root.insert("kind".into(), Json::Str(self.kind.clone()));
        root.insert(
            "cells".into(),
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("model".into(), Json::Str(c.model.clone()));
                        m.insert("label".into(), Json::Str(c.label.clone()));
                        m.insert("method_key".into(), Json::Str(c.method_key.clone()));
                        m.insert("trace".into(), Json::Str(c.trace.clone()));
                        // Decimal strings, not JSON numbers: u64 seeds
                        // past 2^53 must survive the round trip.
                        m.insert(
                            "seeds".into(),
                            Json::Arr(
                                c.seeds.iter().map(|s| Json::Str(s.to_string())).collect(),
                            ),
                        );
                        m.insert(
                            "job_keys".into(),
                            Json::Arr(
                                c.job_keys.iter().map(|k| Json::Str(k.clone())).collect(),
                            ),
                        );
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        let mut jobs = BTreeMap::new();
        for (k, e) in &self.entries {
            let mut m = BTreeMap::new();
            m.insert("model".into(), Json::Str(e.model.clone()));
            m.insert("method_key".into(), Json::Str(e.method_key.clone()));
            m.insert("seed".into(), Json::Str(e.seed.to_string()));
            m.insert("digest".into(), Json::Str(format!("{:016x}", e.digest)));
            m.insert("config_hash".into(), Json::Str(format!("{:016x}", e.config_hash)));
            m.insert("wall_s".into(), Json::Num(e.wall_s));
            m.insert("result".into(), e.result.to_json());
            jobs.insert(k.clone(), Json::Obj(m));
        }
        root.insert("jobs".into(), Json::Obj(jobs));
        Json::Obj(root)
    }

    /// Parse a `ledger.json` document.
    pub fn from_json(j: &Json) -> Result<Ledger> {
        let schema = j.req("schema")?.as_i64().context("ledger schema")? as u64;
        anyhow::ensure!(
            schema == LEDGER_SCHEMA_VERSION,
            "unsupported ledger schema {schema} (this build reads {LEDGER_SCHEMA_VERSION})"
        );
        let grid_id = j.req("grid_id")?.as_str().context("ledger grid_id")?.to_string();
        let kind = j.req("kind")?.as_str().context("ledger kind")?.to_string();
        let mut cells = Vec::new();
        for c in j.req("cells")?.as_arr().context("ledger cells")? {
            cells.push(CellMeta {
                model: c.req("model")?.as_str().context("cell model")?.to_string(),
                label: c.req("label")?.as_str().context("cell label")?.to_string(),
                method_key: c
                    .req("method_key")?
                    .as_str()
                    .context("cell method_key")?
                    .to_string(),
                trace: c.req("trace")?.as_str().context("cell trace")?.to_string(),
                seeds: c
                    .req("seeds")?
                    .as_arr()
                    .context("cell seeds")?
                    .iter()
                    .map(|s| -> Result<u64> {
                        s.as_str()
                            .context("cell seed not a string")?
                            .parse()
                            .context("cell seed not a u64")
                    })
                    .collect::<Result<_>>()?,
                job_keys: c
                    .req("job_keys")?
                    .as_arr()
                    .context("cell job_keys")?
                    .iter()
                    .map(|k| k.as_str().map(str::to_string).context("cell job key"))
                    .collect::<Result<_>>()?,
            });
        }
        let mut entries = BTreeMap::new();
        for (k, e) in j.req("jobs")?.as_obj().context("ledger jobs")? {
            entries.insert(
                k.clone(),
                LedgerEntry {
                    key: k.clone(),
                    model: e.req("model")?.as_str().context("job model")?.to_string(),
                    method_key: e
                        .req("method_key")?
                        .as_str()
                        .context("job method_key")?
                        .to_string(),
                    seed: e
                        .req("seed")?
                        .as_str()
                        .context("job seed not a string")?
                        .parse()
                        .context("job seed not a u64")?,
                    digest: hex_u64(e, "digest")?,
                    config_hash: hex_u64(e, "config_hash")?,
                    wall_s: e.req("wall_s")?.as_f64().context("job wall_s")?,
                    result: SeedResult::from_json(e.req("result")?)
                        .with_context(|| format!("job `{k}` result"))?,
                },
            );
        }
        Ok(Ledger { schema, grid_id, kind, cells, entries })
    }

    /// Load a ledger file.
    pub fn load(path: &Path) -> Result<Ledger> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!("{}: {e} — delete the grid directory to start over", path.display())
        })?;
        Self::from_json(&j).with_context(|| format!("parsing {}", path.display()))
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`. A kill mid-save leaves the previous ledger intact.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string_compact())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        Ok(())
    }
}
