//! Small statistics helpers shared by metrics, memsim, and benches.

/// Online mean/variance (Welford). Used for per-epoch timing stats and the
/// mean±std rows in the table harness.
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1); 0 for n<2.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Exponential moving average with bias-corrected warmup, matching the
/// paper's v_l(t) = β·v_l(t-1) + (1-β)·Var[∇_l(t)].
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        Ema { beta, value: 0.0, steps: 0 }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.steps += 1;
        self.get()
    }

    /// Bias-corrected estimate (Adam-style), so early windows aren't
    /// dragged toward zero and the thresholds τ behave from step 1.
    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            let corr = 1.0 - self.beta.powi(self.steps as i32);
            self.value / corr
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Raw (uncorrected value, steps) pair — checkpoint serialization.
    pub fn raw(&self) -> (f64, u64) {
        (self.value, self.steps)
    }

    /// Restore from a [`Self::raw`] pair (checkpoint resume).
    pub fn set_raw(&mut self, value: f64, steps: u64) {
        self.value = value;
        self.steps = steps;
    }
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.std() - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn ema_converges_to_constant() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.update(5.0);
        }
        assert!((e.get() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn ema_bias_correction_first_step() {
        let mut e = Ema::new(0.99);
        e.update(3.0);
        // Without correction this would read 0.03; corrected it reads 3.0.
        assert!((e.get() - 3.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn ema_rejects_bad_beta() {
        Ema::new(1.0);
    }

    #[test]
    fn ema_raw_roundtrip() {
        let mut a = Ema::new(0.9);
        a.update(2.0);
        a.update(5.0);
        let (v, s) = a.raw();
        let mut b = Ema::new(0.9);
        b.set_raw(v, s);
        assert_eq!(a.get(), b.get());
        assert_eq!(a.steps(), b.steps());
        b.update(1.0);
        a.update(1.0);
        assert_eq!(a.get(), b.get(), "restored EMA continues identically");
    }

    #[test]
    fn percentile_basic() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
    }
}
