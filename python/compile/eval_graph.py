"""L2 eval step: running-stat BN, precision codes still honoured so the
Rust side can also measure quantized-inference accuracy (all-FP32 codes =
the paper's test-time protocol)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models import common as C


def make_eval_step(model):
    def eval_step(params, state, x, y, codes):
        logits, _ = model.apply(tuple(params), tuple(state), x, codes, train=False)
        loss = C.cross_entropy(logits, y)
        correct = C.correct_count(logits, y)
        return loss, correct

    return eval_step


def example_args(model, batch: int):
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    params = tuple(sds(p.shape, f32) for p in model.params)
    state = tuple(sds(s.shape, f32) for s in model.state)
    x = sds((batch, 32, 32, 3), f32)
    y = sds((batch,), jnp.int32)
    codes = sds((model.num_layers,), jnp.int32)
    return (params, state, x, y, codes)
