"""L2 model zoo (from-scratch JAX; no flax/optax)."""

from . import common, effnet, resnet, tiny_cnn  # noqa: F401

REGISTRY = {
    tiny_cnn.NAME: tiny_cnn.build,
    resnet.NAME: resnet.build,
    effnet.NAME: effnet.build,
}


def build(name: str, num_classes: int = 10, seed: int = 0):
    return REGISTRY[name](num_classes=num_classes, seed=seed)
