//! Integration tests over the PJRT runtime layer: manifest → engine →
//! session, exercising the real AOT artifacts (`make artifacts` first).
//! Uses `tiny_cnn_c10` — the CI-speed model.

use tri_accel::data::{synthetic::SyntheticCifar, BatchIter, Dataset};
use tri_accel::manifest::{BF16, FP16, FP32};
use tri_accel::runtime::{Engine, Session, StepCtrl};

fn engine() -> Engine {
    Engine::new(std::path::Path::new("artifacts"))
        .expect("run `make artifacts` before cargo test")
}

fn batch(n: usize, seed: u64) -> tri_accel::runtime::Batch {
    let ds = SyntheticCifar::new(10, 512, true, seed);
    BatchIter::new(Box::new(ds), seed, false).next_batch(n).unwrap()
}

#[test]
fn manifest_lists_all_models_with_artifacts() {
    let e = engine();
    for key in ["tiny_cnn_c10", "resnet18_c10", "resnet18_c100", "effnet_lite_c10", "effnet_lite_c100"] {
        let m = e.manifest.model(key).unwrap();
        assert!(m.num_layers > 0);
        assert!(!m.train_buckets.is_empty());
        // Every advertised artifact file must exist on disk.
        for name in m.artifacts.keys() {
            let p = e.manifest.artifact_path(m, name).unwrap();
            assert!(p.exists(), "{key}: missing artifact {p:?}");
        }
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let e = engine();
    let s1 = Session::init(&e, "tiny_cnn_c10", 7).unwrap();
    let s2 = Session::init(&e, "tiny_cnn_c10", 7).unwrap();
    let s3 = Session::init(&e, "tiny_cnn_c10", 8).unwrap();
    for i in 0..3 {
        assert_eq!(s1.param_norm(i).unwrap(), s2.param_norm(i).unwrap());
    }
    let diff = (0..3).any(|i| s1.param_norm(i).unwrap() != s3.param_norm(i).unwrap());
    assert!(diff, "different seeds must give different inits");
}

#[test]
fn train_step_updates_params_and_reports_stats() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let before: Vec<f64> = (0..n).map(|i| s.param_norm(i).unwrap()).collect();
    let b = batch(16, 0);
    let ctrl = StepCtrl::uniform(n, FP32, 0.05, 5e-4);
    let out = s.train_step(&b, &ctrl).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0, "loss {}", out.loss);
    assert!((0..=16).contains(&out.correct));
    assert_eq!(out.grad_var.len(), n);
    assert!(out.grad_var.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(out.grad_norm.iter().all(|g| g.is_finite() && *g >= 0.0));
    assert!(!out.overflow);
    let after: Vec<f64> = (0..n).map(|i| s.param_norm(i).unwrap()).collect();
    assert_ne!(before, after, "params must move");
}

#[test]
fn train_step_rejects_non_bucket_batch() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let b = batch(13, 0); // 13 is not an AOT bucket
    let ctrl = StepCtrl::uniform(n, FP32, 0.05, 0.0);
    assert!(s.train_step(&b, &ctrl).is_err());
}

#[test]
fn train_step_rejects_bad_arity() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let b = batch(16, 0);
    let ctrl = StepCtrl::uniform(2, FP32, 0.05, 0.0); // wrong layer count
    if s.num_layers() != 2 {
        assert!(s.train_step(&b, &ctrl).is_err());
    }
}

#[test]
fn training_is_bitwise_reproducible() {
    let e = engine();
    let run = || {
        let mut s = Session::init(&e, "tiny_cnn_c10", 3).unwrap();
        let n = s.num_layers();
        let ctrl = StepCtrl::uniform(n, BF16, 0.05, 5e-4);
        let mut losses = Vec::new();
        for i in 0..3 {
            let b = batch(16, 100 + i);
            losses.push(s.train_step(&b, &ctrl).unwrap().loss);
        }
        (losses, s.params_host().unwrap())
    };
    let (l1, p1) = run();
    let (l2, p2) = run();
    assert_eq!(l1, l2, "loss trajectory must be bit-identical");
    assert_eq!(p1, p2, "parameters must be bit-identical");
}

#[test]
fn precision_codes_change_numerics_but_stay_close() {
    let e = engine();
    let run_at = |code: i32| {
        let mut s = Session::init(&e, "tiny_cnn_c10", 1).unwrap();
        let ctrl = StepCtrl::uniform(s.num_layers(), code, 0.05, 0.0);
        let b = batch(16, 9);
        let out = s.train_step(&b, &ctrl).unwrap();
        (out.loss, out.grad_var)
    };
    let (l32, v32) = run_at(FP32);
    let (l16, v16) = run_at(FP16);
    let (lbf, vbf) = run_at(BF16);
    // The quantization must actually perturb the computation. The
    // scalar loss can coincidentally round identically (observed for
    // fp16 at init), so the robust check is on the gradient statistics,
    // which integrate rounding error across every parameter.
    assert_ne!(v32, v16, "fp16 emulation must perturb gradients");
    assert_ne!(v32, vbf, "bf16 emulation must perturb gradients");
    // ... but only slightly: same loss to 10%, grad variance same scale.
    assert!((l32 - l16).abs() / l32 < 0.1, "fp16 loss far off: {l32} vs {l16}");
    assert!((l32 - lbf).abs() / l32 < 0.1, "bf16 loss far off: {l32} vs {lbf}");
    for (a, b) in v32.iter().zip(&v16) {
        assert!((a / b).max(b / a) < 2.0, "fp16 grad_var off-scale: {a} vs {b}");
    }
}

#[test]
fn eval_counts_correct_within_batch() {
    let e = engine();
    let s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let codes = vec![FP32; s.num_layers()];
    let ds = SyntheticCifar::new(10, 512, false, 4);
    let mut x = vec![0f32; 16 * 32 * 32 * 3];
    let mut y = vec![0i32; 16];
    for i in 0..16 {
        y[i] = ds.example(i, &mut x[i * 3072..(i + 1) * 3072]);
    }
    let b = tri_accel::runtime::Batch::new(x, y);
    let r = s.eval_batch(&b, &codes).unwrap();
    assert!(r.loss.is_finite() && r.loss > 0.0);
    assert!((0..=16).contains(&r.correct));
    assert_eq!(r.total, 16);
}

#[test]
fn curvature_probe_converges_to_stable_lambda() {
    let e = engine();
    let mut s = Session::init(&e, "tiny_cnn_c10", 0).unwrap();
    let n = s.num_layers();
    let codes = vec![FP32; n];
    let cb = s.entry.curv_batch;
    let b = batch(cb, 5);
    let mut last = Vec::new();
    for _ in 0..6 {
        last = s.curv_step(&b, &codes, 11).unwrap();
        assert_eq!(last.len(), n);
    }
    let next = s.curv_step(&b, &codes, 11).unwrap();
    for (l, (a, b_)) in last.iter().zip(&next).enumerate() {
        assert!(a.is_finite() && b_.is_finite(), "layer {l}: λ not finite");
        // Power iteration on a fixed batch should be near-converged
        // after 7 steps: successive Rayleigh quotients within 25%.
        let denom = a.abs().max(1e-3);
        assert!(
            (a - b_).abs() / denom < 0.25,
            "layer {l}: λ jitter {a} → {b_}"
        );
    }
}

#[test]
fn executable_cache_compiles_once() {
    let e = engine();
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    assert!(!e.is_warm(&entry, "train_b16"));
    let _ = e.executable(&entry, "train_b16").unwrap();
    assert!(e.is_warm(&entry, "train_b16"));
    let log1 = e.compile_log().len();
    let _ = e.executable(&entry, "train_b16").unwrap();
    assert_eq!(e.compile_log().len(), log1, "second fetch must hit the cache");
}

#[test]
fn loss_scale_is_value_neutral_for_fp32() {
    // The train graph divides the scale back out — an FP32 run with
    // scale 1024 must match scale 1 bit-for-bit (no fp16 rounding).
    let e = engine();
    let run = |scale: f32| {
        let mut s = Session::init(&e, "tiny_cnn_c10", 2).unwrap();
        let n = s.num_layers();
        let mut ctrl = StepCtrl::uniform(n, FP32, 0.05, 0.0);
        ctrl.loss_scale = scale;
        let b = batch(16, 77);
        let out = s.train_step(&b, &ctrl).unwrap();
        (out.loss, s.params_host().unwrap())
    };
    let (l1, p1) = run(1.0);
    let (l2, p2) = run(1024.0);
    assert_eq!(l1, l2);
    // Gradients go through *2^k scaling — exact in binary fp.
    assert_eq!(p1, p2, "2^k loss scaling must be exact for fp32");
}
