//! Manifest-driven layer-graph executor for the native backend.
//!
//! Every native model is a typed node list ([`crate::manifest::NodeSpec`])
//! the manifest carries: conv k×k (any stride), 1×1 conv, depthwise
//! conv, BatchNorm, ReLU, 2×2 max pool, global average pool, residual
//! add, dense head, and a terminal softmax cross-entropy. The executor
//! walks the list forward (caching what each op's VJP needs) and in
//! reverse (cotangent buffers per node, accumulated at residual forks),
//! with the PR-2 compute core threaded through every node: convs run as
//! fused-qdq im2col + tiled GEMM, depthwise convs as direct fixed-order
//! kernels, every scratch buffer comes from the [`Exec`] arena (a warm
//! train step performs zero *scratch-buffer* allocations; the per-call
//! [`Plan`] bookkeeping is a handful of tiny vecs, negligible next to
//! one conv), and all parallelism
//! goes through the deterministic worker pool — output is bit-identical
//! for every `TRIACCEL_THREADS` value.
//!
//! Semantics (unchanged from the hand-written tiny_cnn executor, which
//! this replaces bit-compatibly — pinned by `tests/golden_trace.rs`):
//! * forward: conv/dense consume the precision code of their layer
//!   (weights + input activations rounded through qdq, BN always fp32);
//! * backward: Pallas-kernel VJP contract — cotangents leaving a
//!   precision layer are re-quantized at that layer's code;
//! * train step: loss-scaled grads, overflow detection (any non-finite
//!   grad skips the whole update and holds BN state), per-layer
//!   grad-variance/norm stats, fused SGD+momentum with weight decay and
//!   per-layer LR scales;
//! * curv step: block-diagonal Hessian-vector products via per-layer
//!   central differences of the gradient (one power-iteration step per
//!   firing), the strict-block variant of `curv_graph.py`.
//!
//! Shape inference happens once per call in [`Plan::build`]: node input
//! dims are propagated from the 32×32×3 batch images and validated
//! against every parameter shape, so a malformed manifest fails loudly
//! before any compute.

#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::arena::Arena;
use super::gemm;
use super::ops;
use super::qdq;
use super::Exec;
use crate::manifest::{ModelEntry, NodeOp, NODE_INPUT_IMAGE};
use crate::runtime::backend::ModelState;
use crate::runtime::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::util::rng::Rng;

/// Batch images are CIFAR-shaped (the [`Batch`] contract).
const INPUT_H: usize = 32;
const INPUT_W: usize = 32;
const INPUT_C: usize = 3;

/// SGD momentum (kernels/ref.py::SGD_MOMENTUM).
const MOMENTUM: f32 = 0.9;

/// Spatial/channel extent of one node's activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Dims {
    pub(crate) h: usize,
    pub(crate) w: usize,
    pub(crate) c: usize,
}

impl Dims {
    pub(crate) fn elems(&self, n: usize) -> usize {
        n * self.h * self.w * self.c
    }
}

/// Per-node shape plan: the node's input and output extents.
pub(crate) struct NodePlan {
    pub(crate) din: Dims,
    pub(crate) dout: Dims,
}

/// The validated execution plan for one model entry.
pub(crate) struct Plan {
    pub(crate) nd: Vec<NodePlan>,
}

impl Plan {
    /// Infer and validate every node's shapes against the manifest's
    /// parameter/state tables. Also checks the structural invariants
    /// the executor relies on: every parameter owned by exactly one
    /// node, every BN state slot by exactly one BN node, and every
    /// non-terminal node's output consumed by someone.
    pub(crate) fn build(entry: &ModelEntry) -> Result<Plan> {
        anyhow::ensure!(
            !entry.nodes.is_empty(),
            "model `{}` has no layer graph (artifact-only entry)",
            entry.key
        );
        let img = Dims { h: INPUT_H, w: INPUT_W, c: INPUT_C };
        let mut nd: Vec<NodePlan> = Vec::with_capacity(entry.nodes.len());
        let mut param_used = vec![false; entry.params.len()];
        let mut state_used = vec![false; entry.state_shapes.len()];
        let mut out_used = vec![false; entry.nodes.len()];
        let mut claim_param = |w: usize, what: &str| -> Result<()> {
            anyhow::ensure!(
                !std::mem::replace(&mut param_used[w], true),
                "{}: param {w} ({what}) claimed by two nodes",
                entry.key
            );
            Ok(())
        };
        for (i, node) in entry.nodes.iter().enumerate() {
            let ctx = |what: &str| format!("{}: graph[{i}]: {what}", entry.key);
            // Index sanity for hand-built entries (the manifest parser
            // already validates what it loads).
            match node.op {
                NodeOp::Conv { w, layer, .. }
                | NodeOp::DwConv { w, layer, .. }
                | NodeOp::Dense { w, layer, .. } => {
                    anyhow::ensure!(
                        w < entry.params.len() && layer < entry.num_layers,
                        "{}",
                        ctx("param/layer index out of range")
                    );
                }
                NodeOp::Bn { gamma, beta, state } => {
                    anyhow::ensure!(
                        gamma < entry.params.len()
                            && beta < entry.params.len()
                            && state + 2 <= entry.state_shapes.len(),
                        "{}",
                        ctx("bn param/state index out of range")
                    );
                }
                _ => {}
            }
            if let NodeOp::Dense { b, .. } = node.op {
                anyhow::ensure!(b < entry.params.len(), "{}", ctx("bias index out of range"));
            }
            let din = if node.input == NODE_INPUT_IMAGE {
                // Only the conv kinds may read the images directly (the
                // backward's pre-activation/argmax caching assumes every
                // other op's input is a cached node output).
                anyhow::ensure!(
                    matches!(node.op, NodeOp::Conv { .. } | NodeOp::DwConv { .. }),
                    "{}: graph[{i}]: only conv/dwconv may read the image input",
                    entry.key
                );
                img
            } else {
                anyhow::ensure!(
                    node.input >= 0 && (node.input as usize) < i,
                    "{}",
                    ctx("input must be an earlier node")
                );
                out_used[node.input as usize] = true;
                nd[node.input as usize].dout
            };
            let dout = match node.op {
                NodeOp::Conv { k, stride, w, .. } => {
                    let spec = &entry.params[w];
                    anyhow::ensure!(
                        spec.shape.len() == 4
                            && spec.shape[0] == k
                            && spec.shape[1] == k
                            && spec.shape[2] == din.c,
                        "{}",
                        ctx(&format!(
                            "conv weight `{}` shape {:?} != [{k},{k},{},cout]",
                            spec.name, spec.shape, din.c
                        ))
                    );
                    claim_param(w, "conv/w")?;
                    Dims {
                        h: gemm::conv_out_dim(din.h, stride),
                        w: gemm::conv_out_dim(din.w, stride),
                        c: spec.shape[3],
                    }
                }
                NodeOp::DwConv { k, stride, w, .. } => {
                    let spec = &entry.params[w];
                    anyhow::ensure!(
                        spec.shape == [k, k, 1, din.c],
                        "{}",
                        ctx(&format!(
                            "dwconv weight `{}` shape {:?} != [{k},{k},1,{}]",
                            spec.name, spec.shape, din.c
                        ))
                    );
                    claim_param(w, "dwconv/w")?;
                    Dims {
                        h: gemm::conv_out_dim(din.h, stride),
                        w: gemm::conv_out_dim(din.w, stride),
                        c: din.c,
                    }
                }
                NodeOp::Bn { gamma, beta, state } => {
                    for (p, what) in [(gamma, "gamma"), (beta, "beta")] {
                        anyhow::ensure!(
                            entry.params[p].elems == din.c,
                            "{}",
                            ctx(&format!("bn {what} arity != {} channels", din.c))
                        );
                        claim_param(p, what)?;
                    }
                    for s in [state, state + 1] {
                        anyhow::ensure!(
                            entry.state_shapes[s].iter().product::<usize>() == din.c,
                            "{}",
                            ctx("bn state arity != channels")
                        );
                        anyhow::ensure!(
                            !std::mem::replace(&mut state_used[s], true),
                            "{}",
                            ctx("bn state slot claimed twice")
                        );
                    }
                    din
                }
                NodeOp::Relu => din,
                NodeOp::MaxPool2 => {
                    anyhow::ensure!(
                        din.h % 2 == 0 && din.w % 2 == 0,
                        "{}",
                        ctx("maxpool2 needs even spatial dims")
                    );
                    Dims { h: din.h / 2, w: din.w / 2, c: din.c }
                }
                NodeOp::Gap => Dims { h: 1, w: 1, c: din.c },
                NodeOp::Dense { w, b, .. } => {
                    anyhow::ensure!(
                        din.h == 1 && din.w == 1,
                        "{}",
                        ctx("dense needs pooled (1×1) input")
                    );
                    let spec = &entry.params[w];
                    anyhow::ensure!(
                        spec.shape.len() == 2 && spec.shape[0] == din.c,
                        "{}",
                        ctx(&format!(
                            "dense weight `{}` shape {:?} != [{}, classes]",
                            spec.name, spec.shape, din.c
                        ))
                    );
                    let classes = spec.shape[1];
                    anyhow::ensure!(
                        entry.params[b].elems == classes,
                        "{}",
                        ctx("dense bias arity != classes")
                    );
                    claim_param(w, "dense/w")?;
                    claim_param(b, "dense/b")?;
                    Dims { h: 1, w: 1, c: classes }
                }
                NodeOp::Add { rhs } => {
                    anyhow::ensure!(rhs < i, "{}", ctx("add rhs must be an earlier node"));
                    out_used[rhs] = true;
                    anyhow::ensure!(
                        nd[rhs].dout == din,
                        "{}",
                        ctx("residual add branches disagree on shape")
                    );
                    din
                }
                NodeOp::SoftmaxCe => {
                    anyhow::ensure!(
                        i + 1 == entry.nodes.len(),
                        "{}",
                        ctx("softmax_ce must be the terminal node")
                    );
                    anyhow::ensure!(
                        din.h == 1 && din.w == 1 && din.c == entry.num_classes,
                        "{}",
                        ctx("loss input must be (1×1, num_classes) logits")
                    );
                    din
                }
            };
            nd.push(NodePlan { din, dout });
        }
        for (w, used) in param_used.iter().enumerate() {
            anyhow::ensure!(
                used,
                "{}: param {w} (`{}`) not referenced by the graph",
                entry.key,
                entry.params[w].name
            );
        }
        for (s, used) in state_used.iter().enumerate() {
            anyhow::ensure!(used, "{}: state slot {s} not owned by any bn node", entry.key);
        }
        for (i, used) in out_used.iter().enumerate().take(entry.nodes.len() - 1) {
            anyhow::ensure!(used, "{}: node {i}'s output is never consumed", entry.key);
        }
        // The executor seeds the backward from the loss node; a graph
        // without one would silently eval to loss 0 and panic in train.
        anyhow::ensure!(
            matches!(entry.nodes.last().map(|n| &n.op), Some(NodeOp::SoftmaxCe)),
            "{}: graph must end in a softmax_ce loss node",
            entry.key
        );
        Ok(Plan { nd })
    }
}

/// Per-node forward caches the backward consumes. All buffers are
/// arena-backed; [`release_fwd`] checks them back in.
pub(crate) enum Aux {
    None,
    /// Quantized im2col panels + quantized weights.
    Conv { cols: Vec<f32>, wq: Vec<f32> },
    /// Quantized input copy + quantized weights.
    DwConv { xq: Vec<f32>, wq: Vec<f32> },
    /// Batch statistics (running stats in eval mode).
    Bn { mean: Vec<f32>, inv: Vec<f32> },
    /// Max-pool argmax map.
    Pool { arg: Vec<u8> },
    /// Quantized dense input / weight.
    Dense { xq: Vec<f32>, wq: Vec<f32> },
}

pub(crate) struct NodeCache {
    /// Output activation (empty for the terminal loss node).
    pub(crate) act: Vec<f32>,
    pub(crate) aux: Aux,
}

/// Scalar outputs of the loss node, accumulated per forward walk (one
/// logical batch for the fused path, one shard for the replica path).
#[derive(Default)]
pub(crate) struct FwdScalars {
    /// Cotangent of the (unscaled) mean loss w.r.t. the logits.
    pub(crate) dlogits: Vec<f32>,
    /// Unnormalized f64 CE loss sum (divide by the logical batch).
    pub(crate) loss_sum: f64,
    pub(crate) correct: i64,
}

struct Fwd {
    caches: Vec<NodeCache>,
    /// Updated BN running stats (train mode), indexed like `st.state`.
    new_state: Vec<Vec<f32>>,
    /// Cotangent of the (unscaled) mean loss w.r.t. the logits.
    dlogits: Vec<f32>,
    loss: f32,
    correct: i64,
}

/// Return a forward walk's node caches to the arena (the replica path
/// releases per-shard cache vectors through this same hook).
pub(crate) fn release_caches(ex: &mut Exec, caches: Vec<NodeCache>) {
    for c in caches {
        ex.arena.put(c.act);
        match c.aux {
            Aux::None => {}
            Aux::Conv { cols, wq } => {
                ex.arena.put(cols);
                ex.arena.put(wq);
            }
            Aux::DwConv { xq, wq } => {
                ex.arena.put(xq);
                ex.arena.put(wq);
            }
            Aux::Bn { mean, inv } => {
                ex.arena.put(mean);
                ex.arena.put(inv);
            }
            Aux::Pool { arg } => ex.arena.put_u8(arg),
            Aux::Dense { xq, wq } => {
                ex.arena.put(xq);
                ex.arena.put(wq);
            }
        }
    }
}

/// Return every forward cache to the arena.
fn release_fwd(ex: &mut Exec, fwd: Fwd) {
    let Fwd { caches, new_state, dlogits, .. } = fwd;
    release_caches(ex, caches);
    ex.arena.put_all(new_state);
    ex.arena.put(dlogits);
}

/// One node of the forward walk. `n` is the sample count this walk
/// carries (the whole batch on the fused path, one canonical shard on
/// the replica path) and `n_loss` is the logical batch size the CE
/// mean normalizes by (`== n` on the fused path). BN nodes here
/// compute whole-walk batch statistics — the replica path normalizes
/// its BN nodes against globally reduced statistics instead and never
/// routes them through this function (`replica.rs`).
pub(crate) fn forward_node(
    ex: &mut Exec,
    entry: &ModelEntry,
    plan: &Plan,
    i: usize,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    n: usize,
    n_loss: usize,
    codes: &[i32],
    train: bool,
    caches: &mut Vec<NodeCache>,
    new_state: &mut [Vec<f32>],
    scal: &mut FwdScalars,
) {
    let Exec { pool, arena } = ex;
    let node = &entry.nodes[i];
    {
        let p = &plan.nd[i];
        let (din, dout) = (p.din, p.dout);
        let src: &[f32] = if node.input == NODE_INPUT_IMAGE {
            x
        } else {
            &caches[node.input as usize].act
        };
        let cache = match node.op {
            NodeOp::Conv { k, stride, w, layer } => {
                let code = codes[layer];
                let rows = n * dout.h * dout.w;
                let kk = k * k * din.c;
                // im2col with the qdq round-trip fused into the pack —
                // the only place input activations are rounded; no
                // quantized activation copy is materialized.
                let mut cols = arena.take(rows * kk);
                gemm::im2col_qdq(pool, src, n, din.h, din.w, din.c, k, stride, code, &mut cols);
                let mut wq = arena.take(params[w].len());
                qdq::qdq_into(&params[w], &mut wq, code);
                let mut out = arena.take(rows * dout.c);
                gemm::gemm(pool, arena, &cols, &wq, &mut out, rows, kk, dout.c, false);
                NodeCache { act: out, aux: Aux::Conv { cols, wq } }
            }
            NodeOp::DwConv { k, stride, w, layer } => {
                let code = codes[layer];
                // Depthwise runs direct (no im2col), so the quantized
                // input copy is materialized once for fwd + dw-bwd use.
                let mut xq = arena.take(din.elems(n));
                qdq::qdq_into(src, &mut xq, code);
                let mut wq = arena.take(params[w].len());
                qdq::qdq_into(&params[w], &mut wq, code);
                let mut out = arena.take(dout.elems(n));
                ops::dwconv_fwd_into(pool, &xq, n, din.h, din.w, din.c, k, stride, &wq, &mut out);
                NodeCache { act: out, aux: Aux::DwConv { xq, wq } }
            }
            NodeOp::Bn { gamma, beta, state: st } => {
                let rows = n * din.h * din.w;
                let c = din.c;
                let mut out = arena.take(rows * c);
                let mut nrm = arena.take(c);
                let mut nrv = arena.take(c);
                let mut mean = arena.take(c);
                let mut inv = arena.take(c);
                ops::bn_fwd_into(
                    src,
                    rows,
                    c,
                    &params[gamma],
                    &params[beta],
                    &state[st],
                    &state[st + 1],
                    train,
                    &mut out,
                    &mut nrm,
                    &mut nrv,
                    &mut mean,
                    &mut inv,
                );
                new_state[st] = nrm;
                new_state[st + 1] = nrv;
                NodeCache { act: out, aux: Aux::Bn { mean, inv } }
            }
            NodeOp::Relu => {
                // ReLU on a copy — the input stays cached as the
                // pre-activation the backward masks against.
                let mut out = arena.take(din.elems(n));
                out.copy_from_slice(src);
                ops::relu_inplace(&mut out);
                NodeCache { act: out, aux: Aux::None }
            }
            NodeOp::MaxPool2 => {
                let mut out = arena.take(dout.elems(n));
                let mut arg = arena.take_u8(dout.elems(n));
                ops::maxpool2_fwd_into(src, n, din.h, din.w, din.c, &mut out, &mut arg);
                NodeCache { act: out, aux: Aux::Pool { arg } }
            }
            NodeOp::Gap => {
                let mut out = arena.take(n * din.c);
                ops::gap_fwd_into(src, n, din.h, din.w, din.c, &mut out);
                NodeCache { act: out, aux: Aux::None }
            }
            NodeOp::Dense { w, b, layer } => {
                let code = codes[layer];
                let (features, classes) = (din.c, dout.c);
                let mut xq = arena.take(n * features);
                qdq::qdq_into(src, &mut xq, code);
                let mut wq = arena.take(params[w].len());
                qdq::qdq_into(&params[w], &mut wq, code);
                // Bias-preloaded GEMM (mp_matmul operand quantization).
                let mut out = arena.take(n * classes);
                for r in 0..n {
                    out[r * classes..(r + 1) * classes].copy_from_slice(&params[b]);
                }
                gemm::gemm(pool, arena, &xq, &wq, &mut out, n, features, classes, true);
                NodeCache { act: out, aux: Aux::Dense { xq, wq } }
            }
            NodeOp::Add { rhs } => {
                let rhs_act = &caches[rhs].act;
                let mut out = arena.take(din.elems(n));
                for ((o, &a), &b) in out.iter_mut().zip(src.iter()).zip(rhs_act.iter()) {
                    *o = a + b;
                }
                NodeCache { act: out, aux: Aux::None }
            }
            NodeOp::SoftmaxCe => {
                let classes = din.c;
                let mut dl = arena.take(n * classes);
                let (ls, corr) = ops::softmax_ce_sum_into(src, y, n, classes, n_loss, &mut dl);
                scal.dlogits = dl;
                scal.loss_sum = ls;
                scal.correct = corr;
                NodeCache { act: Vec::new(), aux: Aux::None }
            }
        };
        caches.push(cache);
    }
}

fn forward(
    ex: &mut Exec,
    entry: &ModelEntry,
    plan: &Plan,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    n: usize,
    codes: &[i32],
    train: bool,
) -> Fwd {
    let mut caches: Vec<NodeCache> = Vec::with_capacity(entry.nodes.len());
    let mut new_state: Vec<Vec<f32>> = (0..entry.state_shapes.len()).map(|_| Vec::new()).collect();
    let mut scal = FwdScalars::default();
    for i in 0..entry.nodes.len() {
        forward_node(
            ex, entry, plan, i, params, state, x, y, n, n, codes, train, &mut caches,
            &mut new_state, &mut scal,
        );
    }
    let FwdScalars { dlogits, loss_sum, correct } = scal;
    Fwd { caches, new_state, dlogits, loss: (loss_sum / n as f64) as f32, correct }
}

/// Hand a cotangent buffer to `grad[input]`: moved when the slot is
/// empty (the common single-consumer chain — value-exact), accumulated
/// when a residual fork already deposited one. Cotangents aimed at the
/// batch images are dropped (never consumed — the stem conv skips that
/// GEMM entirely).
pub(crate) fn send(arena: &mut Arena, grad: &mut [Option<Vec<f32>>], input: i64, buf: Vec<f32>) {
    if input == NODE_INPUT_IMAGE {
        arena.put(buf);
        return;
    }
    let slot = &mut grad[input as usize];
    if let Some(acc) = slot {
        for (a, &b) in acc.iter_mut().zip(buf.iter()) {
            *a += b;
        }
        arena.put(buf);
    } else {
        *slot = Some(buf);
    }
}

/// One node of the reverse walk over one forward walk's `caches`. The
/// SoftmaxCe arm seeds from `dlogits × loss_scale`; every other arm
/// consumes the cotangent deposited in `grad[i]` and writes parameter
/// gradients of the *scaled* loss into `grads` (the caller unscales).
/// The BN arm reduces whole-walk statistics — as in the forward, the
/// replica path handles BN nodes itself and never routes them here.
pub(crate) fn backward_node(
    ex: &mut Exec,
    entry: &ModelEntry,
    plan: &Plan,
    i: usize,
    caches: &[NodeCache],
    dlogits: &[f32],
    params: &[Vec<f32>],
    codes: &[i32],
    loss_scale: f32,
    n: usize,
    grad: &mut [Option<Vec<f32>>],
    grads: &mut [Vec<f32>],
) {
    let Exec { pool, arena } = ex;
    let node = &entry.nodes[i];
    {
        let p = &plan.nd[i];
        let (din, dout) = (p.din, p.dout);
        if let NodeOp::SoftmaxCe = node.op {
            // Seed with the cotangent of the scaled loss.
            let mut g = arena.take(n * din.c);
            for (d, &v) in g.iter_mut().zip(dlogits.iter()) {
                *d = v * loss_scale;
            }
            send(arena, grad, node.input, g);
            return;
        }
        // detlint: allow(d6) — Plan validation proved every non-loss
        // node's output is consumed, so the reverse walk always finds a
        // deposited cotangent; a miss is executor-corruption, not input.
        let mut g = grad[i].take().expect("consumed node has a cotangent");
        match node.op {
            NodeOp::Conv { k, stride, w, layer } => {
                let code = codes[layer];
                let (cols, wq) = match &caches[i].aux {
                    Aux::Conv { cols, wq } => (cols, wq),
                    _ => unreachable!("conv node caches conv aux"),
                };
                let rows = n * dout.h * dout.w;
                let kk = k * k * din.c;
                // dw = x_colsᵀ·g (ordered-reduction GEMM), then
                // dx = col2im(g·Wᵀ); qdq VJP rounds both cotangents.
                let mut dw = arena.take(kk * dout.c);
                gemm::gemm_at_b(pool, arena, cols, &g, &mut dw, rows, kk, dout.c);
                qdq::qdq_inplace(&mut dw, code);
                grads[w] = dw;
                if node.input == NODE_INPUT_IMAGE {
                    // The cotangent w.r.t. the images is never consumed
                    // — skip its GEMM + col2im entirely.
                    arena.put(g);
                } else {
                    let mut dcols = arena.take(rows * kk);
                    gemm::gemm_a_bt(pool, arena, &g, wq, &mut dcols, rows, dout.c, kk, false);
                    arena.put(g);
                    let mut dx = arena.take(din.elems(n));
                    gemm::col2im(pool, &dcols, n, din.h, din.w, din.c, k, stride, &mut dx);
                    arena.put(dcols);
                    qdq::qdq_inplace(&mut dx, code);
                    send(arena, grad, node.input, dx);
                }
            }
            NodeOp::DwConv { k, stride, w, layer } => {
                let code = codes[layer];
                let (xq, wq) = match &caches[i].aux {
                    Aux::DwConv { xq, wq } => (xq, wq),
                    _ => unreachable!("dwconv node caches dwconv aux"),
                };
                let mut dw = arena.take(k * k * din.c);
                ops::dwconv_dw_into(xq, &g, n, din.h, din.w, din.c, k, stride, &mut dw);
                qdq::qdq_inplace(&mut dw, code);
                grads[w] = dw;
                if node.input == NODE_INPUT_IMAGE {
                    arena.put(g);
                } else {
                    let mut dx = arena.take(din.elems(n));
                    ops::dwconv_dx_into(pool, &g, wq, n, din.h, din.w, din.c, k, stride, &mut dx);
                    arena.put(g);
                    qdq::qdq_inplace(&mut dx, code);
                    send(arena, grad, node.input, dx);
                }
            }
            NodeOp::Bn { gamma, beta, state: _ } => {
                let (mean, inv) = match &caches[i].aux {
                    Aux::Bn { mean, inv } => (mean, inv),
                    _ => unreachable!("bn node caches bn aux"),
                };
                let rows = n * din.h * din.w;
                let c = din.c;
                let conv_out: &[f32] = if node.input == NODE_INPUT_IMAGE {
                    unreachable!("bn never reads the images directly")
                } else {
                    &caches[node.input as usize].act
                };
                let mut dx = arena.take(rows * c);
                let mut dgamma = arena.take(c);
                let mut dbeta = arena.take(c);
                ops::bn_bwd_into(
                    conv_out,
                    &g,
                    rows,
                    c,
                    &params[gamma],
                    mean,
                    inv,
                    &mut dx,
                    &mut dgamma,
                    &mut dbeta,
                );
                arena.put(g);
                grads[gamma] = dgamma;
                grads[beta] = dbeta;
                send(arena, grad, node.input, dx);
            }
            NodeOp::Relu => {
                let pre: &[f32] = &caches[node.input as usize].act;
                ops::relu_bwd_inplace(&mut g, pre);
                send(arena, grad, node.input, g);
            }
            NodeOp::MaxPool2 => {
                let arg = match &caches[i].aux {
                    Aux::Pool { arg } => arg,
                    _ => unreachable!("pool node caches its argmax"),
                };
                let mut dx = arena.take(din.elems(n));
                ops::maxpool2_bwd_into(&g, arg, n, din.h, din.w, din.c, &mut dx);
                arena.put(g);
                send(arena, grad, node.input, dx);
            }
            NodeOp::Gap => {
                let mut dx = arena.take(din.elems(n));
                ops::gap_bwd_into(&g, n, din.h, din.w, din.c, &mut dx);
                arena.put(g);
                send(arena, grad, node.input, dx);
            }
            NodeOp::Dense { w, b, layer } => {
                let code = codes[layer];
                let (xq, wq) = match &caches[i].aux {
                    Aux::Dense { xq, wq } => (xq, wq),
                    _ => unreachable!("dense node caches dense aux"),
                };
                let (features, classes) = (din.c, dout.c);
                // mp_matmul VJP: dx/dw see the quantized cotangent, the
                // bias grad sits outside the kernel and sees the raw one.
                let mut gq = arena.take(n * classes);
                qdq::qdq_into(&g, &mut gq, code);
                let mut dx = arena.take(n * features);
                gemm::gemm_a_bt(pool, arena, &gq, wq, &mut dx, n, classes, features, false);
                let mut dw = arena.take(features * classes);
                gemm::gemm_at_b(pool, arena, xq, &gq, &mut dw, n, features, classes);
                arena.put(gq);
                let mut db = arena.take(classes);
                for bi in 0..n {
                    for (d, &v) in db.iter_mut().zip(g[bi * classes..(bi + 1) * classes].iter()) {
                        *d += v;
                    }
                }
                arena.put(g);
                grads[w] = dw;
                grads[b] = db;
                send(arena, grad, node.input, dx);
            }
            NodeOp::Add { rhs } => {
                // The residual add copies the cotangent to both
                // branches unchanged.
                let mut side = arena.take(g.len());
                side.copy_from_slice(&g);
                send(arena, grad, rhs as i64, side);
                send(arena, grad, node.input, g);
            }
            NodeOp::SoftmaxCe => unreachable!("handled above"),
        }
    }
}

/// Divide every gradient by the loss scale (exact for 2^k scales).
pub(crate) fn unscale_grads(grads: &mut [Vec<f32>], loss_scale: f32) {
    let inv = 1.0 / loss_scale;
    for gvec in grads.iter_mut() {
        for v in gvec.iter_mut() {
            *v *= inv;
        }
    }
}

/// Reverse pass: returns the parameter gradients of the *unscaled* mean
/// loss (the loss-scale round-trip is exact for 2^k scales). Gradients
/// are arena buffers; the caller checks them back in.
fn backward(
    ex: &mut Exec,
    entry: &ModelEntry,
    plan: &Plan,
    fwd: &Fwd,
    params: &[Vec<f32>],
    codes: &[i32],
    loss_scale: f32,
    n: usize,
) -> Vec<Vec<f32>> {
    let mut grads: Vec<Vec<f32>> = (0..params.len()).map(|_| Vec::new()).collect();
    let mut grad: Vec<Option<Vec<f32>>> = (0..entry.nodes.len()).map(|_| None).collect();
    for i in (0..entry.nodes.len()).rev() {
        backward_node(
            ex, entry, plan, i, &fwd.caches, &fwd.dlogits, params, codes, loss_scale, n,
            &mut grad, &mut grads,
        );
    }
    unscale_grads(&mut grads, loss_scale);
    grads
}

/// Per-precision-layer (variance, Σg²) of the parameter gradients,
/// mirroring `train_graph._per_layer_grad_stats`. NaN/inf gradients
/// propagate into the stats (the controller ignores non-finite values).
pub(crate) fn layer_stats(entry: &ModelEntry, grads: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let l_count = entry.num_layers;
    let mut sum = vec![0f64; l_count];
    let mut sq = vec![0f64; l_count];
    let mut count = vec![0usize; l_count];
    for (spec, g) in entry.params.iter().zip(grads) {
        if spec.layer_idx < 0 {
            continue;
        }
        let li = spec.layer_idx as usize;
        for &v in g {
            sum[li] += v as f64;
            sq[li] += (v as f64) * (v as f64);
        }
        count[li] += g.len();
    }
    let mut var = Vec::with_capacity(l_count);
    let mut norm = Vec::with_capacity(l_count);
    for li in 0..l_count {
        let cnt = count[li].max(1) as f64;
        let mean = sum[li] / cnt;
        let raw = sq[li] / cnt - mean * mean;
        // Clamp round-off below zero but let NaN through (overflow
        // steps must not report a fake zero variance).
        let v = if raw.is_nan() { f64::NAN } else { raw.max(0.0) };
        var.push(v as f32);
        norm.push(sq[li] as f32);
    }
    (var, norm)
}

/// Seed-deterministic parameter/state materialization (he-normal convs
/// — depthwise fan-in is k² via the [k,k,1,c] shape — kaiming-uniform
/// dense, unit gammas, zero betas/bias; BN running stats start at
/// (0, 1)). Each tensor draws from its own RNG stream, so the init is
/// independent of evaluation order.
pub fn init(entry: &ModelEntry, seed: i32) -> Result<ModelState> {
    Plan::build(entry)?; // validate the graph before materializing
    let base = seed as i64 as u64;
    let mut params = Vec::with_capacity(entry.params.len());
    for (i, spec) in entry.params.iter().enumerate() {
        let mut rng = Rng::stream(base, 0x1817 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let v: Vec<f32> = if spec.shape.len() == 4 {
            // conv kernel: he_normal, fan_in = k*k*cin.
            let fan_in = (spec.shape[0] * spec.shape[1] * spec.shape[2]).max(1);
            let s = (2.0 / fan_in as f64).sqrt() as f32;
            (0..spec.elems).map(|_| rng.next_normal() * s).collect()
        } else if spec.shape.len() == 2 {
            // dense kernel: uniform(-1/sqrt(fan_in), 1/sqrt(fan_in)).
            let bound = 1.0 / (spec.shape[0].max(1) as f32).sqrt();
            (0..spec.elems)
                .map(|_| -bound + rng.next_f32() * (2.0 * bound))
                .collect()
        } else if spec.name.ends_with("gamma") {
            vec![1.0; spec.elems]
        } else {
            vec![0.0; spec.elems] // beta / bias
        };
        params.push(v);
    }
    let mom = entry.params.iter().map(|p| vec![0f32; p.elems]).collect();
    // BN state interleaves [running_mean, running_var] per block.
    let state = entry
        .state_shapes
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let elems: usize = shape.iter().product();
            if i % 2 == 0 {
                vec![0f32; elems]
            } else {
                vec![1f32; elems]
            }
        })
        .collect();
    Ok(ModelState { params, mom, state })
}

/// Fused SGD+momentum update with the overflow gate as a runtime mask:
/// an overflowed step leaves params and momentum untouched. Shared by
/// the single-engine path and the replica path (which applies it once,
/// to the order-reduced gradients).
pub(crate) fn apply_update(
    entry: &ModelEntry,
    st: &mut ModelState,
    grads: &[Vec<f32>],
    ctrl: &StepCtrl,
    overflow: bool,
) {
    let mask = if overflow { 0f32 } else { 1f32 };
    for (i, spec) in entry.params.iter().enumerate() {
        let scale = if spec.layer_idx >= 0 {
            ctrl.lr_scales[spec.layer_idx as usize]
        } else {
            1.0
        };
        let lr_eff = ctrl.lr * scale;
        let p = &mut st.params[i];
        let m = &mut st.mom[i];
        let g = &grads[i];
        for k in 0..p.len() {
            let g_eff = (g[k] + ctrl.weight_decay * p[k]) * mask;
            let m_new = MOMENTUM * m[k] + g_eff;
            let m_out = if mask > 0.5 { m_new } else { m[k] };
            p[k] -= lr_eff * mask * m_out;
            m[k] = m_out;
        }
    }
}

/// One fused SGD+momentum training step (train_graph.py semantics).
pub fn train_step(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &mut ModelState,
    batch: &Batch,
    ctrl: &StepCtrl,
) -> Result<TrainOutputs> {
    let plan = Plan::build(entry)?;
    let n = batch.n;
    let mut fwd = forward(
        ex,
        entry,
        &plan,
        &st.params,
        &st.state,
        &batch.x,
        &batch.y,
        n,
        &ctrl.codes,
        true,
    );
    let grads = backward(ex, entry, &plan, &fwd, &st.params, &ctrl.codes, ctrl.loss_scale, n);
    let overflow = grads.iter().any(|g| g.iter().any(|v| !v.is_finite()));
    let (grad_var, grad_norm) = layer_stats(entry, &grads);
    apply_update(entry, st, &grads, ctrl, overflow);
    if !overflow {
        // Swap the arena-backed running stats in; the displaced old
        // state vectors ride back to the arena through `new_state`.
        for (dst, src) in st.state.iter_mut().zip(fwd.new_state.iter_mut()) {
            std::mem::swap(dst, src);
        }
    }
    let (loss, correct) = (fwd.loss, fwd.correct);
    ex.arena.put_all(grads);
    release_fwd(ex, fwd);
    Ok(TrainOutputs { loss, correct, grad_var, grad_norm, overflow })
}

/// Eval with running-stat BN (codes honoured, state untouched).
pub fn eval_batch(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    codes: &[i32],
) -> Result<EvalResult> {
    let plan = Plan::build(entry)?;
    let fwd = forward(
        ex,
        entry,
        &plan,
        &st.params,
        &st.state,
        &batch.x,
        &batch.y,
        batch.n,
        codes,
        false,
    );
    let (loss, correct) = (fwd.loss, fwd.correct);
    release_fwd(ex, fwd);
    Ok(EvalResult { loss, correct, total: batch.n })
}

/// Relative step size of the central-difference HVP probe.
const FD_EPS_REL: f64 = 1e-2;

/// Gradients of the unscaled train-mode loss at `params` (arena-backed;
/// the caller returns them).
fn grad_at(
    ex: &mut Exec,
    entry: &ModelEntry,
    plan: &Plan,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    batch: &Batch,
    codes: &[i32],
) -> Vec<Vec<f32>> {
    let fwd = forward(
        ex,
        entry,
        plan,
        params,
        state,
        &batch.x,
        &batch.y,
        batch.n,
        codes,
        true,
    );
    let grads = backward(ex, entry, plan, &fwd, params, codes, 1.0, batch.n);
    release_fwd(ex, fwd);
    grads
}

/// Train-mode loss and parameter gradients at `st` — the whole-model
/// finite-difference gradcheck hook (`tests/prop_substrates.rs`).
/// Returned gradients are fresh vectors (the arena stays balanced).
pub fn loss_and_grads(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    codes: &[i32],
) -> Result<(f32, Vec<Vec<f32>>)> {
    let plan = Plan::build(entry)?;
    let fwd = forward(
        ex,
        entry,
        &plan,
        &st.params,
        &st.state,
        &batch.x,
        &batch.y,
        batch.n,
        codes,
        true,
    );
    let loss = fwd.loss;
    let grads = backward(ex, entry, &plan, &fwd, &st.params, codes, 1.0, batch.n);
    release_fwd(ex, fwd);
    let out: Vec<Vec<f32>> = grads.iter().map(|g| g.to_vec()).collect();
    ex.arena.put_all(grads);
    Ok((loss, out))
}

/// Train-mode loss at `params` (the FD probe the gradchecks drive).
pub fn loss_at(
    ex: &mut Exec,
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    batch: &Batch,
    codes: &[i32],
) -> Result<f32> {
    let plan = Plan::build(entry)?;
    let fwd = forward(
        ex,
        entry,
        &plan,
        params,
        state,
        &batch.x,
        &batch.y,
        batch.n,
        codes,
        true,
    );
    let loss = fwd.loss;
    release_fwd(ex, fwd);
    Ok(loss)
}

/// One amortized power-iteration step per precision layer:
/// block-diagonal HVP `H_l u_l` via a per-layer central difference of
/// the gradient, Rayleigh quotient `λ_l`, and normalized next probe
/// written back into `probes` (curv_graph.py strict-block semantics).
/// The two perturbed parameter sets are plain clones — the parameter
/// footprint is tiny next to the activation scratch, and curvature
/// fires on the amortized control cadence, not every step.
pub fn curv_step(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    probes: &mut [Vec<f32>],
    codes: &[i32],
) -> Result<Vec<f32>> {
    let plan = Plan::build(entry)?;
    let l_count = entry.num_layers;
    let mut lambdas = vec![0f32; l_count];
    for li in 0..l_count {
        let idxs: Vec<usize> = entry
            .params
            .iter()
            .enumerate()
            .filter(|(_, s)| s.layer_idx == li as i64)
            .map(|(i, _)| i)
            .collect();
        let un: f64 = idxs
            .iter()
            // detlint: ordered — sequential iterator sums: elements in
            // buffer order, tensors in fixed idxs order (next 2 lines).
            .map(|&i| probes[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>() // detlint: ordered — see above
            .sqrt();
        if un < 1e-12 {
            continue; // degenerate probe — λ stays 0, probe untouched
        }
        let tn: f64 = idxs
            .iter()
            // detlint: ordered — same fixed buffer/idxs order as `un`.
            .map(|&i| st.params[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>() // detlint: ordered — see above
            .sqrt();
        let eps = (FD_EPS_REL * (tn + 1.0) / un) as f32;

        let mut pp = st.params.clone();
        let mut pm = st.params.clone();
        for &i in &idxs {
            for k in 0..pp[i].len() {
                let d = eps * probes[i][k];
                pp[i][k] += d;
                pm[i][k] -= d;
            }
        }
        let gp = grad_at(ex, entry, &plan, &pp, &st.state, batch, codes);
        let gm = grad_at(ex, entry, &plan, &pm, &st.state, batch, codes);

        let inv2e = 1.0 / (2.0 * eps);
        let mut num = 0f64;
        let mut den = 0f64;
        let mut hn2 = 0f64;
        let mut hu: Vec<(usize, Vec<f32>)> = Vec::with_capacity(idxs.len());
        for &i in &idxs {
            let mut h = ex.arena.take(gp[i].len());
            for (hv, (&a, &b)) in h.iter_mut().zip(gp[i].iter().zip(gm[i].iter())) {
                *hv = (a - b) * inv2e;
            }
            for (k, &hv) in h.iter().enumerate() {
                num += probes[i][k] as f64 * hv as f64;
                den += (probes[i][k] as f64) * (probes[i][k] as f64);
                hn2 += (hv as f64) * (hv as f64);
            }
            hu.push((i, h));
        }
        let hn = hn2.sqrt() + 1e-12;
        lambdas[li] = (num / (den + 1e-12)) as f32;
        for (i, h) in hu {
            for (p, &hv) in probes[i].iter_mut().zip(h.iter()) {
                *p = (hv as f64 / hn) as f32;
            }
            ex.arena.put(h);
        }
        ex.arena.put_all(gp);
        ex.arena.put_all(gm);
    }
    Ok(lambdas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{BF16, FP16, FP32};
    use crate::runtime::native::builtin_manifest;

    const GRID: [&str; 3] = ["tiny_cnn_c10", "resnet_mini_c10", "effnet_lite_c10"];

    fn entry(key: &str) -> ModelEntry {
        builtin_manifest().model(key).unwrap().clone()
    }

    fn rand_batch(n: usize, classes: u64, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
        Batch::new(x, y)
    }

    #[test]
    fn init_shapes_match_manifest_for_every_model() {
        for key in GRID {
            let e = entry(key);
            let st = init(&e, 3).unwrap();
            assert_eq!(st.params.len(), e.params.len(), "{key}");
            for (p, spec) in st.params.iter().zip(&e.params) {
                assert_eq!(p.len(), spec.elems, "{key}: {}", spec.name);
            }
            assert_eq!(st.state.len(), e.state_shapes.len(), "{key}");
            // gammas one, betas zero, running stats (0, 1).
            for (i, spec) in e.params.iter().enumerate() {
                if spec.name.ends_with("gamma") {
                    assert!(st.params[i].iter().all(|&v| v == 1.0), "{key}: {}", spec.name);
                }
                if spec.name.ends_with("beta") {
                    assert!(st.params[i].iter().all(|&v| v == 0.0), "{key}: {}", spec.name);
                }
            }
            assert!(st.state[0].iter().all(|&v| v == 0.0), "{key}: rm");
            assert!(st.state[1].iter().all(|&v| v == 1.0), "{key}: rv");
            // conv weights have he-normal-ish spread.
            // detlint: ordered — sequential sum in buffer order.
            let norm: f64 = st.params[0].iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
            assert!(norm > 0.1 && norm < 1000.0, "{key}: stem norm² {norm}");
        }
    }

    #[test]
    fn whole_model_gradcheck_fp32() {
        let e = entry("tiny_cnn_c10");
        let mut ex = Exec::from_env();
        let mut st = init(&e, 7).unwrap();
        let b = rand_batch(4, 10, 1);
        let codes = vec![FP32; e.num_layers];
        let (_, grads) = loss_and_grads(&mut ex, &e, &st, &b, &codes).unwrap();
        let mut rng = Rng::new(0xFD);
        // Spot-check a few components of every parameter tensor.
        for pi in 0..st.params.len() {
            for _ in 0..4 {
                let k = rng.below(st.params[pi].len() as u64) as usize;
                let eps = 5e-3f32;
                let orig = st.params[pi][k];
                st.params[pi][k] = orig + eps;
                let lp = loss_at(&mut ex, &e, &st.params, &st.state, &b, &codes).unwrap() as f64;
                st.params[pi][k] = orig - eps;
                let lm = loss_at(&mut ex, &e, &st.params, &st.state, &b, &codes).unwrap() as f64;
                st.params[pi][k] = orig;
                let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let analytic = grads[pi][k];
                let diff = (numeric - analytic).abs();
                let scale = numeric.abs().max(analytic.abs()).max(3e-2);
                assert!(
                    diff / scale < 0.15,
                    "param {pi}[{k}]: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn overfits_one_batch() {
        let e = entry("tiny_cnn_c10");
        let mut ex = Exec::from_env();
        let mut st = init(&e, 1).unwrap();
        let b = rand_batch(8, 10, 5);
        let ctrl = StepCtrl::uniform(4, FP32, 0.1, 0.0);
        let mut first = 0f32;
        let mut last = TrainOutputs {
            loss: 0.0,
            correct: 0,
            grad_var: vec![],
            grad_norm: vec![],
            overflow: false,
        };
        for step in 0..40 {
            last = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
            if step == 0 {
                first = last.loss;
            }
        }
        assert!(
            last.loss < 0.5 && last.loss < first * 0.5,
            "no memorization: {first} -> {}",
            last.loss
        );
        assert_eq!(last.correct, 8, "one batch must be memorized");
    }

    #[test]
    fn new_architectures_train_and_eval() {
        // resnet_mini and effnet_lite: loss is finite, a few steps
        // reduce it on a fixed batch, eval runs on the updated state,
        // and every residual/downsample/depthwise parameter receives a
        // finite gradient (the fork-accumulation path included).
        for key in ["resnet_mini_c10", "effnet_lite_c10"] {
            let e = entry(key);
            let mut ex = Exec::from_env();
            let mut st = init(&e, 2).unwrap();
            let b = rand_batch(8, 10, 11);
            let codes = vec![FP32; e.num_layers];
            let (_, grads) = loss_and_grads(&mut ex, &e, &st, &b, &codes).unwrap();
            for (g, spec) in grads.iter().zip(&e.params) {
                assert!(
                    g.iter().all(|v| v.is_finite()),
                    "{key}: {} grad non-finite",
                    spec.name
                );
                // detlint: ordered — sequential sum in buffer order.
                let norm: f64 = g.iter().map(|&v| (v as f64).powi(2)).sum();
                assert!(norm > 0.0, "{key}: {} grad identically zero", spec.name);
            }
            let ctrl = StepCtrl::uniform(e.num_layers, FP32, 0.05, 0.0);
            let mut first = 0f32;
            let mut last = 0f32;
            for step in 0..25 {
                let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
                assert!(out.loss.is_finite(), "{key} step {step}");
                if step == 0 {
                    first = out.loss;
                }
                last = out.loss;
            }
            assert!(last < first * 0.6, "{key}: no learning: {first} -> {last}");
            let ev = eval_batch(&mut ex, &e, &st, &rand_batch(16, 10, 12), &codes).unwrap();
            assert!(ev.loss.is_finite() && ev.total == 16, "{key}");
        }
    }

    #[test]
    fn overflow_masks_the_update() {
        let e = entry("tiny_cnn_c10");
        let mut ex = Exec::from_env();
        let mut st = init(&e, 2).unwrap();
        let before = st.clone();
        let b = rand_batch(8, 10, 9);
        let mut ctrl = StepCtrl::uniform(4, FP16, 0.05, 0.0);
        ctrl.loss_scale = 1e30; // cotangents overflow binary16 -> inf
        let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert!(out.overflow, "1e30 scale through fp16 must overflow");
        assert_eq!(st.params, before.params, "params held on overflow");
        assert_eq!(st.mom, before.mom, "momentum held on overflow");
        assert_eq!(st.state, before.state, "BN state held on overflow");
        // A sane scale on the same batch recovers immediately.
        ctrl.loss_scale = 1024.0;
        let ok = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert!(!ok.overflow);
        assert_ne!(st.params, before.params, "clean step updates params");
    }

    #[test]
    fn grad_stats_have_layer_arity_and_scale() {
        let e = entry("tiny_cnn_c10");
        let mut ex = Exec::from_env();
        let mut st = init(&e, 4).unwrap();
        let b = rand_batch(16, 10, 2);
        let ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
        let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
        assert_eq!(out.grad_var.len(), 4);
        assert_eq!(out.grad_norm.len(), 4);
        assert!(out.grad_var.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(out.grad_norm.iter().all(|v| v.is_finite() && *v >= 0.0));
        // The dense head sees the largest per-element gradients at init.
        assert!(out.grad_var[3] > out.grad_var[1]);
    }

    #[test]
    fn warm_train_step_performs_zero_buffer_allocs() {
        for key in GRID {
            let e = entry(key);
            let mut ex = Exec::from_env();
            let mut st = init(&e, 6).unwrap();
            let b = rand_batch(16, 10, 13);
            let ctrl = StepCtrl::uniform(e.num_layers, BF16, 0.05, 5e-4);
            // Three warm-up steps: the graph path's working set is
            // larger than the old hardcoded executor's, so give the
            // best-fit free list one extra step to reach its fixpoint.
            for _ in 0..3 {
                train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
            }
            let warm_allocs = ex.arena.fresh_allocs();
            let warm_pooled = ex.arena.pooled();
            for _ in 0..3 {
                train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
                assert_eq!(
                    ex.arena.fresh_allocs(),
                    warm_allocs,
                    "{key}: steady-state train step allocated a buffer"
                );
                assert_eq!(
                    ex.arena.pooled(),
                    warm_pooled,
                    "{key}: buffer leak — a take without a matching put"
                );
            }
        }
    }

    #[test]
    fn train_bits_identical_across_thread_counts() {
        let e = entry("tiny_cnn_c10");
        let b = rand_batch(16, 10, 21);
        let run = |threads: usize| {
            let mut ex = Exec::new(threads);
            let mut st = init(&e, 9).unwrap();
            let mut ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
            ctrl.codes = vec![FP16, BF16, FP32, BF16];
            let mut trace = Vec::new();
            for _ in 0..3 {
                let out = train_step(&mut ex, &e, &mut st, &b, &ctrl).unwrap();
                trace.push(out.loss.to_bits());
                trace.extend(out.grad_var.iter().map(|v| v.to_bits()));
            }
            for p in &st.params {
                trace.extend(p.iter().map(|v| v.to_bits()));
            }
            trace
        };
        let t1 = run(1);
        assert_eq!(t1, run(2), "2 threads must match 1");
        assert_eq!(t1, run(4), "4 threads must match 1");
    }

    #[test]
    fn graphless_entries_are_rejected_loudly() {
        let mut e = entry("tiny_cnn_c10");
        e.nodes.clear();
        assert!(init(&e, 0).is_err(), "no graph, no plan");
        let mut bad = entry("resnet_mini_c10");
        // Corrupt a conv weight shape: the plan must catch it.
        if let NodeOp::Conv { w, .. } = bad.nodes[0].op {
            bad.params[w].shape[2] = 5;
        }
        assert!(init(&bad, 0).is_err(), "shape mismatch must fail the plan");
        let mut lossless = entry("tiny_cnn_c10");
        lossless.nodes.pop();
        assert!(init(&lossless, 0).is_err(), "a graph without its loss node is rejected");
    }

    #[test]
    fn curv_step_returns_layer_lambdas_for_new_models() {
        let e = entry("effnet_lite_c10");
        let mut ex = Exec::from_env();
        let st = init(&e, 3).unwrap();
        let b = rand_batch(e.curv_batch, 10, 17);
        let codes = vec![FP32; e.num_layers];
        let mut rng = Rng::new(0xAB);
        let mut probes: Vec<Vec<f32>> = e
            .params
            .iter()
            .map(|p| {
                if p.layer_idx >= 0 {
                    (0..p.elems).map(|_| rng.next_normal()).collect()
                } else {
                    vec![0f32; p.elems]
                }
            })
            .collect();
        let lam = curv_step(&mut ex, &e, &st, &b, &mut probes, &codes).unwrap();
        assert_eq!(lam.len(), e.num_layers);
        assert!(lam.iter().all(|v| v.is_finite()));
    }
}
