//! Configuration system: every paper hyperparameter (§3, §4.3) as a typed
//! field with the paper's defaults, loadable from a JSON file with CLI
//! overrides (`--set key=value`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Training method — the paper's three Table-1 columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// FP32 SGD+momentum baseline.
    Fp32,
    /// Static AMP: uniform BF16 compute everywhere, dynamic loss scale,
    /// no per-layer adaptivity (the paper's "AMP (Static)").
    AmpStatic,
    /// The full adaptive system.
    TriAccel,
}

impl Method {
    /// Parse one of the three Table-1 *family* names. CLI method
    /// selection goes through the registry instead
    /// (`Config::set("method", …)` → [`crate::policy::registry`]),
    /// which also accepts the composed methods and prints the full
    /// registry on unknown names.
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "fp32" => Method::Fp32,
            "amp" | "amp_static" => Method::AmpStatic,
            "tri_accel" | "tri-accel" | "triaccel" => Method::TriAccel,
            _ => anyhow::bail!("unknown method family `{s}` (fp32|amp|tri_accel)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp32 => "FP32 Baseline",
            Method::AmpStatic => "AMP (Static)",
            Method::TriAccel => "Tri-Accel",
        }
    }
}

/// Component toggles for the Table-2 ablation rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ablation {
    pub dynamic_precision: bool,
    pub dynamic_batch: bool,
    pub curvature: bool,
}

impl Ablation {
    pub const fn full() -> Self {
        Ablation { dynamic_precision: true, dynamic_batch: true, curvature: true }
    }

    pub const fn none() -> Self {
        Ablation { dynamic_precision: false, dynamic_batch: false, curvature: false }
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    // -- workload ---------------------------------------------------------
    /// Manifest key. The native backend serves the built-in grid
    /// (`tiny_cnn`/`resnet_mini`/`effnet_lite` × `_c10`/`_c100`);
    /// artifact backends add their own (e.g. `resnet18_c10`).
    pub model_key: String,
    pub method: Method,
    pub ablation: Ablation,
    /// Precision pin for the non-adaptive precision policy: `None` =
    /// the family default (FP32 baseline pins FP32, otherwise BF16).
    /// Set by registry specs (e.g. `amp_dynamic` pins FP16) or
    /// `--set pin=fp16|bf16|fp32|auto`; ignored when dynamic precision
    /// is active.
    pub pin_override: Option<i32>,
    pub seed: u64,
    pub epochs: usize,
    /// Steps per epoch; None = full pass over the training set.
    pub steps_per_epoch: Option<usize>,
    pub train_examples: usize, // synthetic set size (50k = CIFAR)
    pub eval_examples: usize,  // test set size (10k = CIFAR)

    // -- optimizer (paper §4.1: SGD momentum 0.9, tuned lr/wd) ------------
    pub base_lr: f32,
    pub weight_decay: f32,
    pub warmup_epochs: usize,
    /// Linear LR/batch scaling (Smith et al. [8], Goyal et al. [49]):
    /// when the elastic controller moves B(t), scale the LR by
    /// B(t)/batch_init to keep the per-example step size consistent.
    /// Off by default — the paper couples B only through memory.
    pub lr_batch_scaling: bool,

    // -- precision controller (§3.1) ---------------------------------------
    pub beta: f64,      // EMA smoothing of Var[∇_l]
    pub tau_low: f64,   // v < τ_low  → FP16
    pub tau_high: f64,  // v ≥ τ_high → FP32
    /// Auto-calibrate τ from the observed variance distribution after the
    /// first control window ("automatic optimization without manual
    /// hyperparameter tuning", abstract).
    pub auto_threshold: bool,
    pub t_ctrl: u64, // control-loop cadence in steps (§3.4)

    // -- curvature (§3.2, §4.3) --------------------------------------------
    pub t_curv: u64,     // probe cadence (paper: 200)
    pub alpha: f32,      // η_l = η0 / (1 + α·λ_max)
    pub tau_curv: f64,   // precision promotion threshold on λ
    pub curv_warmup: u64, // power-iteration steps before trusting λ

    // -- elastic batching (§3.3) -------------------------------------------
    pub batch_init: usize, // paper: 96
    pub rho_low: f64,      // grow when usage < ρ_low·budget
    pub rho_high: f64,     // shrink when usage > ρ_high·budget
    pub batch_cooldown: u64, // min steps between batch moves

    // -- data-parallel replicas ---------------------------------------------
    /// Replica engines per job (1, 2, or 4). The native replicated
    /// backend guarantees bit-identical trajectories for every value;
    /// the scheduler budgets jobs × replicas × threads against the
    /// machine.
    pub replicas: usize,
    /// Let the control plane elastically shed/restore live replicas
    /// under VRAM pressure (the `tri_accel_replica` method). Ignored
    /// when `replicas == 1`.
    pub elastic_replicas: bool,

    // -- memory simulator ---------------------------------------------------
    /// MemMax: the strict single-GPU budget. `0` = auto: 1.05× the FP32
    /// footprint at `batch_init` — the paper's "strict memory budget"
    /// around the workload, scaled per model.
    pub mem_budget_gb: f64,
    pub mem_noise: f64,     // allocator transient noise fraction
    /// Time-varying budget trace (`memsim::BudgetTrace` spec): "const"
    /// (default), "step:FRAC@STEP", "ramp:START:END:FLOOR",
    /// "saw:PERIOD:DEPTH", "replay:FILE[#DIGEST]" (a recorded absolute
    /// MemMax series, see `docs/MEMORY.md`), or "scenario:NAME"
    /// (spike|frag|leak) — the VRAM-pressure scenarios a co-tenant or
    /// shrinking allocation imposes on the elastic controller.
    pub mem_trace: String,
    /// Control-window budget source: "sim" (default — the VRAM
    /// simulator, fully deterministic) or "host" (real
    /// `/proc/self/statm` RSS + MemTotal readings at control windows;
    /// observational, feeds telemetry and the policy observe path
    /// only — see `docs/MEMORY.md`).
    pub mem_source: String,

    // -- loss scaling --------------------------------------------------------
    pub init_loss_scale: f32,
    pub loss_scale_growth_interval: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            // The native backend's built-in model; artifact-driven
            // backends override via --model / model_key.
            model_key: "tiny_cnn_c10".into(),
            method: Method::TriAccel,
            ablation: Ablation::full(),
            pin_override: None,
            seed: 0,
            epochs: 2,
            steps_per_epoch: None,
            train_examples: 50_000,
            eval_examples: 10_000,
            base_lr: 0.1,
            weight_decay: 5e-4,
            warmup_epochs: 5,
            lr_batch_scaling: false,
            beta: 0.9,
            tau_low: 1e-6,
            tau_high: 1e-4,
            auto_threshold: true,
            t_ctrl: 20,
            t_curv: 200,
            alpha: 0.5,
            tau_curv: 50.0,
            curv_warmup: 3,
            batch_init: 96,
            rho_low: 0.70,
            rho_high: 0.90,
            batch_cooldown: 30,
            replicas: 1,
            elastic_replicas: false,
            mem_budget_gb: 0.45,
            mem_noise: 0.01,
            mem_trace: "const".into(),
            mem_source: "sim".into(),
            init_loss_scale: 1024.0,
            loss_scale_growth_interval: 200,
        }
    }
}

impl Config {
    /// Paper evaluation preset for one Table-1 cell.
    pub fn cell(model_key: &str, method: Method, seed: u64) -> Config {
        Config {
            model_key: model_key.into(),
            method,
            ablation: match method {
                Method::TriAccel => Ablation::full(),
                _ => Ablation::none(),
            },
            seed,
            ..Config::default()
        }
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let mut cfg = Config::default();
        let j = Json::parse(&text).context("config json")?;
        let obj = j.as_obj().context("config must be a JSON object")?;
        // `method` resolves through the registry and resets the
        // ablation/pin fields; apply it first so explicit per-field
        // keys in the same file win regardless of JSON key order.
        if let Some(v) = obj.get("method") {
            cfg.set("method", &json_to_str(v))?;
        }
        for (k, v) in obj {
            if k.as_str() == "method" {
                continue;
            }
            cfg.set(k, &json_to_str(v))?;
        }
        Ok(cfg)
    }

    /// Set one field by name from a string (CLI `--set k=v` / JSON load).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        macro_rules! num {
            () => {
                val.parse().with_context(|| format!("config {key}={val}"))?
            };
        }
        match key {
            "model_key" => self.model_key = val.to_string(),
            "method" => {
                let spec = crate::policy::registry::resolve(val)?;
                crate::policy::registry::apply(self, spec);
            }
            "pin" => {
                self.pin_override = match val {
                    "auto" | "none" => None,
                    "fp16" => Some(crate::manifest::FP16),
                    "bf16" => Some(crate::manifest::BF16),
                    "fp32" => Some(crate::manifest::FP32),
                    _ => anyhow::bail!("pin must be auto|fp16|bf16|fp32, got `{val}`"),
                }
            }
            "seed" => self.seed = num!(),
            "epochs" => self.epochs = num!(),
            "steps_per_epoch" => {
                self.steps_per_epoch = if val == "full" { None } else { Some(num!()) }
            }
            "train_examples" => self.train_examples = num!(),
            "eval_examples" => self.eval_examples = num!(),
            "base_lr" => self.base_lr = num!(),
            "weight_decay" => self.weight_decay = num!(),
            "warmup_epochs" => self.warmup_epochs = num!(),
            "lr_batch_scaling" => self.lr_batch_scaling = parse_bool(val)?,
            "beta" => self.beta = num!(),
            "tau_low" => self.tau_low = num!(),
            "tau_high" => self.tau_high = num!(),
            "auto_threshold" => self.auto_threshold = parse_bool(val)?,
            "t_ctrl" => self.t_ctrl = num!(),
            "t_curv" => self.t_curv = num!(),
            "alpha" => self.alpha = num!(),
            "tau_curv" => self.tau_curv = num!(),
            "curv_warmup" => self.curv_warmup = num!(),
            "batch_init" => self.batch_init = num!(),
            "rho_low" => self.rho_low = num!(),
            "rho_high" => self.rho_high = num!(),
            "batch_cooldown" => self.batch_cooldown = num!(),
            "replicas" => self.replicas = num!(),
            "elastic_replicas" => self.elastic_replicas = parse_bool(val)?,
            "mem_budget_gb" => self.mem_budget_gb = num!(),
            "mem_noise" => self.mem_noise = num!(),
            "mem_trace" => self.mem_trace = val.to_string(),
            "mem_source" => self.mem_source = val.to_string(),
            "init_loss_scale" => self.init_loss_scale = num!(),
            "loss_scale_growth_interval" => self.loss_scale_growth_interval = num!(),
            "dynamic_precision" => self.ablation.dynamic_precision = parse_bool(val)?,
            "dynamic_batch" => self.ablation.dynamic_batch = parse_bool(val)?,
            "curvature" => self.ablation.curvature = parse_bool(val)?,
            _ => anyhow::bail!("unknown config key `{key}`"),
        }
        Ok(())
    }

    /// FNV-1a fingerprint of every field (via the derived `Debug`
    /// formatting, which is a stable total description of the struct).
    /// The experiment scheduler keys its grid ledger on this hash (plus
    /// the model-graph digest and seed), so a changed hyperparameter
    /// invalidates persisted cell results instead of silently reusing
    /// them — see `docs/TELEMETRY.md`.
    pub fn fingerprint(&self) -> u64 {
        crate::checkpoint::fnv1a(format!("{self:?}").as_bytes())
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!((0.0..1.0).contains(&self.beta), "beta in [0,1)");
        anyhow::ensure!(self.tau_low <= self.tau_high, "tau_low <= tau_high");
        anyhow::ensure!(
            0.0 < self.rho_low && self.rho_low < self.rho_high && self.rho_high <= 1.0,
            "0 < rho_low < rho_high <= 1"
        );
        anyhow::ensure!(self.mem_budget_gb >= 0.0, "mem_budget_gb >= 0 (0 = auto)");
        anyhow::ensure!(self.batch_init > 0 && self.epochs > 0, "positive sizes");
        anyhow::ensure!(
            matches!(self.replicas, 1 | 2 | 4),
            "replicas must be 1, 2, or 4 (got {})",
            self.replicas
        );
        crate::memsim::BudgetTrace::parse(&self.mem_trace)
            .context("mem_trace spec")?;
        anyhow::ensure!(
            matches!(self.mem_source.as_str(), "sim" | "host"),
            "mem_source must be sim|host (got `{}`)",
            self.mem_source
        );
        Ok(())
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => anyhow::bail!("bad bool `{v}`"),
    }
}

fn json_to_str(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string_compact(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_match_paper() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.batch_init, 96); // §4: "initial batch size of 96"
        assert_eq!(c.t_curv, 200); // §4.3: T_curv = 200
        assert_eq!(c.warmup_epochs, 5); // §4.3: 5-epoch warmup
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::default();
        c.set("method", "amp").unwrap();
        c.set("epochs", "7").unwrap();
        c.set("rho_high", "0.95").unwrap();
        c.set("dynamic_batch", "false").unwrap();
        assert_eq!(c.method, Method::AmpStatic);
        assert_eq!(c.epochs, 7);
        assert!(!c.ablation.dynamic_batch);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("epochs", "x").is_err());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        let mut c = Config::default();
        c.rho_low = 0.95;
        c.rho_high = 0.9;
        assert!(c.validate().is_err());
        let mut c2 = Config::default();
        c2.beta = 1.5;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn method_parse_names() {
        assert_eq!(Method::parse("fp32").unwrap().name(), "FP32 Baseline");
        assert_eq!(Method::parse("tri-accel").unwrap(), Method::TriAccel);
        assert!(Method::parse("adam").is_err());
    }

    #[test]
    fn method_key_resolves_registry_compositions() {
        let mut c = Config::default();
        c.set("method", "greedy_batch").unwrap();
        assert_eq!(c.method, Method::TriAccel);
        assert!(!c.ablation.dynamic_precision, "elasticity-only: precision pinned");
        assert!(c.ablation.dynamic_batch && !c.ablation.curvature);
        c.set("method", "amp_dynamic").unwrap();
        assert_eq!(c.method, Method::AmpStatic);
        assert_eq!(c.pin_override, Some(crate::manifest::FP16));
        let err = c.set("method", "sgd").unwrap_err().to_string();
        assert!(err.contains("tri_accel_nocurv"), "unknown method lists registry: {err}");
    }

    #[test]
    fn pin_key_parses_codes() {
        let mut c = Config::default();
        c.set("pin", "fp16").unwrap();
        assert_eq!(c.pin_override, Some(crate::manifest::FP16));
        c.set("pin", "auto").unwrap();
        assert_eq!(c.pin_override, None);
        assert!(c.set("pin", "int8").is_err());
    }

    #[test]
    fn fingerprint_is_stable_and_field_sensitive() {
        let a = Config::default();
        assert_eq!(a.fingerprint(), Config::default().fingerprint());
        let mut b = Config::default();
        b.epochs += 1;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = Config::default();
        c.seed = 7;
        assert_ne!(a.fingerprint(), c.fingerprint(), "seed is part of the key");
    }

    #[test]
    fn replicas_validated_and_settable() {
        let mut c = Config::default();
        assert_eq!(c.replicas, 1);
        c.set("replicas", "4").unwrap();
        c.set("elastic_replicas", "true").unwrap();
        c.validate().unwrap();
        assert!(c.elastic_replicas);
        c.replicas = 3;
        assert!(c.validate().is_err(), "only power-of-two replica ladders");
    }

    #[test]
    fn mem_trace_validated() {
        let mut c = Config::default();
        c.set("mem_trace", "step:0.6@100").unwrap();
        c.validate().unwrap();
        c.mem_trace = "wobble:9".into();
        assert!(c.validate().is_err());
        c.set("mem_trace", "scenario:leak").unwrap();
        c.validate().unwrap();
        c.mem_trace = "scenario:surge".into();
        assert!(c.validate().is_err());
        c.mem_trace = "replay:/no/such/file.json".into();
        assert!(c.validate().is_err(), "missing trace file fails at validation");
    }

    #[test]
    fn mem_source_validated() {
        let mut c = Config::default();
        assert_eq!(c.mem_source, "sim", "deterministic simulator is the default");
        c.set("mem_source", "host").unwrap();
        c.validate().unwrap();
        c.mem_source = "gpu".into();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("sim|host"), "{err}");
    }
}
