//! End-to-end integration over the trainer: full Tri-Accel loop against
//! the native reference backend, plus method/ablation behaviour the
//! tables depend on. Hermetic (no artifacts); small step budgets keep
//! this in CI range.

use tri_accel::config::{Config, Method};
use tri_accel::manifest::FP32;
use tri_accel::memsim::MemoryMonitor;
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

fn engine() -> Engine {
    Engine::native()
}

fn quick_cfg(method: Method, seed: u64) -> Config {
    let mut cfg = Config::cell("tiny_cnn_c10", method, seed);
    cfg.epochs = 1;
    cfg.steps_per_epoch = Some(25);
    cfg.train_examples = 2048;
    cfg.eval_examples = 256;
    cfg.batch_init = 16;
    cfg.t_ctrl = 5;
    cfg.t_curv = 10;
    cfg.curv_warmup = 1;
    cfg.batch_cooldown = 5;
    cfg.warmup_epochs = 0;
    // Base runtime overhead in the simulator is ~0.047GB; 0.06 leaves
    // headroom for small batches but pressures large ones.
    cfg.mem_budget_gb = 0.06;
    cfg.mem_noise = 0.0;
    cfg
}

#[test]
fn triaccel_epoch_produces_sane_record() {
    let e = engine();
    let mut tr = Trainer::new(&e, quick_cfg(Method::TriAccel, 0)).unwrap();
    let r = tr.run_epoch(0).unwrap();
    assert_eq!(r.steps, 25);
    assert!(r.train_loss.is_finite() && r.train_loss > 0.0);
    assert!((0.0..=100.0).contains(&r.train_acc));
    assert!((0.0..=100.0).contains(&r.test_acc));
    assert!(r.peak_vram_gb > 0.0 && r.peak_vram_gb < 1.0);
    assert!(r.modeled_s > 0.0 && r.wall_s > 0.0);
    assert!(r.mean_batch > 0.0);
    let mix_sum = r.mix.fp16 + r.mix.bf16 + r.mix.fp32;
    assert!((mix_sum - 1.0).abs() < 1e-9);
    assert!(r.eff_score > 0.0);
}

#[test]
fn triaccel_learns_above_chance_within_25_step_epochs() {
    // The acceptance bar: the full Tri-Accel method, trained in
    // 25-step epochs on the synthetic dataset, must clear 10-class
    // chance comfortably by the third epoch.
    let e = engine();
    let mut cfg = quick_cfg(Method::TriAccel, 1);
    cfg.epochs = 3;
    cfg.base_lr = 0.2;
    cfg.batch_init = 32;
    cfg.t_curv = 20; // probe cadence down: keeps the test CPU-friendly
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let first = tr.run_epoch(0).unwrap();
    tr.run_epoch(1).unwrap();
    let last = tr.run_epoch(2).unwrap();
    assert!(
        last.train_loss < first.train_loss,
        "no learning: {} → {}",
        first.train_loss,
        last.train_loss
    );
    assert!(
        last.train_acc > 20.0,
        "train acc {} ≤ 2× chance after 3×25 steps",
        last.train_acc
    );
    // Synthetic classes are separable — test accuracy beats chance too.
    assert!(last.test_acc > 15.0, "test acc {} ≤ chance", last.test_acc);
}

#[test]
fn methods_are_reproducible_per_seed() {
    let e = engine();
    let run = |seed| {
        let mut tr = Trainer::new(&e, quick_cfg(Method::TriAccel, seed)).unwrap();
        let r = tr.run_epoch(0).unwrap();
        (r.train_loss, r.test_acc, tr.controller.codes())
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a, b, "same seed ⇒ identical run");
    let c = run(6);
    assert_ne!(a.0, c.0, "different seed ⇒ different trajectory");
}

#[test]
fn fp32_baseline_stays_fp32_and_fixed_batch() {
    let e = engine();
    let mut tr = Trainer::new(&e, quick_cfg(Method::Fp32, 0)).unwrap();
    tr.run_epoch(0).unwrap();
    assert!(tr.controller.codes().iter().all(|&c| c == FP32));
    assert_eq!(tr.metrics.batch_trace.len(), 1, "batch never moves");
    assert_eq!(tr.metrics.curv_firings, 0);
    assert_eq!(tr.metrics.promotions, 0);
}

#[test]
fn amp_static_has_lower_memory_than_fp32() {
    let e = engine();
    let peak = |method| {
        let mut tr = Trainer::new(&e, quick_cfg(method, 0)).unwrap();
        tr.run_epoch(0).unwrap();
        tr.metrics.peak_vram_gb()
    };
    let fp32 = peak(Method::Fp32);
    let amp = peak(Method::AmpStatic);
    assert!(amp < fp32, "AMP {amp} must beat FP32 {fp32} on memory");
}

#[test]
fn triaccel_curvature_fires_and_scales_lr() {
    let e = engine();
    let mut tr = Trainer::new(&e, quick_cfg(Method::TriAccel, 2)).unwrap();
    tr.run_epoch(0).unwrap();
    assert!(tr.metrics.curv_firings >= 2, "t_curv=10 over 25 steps");
    let scales = tr.controller.lr_scales();
    assert!(scales.iter().all(|&s| s > 0.0 && s <= 1.0));
    // After warmup at least one layer should see real curvature.
    assert!(
        scales.iter().any(|&s| s < 1.0),
        "curvature had no effect: {scales:?}"
    );
}

#[test]
fn elastic_batch_responds_to_budget() {
    let e = engine();
    // Roomy budget → B grows above its initial bucket.
    let mut roomy = quick_cfg(Method::TriAccel, 3);
    roomy.mem_budget_gb = 0.5;
    roomy.steps_per_epoch = Some(40);
    roomy.batch_cooldown = 3;
    let mut tr = Trainer::new(&e, roomy).unwrap();
    tr.run_epoch(0).unwrap();
    let max_b = tr.metrics.batch_trace.iter().map(|&(_, b)| b).max().unwrap();
    assert!(max_b > 16, "batch never grew under roomy budget");

    // Starved budget → controller shrinks/holds at the floor, never OOM-loops.
    let mut tight = quick_cfg(Method::TriAccel, 3);
    tight.mem_budget_gb = 0.05;
    tight.batch_init = 96;
    let mut tr2 = Trainer::new(&e, tight).unwrap();
    tr2.run_epoch(0).unwrap();
    let last_b = tr2.metrics.batch_trace.last().unwrap().1;
    assert!(last_b < 96, "batch never shrank under starved budget");
    assert!(tr2.memsim.peak_gb() > 0.0);
}

#[test]
fn evaluate_covers_whole_test_set() {
    let e = engine();
    let mut cfg = quick_cfg(Method::Fp32, 0);
    cfg.eval_examples = 272; // 2×128 + 16 — exercises both buckets
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let (loss, acc) = tr.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=100.0).contains(&acc));
}

#[test]
fn eval_examples_must_align_to_bucket() {
    let e = engine();
    let mut cfg = quick_cfg(Method::Fp32, 0);
    cfg.eval_examples = 250; // not a multiple of 16
    assert!(Trainer::new(&e, cfg).is_err());
}

#[test]
fn run_summary_aggregates_last_epochs() {
    let e = engine();
    let mut cfg = quick_cfg(Method::TriAccel, 0);
    cfg.epochs = 2;
    cfg.steps_per_epoch = Some(10);
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let s = tr.run().unwrap();
    assert_eq!(tr.metrics.epochs.len(), 2);
    assert!(s.test_acc_pct >= 0.0);
    assert!(s.wall_s_per_epoch > 0.0 && s.modeled_s_per_epoch > 0.0);
    assert!(s.peak_vram_gb > 0.0);
    assert!(s.eff_score > 0.0);
    assert_eq!(s.method, Method::TriAccel);
}

#[test]
fn metrics_files_written() {
    let e = engine();
    let mut cfg = quick_cfg(Method::TriAccel, 0);
    cfg.steps_per_epoch = Some(6);
    let mut tr = Trainer::new(&e, cfg).unwrap();
    tr.run_epoch(0).unwrap();
    let dir = std::env::temp_dir().join(format!("triaccel_it_{}", std::process::id()));
    tr.metrics.write(&dir, "itest").unwrap();
    let csv = std::fs::read_to_string(dir.join("itest_epochs.csv")).unwrap();
    assert!(csv.lines().count() >= 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let e = engine();
    let ckpt_path =
        std::env::temp_dir().join(format!("triaccel_ckpt_it_{}.bin", std::process::id()));

    // Train 10 steps, checkpoint, then 5 more.
    let mut cfg = quick_cfg(Method::Fp32, 9);
    cfg.t_curv = 0;
    let mut tr = Trainer::new(&e, cfg.clone()).unwrap();
    for _ in 0..10 {
        tr.step().unwrap();
    }
    tr.save_checkpoint(&ckpt_path).unwrap();
    let mut direct_losses = Vec::new();
    for _ in 0..5 {
        direct_losses.push(tr.step().unwrap().0);
    }

    // Fresh trainer, resume, same 5 steps must be bit-identical: the
    // checkpoint captures params+mom+state, the controller, the
    // data-stream position, and the step counter (which keys the LR
    // schedule).
    let mut tr2 = Trainer::new(&e, cfg).unwrap();
    let step = tr2.resume_from(&ckpt_path).unwrap();
    assert_eq!(step, 10);
    let mut resumed_losses = Vec::new();
    for _ in 0..5 {
        resumed_losses.push(tr2.step().unwrap().0);
    }
    assert_eq!(direct_losses, resumed_losses, "resume must be bit-exact");
    std::fs::remove_file(&ckpt_path).ok();
}

#[test]
fn triaccel_resume_restores_controller_state() {
    // Satellite regression: resuming used to reset precision codes,
    // loss scale, batch-ladder index, and curvature EMAs to defaults,
    // so a resumed Tri-Accel run diverged from an uninterrupted one.
    // With controller state in the checkpoint, the continuation must be
    // bit-exact (noise-free memsim).
    let e = engine();
    let ckpt_path =
        std::env::temp_dir().join(format!("triaccel_ckpt_ctrl_{}.bin", std::process::id()));
    let mut cfg = quick_cfg(Method::TriAccel, 4);
    cfg.steps_per_epoch = Some(40);
    cfg.t_ctrl = 3;
    cfg.t_curv = 6;
    cfg.batch_cooldown = 3;
    cfg.mem_budget_gb = 0.5; // roomy so the batch ladder actually moves

    let mut tr = Trainer::new(&e, cfg.clone()).unwrap();
    for _ in 0..12 {
        tr.step().unwrap();
    }
    tr.save_checkpoint(&ckpt_path).unwrap();
    let saved_codes = tr.controller.codes();
    let saved_scale = tr.controller.scaler.scale();
    let saved_batch = tr.controller.batch_size();
    let mut direct = Vec::new();
    for _ in 0..6 {
        let (loss, _, b, _) = tr.step().unwrap();
        direct.push((loss, b, tr.controller.codes()));
    }

    let mut tr2 = Trainer::new(&e, cfg).unwrap();
    tr2.resume_from(&ckpt_path).unwrap();
    assert_eq!(tr2.controller.codes(), saved_codes, "codes restored");
    assert_eq!(tr2.controller.scaler.scale(), saved_scale, "scale restored");
    assert_eq!(tr2.controller.batch_size(), saved_batch, "ladder restored");
    let mut resumed = Vec::new();
    for _ in 0..6 {
        let (loss, _, b, _) = tr2.step().unwrap();
        resumed.push((loss, b, tr2.controller.codes()));
    }
    assert_eq!(direct, resumed, "Tri-Accel resume must continue the policy");
    std::fs::remove_file(&ckpt_path).ok();
}

#[test]
fn checkpoint_rejects_wrong_model() {
    let e = engine();
    let ckpt_path =
        std::env::temp_dir().join(format!("triaccel_ckpt_wm_{}.bin", std::process::id()));
    let mut cfg = quick_cfg(Method::Fp32, 0);
    cfg.t_curv = 0;
    let tr = Trainer::new(&e, cfg).unwrap();
    tr.save_checkpoint(&ckpt_path).unwrap();
    let mut ckpt = tri_accel::checkpoint::Checkpoint::load(&ckpt_path).unwrap();
    ckpt.model_key = "tiny_cnn_c100".into();
    let mut cfg2 = quick_cfg(Method::Fp32, 0);
    cfg2.t_curv = 0;
    let mut tr2 = Trainer::new(&e, cfg2).unwrap();
    assert!(tr2.session.restore(&ckpt).is_err(), "model-key mismatch must fail");
    std::fs::remove_file(&ckpt_path).ok();
}

#[test]
fn lr_batch_scaling_scales_step_size() {
    let e = engine();
    // With scaling on and a roomy budget (batch grows), training still
    // works; smoke-level: loss finite and decreasing-ish.
    let mut cfg = quick_cfg(Method::TriAccel, 4);
    cfg.lr_batch_scaling = true;
    cfg.mem_budget_gb = 0.5;
    cfg.steps_per_epoch = Some(20);
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let r = tr.run_epoch(0).unwrap();
    assert!(r.train_loss.is_finite());
}

#[test]
fn full_epoch_mode_consumes_train_examples() {
    let e = engine();
    let mut cfg = quick_cfg(Method::Fp32, 0);
    cfg.steps_per_epoch = None; // full pass
    cfg.train_examples = 160; // 10 steps at B=16
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let r = tr.run_epoch(0).unwrap();
    assert_eq!(r.steps, 10);
}
