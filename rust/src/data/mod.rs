//! Data pipeline substrate: CIFAR-10/100 sources (real binaries when
//! present, deterministic synthetic otherwise — DESIGN.md §5), the
//! paper's augmentations (random horizontal flip + pad-4 random crop),
//! and a dynamic-batch iterator that serves whatever batch size the
//! elastic controller currently wants.

pub mod augment;
pub mod cifar_bin;
pub mod synthetic;

use anyhow::Result;

use crate::runtime::Batch;
use crate::util::rng::Rng;

/// CIFAR per-channel normalization constants (the paper: "all images are
/// normalized per channel").
pub const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
pub const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_C: usize = 3;
pub const IMG_ELEMS: usize = IMG_H * IMG_W * IMG_C;

/// An indexable example source producing normalized NHWC f32 images.
/// Both the synthetic generator and the real-binary loader implement
/// this, so the trainer is agnostic to the source (DESIGN.md §5: "the
/// loader interface is identical for both").
pub trait Dataset: Send {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn num_classes(&self) -> usize;
    /// Write example `idx` (un-augmented, normalized) into `out`
    /// (NHWC, `IMG_ELEMS` floats) and return its label.
    fn example(&self, idx: usize, out: &mut [f32]) -> i32;
}

/// Resolve the data source for a model key: real CIFAR binaries if the
/// well-known directory exists, else the synthetic generator.
pub fn auto_source(num_classes: usize, train: bool, examples: usize, seed: u64) -> Box<dyn Dataset> {
    let dir = match num_classes {
        10 => "data/cifar-10-batches-bin",
        _ => "data/cifar-100-binary",
    };
    if let Ok(ds) = cifar_bin::CifarBin::load(std::path::Path::new(dir), num_classes, train) {
        return Box::new(ds);
    }
    Box::new(synthetic::SyntheticCifar::new(num_classes, examples, train, seed))
}

/// Epoch-shuffled, augmentation-applying iterator that serves batches of
/// *any* requested size — the bridge between the fixed-size dataset and
/// the elastic batch controller. Order within an epoch is fixed by
/// (seed, epoch); batch boundaries move freely as B(t) changes.
pub struct BatchIter {
    ds: Box<dyn Dataset>,
    order: Vec<u32>,
    pos: usize,
    epoch: u64,
    seed: u64,
    augment: bool,
}

impl BatchIter {
    pub fn new(ds: Box<dyn Dataset>, seed: u64, augment: bool) -> BatchIter {
        let mut it = BatchIter {
            order: (0..ds.len() as u32).collect(),
            ds,
            pos: 0,
            epoch: 0,
            seed,
            augment,
        };
        it.reshuffle();
        it
    }

    pub fn dataset(&self) -> &dyn Dataset {
        self.ds.as_ref()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Examples remaining in the current epoch.
    pub fn remaining(&self) -> usize {
        self.order.len() - self.pos
    }

    fn reshuffle(&mut self) {
        let mut rng = Rng::stream(self.seed, 0x5348 ^ self.epoch);
        rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    /// Advance to the next epoch (reshuffles; resets position).
    pub fn next_epoch(&mut self) {
        self.epoch += 1;
        self.reshuffle();
    }

    /// (epoch, within-epoch position) — checkpoint resume state.
    pub fn stream_state(&self) -> (u64, usize) {
        (self.epoch, self.pos)
    }

    /// Seek to a position saved by [`Self::stream_state`]. The epoch
    /// permutation is a pure function of (seed, epoch), so seeking
    /// reproduces the exact example stream the saved run would have
    /// continued with — including under elastic batch histories, where
    /// "skip N batches" cannot reconstruct the consumed-example count.
    /// Errors if `pos` lies beyond this dataset (checkpoint saved
    /// against a different `train_examples`) — silently clamping would
    /// break the exact-stream guarantee.
    pub fn seek(&mut self, epoch: u64, pos: usize) -> Result<()> {
        anyhow::ensure!(
            pos <= self.order.len(),
            "stream position {pos} beyond dataset of {} examples (checkpoint from a different data config?)",
            self.order.len()
        );
        self.epoch = epoch;
        self.reshuffle();
        self.pos = pos;
        Ok(())
    }

    /// Draw the next `n` examples. Wraps into the next epoch when the
    /// current one is exhausted mid-batch (keeps every batch full, which
    /// the fixed-shape AOT executables require).
    pub fn next_batch(&mut self, n: usize) -> Result<Batch> {
        anyhow::ensure!(n > 0 && n <= self.ds.len(), "bad batch size {n}");
        let mut x = vec![0f32; n * IMG_ELEMS];
        let mut y = vec![0i32; n];
        for i in 0..n {
            if self.pos >= self.order.len() {
                self.next_epoch();
            }
            let idx = self.order[self.pos] as usize;
            self.pos += 1;
            let out = &mut x[i * IMG_ELEMS..(i + 1) * IMG_ELEMS];
            y[i] = self.ds.example(idx, out);
            if self.augment {
                // Keyed by (seed, epoch, example): bit-reproducible
                // across batch-size histories.
                let mut rng =
                    Rng::stream(self.seed ^ 0xA06, self.epoch.wrapping_mul(1_000_003) ^ idx as u64);
                augment::flip_crop(out, &mut rng);
            }
        }
        Ok(Batch::new(x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iter(n: usize) -> BatchIter {
        let ds = synthetic::SyntheticCifar::new(10, n, true, 7);
        BatchIter::new(Box::new(ds), 3, true)
    }

    #[test]
    fn batches_have_requested_size() {
        let mut it = iter(100);
        for &n in &[8usize, 32, 17, 96] {
            let b = it.next_batch(n).unwrap();
            assert_eq!(b.n, n);
            assert_eq!(b.x.len(), n * IMG_ELEMS);
        }
    }

    #[test]
    fn epoch_order_is_deterministic() {
        let mut a = iter(64);
        let mut b = iter(64);
        let ba = a.next_batch(16).unwrap();
        let bb = b.next_batch(16).unwrap();
        assert_eq!(ba.y, bb.y);
        assert_eq!(ba.x, bb.x);
    }

    #[test]
    fn reshuffle_changes_order() {
        let mut it = iter(256);
        let b1 = it.next_batch(32).unwrap();
        it.next_epoch();
        let b2 = it.next_batch(32).unwrap();
        assert_ne!(b1.y, b2.y, "different epoch, different order");
    }

    #[test]
    fn wraps_across_epoch_boundary() {
        let mut it = iter(40);
        let _ = it.next_batch(32).unwrap();
        let b = it.next_batch(32).unwrap(); // 8 left + 24 from next epoch
        assert_eq!(b.n, 32);
        assert_eq!(it.epoch(), 1);
    }

    #[test]
    fn labels_in_range_and_normalized_pixels() {
        let mut it = iter(128);
        let b = it.next_batch(64).unwrap();
        assert!(b.y.iter().all(|&y| (0..10).contains(&y)));
        // Normalized CIFAR pixels live in roughly [-3, 3].
        assert!(b.x.iter().all(|&v| v.abs() < 4.0));
        // detlint: ordered — sequential sum in pixel-buffer order.
        let mean: f32 = b.x.iter().sum::<f32>() / b.x.len() as f32;
        assert!(mean.abs() < 1.0, "roughly centered, got {mean}");
    }

    #[test]
    fn oversized_batch_rejected() {
        let mut it = iter(16);
        assert!(it.next_batch(17).is_err());
        assert!(it.next_batch(0).is_err());
    }
}
