//! The `tiny_cnn` model for the native backend — a pure-Rust port of
//! `python/compile/models/tiny_cnn.py` + `train_graph.py` semantics:
//!
//! * forward: [conv3×3 → BN → ReLU → maxpool2]×2 → conv3×3 → BN → ReLU
//!   → global-avg-pool → dense head; each conv/dense consumes one entry
//!   of the runtime `codes` vector (weights + input activations rounded
//!   through qdq / mp_matmul, BN always fp32);
//! * backward: hand-written reverse pass with the Pallas kernels' VJP
//!   contract (cotangents re-quantized at each precision layer);
//! * train step: loss-scaled grads, overflow detection (any non-finite
//!   grad skips the whole update and holds BN state), per-layer
//!   grad-variance/norm stats, fused SGD+momentum with weight decay and
//!   per-layer LR scales;
//! * curv step: block-diagonal Hessian-vector products via per-layer
//!   central-difference of the gradient (one power-iteration step per
//!   firing, probe vectors normalized per layer) — the strict-block
//!   variant of `curv_graph.py`.
//!
//! Parameter order (the manifest contract): conv{1,2,3}/w, bn{1,2,3}
//! gamma+beta interleaved per block, then head/w, head/b. BN state is
//! [rm, rv] per block, zeros/ones initialized.

#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::ops::{self, BnCache};
use super::qdq;
use crate::manifest::ModelEntry;
use crate::runtime::backend::ModelState;
use crate::runtime::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::util::rng::Rng;

/// Conv-block output channels.
pub const CHANNELS: [usize; 3] = [16, 32, 64];
/// Spatial side length at the input of each conv block.
const DIMS: [usize; 3] = [32, 16, 8];
/// Dense-head input features (= last conv channels after GAP).
const FEATURES: usize = 64;
/// SGD momentum (kernels/ref.py::SGD_MOMENTUM).
const MOMENTUM: f32 = 0.9;
/// Number of flat parameter tensors.
const N_PARAMS: usize = 11;

/// Forward-pass caches consumed by [`backward`].
struct Fwd {
    /// Quantized conv inputs, per conv block.
    xq: Vec<Vec<f32>>,
    /// Quantized conv weights, per conv block.
    wq: Vec<Vec<f32>>,
    /// Conv outputs (BN inputs), per conv block.
    conv_out: Vec<Vec<f32>>,
    /// BN statistics, per conv block.
    bn: Vec<BnCache>,
    /// BN outputs (ReLU pre-activations), per conv block.
    bn_out: Vec<Vec<f32>>,
    /// Max-pool argmax maps for blocks 0 and 1.
    arg: Vec<Vec<u8>>,
    /// Quantized dense input / weight.
    head_xq: Vec<f32>,
    head_wq: Vec<f32>,
    /// Cotangent of the (unscaled) mean loss w.r.t. the logits.
    dlogits: Vec<f32>,
    /// Updated BN running stats (train mode).
    new_state: Vec<Vec<f32>>,
    loss: f32,
    correct: i64,
}

fn forward(
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    n: usize,
    codes: &[i32],
    train: bool,
) -> Fwd {
    debug_assert_eq!(params.len(), N_PARAMS);
    let classes = entry.num_classes;
    let mut h = x.to_vec();
    let mut cin = 3usize;
    let mut xq_v = Vec::with_capacity(3);
    let mut wq_v = Vec::with_capacity(3);
    let mut conv_v = Vec::with_capacity(3);
    let mut bn_v = Vec::with_capacity(3);
    let mut bn_out_v = Vec::with_capacity(3);
    let mut arg_v = Vec::with_capacity(2);
    let mut new_state = Vec::with_capacity(6);
    for li in 0..3 {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let code = codes[li];
        let hq = qdq::qdq(&h, code);
        let wq = qdq::qdq(&params[li * 3], code);
        let conv = ops::conv3x3_fwd(&hq, n, dim, dim, cin, &wq, cout);
        let rows = n * dim * dim;
        let (bn_out, nrm, nrv, cache) = ops::bn_fwd(
            &conv,
            rows,
            cout,
            &params[li * 3 + 1],
            &params[li * 3 + 2],
            &state[li * 2],
            &state[li * 2 + 1],
            train,
        );
        new_state.push(nrm);
        new_state.push(nrv);
        let mut r = bn_out.clone();
        ops::relu_inplace(&mut r);
        if li < 2 {
            let (pool, arg) = ops::maxpool2_fwd(&r, n, dim, dim, cout);
            arg_v.push(arg);
            h = pool;
        } else {
            h = ops::gap_fwd(&r, n, dim, dim, cout);
        }
        xq_v.push(hq);
        wq_v.push(wq);
        conv_v.push(conv);
        bn_v.push(cache);
        bn_out_v.push(bn_out);
        cin = cout;
    }
    let code = codes[3];
    let head_xq = qdq::qdq(&h, code);
    let head_wq = qdq::qdq(&params[9], code);
    let logits = ops::dense_fwd(&head_xq, n, FEATURES, &head_wq, classes, &params[10]);
    let (loss, correct, dlogits) = ops::softmax_ce(&logits, y, n, classes);
    Fwd {
        xq: xq_v,
        wq: wq_v,
        conv_out: conv_v,
        bn: bn_v,
        bn_out: bn_out_v,
        arg: arg_v,
        head_xq,
        head_wq,
        dlogits,
        new_state,
        loss,
        correct,
    }
}

/// Reverse pass: returns the 11 parameter gradients of the *unscaled*
/// mean loss (the loss-scale round-trip is exact for 2^k scales).
fn backward(
    entry: &ModelEntry,
    fwd: &Fwd,
    params: &[Vec<f32>],
    codes: &[i32],
    loss_scale: f32,
    n: usize,
) -> Vec<Vec<f32>> {
    let classes = entry.num_classes;
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); N_PARAMS];

    // Seed with the cotangent of the scaled loss.
    let g_logits: Vec<f32> = fwd.dlogits.iter().map(|&v| v * loss_scale).collect();

    // Dense head (mp_matmul VJP): dx/dw see the quantized cotangent,
    // the bias grad sits outside the kernel and sees the raw one.
    let gq = qdq::qdq(&g_logits, codes[3]);
    let (dx_head, dw_head, _) =
        ops::dense_bwd(&fwd.head_xq, n, FEATURES, &fwd.head_wq, classes, &gq);
    let mut db = vec![0f32; classes];
    for bi in 0..n {
        for (co, d) in db.iter_mut().enumerate() {
            *d += g_logits[bi * classes + co];
        }
    }
    grads[9] = dw_head;
    grads[10] = db;

    let mut g = dx_head;
    for li in (0..3).rev() {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let cin = if li == 0 { 3 } else { CHANNELS[li - 1] };
        let mut gs = if li == 2 {
            ops::gap_bwd(&g, n, dim, dim, cout)
        } else {
            ops::maxpool2_bwd(&g, &fwd.arg[li], n, dim, dim, cout)
        };
        ops::relu_bwd_inplace(&mut gs, &fwd.bn_out[li]);
        let rows = n * dim * dim;
        let (dxbn, dgamma, dbeta) = ops::bn_bwd(
            &fwd.conv_out[li],
            &gs,
            rows,
            cout,
            &params[li * 3 + 1],
            &fwd.bn[li],
        );
        let (dxq, dwq) =
            ops::conv3x3_bwd(&fwd.xq[li], n, dim, dim, cin, &fwd.wq[li], cout, &dxbn);
        // qdq VJP: cotangents are rounded to the layer's precision.
        grads[li * 3] = qdq::qdq(&dwq, codes[li]);
        grads[li * 3 + 1] = dgamma;
        grads[li * 3 + 2] = dbeta;
        g = qdq::qdq(&dxq, codes[li]);
    }

    // Unscale (exact for power-of-two loss scales).
    let inv = 1.0 / loss_scale;
    for gvec in grads.iter_mut() {
        for v in gvec.iter_mut() {
            *v *= inv;
        }
    }
    grads
}

/// Per-precision-layer (variance, Σg²) of the parameter gradients,
/// mirroring `train_graph._per_layer_grad_stats`. NaN/inf gradients
/// propagate into the stats (the controller ignores non-finite values).
fn layer_stats(entry: &ModelEntry, grads: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let l_count = entry.num_layers;
    let mut sum = vec![0f64; l_count];
    let mut sq = vec![0f64; l_count];
    let mut count = vec![0usize; l_count];
    for (spec, g) in entry.params.iter().zip(grads) {
        if spec.layer_idx < 0 {
            continue;
        }
        let li = spec.layer_idx as usize;
        for &v in g {
            sum[li] += v as f64;
            sq[li] += (v as f64) * (v as f64);
        }
        count[li] += g.len();
    }
    let mut var = Vec::with_capacity(l_count);
    let mut norm = Vec::with_capacity(l_count);
    for li in 0..l_count {
        let cnt = count[li].max(1) as f64;
        let mean = sum[li] / cnt;
        let raw = sq[li] / cnt - mean * mean;
        // Clamp round-off below zero but let NaN through (overflow
        // steps must not report a fake zero variance).
        let v = if raw.is_nan() { f64::NAN } else { raw.max(0.0) };
        var.push(v as f32);
        norm.push(sq[li] as f32);
    }
    (var, norm)
}

/// Seed-deterministic parameter/state materialization (he-normal convs,
/// kaiming-uniform dense, unit gammas, zero betas/bias; BN running
/// stats start at (0, 1)). Each tensor draws from its own RNG stream,
/// so the init is independent of evaluation order.
pub fn init(entry: &ModelEntry, seed: i32) -> Result<ModelState> {
    let base = seed as i64 as u64;
    let mut params = Vec::with_capacity(entry.params.len());
    for (i, spec) in entry.params.iter().enumerate() {
        let mut rng = Rng::stream(base, 0x1817 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let v: Vec<f32> = if spec.shape.len() == 4 {
            // conv kernel: he_normal, fan_in = k*k*cin.
            let fan_in = (spec.shape[0] * spec.shape[1] * spec.shape[2]).max(1);
            let s = (2.0 / fan_in as f64).sqrt() as f32;
            (0..spec.elems).map(|_| rng.next_normal() * s).collect()
        } else if spec.shape.len() == 2 {
            // dense kernel: uniform(-1/sqrt(fan_in), 1/sqrt(fan_in)).
            let bound = 1.0 / (spec.shape[0].max(1) as f32).sqrt();
            (0..spec.elems)
                .map(|_| -bound + rng.next_f32() * (2.0 * bound))
                .collect()
        } else if spec.name.ends_with("gamma") {
            vec![1.0; spec.elems]
        } else {
            vec![0.0; spec.elems] // beta / bias
        };
        params.push(v);
    }
    let mom = entry.params.iter().map(|p| vec![0f32; p.elems]).collect();
    // BN state interleaves [running_mean, running_var] per block.
    let state = entry
        .state_shapes
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let elems: usize = shape.iter().product();
            if i % 2 == 0 {
                vec![0f32; elems]
            } else {
                vec![1f32; elems]
            }
        })
        .collect();
    Ok(ModelState { params, mom, state })
}

/// One fused SGD+momentum training step (train_graph.py semantics).
pub fn train_step(
    entry: &ModelEntry,
    st: &mut ModelState,
    batch: &Batch,
    ctrl: &StepCtrl,
) -> Result<TrainOutputs> {
    let n = batch.n;
    let fwd = forward(entry, &st.params, &st.state, &batch.x, &batch.y, n, &ctrl.codes, true);
    let grads = backward(entry, &fwd, &st.params, &ctrl.codes, ctrl.loss_scale, n);
    let overflow = grads.iter().any(|g| g.iter().any(|v| !v.is_finite()));
    let (grad_var, grad_norm) = layer_stats(entry, &grads);

    // Fused update with the overflow gate as a runtime mask: an
    // overflowed step leaves params, momentum, and BN state untouched.
    let mask = if overflow { 0f32 } else { 1f32 };
    for (i, spec) in entry.params.iter().enumerate() {
        let scale = if spec.layer_idx >= 0 {
            ctrl.lr_scales[spec.layer_idx as usize]
        } else {
            1.0
        };
        let lr_eff = ctrl.lr * scale;
        let p = &mut st.params[i];
        let m = &mut st.mom[i];
        let g = &grads[i];
        for k in 0..p.len() {
            let g_eff = (g[k] + ctrl.weight_decay * p[k]) * mask;
            let m_new = MOMENTUM * m[k] + g_eff;
            let m_out = if mask > 0.5 { m_new } else { m[k] };
            p[k] -= lr_eff * mask * m_out;
            m[k] = m_out;
        }
    }
    if !overflow {
        st.state = fwd.new_state;
    }
    Ok(TrainOutputs {
        loss: fwd.loss,
        correct: fwd.correct,
        grad_var,
        grad_norm,
        overflow,
    })
}

/// Eval with running-stat BN (codes honoured, state untouched).
pub fn eval_batch(
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    codes: &[i32],
) -> Result<EvalResult> {
    let fwd = forward(entry, &st.params, &st.state, &batch.x, &batch.y, batch.n, codes, false);
    Ok(EvalResult {
        loss: fwd.loss,
        correct: fwd.correct,
        total: batch.n,
    })
}

/// Relative step size of the central-difference HVP probe.
const FD_EPS_REL: f64 = 1e-2;

/// Gradients of the unscaled train-mode loss at `params`.
fn grad_at(
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    batch: &Batch,
    codes: &[i32],
) -> Vec<Vec<f32>> {
    let fwd = forward(entry, params, state, &batch.x, &batch.y, batch.n, codes, true);
    backward(entry, &fwd, params, codes, 1.0, batch.n)
}

/// One amortized power-iteration step per precision layer:
/// block-diagonal HVP `H_l u_l` via a per-layer central difference of
/// the gradient, Rayleigh quotient `λ_l`, and normalized next probe
/// written back into `probes` (curv_graph.py strict-block semantics).
pub fn curv_step(
    entry: &ModelEntry,
    st: &ModelState,
    batch: &Batch,
    probes: &mut [Vec<f32>],
    codes: &[i32],
) -> Result<Vec<f32>> {
    let l_count = entry.num_layers;
    let mut lambdas = vec![0f32; l_count];
    for li in 0..l_count {
        let idxs: Vec<usize> = entry
            .params
            .iter()
            .enumerate()
            .filter(|(_, s)| s.layer_idx == li as i64)
            .map(|(i, _)| i)
            .collect();
        let un: f64 = idxs
            .iter()
            .map(|&i| probes[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt();
        if un < 1e-12 {
            continue; // degenerate probe — λ stays 0, probe untouched
        }
        let tn: f64 = idxs
            .iter()
            .map(|&i| st.params[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt();
        let eps = (FD_EPS_REL * (tn + 1.0) / un) as f32;

        let mut pp = st.params.clone();
        let mut pm = st.params.clone();
        for &i in &idxs {
            for k in 0..pp[i].len() {
                let d = eps * probes[i][k];
                pp[i][k] += d;
                pm[i][k] -= d;
            }
        }
        let gp = grad_at(entry, &pp, &st.state, batch, codes);
        let gm = grad_at(entry, &pm, &st.state, batch, codes);

        let inv2e = 1.0 / (2.0 * eps);
        let mut num = 0f64;
        let mut den = 0f64;
        let mut hn2 = 0f64;
        let mut hu: Vec<(usize, Vec<f32>)> = Vec::with_capacity(idxs.len());
        for &i in &idxs {
            let h: Vec<f32> = gp[i]
                .iter()
                .zip(gm[i].iter())
                .map(|(&a, &b)| (a - b) * inv2e)
                .collect();
            for (k, &hv) in h.iter().enumerate() {
                num += probes[i][k] as f64 * hv as f64;
                den += (probes[i][k] as f64) * (probes[i][k] as f64);
                hn2 += (hv as f64) * (hv as f64);
            }
            hu.push((i, h));
        }
        let hn = hn2.sqrt() + 1e-12;
        lambdas[li] = (num / (den + 1e-12)) as f32;
        for (i, h) in hu {
            probes[i] = h.iter().map(|&v| (v as f64 / hn) as f32).collect();
        }
    }
    Ok(lambdas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{FP16, FP32};
    use crate::runtime::native::builtin_manifest;

    fn entry() -> ModelEntry {
        builtin_manifest().model("tiny_cnn_c10").unwrap().clone()
    }

    fn rand_batch(n: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        Batch::new(x, y)
    }

    #[test]
    fn init_shapes_match_manifest() {
        let e = entry();
        let st = init(&e, 3).unwrap();
        assert_eq!(st.params.len(), e.params.len());
        for (p, spec) in st.params.iter().zip(&e.params) {
            assert_eq!(p.len(), spec.elems, "{}", spec.name);
        }
        assert_eq!(st.state.len(), e.state_shapes.len());
        // gammas one, betas zero, running stats (0, 1).
        assert!(st.params[1].iter().all(|&v| v == 1.0), "gamma");
        assert!(st.params[2].iter().all(|&v| v == 0.0), "beta");
        assert!(st.state[0].iter().all(|&v| v == 0.0), "rm");
        assert!(st.state[1].iter().all(|&v| v == 1.0), "rv");
        // conv weights have he-normal-ish spread.
        let w0 = &st.params[0];
        let norm: f64 = w0.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        assert!(norm > 1.0 && norm < 100.0, "conv1 norm² {norm}");
    }

    #[test]
    fn whole_model_gradcheck_fp32() {
        let e = entry();
        let mut st = init(&e, 7).unwrap();
        let b = rand_batch(4, 1);
        let codes = vec![FP32; 4];
        let grads = grad_at(&e, &st.params, &st.state, &b, &codes);
        let loss_at = |params: &[Vec<f32>], st: &ModelState| -> f64 {
            forward(&e, params, &st.state, &b.x, &b.y, b.n, &codes, true).loss as f64
        };
        let mut rng = Rng::new(0xFD);
        // Spot-check a few components of every parameter tensor.
        for pi in 0..st.params.len() {
            for _ in 0..4 {
                let k = rng.below(st.params[pi].len() as u64) as usize;
                let eps = 5e-3f32;
                let orig = st.params[pi][k];
                st.params[pi][k] = orig + eps;
                let lp = loss_at(&st.params, &st);
                st.params[pi][k] = orig - eps;
                let lm = loss_at(&st.params, &st);
                st.params[pi][k] = orig;
                let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let analytic = grads[pi][k];
                let diff = (numeric - analytic).abs();
                let scale = numeric.abs().max(analytic.abs()).max(3e-2);
                assert!(
                    diff / scale < 0.15,
                    "param {pi}[{k}]: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn overfits_one_batch() {
        let e = entry();
        let mut st = init(&e, 1).unwrap();
        let b = rand_batch(8, 5);
        let ctrl = StepCtrl::uniform(4, FP32, 0.1, 0.0);
        let mut first = 0f32;
        let mut last = TrainOutputs {
            loss: 0.0,
            correct: 0,
            grad_var: vec![],
            grad_norm: vec![],
            overflow: false,
        };
        for step in 0..40 {
            last = train_step(&e, &mut st, &b, &ctrl).unwrap();
            if step == 0 {
                first = last.loss;
            }
        }
        assert!(
            last.loss < 0.5 && last.loss < first * 0.5,
            "no memorization: {first} -> {}",
            last.loss
        );
        assert_eq!(last.correct, 8, "one batch must be memorized");
    }

    #[test]
    fn overflow_masks_the_update() {
        let e = entry();
        let mut st = init(&e, 2).unwrap();
        let before = st.clone();
        let b = rand_batch(8, 9);
        let mut ctrl = StepCtrl::uniform(4, FP16, 0.05, 0.0);
        ctrl.loss_scale = 1e30; // cotangents overflow binary16 -> inf
        let out = train_step(&e, &mut st, &b, &ctrl).unwrap();
        assert!(out.overflow, "1e30 scale through fp16 must overflow");
        assert_eq!(st.params, before.params, "params held on overflow");
        assert_eq!(st.mom, before.mom, "momentum held on overflow");
        assert_eq!(st.state, before.state, "BN state held on overflow");
        // A sane scale on the same batch recovers immediately.
        ctrl.loss_scale = 1024.0;
        let ok = train_step(&e, &mut st, &b, &ctrl).unwrap();
        assert!(!ok.overflow);
        assert_ne!(st.params, before.params, "clean step updates params");
    }

    #[test]
    fn grad_stats_have_layer_arity_and_scale() {
        let e = entry();
        let mut st = init(&e, 4).unwrap();
        let b = rand_batch(16, 2);
        let ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
        let out = train_step(&e, &mut st, &b, &ctrl).unwrap();
        assert_eq!(out.grad_var.len(), 4);
        assert_eq!(out.grad_norm.len(), 4);
        assert!(out.grad_var.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(out.grad_norm.iter().all(|v| v.is_finite() && *v >= 0.0));
        // The dense head sees the largest per-element gradients at init.
        assert!(out.grad_var[3] > out.grad_var[1]);
    }
}
