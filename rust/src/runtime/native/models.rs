//! Built-in model definitions for the native backend.
//!
//! Each model is assembled by [`GraphBuilder`], which tracks activation
//! shapes while appending nodes and emits the manifest JSON — layer
//! table, parameter table, state shapes, and the typed layer graph —
//! with every derived count (param_elems, act_elems, MACs, param_count)
//! computed from the same shape walk the executor will re-validate, so
//! the tables can never drift from the graph.
//!
//! The grid (paper Table 1 shape, hermetic):
//! * `tiny_cnn` — the CI-speed stack: [conv3×3 → BN → ReLU → pool]×2 →
//!   conv3×3 → BN → ReLU → GAP → dense. Bit-compatible with the
//!   pre-graph hand-written executor (`tests/golden_trace.rs`).
//! * `resnet_mini` — CIFAR-style residual net standing in for the
//!   paper's ResNet-18: stem + three residual stages (8→16→32 channels,
//!   stride-2 downsampling with 1×1-conv shortcuts) → GAP → dense.
//! * `effnet_lite` — depthwise-separable net standing in for
//!   EfficientNet-B0: stem + three [dw3×3 → BN → ReLU → pw1×1 → BN]
//!   blocks (one residual) + 1×1 head conv → GAP → dense.
//!
//! Every model ships as `<name>_c10` and `<name>_c100`.

use std::fmt::Write as _;

/// A saved position in the graph walk (node index + activation shape),
/// used to branch residual paths and to name `add` operands.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Pos {
    idx: i64,
    h: usize,
    w: usize,
    c: usize,
}

/// Shape-tracking builder: appends typed nodes, derives the layer /
/// param / state tables, and renders one manifest model entry.
pub(crate) struct GraphBuilder {
    model: String,
    classes: usize,
    h: usize,
    w: usize,
    c: usize,
    prev: i64,
    next_layer: usize,
    next_state: usize,
    layers: Vec<String>,
    params: Vec<String>,
    nodes: Vec<String>,
    state_shapes: Vec<String>,
    param_count: usize,
}

fn out_dim(h: usize, stride: usize) -> usize {
    h.div_ceil(stride)
}

impl GraphBuilder {
    pub(crate) fn new(model: &str, classes: usize) -> GraphBuilder {
        GraphBuilder {
            model: model.to_string(),
            classes,
            h: 32,
            w: 32,
            c: 3,
            prev: -1,
            next_layer: 0,
            next_state: 0,
            layers: Vec::new(),
            params: Vec::new(),
            nodes: Vec::new(),
            state_shapes: Vec::new(),
            param_count: 0,
        }
    }

    /// Current position (for residual branches).
    pub(crate) fn here(&self) -> Pos {
        Pos { idx: self.prev, h: self.h, w: self.w, c: self.c }
    }

    /// Rewind the walk to a saved position (start of a side branch).
    pub(crate) fn goto(&mut self, p: Pos) {
        self.prev = p.idx;
        self.h = p.h;
        self.w = p.w;
        self.c = p.c;
    }

    fn push_param(&mut self, name: &str, shape: &[usize], layer_idx: i64) -> usize {
        let elems: usize = shape.iter().product();
        let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
        self.params.push(format!(
            r#"{{"name":"{name}","shape":[{}],"layer_idx":{layer_idx},"elems":{elems}}}"#,
            dims.join(",")
        ));
        self.param_count += elems;
        self.params.len() - 1
    }

    fn push_layer(&mut self, name: &str, kind: &str, param_elems: usize, act: usize, flops: usize) -> usize {
        self.layers.push(format!(
            r#"{{"name":"{name}","kind":"{kind}","param_elems":{param_elems},"act_elems":{act},"flops":{flops}}}"#
        ));
        let li = self.next_layer;
        self.next_layer += 1;
        li
    }

    fn push_node(&mut self, body: String) {
        self.nodes.push(body);
        self.prev = self.nodes.len() as i64 - 1;
    }

    /// SAME k×k stride-`s` convolution to `cout` channels — one
    /// precision layer.
    pub(crate) fn conv(&mut self, name: &str, k: usize, stride: usize, cout: usize) {
        let (ho, wo) = (out_dim(self.h, stride), out_dim(self.w, stride));
        let w = self.push_param(&format!("{name}/w"), &[k, k, self.c, cout], self.next_layer as i64);
        let li = self.push_layer(
            name,
            "conv",
            k * k * self.c * cout,
            ho * wo * cout,
            k * k * self.c * cout * ho * wo,
        );
        let input = self.prev;
        self.push_node(format!(
            r#"{{"op":"conv","k":{k},"stride":{stride},"w":{w},"layer":{li},"in":{input}}}"#
        ));
        self.h = ho;
        self.w = wo;
        self.c = cout;
    }

    /// SAME depthwise k×k stride-`s` convolution — one precision layer.
    pub(crate) fn dwconv(&mut self, name: &str, k: usize, stride: usize) {
        let (ho, wo) = (out_dim(self.h, stride), out_dim(self.w, stride));
        let c = self.c;
        let w = self.push_param(&format!("{name}/w"), &[k, k, 1, c], self.next_layer as i64);
        let li = self.push_layer(name, "dwconv", k * k * c, ho * wo * c, k * k * c * ho * wo);
        let input = self.prev;
        self.push_node(format!(
            r#"{{"op":"dwconv","k":{k},"stride":{stride},"w":{w},"layer":{li},"in":{input}}}"#
        ));
        self.h = ho;
        self.w = wo;
    }

    /// BatchNorm over the current channels (fp32-only params + two
    /// running-stat state slots).
    pub(crate) fn bn(&mut self, name: &str) {
        let c = self.c;
        let gamma = self.push_param(&format!("{name}/gamma"), &[c], -1);
        let beta = self.push_param(&format!("{name}/beta"), &[c], -1);
        let state = self.next_state;
        self.state_shapes.push(format!("[{c}]"));
        self.state_shapes.push(format!("[{c}]"));
        self.next_state += 2;
        let input = self.prev;
        self.push_node(format!(
            r#"{{"op":"bn","gamma":{gamma},"beta":{beta},"state":{state},"in":{input}}}"#
        ));
    }

    pub(crate) fn relu(&mut self) {
        let input = self.prev;
        self.push_node(format!(r#"{{"op":"relu","in":{input}}}"#));
    }

    pub(crate) fn maxpool2(&mut self) {
        let input = self.prev;
        self.push_node(format!(r#"{{"op":"maxpool2","in":{input}}}"#));
        self.h /= 2;
        self.w /= 2;
    }

    pub(crate) fn gap(&mut self) {
        let input = self.prev;
        self.push_node(format!(r#"{{"op":"gap","in":{input}}}"#));
        self.h = 1;
        self.w = 1;
    }

    /// Residual add of the branch ending at `rhs` onto the current path.
    pub(crate) fn add(&mut self, rhs: Pos) {
        assert_eq!((self.h, self.w, self.c), (rhs.h, rhs.w, rhs.c), "residual shape");
        let input = self.prev;
        self.push_node(format!(r#"{{"op":"add","rhs":{},"in":{input}}}"#, rhs.idx));
    }

    /// Dense head to `classes` logits — one precision layer.
    pub(crate) fn dense(&mut self, name: &str) {
        assert_eq!((self.h, self.w), (1, 1), "dense needs pooled input");
        let (features, classes) = (self.c, self.classes);
        let w = self.push_param(&format!("{name}/w"), &[features, classes], self.next_layer as i64);
        let b = self.push_param(&format!("{name}/b"), &[classes], -1);
        let li = self.push_layer(name, "dense", features * classes, classes, features * classes);
        let input = self.prev;
        self.push_node(format!(
            r#"{{"op":"dense","w":{w},"b":{b},"layer":{li},"in":{input}}}"#
        ));
        self.c = classes;
    }

    /// Append the terminal loss node and render the model entry JSON.
    pub(crate) fn finish(mut self, curv_batch: usize) -> String {
        let input = self.prev;
        self.push_node(format!(r#"{{"op":"softmax_ce","in":{input}}}"#));
        let mut s = String::new();
        let _ = write!(
            s,
            r#"{{
      "model": "{}",
      "num_classes": {},
      "num_layers": {},
      "param_count": {},
      "layers": [{}],
      "params": [{}],
      "graph": [{}],
      "state_shapes": [{}],
      "train_buckets": [16, 32, 64, 96, 128],
      "eval_buckets": [16, 128],
      "curv_batch": {curv_batch},
      "artifacts": {{}}
    }}"#,
            self.model,
            self.classes,
            self.next_layer,
            self.param_count,
            self.layers.join(","),
            self.params.join(","),
            self.nodes.join(","),
            self.state_shapes.join(","),
        );
        s
    }
}

/// The CI-speed stack — the same architecture (and parameter table) the
/// hand-written pre-graph executor implemented.
fn tiny_cnn(classes: usize) -> String {
    let mut g = GraphBuilder::new("tiny_cnn", classes);
    for (i, &ch) in [16usize, 32, 64].iter().enumerate() {
        g.conv(&format!("conv{}", i + 1), 3, 1, ch);
        g.bn(&format!("bn{}", i + 1));
        g.relu();
        if i < 2 {
            g.maxpool2();
        }
    }
    g.gap();
    g.dense("head");
    g.finish(32)
}

/// One residual basic block: conv3×3(s) → BN → ReLU → conv3×3 → BN,
/// plus a 1×1-conv + BN shortcut whenever the shape changes, joined by
/// a residual add and a trailing ReLU (He et al., CIFAR variant).
fn basic_block(g: &mut GraphBuilder, name: &str, features: usize, stride: usize) {
    let block_in = g.here();
    g.conv(&format!("{name}/conv1"), 3, stride, features);
    g.bn(&format!("{name}/bn1"));
    g.relu();
    g.conv(&format!("{name}/conv2"), 3, 1, features);
    g.bn(&format!("{name}/bn2"));
    let main = g.here();
    let identity = if stride != 1 || block_in.c != features {
        g.goto(block_in);
        g.conv(&format!("{name}/down"), 1, stride, features);
        g.bn(&format!("{name}/bn_down"));
        g.here()
    } else {
        block_in
    };
    g.goto(main);
    g.add(identity);
    g.relu();
}

/// CIFAR-style residual net (the paper's ResNet-18 scaled to the
/// CPU-trainable grid): stem + stages (8, s1)(16, s2)(32, s2).
fn resnet_mini(classes: usize) -> String {
    let mut g = GraphBuilder::new("resnet_mini", classes);
    g.conv("stem", 3, 1, 8);
    g.bn("bn_stem");
    g.relu();
    basic_block(&mut g, "s1b", 8, 1);
    basic_block(&mut g, "s2b", 16, 2);
    basic_block(&mut g, "s3b", 32, 2);
    g.gap();
    g.dense("head");
    g.finish(32)
}

/// One depthwise-separable block: dw3×3(s) → BN → ReLU → pw1×1 → BN,
/// with a residual add when the shape is preserved (EfficientNet-lite
/// MBConv without expansion/SE, per the python reference's scaling).
fn sep_block(g: &mut GraphBuilder, name: &str, features: usize, stride: usize) {
    let block_in = g.here();
    g.dwconv(&format!("{name}/dw"), 3, stride);
    g.bn(&format!("{name}/bn_dw"));
    g.relu();
    g.conv(&format!("{name}/pw"), 1, 1, features);
    g.bn(&format!("{name}/bn_pw"));
    if stride == 1 && block_in.c == features {
        g.add(block_in);
    }
}

/// Depthwise-separable net (EfficientNet-B0's ingredients at the
/// CPU-trainable grid): stem + blocks (24, s2)(24, s1 residual)(40, s2)
/// + 1×1 head conv.
fn effnet_lite(classes: usize) -> String {
    let mut g = GraphBuilder::new("effnet_lite", classes);
    g.conv("stem", 3, 1, 16);
    g.bn("bn_stem");
    g.relu();
    sep_block(&mut g, "b1", 24, 2);
    sep_block(&mut g, "b2", 24, 1);
    sep_block(&mut g, "b3", 40, 2);
    g.conv("head_conv", 1, 1, 64);
    g.bn("bn_head");
    g.relu();
    g.gap();
    g.dense("head");
    g.finish(32)
}

/// Render the full built-in manifest: every architecture × {c10, c100}.
pub(crate) fn builtin_manifest_json() -> String {
    let builders: [(&str, fn(usize) -> String); 3] =
        [("tiny_cnn", tiny_cnn), ("resnet_mini", resnet_mini), ("effnet_lite", effnet_lite)];
    let mut entries = Vec::new();
    for (name, build) in builders {
        for classes in [10usize, 100] {
            entries.push(format!(r#""{name}_c{classes}": {}"#, build(classes)));
        }
    }
    format!(
        r#"{{
  "precision_codes": {{"fp16": 0, "bf16": 1, "fp32": 2}},
  "models": {{
    {}
  }}
}}"#,
        entries.join(",\n    ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use std::path::Path;

    #[test]
    fn builder_json_parses_for_every_builtin() {
        let m = Manifest::parse(&builtin_manifest_json(), Path::new("builtin")).unwrap();
        assert_eq!(m.models.len(), 6);
        for name in ["tiny_cnn", "resnet_mini", "effnet_lite"] {
            for classes in [10usize, 100] {
                let e = m.model(&format!("{name}_c{classes}")).unwrap();
                assert_eq!(e.model, name);
                assert_eq!(e.num_classes, classes);
                assert!(!e.nodes.is_empty(), "{name}: graph present");
            }
        }
    }

    #[test]
    fn tiny_cnn_tables_match_the_pre_graph_manifest() {
        // The exact numbers the hand-written executor's manifest
        // carried — the builder must regenerate them (param order,
        // layer accounting, state shapes all included).
        let m = Manifest::parse(&builtin_manifest_json(), Path::new("builtin")).unwrap();
        let e = m.model("tiny_cnn_c10").unwrap();
        assert_eq!(e.num_layers, 4);
        assert_eq!(e.param_count, 24346);
        assert_eq!(e.layers.iter().map(|l| l.flops).collect::<Vec<_>>(), vec![
            442368, 1179648, 1179648, 640
        ]);
        assert_eq!(e.layers.iter().map(|l| l.act_elems).collect::<Vec<_>>(), vec![
            16384, 8192, 4096, 10
        ]);
        let names: Vec<&str> = e.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec![
            "conv1/w", "bn1/gamma", "bn1/beta", "conv2/w", "bn2/gamma", "bn2/beta",
            "conv3/w", "bn3/gamma", "bn3/beta", "head/w", "head/b"
        ]);
        assert_eq!(e.state_shapes, vec![
            vec![16], vec![16], vec![32], vec![32], vec![64], vec![64]
        ]);
        let e100 = m.model("tiny_cnn_c100").unwrap();
        assert_eq!(e100.param_count, 30196);
    }

    #[test]
    fn resnet_mini_has_downsample_shortcuts_and_ten_layers() {
        let m = Manifest::parse(&builtin_manifest_json(), Path::new("builtin")).unwrap();
        let e = m.model("resnet_mini_c10").unwrap();
        assert_eq!(e.num_layers, 10, "stem + 2+3+3 block convs + head");
        let kinds: Vec<&str> = e.layers.iter().map(|l| l.kind.as_str()).collect();
        assert!(kinds.iter().all(|&k| k == "conv" || k == "dense"));
        // The two downsample shortcuts are 1×1 convs.
        let down: Vec<&crate::manifest::ParamSpec> =
            e.params.iter().filter(|p| p.name.ends_with("down/w")).collect();
        assert_eq!(down.len(), 2);
        assert_eq!(down[0].shape, vec![1, 1, 8, 16]);
        assert_eq!(down[1].shape, vec![1, 1, 16, 32]);
        // Residual adds present.
        let adds = e
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::manifest::NodeOp::Add { .. }))
            .count();
        assert_eq!(adds, 3, "one residual join per stage");
    }

    #[test]
    fn effnet_lite_is_depthwise_separable_with_one_residual() {
        let m = Manifest::parse(&builtin_manifest_json(), Path::new("builtin")).unwrap();
        let e = m.model("effnet_lite_c10").unwrap();
        assert_eq!(e.num_layers, 9);
        let dw = e.layers.iter().filter(|l| l.kind == "dwconv").count();
        assert_eq!(dw, 3, "one depthwise conv per block");
        let adds = e
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::manifest::NodeOp::Add { .. }))
            .count();
        assert_eq!(adds, 1, "only the shape-preserving block is residual");
        // Depthwise weights use the [k,k,1,c] shape (fan_in = k²).
        let b2dw = e.params.iter().find(|p| p.name == "b2/dw/w").unwrap();
        assert_eq!(b2dw.shape, vec![3, 3, 1, 24]);
    }
}
