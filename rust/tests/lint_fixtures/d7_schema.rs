pub const SCHEMA_VERSION: u64 = 1;

fn emit(m: &mut Map) {
    m.insert("alpha", 1);
    m.insert("beta", 2);
    m.insert("gamma", 3);
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_keys_are_ignored() {
        let mut m = Map::new();
        m.insert("not_a_schema_key", 0);
    }
}
