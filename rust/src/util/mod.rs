//! In-tree substrates for the fully-offline build: JSON, RNG, CLI args,
//! a stats helper, and the micro bench harness used by `cargo bench`.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
