fn go() {
    std::thread::spawn(|| {});
}
