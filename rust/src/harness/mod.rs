//! Table/figure regeneration harness (DESIGN.md §3): runs the paper's
//! (dataset × architecture × method) grid over seeds and prints rows in
//! Table 1 / Table 2 format with mean±std, exactly the §4.3 protocol
//! ("each experiment is repeated 3 times with different random seeds").
//!
//! Absolute numbers live on this CPU substrate; the *shape* — method
//! ordering, memory reductions, ablation progression — is the
//! reproduction target (repro band 0/5 ⇒ simulated hardware, DESIGN.md
//! §5).

use anyhow::Result;

use crate::config::{Ablation, Config, Method};

use crate::metrics::efficiency_score;
use crate::runtime::Engine;
use crate::train::Trainer;
use crate::util::stats::Welford;

/// Aggregate of one (model, method, config) cell over seeds.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub model_key: String,
    pub label: String,
    pub acc: Welford,
    pub wall_s: Welford,
    pub modeled_s: Welford,
    pub peak_gb: Welford,
    pub score: Welford,
}

impl CellResult {
    pub fn row(&self) -> String {
        format!(
            "{:<18} {:<16} acc {:>5.1}±{:>4.2}%  time {:>7.2}±{:.2}s (wall {:>6.2}s)  vram {:>6.4}±{:.4}GB  score {:>6.2}",
            self.model_key,
            self.label,
            self.acc.mean(),
            self.acc.std(),
            self.modeled_s.mean(),
            self.modeled_s.std(),
            self.wall_s.mean(),
            self.peak_gb.mean(),
            self.peak_gb.std(),
            self.score.mean(),
        )
    }
}

/// Run one cell (fixed model/method/ablation) across `seeds`, applying
/// `tweak` to each seed's config (epoch budget etc.).
pub fn run_cell(
    engine: &Engine,
    model_key: &str,
    method: Method,
    label: &str,
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<CellResult> {
    let mut cell = CellResult {
        model_key: model_key.to_string(),
        label: label.to_string(),
        acc: Welford::default(),
        wall_s: Welford::default(),
        modeled_s: Welford::default(),
        peak_gb: Welford::default(),
        score: Welford::default(),
    };
    for &seed in seeds {
        let mut cfg = Config::cell(model_key, method, seed);
        tweak(&mut cfg);
        let mut tr = Trainer::new(engine, cfg)?;
        let s = tr.run()?;
        cell.acc.push(s.test_acc_pct);
        cell.wall_s.push(s.wall_s_per_epoch);
        cell.modeled_s.push(s.modeled_s_per_epoch);
        cell.peak_gb.push(s.peak_vram_gb);
        cell.score.push(s.eff_score);
    }
    Ok(cell)
}

/// Table 1: methods × model keys. Returns rows in paper order.
pub fn table1(
    engine: &Engine,
    model_keys: &[&str],
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<CellResult>> {
    let mut rows = Vec::new();
    for key in model_keys {
        for method in [Method::Fp32, Method::AmpStatic, Method::TriAccel] {
            rows.push(run_cell(engine, key, method, method.name(), seeds, tweak)?);
        }
    }
    Ok(rows)
}

/// Table 2 ablation rows for one model: standard, +batch, +precision,
/// full (paper order).
pub fn table2(
    engine: &Engine,
    model_key: &str,
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<CellResult>> {
    let rows_spec: [(&str, Method, Ablation); 4] = [
        ("Standard Training", Method::Fp32, Ablation::none()),
        (
            "+ Dynamic Batch",
            Method::TriAccel,
            Ablation { dynamic_precision: false, dynamic_batch: true, curvature: false },
        ),
        (
            "+ Dynamic Precision",
            Method::TriAccel,
            Ablation { dynamic_precision: true, dynamic_batch: false, curvature: false },
        ),
        ("+ Full Tri-Accel", Method::TriAccel, Ablation::full()),
    ];
    let mut rows = Vec::new();
    for (label, method, ablation) in rows_spec {
        let t = move |cfg: &mut Config| {
            cfg.ablation = ablation;
            tweak(cfg);
        };
        rows.push(run_cell(engine, model_key, method, label, seeds, &t)?);
    }
    Ok(rows)
}

/// Print Table 2 with the paper's "Reduction" column (vs the first row).
pub fn print_table2(rows: &[CellResult]) {
    let base = rows[0].peak_gb.mean();
    println!("{:<22} {:>10} {:>10}", "Configuration", "VRAM (GB)", "Reduction");
    for (i, r) in rows.iter().enumerate() {
        let red = if i == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", 100.0 * (base - r.peak_gb.mean()) / base)
        };
        println!("{:<22} {:>10.4} {:>10}", r.label, r.peak_gb.mean(), red);
    }
}

/// The adaptive-behaviour figure (abstract: "efficiency gradually
/// improving over the course of training"): per-epoch efficiency-score
/// and batch-size series for one Tri-Accel run.
pub struct AdaptiveTrace {
    pub epoch_eff: Vec<(usize, f64)>,
    pub batch_trace: Vec<(u64, usize)>,
    pub mix_trace: Vec<(usize, f64, f64, f64)>,
}

pub fn fig_adaptive(
    engine: &Engine,
    model_key: &str,
    seed: u64,
    tweak: &dyn Fn(&mut Config),
) -> Result<AdaptiveTrace> {
    let mut cfg = Config::cell(model_key, Method::TriAccel, seed);
    tweak(&mut cfg);
    let mut tr = Trainer::new(engine, cfg)?;
    tr.run()?;
    let epoch_eff = tr
        .metrics
        .epochs
        .iter()
        .map(|e| (e.epoch, e.eff_score))
        .collect();
    let mix_trace = tr
        .metrics
        .epochs
        .iter()
        .map(|e| (e.epoch, e.mix.fp16, e.mix.bf16, e.mix.fp32))
        .collect();
    Ok(AdaptiveTrace {
        epoch_eff,
        batch_trace: tr.metrics.batch_trace.clone(),
        mix_trace,
    })
}

/// Shared "small budget" tweak used by the bench targets so `cargo
/// bench` completes in minutes on this single-core CPU substrate;
/// `reproduce_tables` exposes knobs for bigger runs.
///
/// `batch_init` drops to 64 (the smallest bucket above b_curv): the
/// memory model and controller dynamics are batch-relative, so the
/// Table-1/2 *shape* is preserved while a full B=96 CPU step budget
/// would make regeneration needlessly slow. The paper's B=96 is
/// restored by `--set batch_init=96` / env overrides.
pub fn quick_budget(steps: usize, epochs: usize) -> impl Fn(&mut Config) {
    move |cfg: &mut Config| {
        cfg.steps_per_epoch = Some(steps);
        cfg.epochs = epochs;
        cfg.train_examples = 4096;
        cfg.eval_examples = 128;
        // B=64 keeps the paper's b_curv(32) < B geometry so probe
        // buffers hide under the activation headroom (memsim test
        // `paper_geometry_probe_hides_under_activation_headroom`).
        cfg.batch_init = 64;
        // Place the utilization band so the BF16 footprint (~0.65 of
        // the strict budget) holds rather than grows — the paper's
        // shrink-or-hold Table-2 regime.
        cfg.rho_low = 0.55;
        cfg.t_ctrl = 3;
        cfg.t_curv = 4;
        cfg.curv_warmup = 1;
        cfg.batch_cooldown = 4;
        cfg.warmup_epochs = 1;
        cfg.mem_budget_gb = 0.0; // auto: strict budget around the workload
    }
}

/// Report the headline abstract claims from a Table-1 triple
/// (FP32, AMP, Tri-Accel) — % time reduction, % memory reduction,
/// accuracy delta — so EXPERIMENTS.md can quote ours vs the paper's.
pub fn headline(fp32: &CellResult, tri: &CellResult) -> String {
    let dt = 100.0 * (fp32.modeled_s.mean() - tri.modeled_s.mean()) / fp32.modeled_s.mean();
    let dm = 100.0 * (fp32.peak_gb.mean() - tri.peak_gb.mean()) / fp32.peak_gb.mean();
    let da = tri.acc.mean() - fp32.acc.mean();
    format!(
        "vs FP32: time −{dt:.1}%  memory −{dm:.1}%  accuracy {}{da:.1}pp  score ×{:.2}",
        if da >= 0.0 { "+" } else { "" },
        tri.score.mean() / fp32.score.mean().max(1e-9),
    )
}

/// Aggregate of one (model, method, trace) pressure cell over seeds:
/// how a method behaves when the budget moves under it.
#[derive(Debug, Clone)]
pub struct PressureCell {
    pub method_key: String,
    pub label: String,
    pub acc: Welford,
    pub peak_gb: Welford,
    pub score: Welford,
    /// Simulated OOMs across seeds (a real static-batch run would have
    /// crashed at the first one).
    pub oom_events: u64,
    /// Batch-policy decisions (moves + vetoes) across seeds.
    pub batch_decisions: u64,
    /// Smallest batch the run was squeezed to (min over seeds).
    pub min_batch: usize,
}

/// The VRAM-pressure scenario sweep (ROADMAP "as many scenarios as you
/// can imagine"): run each registry method under a time-varying budget
/// trace and report survival metrics. This is the stress test the
/// paper's memory-elastic claim (§3.3) implies but Table 1/2 never
/// exercises: the static baselines keep B and accumulate simulated
/// OOMs; the elastic methods shed batch and finish inside the budget.
pub fn pressure(
    engine: &Engine,
    model_key: &str,
    method_keys: &[&str],
    seeds: &[u64],
    trace: &str,
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<PressureCell>> {
    // Fail on a bad trace or a bad method key before any training
    // burns time — a typo in the last method must not discard minutes
    // of earlier cells.
    crate::memsim::BudgetTrace::parse(trace)?;
    let specs: Vec<&crate::policy::MethodSpec> = method_keys
        .iter()
        .map(|k| crate::policy::registry::resolve(k.trim()))
        .collect::<Result<_>>()?;
    let mut rows = Vec::new();
    for spec in specs {
        let mut cell = PressureCell {
            method_key: spec.key.to_string(),
            label: spec.label.to_string(),
            acc: Welford::default(),
            peak_gb: Welford::default(),
            score: Welford::default(),
            oom_events: 0,
            batch_decisions: 0,
            min_batch: usize::MAX,
        };
        for &seed in seeds {
            let mut cfg = Config::cell(model_key, spec.family, seed);
            crate::policy::registry::apply(&mut cfg, spec);
            tweak(&mut cfg);
            cfg.mem_trace = trace.to_string();
            let mut tr = Trainer::new(engine, cfg)?;
            let s = tr.run()?;
            cell.acc.push(s.test_acc_pct);
            cell.peak_gb.push(s.peak_vram_gb);
            cell.score.push(s.eff_score);
            cell.oom_events += tr.metrics.oom_events;
            cell.batch_decisions += tr.metrics.batch_decisions;
            let run_min = tr
                .metrics
                .batch_trace
                .iter()
                .map(|&(_, b)| b)
                .min()
                .unwrap_or(0);
            cell.min_batch = cell.min_batch.min(run_min);
        }
        rows.push(cell);
    }
    Ok(rows)
}

/// Pretty-print the pressure sweep (one row per method).
pub fn print_pressure(rows: &[PressureCell], trace: &str) {
    println!(
        "{:<18} {:>12} {:>10} {:>6} {:>7} {:>7} {:>8}   (trace {trace})",
        "Method", "Acc(%)", "VRAM(GB)", "OOMs", "B_min", "Decs", "Score"
    );
    for r in rows {
        let min_b = if r.min_batch == usize::MAX { 0 } else { r.min_batch };
        let acc = format!("{:.1}±{:.2}", r.acc.mean(), r.acc.std());
        println!(
            "{:<18} {:>12} {:>10.4} {:>6} {:>7} {:>7} {:>8.2}",
            r.label,
            acc,
            r.peak_gb.mean(),
            r.oom_events,
            min_b,
            r.batch_decisions,
            r.score.mean(),
        );
    }
}

/// Validate CLI-supplied model keys against the engine's manifest
/// before any session spins up — unknown keys fail at argument-parse
/// time with the supported-model list instead of deep inside a
/// manifest lookup mid-run.
pub fn validate_models(engine: &Engine, keys: &[&str]) -> Result<()> {
    for key in keys {
        if !engine.manifest.models.contains_key(*key) {
            let supported: Vec<&str> =
                engine.manifest.models.keys().map(|s| s.as_str()).collect();
            anyhow::bail!(
                "unknown model `{key}` — supported models: {}",
                supported.join(", ")
            );
        }
    }
    Ok(())
}

/// Sanity used by tests: a VramSim-backed budget check that the elastic
/// controller's ladder can actually express (at least two buckets fit).
pub fn ladder_headroom(engine: &Engine, model_key: &str, budget_gb: f64) -> Result<usize> {
    let entry = engine.manifest.model(model_key)?.clone();
    let mut sim = crate::memsim::VramSim::new(&entry, budget_gb, 0.0, 0);
    let codes = vec![crate::manifest::BF16; entry.num_layers];
    Ok(entry
        .train_buckets
        .iter()
        .filter(|&&b| sim.would_fit(b, &codes, false))
        .count())
}

/// Convenience: pretty header + rows.
pub fn print_table1(rows: &[CellResult]) {
    println!(
        "{:<18} {:<16} {:>7} {:>12} {:>12} {:>8}",
        "Model", "Method", "Acc(%)", "Time(s)", "VRAM(GB)", "Score"
    );
    for r in rows {
        println!(
            "{:<18} {:<16} {:>6.1}±{:<4.2} {:>8.2}±{:<4.2} {:>8.4}±{:<6.4} {:>8.2}",
            r.model_key,
            r.label,
            r.acc.mean(),
            r.acc.std(),
            r.modeled_s.mean(),
            r.modeled_s.std(),
            r.peak_gb.mean(),
            r.peak_gb.std(),
            r.score.mean()
        );
    }
    let _ = efficiency_score(0.0, 1.0, 1.0); // keep the import honest
}
