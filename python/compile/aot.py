"""AOT pipeline: lower every (model × graph × batch bucket) to HLO *text*
and write the manifest the Rust runtime loads everything from.

Interchange format is HLO text, NOT `lowered.compile().serialize()`:
the image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids); `HloModuleProto::from_text_file` re-parses and reassigns ids.

Incremental: an artifact is skipped when its file already exists, unless
--force. `make artifacts` only invokes this when compile/ sources change.

Usage: python -m compile.aot --out-dir ../artifacts [--only tiny_cnn] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import curv_graph, eval_graph, init_graph, train_graph
from .kernels import ref
from .models import REGISTRY, build
from .models import effnet, resnet, tiny_cnn

# Batch-bucket ladder (DESIGN.md §6.2). PJRT executables are
# shape-specialized; the elastic controller snaps B(t) onto this ladder.
TRAIN_BUCKETS = {
    "tiny_cnn": [8, 16, 24, 32, 48, 64, 96, 128],
    "resnet18": [32, 48, 64, 96, 128],
    "effnet_lite": [32, 48, 64, 96, 128],
}
# CIFAR test split is 10000 = 78×128 + 16, so eval needs exactly these two.
EVAL_BUCKETS = [128, 16]
CURV_BATCH = 32  # paper §4.3: b_curv = 32

# (model, num_classes) cells. tiny_cnn is the CI/quickstart model and only
# ships CIFAR-10; the paper's Table-1 grid uses the two real architectures.
CELLS = [
    ("tiny_cnn", 10),
    ("resnet18", 10),
    ("resnet18", 100),
    ("effnet_lite", 10),
    ("effnet_lite", 100),
]

FORWARD_FACTORIES = {
    "tiny_cnn": tiny_cnn.make_forward,
    "resnet18": resnet.make_forward,
    "effnet_lite": effnet.make_forward,
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, args, path: pathlib.Path, force: bool) -> bool:
    if path.exists() and not force:
        return False
    t0 = time.time()
    # keep_unused: the artifact parameter list must match the manifest IO
    # contract exactly — jit's default pruning would silently drop, e.g.,
    # BN state from the curv probe (train-mode batch stats don't read it).
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
    path.write_text(text)
    print(f"  wrote {path.name}  ({len(text)/1e6:.1f} MB, {time.time()-t0:.1f}s)")
    return True


def model_manifest(model, name: str, num_classes: int) -> dict:
    return {
        "model": name,
        "num_classes": num_classes,
        "num_layers": model.num_layers,
        "param_count": model.param_count,
        "layers": [
            {
                "name": ls.name,
                "kind": ls.kind,
                "param_elems": ls.param_elems,
                "act_elems": ls.act_elems,
                "flops": ls.flops,
            }
            for ls in model.layer_specs
        ],
        "params": [
            {
                "name": ps.name,
                "shape": list(ps.shape),
                "layer_idx": ps.layer_idx,
                "elems": int(math.prod(ps.shape)),
            }
            for ps in model.param_specs
        ],
        "state_shapes": [list(s.shape) for s in model.state],
        "train_buckets": TRAIN_BUCKETS[name],
        "eval_buckets": EVAL_BUCKETS,
        "curv_batch": CURV_BATCH,
        "artifacts": {},  # filled by main()
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="limit to one model name")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {
        "precision_codes": {"fp16": ref.FP16, "bf16": ref.BF16, "fp32": ref.FP32},
        "precision_bytes": {str(k): v for k, v in ref.PRECISION_BYTES.items()},
        "io": {
            "train": {
                "inputs": "params*N, mom*N, state*S, x, y, codes, lr_scales, lr, loss_scale, wd",
                "outputs": "params*N, mom*N, state*S, loss, correct, grad_var, grad_norm, overflow",
            },
            "eval": {
                "inputs": "params*N, state*S, x, y, codes",
                "outputs": "loss, correct",
            },
            "curv": {
                "inputs": "params*N, state*S, x, y, u*N, codes",
                "outputs": "u_next*N, lambdas",
            },
            "init": {"inputs": "seed", "outputs": "params*N, state*S"},
        },
        "models": {},
    }

    for name, num_classes in CELLS:
        if args.only and name != args.only:
            continue
        key = f"{name}_c{num_classes}"
        print(f"[{key}]")
        model = build(name, num_classes=num_classes)
        entry = model_manifest(model, name, num_classes)

        ts = train_graph.make_train_step(model)
        for b in TRAIN_BUCKETS[name]:
            fname = f"{key}_train_b{b}.hlo.txt"
            lower_one(ts, train_graph.example_args(model, b), out / fname, args.force)
            entry["artifacts"][f"train_b{b}"] = fname

        es = eval_graph.make_eval_step(model)
        for b in EVAL_BUCKETS:
            fname = f"{key}_eval_b{b}.hlo.txt"
            lower_one(es, eval_graph.example_args(model, b), out / fname, args.force)
            entry["artifacts"][f"eval_b{b}"] = fname

        cp = curv_graph.make_curv_probe(model)
        fname = f"{key}_curv_b{CURV_BATCH}.hlo.txt"
        lower_one(cp, curv_graph.example_args(model, CURV_BATCH), out / fname, args.force)
        entry["artifacts"]["curv"] = fname

        init = init_graph.make_init(REGISTRY[name], num_classes, FORWARD_FACTORIES[name])
        fname = f"{key}_init.hlo.txt"
        lower_one(init, init_graph.example_args(), out / fname, args.force)
        entry["artifacts"]["init"] = fname

        manifest["models"][key] = entry

    mpath = out / "manifest.json"
    if args.only and mpath.exists():
        # Merge into the existing manifest rather than clobbering it.
        old = json.loads(mpath.read_text())
        old["models"].update(manifest["models"])
        manifest = old
    mpath.write_text(json.dumps(manifest, indent=1))
    digest = hashlib.sha256(mpath.read_bytes()).hexdigest()[:12]
    print(f"manifest.json written ({len(manifest['models'])} models, sha {digest})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
