//! Versioned on-disk memory-budget traces: the record half of
//! record/replay.
//!
//! A trace file is one JSON object holding an absolute `MemMax` series
//! in GiB, indexed by optimizer step:
//!
//! ```text
//! {"schema":1,"kind":"mem_trace","source":"<free text>","gb":[0.45,0.45,...]}
//! ```
//!
//! The series is *absolute*, not a factor over some base budget:
//! recording `max_gb / budget` and replaying `budget * factor` would
//! not be bit-exact (`(x/y)*y != x` in general), and an absolute
//! series replays onto any model × method × replica combination
//! without knowing the originating run's budget. Values serialize
//! through [`crate::util::json::Json`]'s shortest-roundtrip f64
//! formatting, so a recorded `f64` parses back to the identical bits.
//!
//! Validation is strict and happens at parse time — a malformed,
//! oversized, empty, or non-finite trace is rejected when the spec is
//! parsed (CLI arg / config validation), never mid-grid. Standard JSON
//! has no NaN/Infinity literals, so a NaN-bearing file already fails
//! at [`Json::parse`]; the finiteness check here guards
//! directly-constructed values too.

use std::path::Path;

use anyhow::{Context, Result};

use crate::checkpoint::fnv1a;
use crate::util::json::Json;

/// Trace-file schema version (`"schema"` field). Bump only for
/// breaking changes; adding new informational fields does not bump it.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// The `"kind"` tag a budget-trace file must carry.
pub const TRACE_KIND: &str = "mem_trace";

/// Hard cap on the step count a trace may hold — far above any real
/// grid (a 60-step × 3-epoch run is 180 entries) and small enough that
/// a hostile file cannot balloon every `Config::validate` call.
pub const MAX_TRACE_STEPS: usize = 100_000;

/// Pre-parse cap on the file size ([`TraceFile::load`] checks the
/// metadata before reading): rejects a runaway file without ever
/// buffering it.
pub const MAX_TRACE_FILE_BYTES: u64 = 16 * 1024 * 1024;

/// One recorded budget trace: an absolute per-step `MemMax` series.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceFile {
    /// Where the series came from (informational — e.g. the recorded
    /// job key, or a scenario name). Not part of [`Self::digest`].
    pub source: String,
    /// `MemMax` in GiB at step `i`; replay clamps past the end.
    pub gb: Vec<f64>,
}

impl TraceFile {
    /// Build a validated trace.
    pub fn new(source: &str, gb: Vec<f64>) -> Result<TraceFile> {
        validate_series(&gb)?;
        Ok(TraceFile { source: source.to_string(), gb })
    }

    /// Parse and validate the JSON text of a trace file.
    pub fn parse(text: &str) -> Result<TraceFile> {
        let j = Json::parse(text).context("trace file json")?;
        let schema = j.req("schema")?.as_i64().context("trace `schema` must be an integer")?;
        anyhow::ensure!(
            schema == TRACE_SCHEMA_VERSION as i64,
            "trace schema {schema} unsupported (this build reads schema {TRACE_SCHEMA_VERSION})"
        );
        let kind = j.req("kind")?.as_str().context("trace `kind` must be a string")?;
        anyhow::ensure!(kind == TRACE_KIND, "trace kind `{kind}` (want `{TRACE_KIND}`)");
        let source = j
            .get("source")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let arr = j.req("gb")?.as_arr().context("trace `gb` must be an array")?;
        anyhow::ensure!(
            arr.len() <= MAX_TRACE_STEPS,
            "trace holds {} steps (cap {MAX_TRACE_STEPS})",
            arr.len()
        );
        let mut gb = Vec::with_capacity(arr.len());
        for (i, v) in arr.iter().enumerate() {
            let x = v.as_f64().with_context(|| format!("trace gb[{i}] must be a number"))?;
            gb.push(x);
        }
        validate_series(&gb)?;
        Ok(TraceFile { source, gb })
    }

    /// Serialize to the canonical one-line JSON form (plus a trailing
    /// newline). Deterministic: the same series always renders the
    /// same bytes.
    pub fn render(&self) -> String {
        let mut m = std::collections::BTreeMap::new();
        m.insert("schema".to_string(), Json::Num(TRACE_SCHEMA_VERSION as f64));
        m.insert("kind".to_string(), Json::Str(TRACE_KIND.to_string()));
        m.insert("source".to_string(), Json::Str(self.source.clone()));
        m.insert("gb".to_string(), Json::Arr(self.gb.iter().map(|&x| Json::Num(x)).collect()));
        let mut out = Json::Obj(m).to_string_compact();
        out.push('\n');
        out
    }

    /// Load and validate a trace file, with the pre-read size cap.
    pub fn load(path: &Path) -> Result<TraceFile> {
        let meta = std::fs::metadata(path)
            .with_context(|| format!("trace file {}", path.display()))?;
        anyhow::ensure!(
            meta.len() <= MAX_TRACE_FILE_BYTES,
            "trace file {} is {} bytes (cap {MAX_TRACE_FILE_BYTES})",
            path.display(),
            meta.len()
        );
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace file {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("trace file {}", path.display()))
    }

    /// Write the canonical form to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.render())
            .with_context(|| format!("writing trace file {}", path.display()))
    }

    /// Content digest over the series bits (FNV-1a-64 of each value's
    /// little-endian IEEE-754 bytes, in order). `source` is excluded —
    /// two recordings of the same series are the same trace. The
    /// `replay:FILE#DIGEST` spec form pins this digest, so a grid's
    /// identity covers the trace *content*, not just its path.
    pub fn digest(&self) -> u64 {
        series_digest(&self.gb)
    }

    /// Extract the `MemMax` series from a telemetry event stream (the
    /// JSONL text of one job's events file): every `step` event's
    /// `max_gb`, indexed by its `step` field. Requires a dense series
    /// (steps 0..n-1 all present) so the recorded trace has no holes.
    pub fn from_events(text: &str, source: &str) -> Result<TraceFile> {
        let mut series: Vec<Option<f64>> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let ev = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("events line {}: {e}", lineno + 1))?;
            if ev.get("event").and_then(Json::as_str) != Some("step") {
                continue;
            }
            let step = ev
                .req("step")?
                .as_usize()
                .with_context(|| format!("events line {}: bad step", lineno + 1))?;
            let max_gb = ev
                .get("max_gb")
                .and_then(Json::as_f64)
                .with_context(|| {
                    format!(
                        "events line {}: step event has no max_gb — the stream predates \
                         trace recording (re-run the grid with this build)",
                        lineno + 1
                    )
                })?;
            anyhow::ensure!(step < MAX_TRACE_STEPS, "step {step} exceeds the trace cap");
            if step >= series.len() {
                series.resize(step + 1, None);
            }
            series[step] = Some(max_gb);
        }
        anyhow::ensure!(!series.is_empty(), "no step events in the stream");
        let mut gb = Vec::with_capacity(series.len());
        for (i, v) in series.iter().enumerate() {
            gb.push(v.with_context(|| format!("step {i} missing from the event stream"))?);
        }
        TraceFile::new(source, gb)
    }
}

/// FNV-1a-64 over the little-endian IEEE-754 bytes of a series — the
/// digest [`TraceFile::digest`] reports and `replay:FILE#DIGEST` pins.
pub fn series_digest(gb: &[f64]) -> u64 {
    let mut bytes = Vec::with_capacity(gb.len() * 8);
    for &x in gb {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    fnv1a(&bytes)
}

/// Validate a budget series: non-empty, under the step cap, every
/// value finite and positive. Shared with [`crate::memsim::BudgetTrace`],
/// whose `Replay` variant holds the same series shape.
pub fn validate_series(gb: &[f64]) -> Result<()> {
    anyhow::ensure!(!gb.is_empty(), "trace holds no steps");
    anyhow::ensure!(
        gb.len() <= MAX_TRACE_STEPS,
        "trace holds {} steps (cap {MAX_TRACE_STEPS})",
        gb.len()
    );
    for (i, &x) in gb.iter().enumerate() {
        anyhow::ensure!(x.is_finite() && x > 0.0, "trace gb[{i}] = {x} (want finite and > 0)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::telemetry;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("triaccel_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn render_parse_round_trip_is_bit_exact() {
        let t = TraceFile::new("unit", vec![0.45, 0.3333333333333333, 1.0 / 3.0 * 0.9]).unwrap();
        let back = TraceFile::parse(&t.render()).unwrap();
        assert_eq!(back.gb.len(), t.gb.len());
        for (a, b) in t.gb.iter().zip(back.gb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "shortest-roundtrip f64 must be exact");
        }
        assert_eq!(back.source, "unit");
        assert_eq!(back.digest(), t.digest());
    }

    #[test]
    fn save_load_round_trip() {
        let path = tmp("rt.json");
        let t = TraceFile::new("job_x", vec![0.5, 0.25, 0.125]).unwrap();
        t.save(&path).unwrap();
        let back = TraceFile::load(&path).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn digest_tracks_content_not_source() {
        let a = TraceFile::new("a", vec![0.5, 0.25]).unwrap();
        let b = TraceFile::new("b", vec![0.5, 0.25]).unwrap();
        let c = TraceFile::new("a", vec![0.5, 0.26]).unwrap();
        assert_eq!(a.digest(), b.digest(), "source is informational");
        assert_ne!(a.digest(), c.digest(), "content moves the digest");
    }

    #[test]
    fn malformed_traces_are_rejected() {
        for bad in [
            "not json",
            "{\"schema\":1,\"kind\":\"mem_trace\"}",          // no gb
            "{\"schema\":2,\"kind\":\"mem_trace\",\"gb\":[1.0]}", // wrong schema
            "{\"schema\":1,\"kind\":\"other\",\"gb\":[1.0]}",  // wrong kind
            "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[]}", // empty
            "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[0.5,\"x\"]}", // non-number
            "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[0.5,NaN]}",   // NaN is not JSON
            "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[0.5,-1.0]}",  // non-positive
            "{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[0.5,0.0]}",   // zero
        ] {
            assert!(TraceFile::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn oversized_series_is_rejected() {
        let mut s = String::from("{\"schema\":1,\"kind\":\"mem_trace\",\"gb\":[");
        for i in 0..(MAX_TRACE_STEPS + 1) {
            if i > 0 {
                s.push(',');
            }
            s.push('1');
        }
        s.push_str("]}");
        let err = TraceFile::parse(&s).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn from_events_extracts_the_max_gb_series() {
        let mut text = String::new();
        for step in 0..4u64 {
            let ev = telemetry::ev_step(step, 32, 1.0, 0.001, 1, 0.2, 0.5 - 0.1 * step as f64);
            text.push_str(&ev.to_string_compact());
            text.push('\n');
        }
        // Non-step events are ignored.
        text.push_str(&telemetry::ev_oom(3, 0.9, 0.2).to_string_compact());
        text.push('\n');
        let t = TraceFile::from_events(&text, "unit").unwrap();
        assert_eq!(t.gb.len(), 4);
        assert_eq!(t.gb[0].to_bits(), 0.5f64.to_bits());
        assert_eq!(t.gb[3].to_bits(), (0.5 - 0.3f64).to_bits());
    }

    #[test]
    fn from_events_requires_a_dense_series() {
        let mut text = String::new();
        for step in [0u64, 2] {
            let ev = telemetry::ev_step(step, 32, 1.0, 0.001, 1, 0.2, 0.5);
            text.push_str(&ev.to_string_compact());
            text.push('\n');
        }
        let err = TraceFile::from_events(&text, "unit").unwrap_err().to_string();
        assert!(err.contains("step 1 missing"), "{err}");
        assert!(TraceFile::from_events("", "unit").is_err(), "empty stream rejected");
    }
}
