"""`qdq` — precision-emulation Pallas kernel (the paper's per-layer
precision mechanism, §3.1).

Quantize-dequantize an f32 tensor through a *runtime-selected* precision:
the code (0=FP16, 1=BF16, 2=FP32) arrives as an i32[1] input, so a single
lowered executable serves every precision policy the Rust controller can
emit — this is what makes runtime precision scheduling possible without
recompilation (DESIGN.md §6.1).

Hardware adaptation (DESIGN.md §4): the kernel is tiled so each block fits
VMEM (BLOCK f32 elements, 512 KiB at the default); on a real TPU the
quantize would fuse into the HBM→VMEM load. Lowered with interpret=True so
the CPU PJRT plugin can run it.

The custom_vjp makes the backward pass *also* quantize the cotangent to the
same precision — modelling AMP's reduced-precision backward, which is the
very signal (gradient variance inflation under FP16) that drives the
paper's adaptive controller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Elements per block: 128Ki f32 = 512 KiB << 16 MiB VMEM, leaving room for
# the output block and double-buffering on a real TPU.
BLOCK = 128 * 1024


def _qdq_kernel(code_ref, x_ref, o_ref):
    x = x_ref[...]
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    b16 = x.astype(jnp.bfloat16).astype(jnp.float32)
    code = code_ref[0]
    o_ref[...] = jnp.where(code == ref.FP16, f16, jnp.where(code == ref.BF16, b16, x))


def _qdq_flat(x_flat: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """Run the kernel over a 1-D f32 array (already padded to BLOCK)."""
    n = x_flat.shape[0]
    grid = n // BLOCK if n >= BLOCK else 1
    block = BLOCK if n >= BLOCK else n
    return pl.pallas_call(
        _qdq_kernel,
        grid=(grid,),
        in_specs=[
            # The code is broadcast to every block (same scalar each step).
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(code.reshape(1).astype(jnp.int32), x_flat)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def qdq(x: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """Precision round-trip of `x` through the format named by `code`.

    Matches `ref.qdq_ref` exactly. Differentiable: the cotangent is itself
    rounded to the same precision (AMP-style reduced-precision backward).
    """
    return _qdq_fwd(x, code)[0]


def _apply(x: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    shape = x.shape
    x_flat = x.astype(jnp.float32).reshape(-1)
    n = x_flat.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        x_flat = jnp.concatenate([x_flat, jnp.zeros((pad,), jnp.float32)])
    out = _qdq_flat(x_flat, code)
    if pad:
        out = out[:n]
    return out.reshape(shape)


def _qdq_fwd(x, code):
    return _apply(x, code), code


def _qdq_bwd(code, g):
    # Reduced-precision backward: the gradient that flows out of a layer
    # running at precision p is itself representable in p.
    return _apply(g, code), None


qdq.defvjp(_qdq_fwd, _qdq_bwd)
