//! Scratch-buffer arena for the native compute core.
//!
//! A free-list of reusable `Vec<f32>` (and `Vec<u8>`) buffers: `take`
//! hands out a zero-filled buffer, preferring the smallest pooled one
//! whose capacity already fits, and `put` checks it back in. After one
//! warm-up step per batch bucket every buffer the train/eval/curvature
//! paths need is resident, so steady-state training performs no buffer
//! allocations at all — the property pinned by
//! [`fresh_allocs`](Arena::fresh_allocs) and the zero-alloc test in
//! `tiny_cnn.rs`.
//!
//! Buffers are plain owned `Vec`s, so any number can be live at once
//! (im2col panels, GEMM partials, forward caches, gradients) with no
//! borrow gymnastics; discipline is simply that every `take` is paired
//! with a `put` once the buffer is dead.

/// Best-fit pop from a free list, zero-filled to `len`. Zeroing keeps
/// the borrow discipline simple (a stale-content reuse would make
/// every consumer's first write load-bearing), and it is one streaming
/// pass — small next to the GEMMs these buffers feed. The fill writes
/// `T::default()` straight into the spare capacity and publishes the
/// length with a single `set_len`, skipping `resize`'s per-push length
/// bookkeeping on the hot path. The per-element-type pools share this
/// one implementation so the fit heuristic and alloc accounting can't
/// drift apart.
fn take_from<T: Copy + Default>(free: &mut Vec<Vec<T>>, fresh: &mut u64, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for (i, b) in free.iter().enumerate() {
        let better = match best {
            None => true,
            Some(j) => b.capacity() < free[j].capacity(),
        };
        if b.capacity() >= len && better {
            best = Some(i);
        }
    }
    let mut v = match best {
        Some(i) => free.swap_remove(i),
        None => {
            *fresh += 1;
            Vec::with_capacity(len)
        }
    };
    v.clear();
    for slot in &mut v.spare_capacity_mut()[..len] {
        slot.write(T::default());
    }
    // SAFETY: `clear` set the length to 0, the loop above initialized
    // the first `len` spare slots, and `len <= capacity` — pooled
    // buffers are best-fit selected with `capacity() >= len`, fresh
    // ones come from `with_capacity(len)` (the slice above would have
    // panicked otherwise).
    unsafe { v.set_len(len) };
    v
}

fn put_into<T>(free: &mut Vec<Vec<T>>, v: Vec<T>) {
    if v.capacity() > 0 {
        free.push(v);
    }
}

/// Reusable scratch buffers for the zero-alloc training hot path.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<Vec<f32>>,
    free_u8: Vec<Vec<u8>>,
    fresh: u64,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Borrow a zero-filled `f32` buffer of exactly `len` elements.
    /// Reuses the best-fitting pooled buffer (no reallocation when its
    /// capacity suffices); allocates fresh only on a cold arena.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        take_from(&mut self.free, &mut self.fresh, len)
    }

    /// Return a buffer for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        put_into(&mut self.free, v);
    }

    /// Return a batch of buffers for reuse.
    pub fn put_all(&mut self, vs: impl IntoIterator<Item = Vec<f32>>) {
        for v in vs {
            self.put(v);
        }
    }

    /// Borrow a zero-filled byte buffer (max-pool argmax maps).
    pub fn take_u8(&mut self, len: usize) -> Vec<u8> {
        take_from(&mut self.free_u8, &mut self.fresh, len)
    }

    /// Return a byte buffer for reuse.
    pub fn put_u8(&mut self, v: Vec<u8>) {
        put_into(&mut self.free_u8, v);
    }

    /// Buffers ever allocated fresh. Steady-state training must keep
    /// this flat across steps — the zero-alloc contract.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh
    }

    /// Buffers currently checked in (leak canary for take/put pairing).
    pub fn pooled(&self) -> usize {
        self.free.len() + self.free_u8.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_even_after_reuse() {
        let mut a = Arena::new();
        let mut v = a.take(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        a.put(v);
        let v2 = a.take(4);
        assert_eq!(v2, vec![0.0; 4], "reused buffer must be re-zeroed");
        assert!(v2.capacity() >= 8, "reuses the pooled buffer");
    }

    #[test]
    fn warm_arena_stops_allocating() {
        let mut a = Arena::new();
        for _ in 0..3 {
            let x = a.take(100);
            let y = a.take(50);
            a.put(x);
            a.put(y);
        }
        let warm = a.fresh_allocs();
        for _ in 0..10 {
            let x = a.take(100);
            let y = a.take(50);
            let z = a.take(10); // fits inside either pooled buffer
            a.put(z);
            a.put(y);
            a.put(x);
        }
        // take(10) grabs the 50-cap buffer (best fit), so the third
        // concurrent buffer forced exactly one more allocation, after
        // which the working set is warm.
        assert!(a.fresh_allocs() <= warm + 1, "steady state must not allocate");
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let mut a = Arena::new();
        a.put(Vec::with_capacity(1000));
        a.put(Vec::with_capacity(10));
        let v = a.take(5);
        assert!(v.capacity() < 1000, "must not burn the big buffer on a small ask");
        assert_eq!(a.fresh_allocs(), 0);
    }

    #[test]
    fn byte_pool_is_independent() {
        let mut a = Arena::new();
        let b = a.take_u8(16);
        assert_eq!(b, vec![0u8; 16]);
        a.put_u8(b);
        let before = a.fresh_allocs();
        let b2 = a.take_u8(8);
        assert_eq!(a.fresh_allocs(), before);
        a.put_u8(b2);
        assert_eq!(a.pooled(), 1);
    }
}
