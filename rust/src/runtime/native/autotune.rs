//! Shape-class autotuning for the GEMM macro-kernel: search the
//! blocking parameters (`row_chunk` rows per parallel chunk, `nr`
//! panel width) per (dispatch tier, shape class, thread count) and
//! persist the winners to an on-disk cache.
//!
//! Determinism: every candidate in [`candidates`] is **bit-identical
//! within a tier** — per-element k-chains are invariant to row and
//! panel blocking (pinned by `tests/prop_substrates.rs` and the unit
//! tests in [`super::gemm`]) — so timing only ever picks *which
//! equally-correct kernel schedule* runs. Values never depend on the
//! clock, the cache file, or the search. The reduction regrouping
//! knob (`gemm_at_b`'s `RED_CHUNK`) is deliberately **not** in the
//! search space: regrouping partials would change bits.
//!
//! Cache file: compact JSON, sorted keys,
//! `{"schema":1,"entries":{"<tier>/m<⌈log2⌉>k<..>n<..>/t<threads>":
//! {"nr":8,"row_chunk":128}}}` — written crash-safe (temp + rename)
//! through the [`crate::faults::ArtifactIo`] seam. Default location:
//! `triaccel_tune.json` in the working directory; override with
//! `TRIACCEL_TUNE_CACHE`. Invalidation: delete the file (an unknown
//! `schema` number is treated as absent).
//!
//! Escape hatches: `TRIACCEL_NO_AUTOTUNE=1` or the CLI flag
//! `--no-autotune` disable both lookups and tuning — every GEMM then
//! runs the [`TuneCfg::default`] legacy blocking.
//!
//! The library GEMM entry points only ever *look up* this cache
//! (never time anything implicitly); tuning runs in the `tri-accel
//! tune` subcommand and the full (non-`--quick`) micro bench.

// detlint: allow-file(d2) — wall-clock here only ranks candidate
// kernel configurations that are proven bit-identical within a tier,
// so time influences scheduling choices, never computed values (see
// module docs).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::arena::Arena;
use super::pool::Pool;
use super::simd::{Tier, MR};
use crate::faults::{ArtifactIo, RealIo};
use crate::util::json::Json;

/// Cache file schema version (bump on format changes; mismatched
/// files are ignored, i.e. self-invalidate).
const SCHEMA: i64 = 1;

/// One blocking configuration for the GEMM macro-kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneCfg {
    /// Output rows per parallel chunk — always a multiple of
    /// [`MR`], so chunk boundaries (and therefore bits) are config
    /// constants that ignore the thread count.
    pub row_chunk: usize,
    /// Packed panel width the micro-kernel consumes (8 or 16).
    pub nr: usize,
}

impl Default for TuneCfg {
    /// The seed kernel's blocking (`row_chunk` 128, `nr` 8) — what
    /// every run uses when autotuning is disabled or the cache is
    /// cold, keeping the scalar tier bit-identical to the seed.
    fn default() -> TuneCfg {
        TuneCfg { row_chunk: 128, nr: 8 }
    }
}

impl TuneCfg {
    /// Clamp to values the kernels support: `row_chunk` a positive
    /// multiple of [`MR`] (≤ 4096), `nr ∈ {8, 16}`. Out-of-range
    /// values (say, a hand-edited cache file) degrade to the nearest
    /// legal config instead of erroring — the cache is an
    /// optimization, not state.
    pub fn sanitized(self) -> TuneCfg {
        let nr = if self.nr == 16 { 16 } else { 8 };
        let rc = self.row_chunk.clamp(MR, 4096);
        TuneCfg { row_chunk: rc.div_ceil(MR) * MR, nr }
    }
}

/// The search space: every combination is bit-identical within a tier
/// (the property that makes autotuning safe under the determinism
/// contract), so the tuner is free to pick purely on speed.
pub fn candidates() -> Vec<TuneCfg> {
    let mut out = Vec::new();
    for &row_chunk in &[32usize, 64, 128, 256] {
        for &nr in &[8usize, 16] {
            out.push(TuneCfg { row_chunk, nr });
        }
    }
    out
}

/// ⌈log2⌉ bucket (0 for 0/1), so one tuned entry covers the band of
/// shapes that behave alike cache-wise.
fn log2_bucket(v: usize) -> u32 {
    (v.max(1) as u64).next_power_of_two().trailing_zeros()
}

/// Cache key for one (tier, shape class, thread count):
/// `"<tier>/m<⌈log2 m⌉>k<⌈log2 k⌉>n<⌈log2 n⌉>/t<threads>"`.
pub fn cache_key(tier: Tier, threads: usize, m: usize, k: usize, n: usize) -> String {
    format!(
        "{}/m{}k{}n{}/t{}",
        tier.name(),
        log2_bucket(m),
        log2_bucket(k),
        log2_bucket(n),
        threads
    )
}

/// The tuning cache: shape-class keys → winning configs, with
/// load/save. A plain struct so tests and tools can run isolated
/// instances against temp paths; the library GEMM entry points
/// consult one process-global instance via [`lookup`] (lookups only —
/// the global never times anything implicitly).
#[derive(Debug)]
pub struct Tuner {
    entries: BTreeMap<String, TuneCfg>,
    path: PathBuf,
    /// When false, every lookup returns [`TuneCfg::default`] and
    /// [`Tuner::tune_gemm`] is a no-op (the `--no-autotune` hatch).
    pub enabled: bool,
}

impl Tuner {
    /// Empty cache that will save to `path`.
    pub fn new(path: &Path) -> Tuner {
        Tuner { entries: BTreeMap::new(), path: path.to_path_buf(), enabled: true }
    }

    /// Load `path`, degrading silently to an empty cache on a
    /// missing, unreadable, malformed, or schema-mismatched file —
    /// worst case is untuned (default-blocking) kernels, never an
    /// error on the compute path.
    pub fn load(path: &Path) -> Tuner {
        let mut t = Tuner::new(path);
        let Ok(text) = std::fs::read_to_string(path) else {
            return t;
        };
        let Ok(j) = Json::parse(&text) else {
            return t;
        };
        if j.get("schema").and_then(|v| v.as_i64()) != Some(SCHEMA) {
            return t;
        }
        let Some(entries) = j.get("entries").and_then(|v| v.as_obj()) else {
            return t;
        };
        for (key, v) in entries {
            let rc = v.get("row_chunk").and_then(|x| x.as_usize());
            let nr = v.get("nr").and_then(|x| x.as_usize());
            if let (Some(rc), Some(nr)) = (rc, nr) {
                t.entries.insert(key.clone(), TuneCfg { row_chunk: rc, nr }.sanitized());
            }
        }
        t
    }

    /// Persist as compact JSON with sorted keys (BTreeMap order —
    /// byte-deterministic for a given entry set) through the
    /// crash-safe temp+rename seam.
    pub fn save(&self) -> std::io::Result<()> {
        let mut entries = BTreeMap::new();
        for (key, cfg) in &self.entries {
            let mut e = BTreeMap::new();
            e.insert("row_chunk".to_string(), Json::Num(cfg.row_chunk as f64));
            e.insert("nr".to_string(), Json::Num(cfg.nr as f64));
            entries.insert(key.clone(), Json::Obj(e));
        }
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Num(SCHEMA as f64));
        root.insert("entries".to_string(), Json::Obj(entries));
        RealIo.write_atomic(&self.path, &Json::Obj(root).to_string_compact())
    }

    /// Where this cache loads from / saves to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of tuned entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entry has been tuned or loaded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The config for one GEMM call: the tuned winner for this
    /// (tier, shape class, thread count) if present, else the default.
    pub fn lookup(&self, tier: Tier, threads: usize, m: usize, k: usize, n: usize) -> TuneCfg {
        if !self.enabled {
            return TuneCfg::default();
        }
        self.entries.get(&cache_key(tier, threads, m, k, n)).copied().unwrap_or_default()
    }

    /// Record a winner for (tier, shape class, thread count).
    pub fn record(&mut self, tier: Tier, threads: usize, m: usize, k: usize, n: usize, c: TuneCfg) {
        self.entries.insert(cache_key(tier, threads, m, k, n), c.sanitized());
    }

    /// Time every candidate on a synthetic (m,k,n) problem (best of
    /// `reps` after one warmup pass each) and record the winner for
    /// this (tier, shape class, thread count). Which candidate wins
    /// may vary with machine noise — fine, because all candidates
    /// compute identical bits within the tier; only speed differs.
    pub fn tune_gemm(
        &mut self,
        pool: &Pool,
        arena: &mut Arena,
        tier: Tier,
        m: usize,
        k: usize,
        n: usize,
        reps: usize,
    ) -> TuneCfg {
        if !self.enabled {
            return TuneCfg::default();
        }
        let mut rng = crate::util::rng::Rng::new(0xA17);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut c = vec![0f32; m * n];
        let mut best = TuneCfg::default();
        let mut best_t = f64::INFINITY;
        for cfg in candidates() {
            super::gemm::gemm_with(tier, cfg, pool, arena, &a, &b, &mut c, m, k, n, false);
            let mut t_min = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let t0 = Instant::now();
                super::gemm::gemm_with(tier, cfg, pool, arena, &a, &b, &mut c, m, k, n, false);
                t_min = t_min.min(t0.elapsed().as_secs_f64());
            }
            if t_min < best_t {
                best_t = t_min;
                best = cfg;
            }
        }
        std::hint::black_box(&c);
        self.record(tier, pool.threads(), m, k, n, best);
        best
    }
}

// --------------------------------------------- the process-global cache

fn global() -> &'static Mutex<Tuner> {
    static GLOBAL: OnceLock<Mutex<Tuner>> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let path = std::env::var("TRIACCEL_TUNE_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("triaccel_tune.json"));
        let mut t = Tuner::load(&path);
        if std::env::var("TRIACCEL_NO_AUTOTUNE").map(|v| v != "0").unwrap_or(false) {
            t.enabled = false;
        }
        Mutex::new(t)
    })
}

/// Blocking config for one GEMM call, from the process-global cache
/// (loaded once from `TRIACCEL_TUNE_CACHE`). Pure lookup — never
/// times anything.
pub fn lookup(tier: Tier, threads: usize, m: usize, k: usize, n: usize) -> TuneCfg {
    global().lock().unwrap().lookup(tier, threads, m, k, n)
}

/// Enable/disable the process-global cache (the CLI `--no-autotune`).
pub fn set_enabled(on: bool) {
    global().lock().unwrap().enabled = on;
}

/// Is the process-global cache consulted at all?
pub fn enabled() -> bool {
    global().lock().unwrap().enabled
}

/// The process-global cache path (for operator-facing printouts).
pub fn cache_path() -> PathBuf {
    global().lock().unwrap().path.clone()
}

/// Tune (m,k,n) for `tier` on the process-global cache and persist
/// the whole cache. A failed save is returned (not raised): the tuned
/// config still applies in-process, and the cache is an optimization.
pub fn tune_and_save(
    pool: &Pool,
    arena: &mut Arena,
    tier: Tier,
    m: usize,
    k: usize,
    n: usize,
    reps: usize,
) -> (TuneCfg, Option<std::io::Error>) {
    let mut g = global().lock().unwrap();
    let cfg = g.tune_gemm(pool, arena, tier, m, k, n, reps);
    if !g.enabled {
        return (cfg, None);
    }
    match g.save() {
        Ok(()) => (cfg, None),
        Err(e) => (cfg, Some(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("triaccel_tune_test_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn sanitized_clamps_to_legal_configs() {
        assert_eq!(TuneCfg::default().sanitized(), TuneCfg::default());
        assert_eq!(TuneCfg { row_chunk: 0, nr: 0 }.sanitized(), TuneCfg { row_chunk: MR, nr: 8 });
        assert_eq!(
            TuneCfg { row_chunk: 33, nr: 16 }.sanitized(),
            TuneCfg { row_chunk: 36, nr: 16 },
            "row_chunk rounds up to a multiple of MR"
        );
        assert_eq!(TuneCfg { row_chunk: 1 << 20, nr: 12 }.sanitized().row_chunk, 4096);
        for c in candidates() {
            assert_eq!(c.sanitized(), c, "candidates must already be legal");
        }
    }

    #[test]
    fn cache_key_buckets_by_log2_tier_and_threads() {
        let a = cache_key(Tier::Scalar, 4, 8192, 144, 32);
        assert_eq!(a, "scalar/m13k8n5/t4");
        // Same bucket for nearby shapes, different for tier/threads.
        assert_eq!(cache_key(Tier::Scalar, 4, 8000, 130, 31), a);
        assert_ne!(cache_key(Tier::Avx2, 4, 8192, 144, 32), a);
        assert_ne!(cache_key(Tier::Scalar, 2, 8192, 144, 32), a);
    }

    #[test]
    fn cache_roundtrips_through_disk() {
        let p = temp_path("roundtrip");
        let mut t = Tuner::new(&p);
        assert!(t.is_empty());
        t.record(Tier::Scalar, 2, 100, 50, 30, TuneCfg { row_chunk: 64, nr: 16 });
        t.record(Tier::Avx2, 4, 8192, 144, 32, TuneCfg { row_chunk: 256, nr: 8 });
        t.save().unwrap();
        let back = Tuner::load(&p);
        std::fs::remove_file(&p).ok();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back.lookup(Tier::Scalar, 2, 100, 50, 30),
            TuneCfg { row_chunk: 64, nr: 16 },
            "reloaded cache must select the identical config"
        );
        assert_eq!(back.lookup(Tier::Avx2, 4, 8192, 144, 32), TuneCfg { row_chunk: 256, nr: 8 });
        // A shape outside the tuned classes falls back to the default.
        assert_eq!(back.lookup(Tier::Scalar, 8, 7, 7, 7), TuneCfg::default());
    }

    #[test]
    fn load_degrades_to_empty_on_bad_files() {
        let p = temp_path("bad");
        assert!(Tuner::load(&p).is_empty(), "missing file");
        std::fs::write(&p, "not json at all").unwrap();
        assert!(Tuner::load(&p).is_empty(), "malformed file");
        let wrong = "{\"schema\":99,\"entries\":{\"x\":{\"row_chunk\":8,\"nr\":8}}}";
        std::fs::write(&p, wrong).unwrap();
        assert!(Tuner::load(&p).is_empty(), "unknown schema self-invalidates");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn disabled_tuner_always_returns_the_default() {
        let p = temp_path("disabled");
        let mut t = Tuner::new(&p);
        t.record(Tier::Scalar, 1, 64, 64, 64, TuneCfg { row_chunk: 32, nr: 16 });
        t.enabled = false;
        assert_eq!(t.lookup(Tier::Scalar, 1, 64, 64, 64), TuneCfg::default());
        let pool = Pool::new(1);
        let mut arena = Arena::new();
        assert_eq!(
            t.tune_gemm(&pool, &mut arena, Tier::Scalar, 16, 8, 8, 1),
            TuneCfg::default(),
            "disabled tuner must not search"
        );
    }

    #[test]
    fn tune_gemm_records_a_candidate_for_the_shape_class() {
        let p = temp_path("tune");
        let mut t = Tuner::new(&p);
        let pool = Pool::new(1);
        let mut arena = Arena::new();
        let best = t.tune_gemm(&pool, &mut arena, Tier::Scalar, 48, 16, 24, 1);
        assert!(candidates().contains(&best), "winner comes from the search space");
        assert_eq!(t.lookup(Tier::Scalar, 1, 48, 16, 24), best, "winner is recorded");
        assert_eq!(t.lookup(Tier::Scalar, 1, 40, 12, 20), best, "same shape class hits");
    }
}
