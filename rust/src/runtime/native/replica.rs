//! Deterministic data-parallel replicas inside one training job.
//!
//! A [`ReplicaGroup`] holds N independent [`Exec`] contexts (own pool +
//! own arena, each with a [`super::pool::budget_threads`]-style share
//! of the compute budget) and runs one optimizer step over a batch
//! split into **fixed canonical shards**. The invariant that makes
//! elastic replica counts safe is the same one [`super::pool`] uses for
//! threads, lifted one level up:
//!
//! * The batch always decomposes into `S = min(4, n)` contiguous
//!   shards whose boundaries depend only on `n` — never on how many
//!   replicas are live.
//! * A live replica *owns* a contiguous run of shards
//!   (`owner(s) = s·live/S`) and executes them with its own `Exec`;
//!   per-shard compute is bit-identical no matter which replica runs
//!   it (the pool's thread-count invariance covers the differing
//!   per-replica thread shares).
//! * Every cross-shard reduction — BN sufficient statistics, the CE
//!   loss sum, and the parameter-gradient reduction — folds the
//!   per-shard partials in **ascending canonical shard order**.
//!
//! Replicas therefore only decide *where* a shard computes, never
//! *what* it computes, and N=1, N=2, and N=4 produce bit-identical
//! parameter trajectories; the control plane may shed or restore
//! replicas mid-run ([`ReplicaGroup::set_live`]) without perturbing a
//! single bit of the training trajectory. The property suite in
//! `tests/prop_replicas.rs` pins this next to the thread-count suite.
//!
//! Numerics: the sharded path computes BN statistics in their one-pass
//! sufficient-statistics form (Σx, Σx² in f64) and the CE loss as
//! per-shard f64 partial sums over a shared `1/n_total` factor, whereas
//! the fused single-engine path ([`super::graph`]) uses two-pass BN
//! and a single whole-batch CE walk. The replica path is therefore its
//! own pinned numeric contract — bit-identical across replica counts
//! and within float tolerance of the fused path, not bit-equal to it
//! (see docs/DETERMINISM.md).

use std::sync::Mutex;

use anyhow::Result;

use super::graph::{self, Aux, FwdScalars, NodeCache, Plan};
use super::ops;
use super::Exec;
use crate::manifest::{ModelEntry, NodeOp, NODE_INPUT_IMAGE};
use crate::runtime::backend::{Backend, ModelState};
use crate::runtime::{Batch, EvalResult, StepCtrl, TrainOutputs};

/// Elements per batch image (the [`Batch`] contract: 32×32×3 NHWC).
const IMG_ELEMS: usize = 32 * 32 * 3;

/// Canonical shard count cap. Every batch splits into
/// `min(MAX_SHARDS, n)` shards regardless of the live replica count,
/// so the reduction tree is a pure function of the batch size.
pub const MAX_SHARDS: usize = 4;

/// The fixed contiguous `(start, len)` decomposition of an `n`-sample
/// batch into canonical shards. Depends only on `n`.
pub fn shard_ranges(n: usize) -> Vec<(usize, usize)> {
    let s_count = MAX_SHARDS.min(n.max(1));
    let base = n / s_count;
    let rem = n % s_count;
    (0..s_count)
        .map(|s| (s * base + s.min(rem), base + usize::from(s < rem)))
        .collect()
}

/// Which live replica executes canonical shard `s` of `s_count`:
/// `s·live/s_count`, a non-decreasing map that hands each replica a
/// contiguous run of shards (possibly empty when `live > s_count`).
pub fn shard_owner(s: usize, s_count: usize, live: usize) -> usize {
    s * live.max(1) / s_count.max(1)
}

/// Per-shard execution context for one step: the forward caches, the
/// loss scalars, the reverse-walk cotangent slots, and this shard's
/// (scaled) parameter-gradient contributions.
struct ShardCtx {
    start: usize,
    len: usize,
    caches: Vec<NodeCache>,
    scal: FwdScalars,
    grad: Vec<Option<Vec<f32>>>,
    grads: Vec<Vec<f32>>,
    /// Dummy BN-state sink for [`graph::forward_node`] — BN nodes never
    /// route through it on this path, so this stays untouched.
    ns: Vec<Vec<f32>>,
}

/// N data-parallel engine instances executing one job's steps over
/// fixed canonical batch shards.
pub struct ReplicaGroup {
    execs: Vec<Exec>,
    live: usize,
    threads_each: usize,
}

impl ReplicaGroup {
    /// A group of `replicas` engines (clamped to ≥ 1), each computing
    /// with `threads_each` pool workers. All replicas start live.
    pub fn new(replicas: usize, threads_each: usize) -> ReplicaGroup {
        let cap = replicas.max(1);
        ReplicaGroup {
            execs: (0..cap).map(|_| Exec::new(threads_each)).collect(),
            live: cap,
            threads_each: threads_each.max(1),
        }
    }

    /// Total replica engines held (the elastic ceiling).
    pub fn capacity(&self) -> usize {
        self.execs.len()
    }

    /// Replicas currently executing shards.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Pool workers per replica engine.
    pub fn threads_each(&self) -> usize {
        self.threads_each
    }

    /// Elastically set the live replica count (clamped to
    /// `1..=capacity`). By the canonical-shard invariant this changes
    /// wall-clock and aggregate memory only — never the numerics.
    pub fn set_live(&mut self, n: usize) {
        self.live = n.clamp(1, self.execs.len());
    }
}

/// Run `f` once per shard with exclusive access to the shard's context
/// and its owning replica's `Exec` — inline when one replica is live,
/// scoped threads (one per replica that owns work) otherwise. Shard
/// ownership is the contiguous [`shard_owner`] map, so the contexts
/// split into disjoint per-replica sub-slices.
fn run_sharded<F>(execs: &mut [Exec], live: usize, ctxs: &mut [ShardCtx], f: F)
where
    F: Fn(&mut Exec, &mut ShardCtx) + Sync,
{
    let s_count = ctxs.len();
    let live = live.clamp(1, execs.len());
    if live == 1 || s_count <= 1 {
        let ex = &mut execs[0];
        for ctx in ctxs.iter_mut() {
            f(&mut *ex, ctx);
        }
        return;
    }
    let mut parts: Vec<(&mut Exec, &mut [ShardCtx])> = Vec::with_capacity(live);
    let mut rest_ctx = ctxs;
    let mut rest_ex = &mut execs[..live];
    let mut s0 = 0usize;
    for r in 0..live {
        let cnt = (s0..s_count).take_while(|&s| shard_owner(s, s_count, live) == r).count();
        let (head_ctx, tail_ctx) = rest_ctx.split_at_mut(cnt);
        let (head_ex, tail_ex) = rest_ex.split_at_mut(1);
        if cnt > 0 {
            parts.push((&mut head_ex[0], head_ctx));
        }
        rest_ctx = tail_ctx;
        rest_ex = tail_ex;
        s0 += cnt;
    }
    let fr = &f;
    // detlint: allow(d3) — replica lanes follow pool.rs's discipline:
    // each scoped thread executes a disjoint, contiguous shard range
    // against shard-local buffers, and every cross-shard reduction
    // folds on the caller in ascending canonical shard order after the
    // scope joins — spawn/completion order can never reach the numbers.
    std::thread::scope(|sc| {
        let mut parts = parts.into_iter();
        let first = parts.next();
        for (ex, group) in parts {
            sc.spawn(move || {
                for ctx in group.iter_mut() {
                    fr(&mut *ex, ctx);
                }
            });
        }
        if let Some((ex, group)) = first {
            for ctx in group.iter_mut() {
                fr(&mut *ex, ctx);
            }
        }
    });
}

/// One fused SGD+momentum training step over canonical shards. Same
/// observable contract as [`graph::train_step`] (loss-scaled grads,
/// overflow gating, per-layer stats, BN state swap), with all
/// cross-shard math reduced in ascending canonical shard order.
pub fn train_step(
    group: &mut ReplicaGroup,
    entry: &ModelEntry,
    st: &mut ModelState,
    batch: &Batch,
    ctrl: &StepCtrl,
) -> Result<TrainOutputs> {
    let plan = Plan::build(entry)?;
    let n = batch.n;
    let ranges = shard_ranges(n);
    let n_nodes = entry.nodes.len();
    let n_params = st.params.len();
    let live = group.live.clamp(1, group.execs.len());

    let mut ctxs: Vec<ShardCtx> = ranges
        .iter()
        .map(|&(start, len)| ShardCtx {
            start,
            len,
            caches: Vec::with_capacity(n_nodes),
            scal: FwdScalars::default(),
            grad: (0..n_nodes).map(|_| None).collect(),
            grads: (0..n_params).map(|_| Vec::new()).collect(),
            ns: Vec::new(),
        })
        .collect();
    let mut new_state: Vec<Vec<f32>> = (0..entry.state_shapes.len()).map(|_| Vec::new()).collect();

    // ---- forward: node-major; shards run in parallel across live
    // replicas, BN nodes synchronize on globally reduced statistics.
    for i in 0..n_nodes {
        let node = &entry.nodes[i];
        match node.op {
            NodeOp::Bn { gamma, beta, state: sidx } => {
                let din = plan.nd[i].din;
                let (c, hw) = (din.c, din.h * din.w);
                let rows_total = n * hw;
                let input = node.input as usize; // BN never reads the images
                // Phase 1 — per-shard sufficient statistics, folded in
                // ascending canonical shard order (f64 throughout).
                let mut sum = vec![0f64; c];
                let mut sq = vec![0f64; c];
                for ctx in ctxs.iter() {
                    ops::bn_partial_into(&ctx.caches[input].act, ctx.len * hw, c, &mut sum, &mut sq);
                }
                let mut mean_g = vec![0f32; c];
                let mut inv_g = vec![0f32; c];
                let mut new_rm = group.execs[0].arena.take(c);
                let mut new_rv = group.execs[0].arena.take(c);
                ops::bn_finalize_stats(
                    &sum,
                    &sq,
                    rows_total,
                    &st.state[sidx],
                    &st.state[sidx + 1],
                    &mut mean_g,
                    &mut inv_g,
                    &mut new_rm,
                    &mut new_rv,
                );
                new_state[sidx] = new_rm;
                new_state[sidx + 1] = new_rv;
                // Phase 2 — every shard normalizes against the shared
                // global statistics (cached per shard for the VJP).
                let (params, mean_ref, inv_ref) = (&st.params, &mean_g, &inv_g);
                run_sharded(&mut group.execs, live, &mut ctxs, |ex, ctx| {
                    let rows = ctx.len * hw;
                    let mut out = ex.arena.take(rows * c);
                    let mut mean = ex.arena.take(c);
                    mean.copy_from_slice(mean_ref);
                    let mut inv = ex.arena.take(c);
                    inv.copy_from_slice(inv_ref);
                    ops::bn_apply_into(
                        &ctx.caches[input].act,
                        rows,
                        c,
                        &params[gamma],
                        &params[beta],
                        &mean,
                        &inv,
                        &mut out,
                    );
                    ctx.caches.push(NodeCache { act: out, aux: Aux::Bn { mean, inv } });
                });
            }
            _ => {
                let (params, state, codes) = (&st.params, &st.state, &ctrl.codes[..]);
                run_sharded(&mut group.execs, live, &mut ctxs, |ex, ctx| {
                    let x = &batch.x[ctx.start * IMG_ELEMS..(ctx.start + ctx.len) * IMG_ELEMS];
                    let y = &batch.y[ctx.start..ctx.start + ctx.len];
                    let ShardCtx { caches, scal, ns, len, .. } = ctx;
                    graph::forward_node(
                        ex, entry, &plan, i, params, state, x, y, *len, n, codes, true, caches,
                        ns, scal,
                    );
                });
            }
        }
    }

    // ---- backward: node-major in reverse; BN nodes reduce their
    // parameter gradients globally before any shard computes dx.
    for i in (0..n_nodes).rev() {
        let node = &entry.nodes[i];
        match node.op {
            NodeOp::Bn { gamma, beta, state: _ } => {
                let din = plan.nd[i].din;
                let (c, hw) = (din.c, din.h * din.w);
                let rows_total = n * hw;
                let input = node.input as usize;
                // Phase 1 — per-shard Σg / Σg·x̂, ascending shard order.
                let mut db = vec![0f64; c];
                let mut dg = vec![0f64; c];
                for ctx in ctxs.iter() {
                    // detlint: allow(d6) — the reverse walk visits nodes
                    // in descending id order, so every consumer already
                    // deposited this node's cotangent in `grad[i]`.
                    let g = ctx.grad[i].as_ref().expect("bn cotangent deposited");
                    let (mean, inv) = match &ctx.caches[i].aux {
                        Aux::Bn { mean, inv } => (mean, inv),
                        _ => unreachable!("bn node caches bn aux"),
                    };
                    ops::bn_bwd_partial_into(
                        &ctx.caches[input].act,
                        g,
                        ctx.len * hw,
                        c,
                        mean,
                        inv,
                        &mut db,
                        &mut dg,
                    );
                }
                let dgamma: Vec<f32> = dg.iter().map(|&v| v as f32).collect();
                let dbeta: Vec<f32> = db.iter().map(|&v| v as f32).collect();
                // The globally reduced BN grads ride on shard 0, so the
                // generic ascending-shard gradient reduction reproduces
                // them verbatim (other shards contribute nothing).
                ctxs[0].grads[gamma] = dgamma.clone();
                ctxs[0].grads[beta] = dbeta.clone();
                // Phase 2 — per-shard dx against the global sums.
                let (params, dgm, dbt) = (&st.params, &dgamma, &dbeta);
                let input_id = node.input;
                run_sharded(&mut group.execs, live, &mut ctxs, |ex, ctx| {
                    let rows = ctx.len * hw;
                    // detlint: allow(d6) — same invariant as phase 1:
                    // the cotangent was deposited before this node ran.
                    let g = ctx.grad[i].take().expect("bn cotangent deposited");
                    let (mean, inv) = match &ctx.caches[i].aux {
                        Aux::Bn { mean, inv } => (mean, inv),
                        _ => unreachable!("bn node caches bn aux"),
                    };
                    let mut dx = ex.arena.take(rows * c);
                    ops::bn_bwd_apply_into(
                        &ctx.caches[input].act,
                        &g,
                        rows,
                        c,
                        &params[gamma],
                        mean,
                        inv,
                        dgm,
                        dbt,
                        rows_total,
                        &mut dx,
                    );
                    ex.arena.put(g);
                    graph::send(&mut ex.arena, &mut ctx.grad, input_id, dx);
                });
            }
            _ => {
                let (params, codes, loss_scale) = (&st.params, &ctrl.codes[..], ctrl.loss_scale);
                run_sharded(&mut group.execs, live, &mut ctxs, |ex, ctx| {
                    let ShardCtx { caches, scal, grad, grads, len, .. } = ctx;
                    graph::backward_node(
                        ex,
                        entry,
                        &plan,
                        i,
                        caches,
                        &scal.dlogits,
                        params,
                        codes,
                        loss_scale,
                        *len,
                        grad,
                        grads,
                    );
                });
            }
        }
    }

    // ---- ordered gradient reduction: fold shard contributions in
    // ascending canonical shard order, elementwise in f32 (exactly the
    // pool.rs chunk-reduction discipline, one level up).
    let mut grads: Vec<Vec<f32>> = (0..n_params).map(|_| Vec::new()).collect();
    let mut surplus: Vec<Vec<f32>> = Vec::new();
    for (pi, total) in grads.iter_mut().enumerate() {
        for ctx in ctxs.iter_mut() {
            let g = std::mem::take(&mut ctx.grads[pi]);
            if g.is_empty() {
                continue;
            }
            if total.is_empty() {
                *total = g;
            } else {
                for (t, &v) in total.iter_mut().zip(g.iter()) {
                    *t += v;
                }
                surplus.push(g);
            }
        }
    }
    graph::unscale_grads(&mut grads, ctrl.loss_scale);
    let overflow = grads.iter().any(|g| g.iter().any(|v| !v.is_finite()));
    let (grad_var, grad_norm) = graph::layer_stats(entry, &grads);
    graph::apply_update(entry, st, &grads, ctrl, overflow);
    if !overflow {
        for (dst, src) in st.state.iter_mut().zip(new_state.iter_mut()) {
            std::mem::swap(dst, src);
        }
    }

    // ---- loss/accuracy: shard partials, ascending shard order.
    let mut loss_sum = 0f64;
    let mut correct = 0i64;
    for ctx in ctxs.iter() {
        loss_sum += ctx.scal.loss_sum;
        correct += ctx.scal.correct;
    }
    let loss = (loss_sum / n as f64) as f32;

    // ---- release every per-shard buffer to its owner's arena, and
    // the shared buffers to replica 0's.
    run_sharded(&mut group.execs, live, &mut ctxs, |ex, ctx| {
        graph::release_caches(ex, std::mem::take(&mut ctx.caches));
        ex.arena.put(std::mem::take(&mut ctx.scal.dlogits));
    });
    let ex0 = &mut group.execs[0];
    ex0.arena.put_all(grads);
    ex0.arena.put_all(surplus);
    ex0.arena.put_all(new_state);
    Ok(TrainOutputs { loss, correct, grad_var, grad_norm, overflow })
}

/// [`Backend`] over a [`ReplicaGroup`]: replicated data-parallel
/// training with elastic live-replica control. Eval and curvature
/// probes run single-engine on replica 0 — they are read-only and
/// already bit-identical to the fused path.
pub struct ReplicaBackend {
    group: Mutex<ReplicaGroup>,
}

impl ReplicaBackend {
    pub fn new(replicas: usize, threads_each: usize) -> ReplicaBackend {
        ReplicaBackend { group: Mutex::new(ReplicaGroup::new(replicas, threads_each)) }
    }
}

impl Backend for ReplicaBackend {
    fn name(&self) -> &'static str {
        "native-replica"
    }

    fn supports(&self, entry: &ModelEntry) -> bool {
        !entry.nodes.is_empty()
    }

    fn init(&self, entry: &ModelEntry, seed: i32) -> Result<ModelState> {
        graph::init(entry, seed)
    }

    fn train_step(
        &self,
        entry: &ModelEntry,
        st: &mut ModelState,
        batch: &Batch,
        ctrl: &StepCtrl,
    ) -> Result<TrainOutputs> {
        let mut group = self.group.lock().unwrap();
        train_step(&mut group, entry, st, batch, ctrl)
    }

    fn eval_batch(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        codes: &[i32],
    ) -> Result<EvalResult> {
        let mut group = self.group.lock().unwrap();
        graph::eval_batch(&mut group.execs[0], entry, st, batch, codes)
    }

    fn curv_step(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        probes: &mut [Vec<f32>],
        codes: &[i32],
    ) -> Result<Vec<f32>> {
        let mut group = self.group.lock().unwrap();
        graph::curv_step(&mut group.execs[0], entry, st, batch, probes, codes)
    }

    fn replica_capacity(&self) -> usize {
        self.group.lock().unwrap().capacity()
    }

    fn live_replicas(&self) -> usize {
        self.group.lock().unwrap().live()
    }

    fn set_live_replicas(&self, n: usize) {
        self.group.lock().unwrap().set_live(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{BF16, FP16, FP32};
    use crate::runtime::native::builtin_manifest;
    use crate::util::rng::Rng;

    fn entry(key: &str) -> ModelEntry {
        builtin_manifest().model(key).unwrap().clone()
    }

    fn rand_batch(n: usize, classes: u64, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
        Batch::new(x, y)
    }

    fn mixed_ctrl(e: &ModelEntry, step: usize) -> StepCtrl {
        let mut ctrl = StepCtrl::uniform(e.num_layers, FP32, 0.05, 5e-4);
        for (l, code) in ctrl.codes.iter_mut().enumerate() {
            *code = match (l + step) % 3 {
                0 => FP32,
                1 => FP16,
                _ => BF16,
            };
        }
        ctrl.loss_scale = if step % 2 == 0 { 1.0 } else { 1024.0 };
        ctrl
    }

    #[test]
    fn shards_are_fixed_contiguous_and_cover_the_batch() {
        for n in 1..=33usize {
            let ranges = shard_ranges(n);
            assert_eq!(ranges.len(), MAX_SHARDS.min(n));
            let mut next = 0usize;
            for &(start, len) in &ranges {
                assert_eq!(start, next, "contiguous at n={n}");
                assert!(len > 0, "no empty shards at n={n}");
                next = start + len;
            }
            assert_eq!(next, n, "covers the batch at n={n}");
        }
        // Ownership is non-decreasing and in range for every live count.
        for live in 1..=4usize {
            let mut prev = 0;
            for s in 0..4 {
                let o = shard_owner(s, 4, live);
                assert!(o >= prev && o < live);
                prev = o;
            }
        }
    }

    #[test]
    fn replica_counts_are_bit_identical() {
        let e = entry("tiny_cnn_c10");
        let base = ReplicaBackend::new(1, 1);
        let mut st1 = base.init(&e, 11).unwrap();
        let mut outs1 = Vec::new();
        for step in 0..4 {
            let batch = rand_batch(10, 10, 90 + step as u64);
            let out = base.train_step(&e, &mut st1, &batch, &mixed_ctrl(&e, step)).unwrap();
            outs1.push((out.loss.to_bits(), out.correct, out.overflow));
        }
        for replicas in [2usize, 4] {
            let b = ReplicaBackend::new(replicas, 1);
            assert_eq!(b.replica_capacity(), replicas);
            let mut st = b.init(&e, 11).unwrap();
            for step in 0..4 {
                let batch = rand_batch(10, 10, 90 + step as u64);
                let out = b.train_step(&e, &mut st, &batch, &mixed_ctrl(&e, step)).unwrap();
                assert_eq!(
                    (out.loss.to_bits(), out.correct, out.overflow),
                    outs1[step],
                    "{replicas} replicas, step {step}"
                );
            }
            assert_eq!(st.params, st1.params, "{replicas} replicas: params diverged");
            assert_eq!(st.mom, st1.mom, "{replicas} replicas: momentum diverged");
            assert_eq!(st.state, st1.state, "{replicas} replicas: BN state diverged");
        }
    }

    #[test]
    fn elastic_live_changes_never_perturb_the_trajectory() {
        let e = entry("resnet_mini_c10");
        let fixed = ReplicaBackend::new(1, 2);
        let elastic = ReplicaBackend::new(4, 1);
        let mut st_f = fixed.init(&e, 5).unwrap();
        let mut st_e = elastic.init(&e, 5).unwrap();
        // Shed/restore on every step — the canonical shards make every
        // live count compute the same bits.
        for (step, live) in [4usize, 1, 3, 2, 4, 1].into_iter().enumerate() {
            elastic.set_live_replicas(live);
            assert_eq!(elastic.live_replicas(), live);
            let batch = rand_batch(9, 10, 700 + step as u64);
            let ctrl = mixed_ctrl(&e, step);
            let a = fixed.train_step(&e, &mut st_f, &batch, &ctrl).unwrap();
            let b = elastic.train_step(&e, &mut st_e, &batch, &ctrl).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss");
            assert_eq!(a.grad_norm, b.grad_norm, "step {step} grad_norm");
        }
        assert_eq!(st_f.params, st_e.params, "elastic moves changed the trajectory");
        assert_eq!(st_f.state, st_e.state);
    }

    #[test]
    fn set_live_clamps_to_capacity() {
        let b = ReplicaBackend::new(2, 1);
        b.set_live_replicas(0);
        assert_eq!(b.live_replicas(), 1);
        b.set_live_replicas(9);
        assert_eq!(b.live_replicas(), 2);
    }

    #[test]
    fn eval_and_curv_match_the_fused_single_engine() {
        let e = entry("tiny_cnn_c10");
        let rep = ReplicaBackend::new(2, 1);
        let single = crate::runtime::native::NativeBackend::with_threads(1);
        let st = rep.init(&e, 3).unwrap();
        let st2 = single.init(&e, 3).unwrap();
        assert_eq!(st.params, st2.params, "init is backend-independent");
        let batch = rand_batch(16, 10, 42);
        let codes = vec![FP32; e.num_layers];
        let a = rep.eval_batch(&e, &st, &batch, &codes).unwrap();
        let b = single.eval_batch(&e, &st2, &batch, &codes).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.correct, b.correct);
        assert_eq!(a.total, b.total);
    }
}
