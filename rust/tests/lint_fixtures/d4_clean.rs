fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>() // detlint: ordered — sequential sum in slice order.
}

fn peak(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::MIN, f32::max)
}

fn fma_tile_x86(acc: __m256, x: __m256, y: __m256) -> __m256 {
    // detlint: ordered — lanes are independent output columns; each
    // lane's accumulation chain stays in ascending-k order.
    _mm256_fmadd_ps(x, y, acc)
}

fn fma_tile_neon(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
    vfmaq_f32(acc, x, y) // detlint: ordered — lanes are independent columns, ascending-k chain.
}
