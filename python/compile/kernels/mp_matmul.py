"""`mp_matmul` — mixed-precision tiled matmul Pallas kernel.

The compute hot-spot for the dense layers: inputs are rounded to the
runtime-selected precision *at the tile boundary* (where a real TPU would
pick the bf16 vs f32 HBM→VMEM layout), then multiplied with an **fp32 VMEM
accumulator** — the Triton "fp16 in, fp32 accumulate" idiom re-expressed
for the MXU (DESIGN.md §4).

Grid = (M/BM, N/BN, K/BK) with the K axis innermost so the accumulator
block stays resident in VMEM across the K sweep; the qdq of each tile fuses
into the load. Block shapes default to MXU-aligned 128×128×128.

Backward (custom_vjp) recomputes the two transposed mixed-precision
matmuls with the same code — AMP semantics for dense layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BM, BN, BK = 128, 128, 128


def _round(x, code):
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    b16 = x.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(code == ref.FP16, f16, jnp.where(code == ref.BF16, b16, x))


def _mm_kernel(code_ref, x_ref, w_ref, o_ref):
    code = code_ref[0]
    xq = _round(x_ref[...], code)
    wq = _round(w_ref[...], code)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        o_ref[...] += acc


def _pad2(a, bm, bk):
    m, k = a.shape
    pm, pk = (-m) % bm, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    return a


def _mp_matmul_raw(x: jnp.ndarray, w: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    # Small problems run as a single block (grid 1×1×1) — padding a tiny
    # dense head up to 128³ would waste the interpreter's time.
    bm, bn, bk = min(BM, m), min(BN, n), min(BK, k)
    xp, wp = _pad2(x.astype(jnp.float32), bm, bk), _pad2(w.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, l: (0,)),
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(code.reshape(1).astype(jnp.int32), xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def mp_matmul(x: jnp.ndarray, w: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """x @ w with both operands rounded to `code`, fp32 accumulation.

    Matches `ref.mp_matmul_ref` (allclose — accumulation order differs
    across tiles).
    """
    return _mp_matmul_raw(x, w, code)


def _fwd(x, w, code):
    return _mp_matmul_raw(x, w, code), (x, w, code)


def _bwd(res, g):
    x, w, code = res
    # AMP backward: the two grad matmuls also run in compute precision.
    dx = _mp_matmul_raw(g, w.T, code)
    dw = _mp_matmul_raw(x.T, g, code)
    return dx, dw, None


mp_matmul.defvjp(_fwd, _bwd)
