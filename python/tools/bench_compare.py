#!/usr/bin/env python3
"""Diff two BENCH_native.json reports (stdlib only).

Usage: bench_compare.py --current BENCH_native.json \
                        --baseline /path/to/baseline.json \
                        [--warn-pct 25] [--strict] [--pin REGEX ...]

Matches result rows by `name` and compares `mean_s` per row:

* slower than the baseline by more than --warn-pct → a `WARN` line;
* faster by more than --warn-pct → an `improved` line;
* within the band → `ok`.

Also renders the scalar-vs-SIMD speedup table from the current
report's per-tier `gemm(MxKxN)[tier]` rows, so the CI log shows the
dispatch win at a glance.

Two verdict modes:

* Default: **warn-only**. Micro-benchmark timings on shared CI runners
  are far too noisy to gate a merge on every row, and the committed
  baseline may have been recorded on different hardware. The exit code
  is 0 whenever both files parse (non-zero on a malformed/unreadable
  report) — thresholds shape the log, not the verdict.
* `--strict`: the *pinned* rows become a gate. A pinned row (name
  fullmatching any `--pin` regex; default: the hot-path
  `train_step(...)` and dispatch `gemm(MxKxN)` rows) that regresses
  beyond --warn-pct exits 1. Pins are deliberately few and chosen for
  stability — the strict gate catches a real hot-path cliff, not
  runner jitter on a 2µs controller row. An empty-baseline (seed stub)
  report never fails strict mode; refresh the baseline first.

To refresh the baseline, download `BENCH_native.json` from a CI bench
artifact (or run `cargo bench --bench micro` locally) and commit it at
the repo root as `BENCH_baseline.json` (`BENCH_native.json` itself is
gitignored — the bench overwrites it).
"""

import argparse
import json
import re
import sys

TIER_ROW_RE = re.compile(r"^(gemm\([0-9x]+\))\[([a-z0-9]+)\]$")

# Default strict-mode pins: the end-to-end hot path (train steps at any
# replica count) and the tuned-dispatch GEMM row. Everything else stays
# warn-only even under --strict.
DEFAULT_PINS = [
    r"train_step\(.*\)",
    r"gemm\([0-9x]+\)",
]


def load_report(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"bench_compare: cannot read {path}: {e}")
    if not isinstance(doc, dict) or not isinstance(doc.get("results"), list):
        sys.exit(f"bench_compare: {path}: expected an object with a `results` array")
    rows = {}
    for row in doc["results"]:
        name, mean = row.get("name"), row.get("mean_s")
        if isinstance(name, str) and isinstance(mean, (int, float)) and mean > 0:
            rows[name] = float(mean)
    return doc, rows


def fmt_s(seconds):
    if seconds < 1e-6:
        return f"{seconds * 1e9:.0f}ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f}µs"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds:.3f}s"


def compare(cur_rows, base_rows, warn_pct, pins):
    warns = 0
    failures = []
    shared = [n for n in cur_rows if n in base_rows]
    for name in shared:
        cur, base = cur_rows[name], base_rows[name]
        delta_pct = (cur / base - 1.0) * 100.0
        pinned = any(p.fullmatch(name) for p in pins)
        if delta_pct > warn_pct:
            warns += 1
            verdict = "FAIL slower [pinned]" if pinned else "WARN slower"
            if pinned:
                failures.append((name, delta_pct))
        elif delta_pct < -warn_pct:
            verdict = "improved"
        else:
            verdict = "ok [pinned]" if pinned else "ok"
        print(
            f"  {name:<44} {fmt_s(base):>10} -> {fmt_s(cur):>10} "
            f"{delta_pct:+7.1f}%  {verdict}"
        )
    for name in cur_rows:
        if name not in base_rows:
            print(f"  {name:<44} {'—':>10} -> {fmt_s(cur_rows[name]):>10}  new row")
    for name in base_rows:
        if name not in cur_rows:
            print(f"  {name:<44} {fmt_s(base_rows[name]):>10} ->   (dropped)")
    return warns, len(shared), failures


def speedup_table(cur_rows):
    # shape -> {tier: mean_s} from `gemm(MxKxN)[tier]` rows.
    by_shape = {}
    for name, mean in cur_rows.items():
        m = TIER_ROW_RE.match(name)
        if m:
            by_shape.setdefault(m.group(1), {})[m.group(2)] = mean
    printed = False
    for shape in sorted(by_shape):
        tiers = by_shape[shape]
        scalar = tiers.get("scalar")
        if scalar is None:
            continue
        for tier in sorted(t for t in tiers if t != "scalar"):
            if not printed:
                print("scalar-vs-SIMD speedups (current report):")
                printed = True
            print(f"  {shape:<28} {tier:>6}: {scalar / tiers[tier]:5.2f}x")
    if not printed:
        print("no per-tier gemm rows in the current report (quick mode or scalar-only host)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="freshly produced BENCH_native.json")
    ap.add_argument("--baseline", required=True, help="committed baseline report")
    ap.add_argument(
        "--warn-pct",
        type=float,
        default=25.0,
        help="percent mean_s regression that draws a WARN line (default 25)",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit 1 when a pinned row regresses beyond --warn-pct",
    )
    ap.add_argument(
        "--pin",
        action="append",
        default=None,
        metavar="REGEX",
        help="row-name regex (fullmatch) gated under --strict; repeatable "
        "(default: the train_step and dispatch gemm rows)",
    )
    args = ap.parse_args()

    cur_doc, cur_rows = load_report(args.current)
    _base_doc, base_rows = load_report(args.baseline)
    try:
        pins = [re.compile(p) for p in (args.pin or DEFAULT_PINS)] if args.strict else []
    except re.error as e:
        sys.exit(f"bench_compare: bad --pin regex: {e}")

    mode = cur_doc.get("mode", "?")
    print(f"bench_compare: {len(cur_rows)} current rows (mode={mode}), {len(base_rows)} baseline rows")
    failures = []
    if not base_rows:
        print("baseline has no timed rows (seed stub) — nothing to diff; refresh it from a CI artifact")
    else:
        warns, shared, failures = compare(cur_rows, base_rows, args.warn_pct, pins)
        print(f"compared {shared} shared row(s): {warns} above the {args.warn_pct:.0f}% warn band")
    speedup_table(cur_rows)
    if args.strict:
        if failures:
            for name, delta in failures:
                print(f"bench_compare: STRICT FAIL: {name} regressed {delta:+.1f}%")
            sys.exit(1)
        print("bench_compare: strict — no pinned row regressed; exit 0")
    else:
        print("bench_compare: warn-only — exit 0")


if __name__ == "__main__":
    main()
