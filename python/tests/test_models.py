"""Model zoo: shapes, parameter accounting, BN state, precision plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.kernels import api


def _batch(b=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, 32, 32, 3), dtype=np.float32))
    return x


@pytest.mark.parametrize("name", ["tiny_cnn", "resnet18", "effnet_lite"])
def test_build_and_forward_shapes(name):
    m = models.build(name, num_classes=10)
    x = _batch(2)
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    logits, new_state = m.apply(m.params, m.state, x, codes, train=True)
    assert logits.shape == (2, 10)
    assert len(new_state) == len(m.state)
    assert all(a.shape == b.shape for a, b in zip(new_state, m.state))


def test_resnet18_matches_paper_scale():
    m = models.build("resnet18", num_classes=10)
    # He et al. CIFAR ResNet-18 ≈ 11.17M params; paper reports ~11.2M-class.
    assert 11_000_000 < m.param_count < 11_300_000
    assert m.num_layers == 21  # 17 convs + 3 downsample + head


def test_num_classes_changes_head_only():
    m10 = models.build("resnet18", num_classes=10)
    m100 = models.build("resnet18", num_classes=100)
    assert m100.param_count - m10.param_count == 512 * 90 + 90  # w + b


@pytest.mark.parametrize("name", ["tiny_cnn", "effnet_lite"])
def test_param_specs_match_params(name):
    m = models.build(name)
    assert len(m.param_specs) == len(m.params)
    for spec, p in zip(m.param_specs, m.params):
        assert tuple(spec.shape) == tuple(p.shape)
    # Every precision layer owns exactly one quantizable weight tensor.
    owners = [s.layer_idx for s in m.param_specs if s.layer_idx >= 0]
    assert sorted(owners) == list(range(m.num_layers))


def test_layer_specs_accounting():
    m = models.build("tiny_cnn")
    specs = {ls.name: ls for ls in m.layer_specs}
    assert specs["conv1"].param_elems == 3 * 3 * 3 * 16
    assert specs["conv1"].act_elems == 32 * 32 * 16
    assert specs["conv2"].act_elems == 16 * 16 * 32
    assert specs["head"].kind == "dense"
    assert specs["head"].param_elems == 64 * 10


def test_bn_state_updates_in_train_mode():
    m = models.build("tiny_cnn")
    x = _batch(8, seed=1) * 5.0 + 2.0
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    _, new_state = m.apply(m.params, m.state, x, codes, train=True)
    changed = [
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(new_state, m.state)
    ]
    assert all(changed), "all running stats must move on a non-trivial batch"
    # Eval mode must NOT change state.
    _, eval_state = m.apply(m.params, m.state, x, codes, train=False)
    for a, b in zip(eval_state, m.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_precision_codes_change_output():
    m = models.build("tiny_cnn")
    x = _batch(4, seed=2)
    full = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    half = jnp.full((m.num_layers,), api.FP16, jnp.int32)
    l32, _ = m.apply(m.params, m.state, x, full, train=False)
    l16, _ = m.apply(m.params, m.state, x, half, train=False)
    assert not np.allclose(np.asarray(l32), np.asarray(l16))
    # ... but not by much: fp16 on a well-scaled net is a small perturbation.
    np.testing.assert_allclose(np.asarray(l32), np.asarray(l16), atol=0.1)


def test_per_layer_codes_are_independent():
    m = models.build("tiny_cnn")
    x = _batch(4, seed=3)
    base = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    one16 = base.at[0].set(api.FP16)
    l_base, _ = m.apply(m.params, m.state, x, base, train=False)
    l_one, _ = m.apply(m.params, m.state, x, one16, train=False)
    assert not np.allclose(np.asarray(l_base), np.asarray(l_one))


def test_ref_and_pallas_backends_agree():
    m = models.build("tiny_cnn")
    x = _batch(4, seed=4)
    codes = jnp.asarray([0, 1, 2, 1], jnp.int32)
    lp, _ = m.apply(m.params, m.state, x, codes, train=False)
    with api.backend("ref"):
        lr_, _ = m.apply(m.params, m.state, x, codes, train=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr_), rtol=1e-5, atol=1e-5)


def test_init_is_seed_deterministic():
    a = models.build("tiny_cnn", seed=7)
    b = models.build("tiny_cnn", seed=7)
    c = models.build("tiny_cnn", seed=8)
    for pa, pb in zip(a.params, b.params):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc))
        for pa, pc in zip(a.params, c.params)
    )


def test_effnet_has_depthwise_layers():
    m = models.build("effnet_lite")
    kinds = {ls.kind for ls in m.layer_specs}
    assert "dwconv" in kinds and "conv" in kinds and "dense" in kinds
