//! A training session: model parameters + optimizer + BN state held as
//! host `f32` vectors, with train / eval / curvature entry points that
//! dispatch to the engine's [`Backend`](super::Backend).
//!
//! The session owns the *state*; the backend owns the *compute*. This
//! is what lets the same Trainer run on the pure-Rust reference
//! executor, the PJRT artifact executor, or any future backend.

use anyhow::{Context, Result};

use super::backend::ModelState;
use super::engine::Engine;
use crate::manifest::ModelEntry;
use crate::util::rng::Rng;

/// One training batch in host memory (NHWC f32 images + i32 labels).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
}

impl Batch {
    pub fn new(x: Vec<f32>, y: Vec<i32>) -> Batch {
        let n = y.len();
        assert_eq!(x.len(), n * 32 * 32 * 3, "batch image payload mismatch");
        Batch { x, y, n }
    }
}

/// Per-step control surface — everything the Tri-Accel coordinator steers.
#[derive(Clone, Debug)]
pub struct StepCtrl {
    pub codes: Vec<i32>,
    pub lr_scales: Vec<f32>,
    pub lr: f32,
    pub loss_scale: f32,
    pub weight_decay: f32,
}

impl StepCtrl {
    pub fn uniform(num_layers: usize, code: i32, lr: f32, wd: f32) -> StepCtrl {
        StepCtrl {
            codes: vec![code; num_layers],
            lr_scales: vec![1.0; num_layers],
            lr,
            loss_scale: 1.0,
            weight_decay: wd,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainOutputs {
    pub loss: f32,
    pub correct: i64,
    pub grad_var: Vec<f32>,
    pub grad_norm: Vec<f32>,
    pub overflow: bool,
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub loss: f32,
    pub correct: i64,
    pub total: usize,
}

pub struct Session<'e> {
    pub engine: &'e Engine,
    pub entry: ModelEntry,
    st: ModelState,
    /// Power-iteration probe vectors, persisted across curvature firings.
    probes: Option<Vec<Vec<f32>>>,
    pub steps: u64,
}

impl<'e> Session<'e> {
    /// Materialize params/momentum/state through the backend's `init`
    /// entry point (seed-deterministic — no weight blobs on disk).
    pub fn init(engine: &'e Engine, model_key: &str, seed: i32) -> Result<Session<'e>> {
        let entry = engine.manifest.model(model_key)?.clone();
        anyhow::ensure!(
            engine.backend().supports(&entry),
            "backend `{}` does not implement model `{}` (architecture `{}`)",
            engine.platform(),
            model_key,
            entry.model
        );
        let st = engine
            .backend()
            .init(&entry, seed)
            .with_context(|| format!("initializing `{model_key}`"))?;
        anyhow::ensure!(
            st.params.len() == entry.params.len(),
            "init params arity {} != manifest {}",
            st.params.len(),
            entry.params.len()
        );
        anyhow::ensure!(
            st.state.len() == entry.state_shapes.len(),
            "init state arity {} != manifest {}",
            st.state.len(),
            entry.state_shapes.len()
        );
        for (p, spec) in st.params.iter().zip(&entry.params) {
            anyhow::ensure!(
                p.len() == spec.elems,
                "init tensor {}: {} elems != manifest {}",
                spec.name,
                p.len(),
                spec.elems
            );
        }
        Ok(Session { engine, entry, st, probes: None, steps: 0 })
    }

    pub fn num_layers(&self) -> usize {
        self.entry.num_layers
    }

    /// One optimizer step through the backend's `train_b{n}` entry point.
    pub fn train_step(&mut self, batch: &Batch, ctrl: &StepCtrl) -> Result<TrainOutputs> {
        anyhow::ensure!(
            self.entry.train_buckets.contains(&batch.n),
            "batch size {} is not a train bucket {:?}",
            batch.n,
            self.entry.train_buckets
        );
        anyhow::ensure!(ctrl.codes.len() == self.entry.num_layers, "codes arity");
        anyhow::ensure!(ctrl.lr_scales.len() == self.entry.num_layers, "lr_scales arity");
        let out = self
            .engine
            .backend()
            .train_step(&self.entry, &mut self.st, batch, ctrl)?;
        anyhow::ensure!(out.grad_var.len() == self.entry.num_layers, "grad_var arity");
        anyhow::ensure!(out.grad_norm.len() == self.entry.num_layers, "grad_norm arity");
        self.steps += 1;
        Ok(out)
    }

    /// Evaluate one batch through `eval_b{n}`. Codes let callers measure
    /// quantized inference; pass all-FP32 for the paper's test protocol.
    pub fn eval_batch(&self, batch: &Batch, codes: &[i32]) -> Result<EvalResult> {
        anyhow::ensure!(
            self.entry.eval_buckets.contains(&batch.n),
            "eval batch size {} not in buckets {:?}",
            batch.n,
            self.entry.eval_buckets
        );
        anyhow::ensure!(codes.len() == self.entry.num_layers, "codes arity");
        self.engine.backend().eval_batch(&self.entry, &self.st, batch, codes)
    }

    /// One amortized power-iteration step on the curvature batch; returns
    /// per-layer Rayleigh quotients λ_l. Probe vectors persist in the
    /// session and warm-start the next firing.
    pub fn curv_step(&mut self, batch: &Batch, codes: &[i32], seed: u64) -> Result<Vec<f32>> {
        anyhow::ensure!(batch.n == self.entry.curv_batch, "curvature batch size");
        anyhow::ensure!(codes.len() == self.entry.num_layers, "codes arity");
        let backend = self.engine.backend();
        let probes = self.probes.get_or_insert_with(|| fresh_probes(&self.entry, seed));
        let lambdas = backend.curv_step(&self.entry, &self.st, batch, probes, codes)?;
        anyhow::ensure!(lambdas.len() == self.entry.num_layers, "lambda arity");
        Ok(lambdas)
    }

    /// Reset the power iteration (e.g. after large parameter jumps).
    pub fn reset_probes(&mut self) {
        self.probes = None;
    }

    /// L2 norm of a parameter tensor (telemetry / tests).
    pub fn param_norm(&self, idx: usize) -> Result<f64> {
        let p = self
            .st
            .params
            .get(idx)
            .with_context(|| format!("no parameter {idx}"))?;
        Ok(p.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt())
    }

    /// Snapshot of all parameters as host vectors (tests / checkpoints).
    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        Ok(self.st.params.clone())
    }

    /// Serialize the full optimizer state (plus live curvature probes,
    /// when warm) into a [`crate::checkpoint::Checkpoint`].
    pub fn export(&self, step: u64) -> Result<crate::checkpoint::Checkpoint> {
        use crate::checkpoint::{Checkpoint, Tensor};
        let mut tensors = Vec::new();
        let mut push = |role: &str, i: usize, data: &[f32], dims: &[usize]| {
            tensors.push(Tensor {
                name: format!("{role}/{i}"),
                dims: dims.iter().map(|&d| d as u64).collect(),
                data: data.to_vec(),
            });
        };
        for (i, (p, spec)) in self.st.params.iter().zip(&self.entry.params).enumerate() {
            push("param", i, p, &spec.shape);
        }
        for (i, (m, spec)) in self.st.mom.iter().zip(&self.entry.params).enumerate() {
            push("mom", i, m, &spec.shape);
        }
        for (i, (s, shape)) in self.st.state.iter().zip(&self.entry.state_shapes).enumerate() {
            push("state", i, s, shape);
        }
        if let Some(probes) = &self.probes {
            for (i, (u, spec)) in probes.iter().zip(&self.entry.params).enumerate() {
                push("probe", i, u, &spec.shape);
            }
        }
        Ok(Checkpoint {
            model_key: self.entry.key.clone(),
            method_key: String::new(),
            graph_digest: self.entry.digest(),
            step,
            tensors,
            ctrl: Vec::new(),
        })
    }

    /// Restore params/momentum/state (and curvature probes, if saved)
    /// from a checkpoint. Model key and every tensor shape are validated
    /// against the manifest.
    pub fn restore(&mut self, ckpt: &crate::checkpoint::Checkpoint) -> Result<u64> {
        anyhow::ensure!(
            ckpt.model_key == self.entry.key,
            "checkpoint is for model `{}`, session is `{}`",
            ckpt.model_key,
            self.entry.key
        );
        // v3 headers carry the graph digest: the same key with a
        // changed definition (layer table, node graph, buckets) must
        // fail here, not as a tensor-shape surprise mid-restore.
        if ckpt.graph_digest != 0 {
            let ours = self.entry.digest();
            anyhow::ensure!(
                ckpt.graph_digest == ours,
                "checkpoint graph digest {:#018x} != current `{}` definition {:#018x} — \
                 the model's graph/geometry changed since this checkpoint was written",
                ckpt.graph_digest,
                self.entry.key,
                ours
            );
        }
        let vec_for = |t: &crate::checkpoint::Tensor, want: &[usize]| -> Result<Vec<f32>> {
            let dims: Vec<usize> = t.dims.iter().map(|&d| d as usize).collect();
            anyhow::ensure!(
                dims == want,
                "tensor {}: checkpoint shape {:?} != manifest {:?}",
                t.name,
                dims,
                want
            );
            Ok(t.data.clone())
        };
        let mut params = Vec::with_capacity(self.st.params.len());
        let mut mom = Vec::with_capacity(self.st.mom.len());
        let mut state = Vec::with_capacity(self.st.state.len());
        for (i, spec) in self.entry.params.iter().enumerate() {
            params.push(vec_for(ckpt.tensor(&format!("param/{i}"))?, &spec.shape)?);
            mom.push(vec_for(ckpt.tensor(&format!("mom/{i}"))?, &spec.shape)?);
        }
        for (i, shape) in self.entry.state_shapes.iter().enumerate() {
            state.push(vec_for(ckpt.tensor(&format!("state/{i}"))?, shape)?);
        }
        // Probes are optional (absent for sessions that never probed).
        let mut probes = Vec::with_capacity(self.entry.params.len());
        let mut have_probes = true;
        for (i, spec) in self.entry.params.iter().enumerate() {
            match ckpt.tensor(&format!("probe/{i}")) {
                Ok(t) => probes.push(vec_for(t, &spec.shape)?),
                Err(_) => {
                    have_probes = false;
                    break;
                }
            }
        }
        self.st = ModelState { params, mom, state };
        self.probes = if have_probes { Some(probes) } else { None };
        self.steps = ckpt.step;
        Ok(ckpt.step)
    }
}

/// Fresh probe vectors: unit-free normals on precision layers, zeros on
/// fp32-only params (BN/bias don't probe).
fn fresh_probes(entry: &ModelEntry, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::stream(seed, 0xC0FFEE);
    entry
        .params
        .iter()
        .map(|p| {
            if p.layer_idx >= 0 {
                (0..p.elems).map(|_| rng.next_normal()).collect()
            } else {
                vec![0f32; p.elems]
            }
        })
        .collect()
}
