//! Rule D7 — schema-drift guard over the serialized field sets.
//!
//! The telemetry events (`metrics/telemetry.rs`) and the grid ledger
//! (`sched/ledger.rs`) are the two on-disk formats external tooling
//! parses, and both carry an explicit schema-version constant. This
//! module digests the *field-key string literals* each file serializes
//! (the first argument of `insert("…")` / `num(&mut m, "…")` /
//! `s(&mut m, "…")` calls outside test code) and pins the
//! `(version, digest)` pair. Renaming, removing, or adding a
//! serialized field changes the digest; if the version constant did
//! not move with it, the lint fails — so a schema change can never
//! ship silently. The bump procedure lives in `docs/TELEMETRY.md`
//! ("Schema-version policy").

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{Context, Result};

use super::rules::Finding;
use super::scan;

/// One pinned schema: file, version constant, and the expected pair.
pub struct SchemaPin {
    /// Path relative to the lint root.
    pub file: &'static str,
    /// Name of the `pub const …: u64` version in that file.
    pub version_const: &'static str,
    /// Pinned version value.
    pub version: u64,
    /// Pinned FNV-1a digest of the sorted serialized-field-key list.
    pub digest: u64,
}

/// The pinned schemas. Update these together with a version bump —
/// `tri-accel lint --format json` prints the freshly computed digests.
pub const PINS: &[SchemaPin] = &[
    SchemaPin {
        file: "metrics/telemetry.rs",
        version_const: "SCHEMA_VERSION",
        version: 1,
        // Re-pinned for the additive `host_mem` event ("source" key);
        // additive fields keep the version (docs/TELEMETRY.md).
        digest: 0x1b51bde31d46413a,
    },
    SchemaPin {
        file: "sched/ledger.rs",
        version_const: "LEDGER_SCHEMA_VERSION",
        version: 2,
        digest: 0x1d8c24f3894add94,
    },
];

/// Computed-vs-pinned status for one schema file (report rendering).
#[derive(Debug, Clone)]
pub struct SchemaStatus {
    /// Path relative to the lint root.
    pub file: String,
    /// Version constant's current value.
    pub version: u64,
    /// Digest of the current serialized-field-key set.
    pub digest: u64,
    /// Pinned version.
    pub pinned_version: u64,
    /// Pinned digest.
    pub pinned_digest: u64,
}

/// 64-bit FNV-1a (matches the repo's other content digests).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialization call shapes whose first argument is a field key.
const KEY_MARKERS: &[&str] = &["insert(\"", "num(&mut m, \"", "s(&mut m, \""];

/// Extract `(version, field keys)` from one schema file's source.
/// Only non-test lines count; the markers are matched on the scanner's
/// comment-stripped code channel so prose can't contribute keys.
pub fn extract(src: &str, version_const: &str) -> (Option<u64>, BTreeSet<String>) {
    let sf = scan::scan_source("schema-input.rs", src);
    let mut keys = BTreeSet::new();
    let mut version = None;
    let version_needle = format!("const {version_const}: u64 =");
    for (i, line) in sf.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        if line.code.contains(&version_needle) {
            version = parse_version(&sf.raw[i], &version_needle);
        }
        for marker in KEY_MARKERS {
            if !line.code.contains(marker) {
                continue;
            }
            // The code channel blanks literal contents, so read the
            // actual key text out of the raw line at the same marker.
            if let Some(at) = sf.raw[i].find(marker) {
                let tail = &sf.raw[i][at + marker.len()..];
                if let Some(end) = tail.find('"') {
                    keys.insert(tail[..end].to_string());
                }
            }
        }
    }
    (version, keys)
}

fn parse_version(raw: &str, needle: &str) -> Option<u64> {
    let at = raw.find(needle)?;
    let tail = raw[at + needle.len()..].trim_start();
    let digits: String = tail.chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

/// Digest a key set: keys sorted (BTreeSet order), comma-joined.
pub fn digest_keys(keys: &BTreeSet<String>) -> u64 {
    let joined = keys.iter().cloned().collect::<Vec<_>>().join(",");
    fnv1a64(joined.as_bytes())
}

/// Compare one extracted schema against its pin.
pub fn check_extracted(
    pin: &SchemaPin,
    version: Option<u64>,
    keys: &BTreeSet<String>,
) -> (Vec<Finding>, SchemaStatus) {
    let mut findings = Vec::new();
    let digest = digest_keys(keys);
    let version = version.unwrap_or(0);
    let status = SchemaStatus {
        file: pin.file.to_string(),
        version,
        digest,
        pinned_version: pin.version,
        pinned_digest: pin.digest,
    };
    let vc = pin.version_const;
    let pinned_version = pin.version;
    let pinned_digest = pin.digest;
    if version != pinned_version {
        findings.push(Finding {
            rule: "d7".to_string(),
            path: pin.file.to_string(),
            line: 1,
            message: format!(
                "{vc} is {version} but the lint pins {pinned_version} — update the PINS \
                 entry in lint/schema.rs (version and digest) together with the bump"
            ),
            snippet: format!("pub const {vc}: u64 = {version};"),
        });
    } else if digest != pinned_digest {
        findings.push(Finding {
            rule: "d7".to_string(),
            path: pin.file.to_string(),
            line: 1,
            message: format!(
                "serialized field set drifted (digest {digest:016x}, pinned \
                 {pinned_digest:016x}) without a {vc} bump — bump the version and re-pin \
                 the digest in lint/schema.rs"
            ),
            snippet: format!("{} field keys: {}", keys.len(), preview(keys)),
        });
    }
    (findings, status)
}

fn preview(keys: &BTreeSet<String>) -> String {
    let mut s = keys.iter().cloned().collect::<Vec<_>>().join(",");
    if s.len() > 100 {
        s.truncate(100);
        s.push('…');
    }
    s
}

/// Check every pinned schema file under `root`.
pub fn check_tree(root: &Path) -> Result<(Vec<Finding>, Vec<SchemaStatus>)> {
    let mut findings = Vec::new();
    let mut statuses = Vec::new();
    for pin in PINS {
        let path = root.join(pin.file);
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading schema-pinned file {}", path.display()))?;
        let (version, keys) = extract(&src, pin.version_const);
        let (f, s) = check_extracted(pin, version, &keys);
        findings.extend(f);
        statuses.push(s);
    }
    Ok((findings, statuses))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIXTURE: &str = "pub const SCHEMA_VERSION: u64 = 3;\nfn ev() {\n\
                           m.insert(\"alpha\".to_string(), v);\nnum(&mut m, \"beta\", 1.0);\n\
                           s(&mut m, \"gamma\", x);\n}\n#[cfg(test)]\nmod tests {\n\
                           m.insert(\"test_only\".to_string(), v);\n}\n";

    #[test]
    fn extracts_version_and_nontest_keys() {
        let (version, keys) = extract(FIXTURE, "SCHEMA_VERSION");
        assert_eq!(version, Some(3));
        let got: Vec<&str> = keys.iter().map(String::as_str).collect();
        assert_eq!(got, vec!["alpha", "beta", "gamma"], "test-mod keys excluded");
    }

    #[test]
    fn drift_without_bump_is_a_finding() {
        let (version, keys) = extract(FIXTURE, "SCHEMA_VERSION");
        let pin = SchemaPin {
            file: "x.rs",
            version_const: "SCHEMA_VERSION",
            version: 3,
            digest: digest_keys(&keys),
        };
        let (f, _) = check_extracted(&pin, version, &keys);
        assert!(f.is_empty(), "matching pin is clean");
        let stale = SchemaPin { digest: 0xdead_beef, ..pin };
        let (f, _) = check_extracted(&stale, version, &keys);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("without a SCHEMA_VERSION bump"), "{}", f[0].message);
    }

    #[test]
    fn version_drift_points_at_the_pin() {
        let (_, keys) = extract(FIXTURE, "SCHEMA_VERSION");
        let pin = SchemaPin {
            file: "x.rs",
            version_const: "SCHEMA_VERSION",
            version: 2,
            digest: digest_keys(&keys),
        };
        let (f, status) = check_extracted(&pin, Some(3), &keys);
        assert_eq!(f.len(), 1);
        assert_eq!(status.version, 3);
        assert_eq!(status.pinned_version, 2);
    }

    #[test]
    fn fnv_reference_vector() {
        // FNV-1a("a") from the reference implementation.
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
