//! # Tri-Accel
//!
//! Reproduction of *"Tri-Accel: Curvature-Aware Precision-Adaptive and
//! Memory-Elastic Optimization for Efficient GPU Usage"* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas numeric-format kernels (qdq / mp_matmul / grad_stats),
//!   authored in `python/compile/kernels/` and lowered into the HLO.
//! * **L2** — JAX train/eval/curvature graphs (`python/compile/`), AOT-
//!   lowered to HLO text artifacts by `make artifacts`.
//! * **L3** — this crate: the unified control loop (precision × curvature
//!   × elastic batching), the PJRT runtime that executes the artifacts,
//!   and every substrate (data pipeline, VRAM simulator, metrics, config,
//!   offline-build utilities).
//!
//! Python never runs on the training path: after `make artifacts` the
//! `tri-accel` binary is self-contained.

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod manifest;
pub mod memsim;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod train;
pub mod util;
