//! Compatibility facade: the Tri-Accel control loop moved to
//! [`crate::policy`], where the three §3 controllers are composable
//! [`crate::policy::PrecisionPolicy`] / [`crate::policy::CurvaturePolicy`]
//! / [`crate::policy::BatchPolicy`] implementations behind a generic
//! [`crate::policy::ControlPlane`]. These re-exports keep the original
//! paths (`coordinator::Controller`, `coordinator::precision::…`)
//! compiling; new code should import from `crate::policy` directly.

pub mod batch {
    pub use crate::policy::batch::*;
}

pub mod control {
    pub use crate::policy::plane::*;
}

pub mod curvature {
    pub use crate::policy::curvature::*;
}

pub mod precision {
    pub use crate::policy::precision::*;
}

pub use crate::policy::{
    BatchController, ControlDecision, Controller, CurvatureScheduler, LossScaler,
    PrecisionController,
};
