//! Deterministic synthetic CIFAR (DESIGN.md §5 substitution for the
//! real downloads). Class-conditional construction:
//!
//! * each class gets a smooth low-frequency *prototype* (sum of a few
//!   seeded 2-D cosine modes per channel) — this is what makes classes
//!   separable, so accuracy is a meaningful (if easier) signal;
//! * each example adds a per-example low-frequency deformation and
//!   white noise, so gradients carry realistic per-layer variance
//!   structure (what the precision controller consumes);
//! * train and test splits draw from the same distribution with
//!   disjoint example streams.
//!
//! Everything is a pure function of (seed, class, index): no storage
//! beyond the prototypes, examples are synthesized on demand.

use super::{Dataset, IMG_C, IMG_ELEMS, IMG_H, IMG_W, MEAN, STD};
use crate::util::rng::Rng;

/// Modes per channel in a class prototype.
const MODES: usize = 4;
/// Amplitude of the per-example deformation relative to the prototype.
const DEFORM: f32 = 1.6;
/// White-noise sigma in raw pixel space (0..1).
const NOISE: f32 = 0.12;

struct Mode {
    fy: f32,
    fx: f32,
    phase: f32,
    amp: f32,
}

pub struct SyntheticCifar {
    num_classes: usize,
    len: usize,
    /// Raw-space prototypes, one image per class.
    protos: Vec<Vec<f32>>,
    seed: u64,
    /// Split tag (train=0, test=1) — keeps example streams disjoint.
    split: u64,
}

impl SyntheticCifar {
    pub fn new(num_classes: usize, len: usize, train: bool, seed: u64) -> SyntheticCifar {
        let protos = (0..num_classes)
            .map(|c| Self::prototype(seed, c))
            .collect();
        SyntheticCifar {
            num_classes,
            len,
            protos,
            seed,
            split: if train { 0 } else { 1 },
        }
    }

    /// Smooth class prototype in raw [0,1] pixel space.
    fn prototype(seed: u64, class: usize) -> Vec<f32> {
        let mut rng = Rng::stream(seed, 0x5052 ^ class as u64);
        let mut img = vec![0.5f32; IMG_ELEMS];
        for c in 0..IMG_C {
            let modes: Vec<Mode> = (0..MODES)
                .map(|_| Mode {
                    fy: 1.0 + rng.next_f32() * 3.0,
                    fx: 1.0 + rng.next_f32() * 3.0,
                    phase: rng.next_f32() * std::f32::consts::TAU,
                    amp: 0.03 + rng.next_f32() * 0.05,
                })
                .collect();
            for y in 0..IMG_H {
                for x in 0..IMG_W {
                    let mut v = 0.0;
                    for m in &modes {
                        let ty = y as f32 / IMG_H as f32;
                        let tx = x as f32 / IMG_W as f32;
                        v += m.amp
                            * (std::f32::consts::TAU * (m.fy * ty + m.fx * tx) + m.phase).cos();
                    }
                    img[(y * IMG_W + x) * IMG_C + c] += v;
                }
            }
        }
        img
    }
}

impl Dataset for SyntheticCifar {
    fn len(&self) -> usize {
        self.len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn example(&self, idx: usize, out: &mut [f32]) -> i32 {
        debug_assert_eq!(out.len(), IMG_ELEMS);
        // Balanced labels: stripes over the index space, then shuffled
        // implicitly by the BatchIter's epoch permutation.
        let label = (idx % self.num_classes) as i32;
        let proto = &self.protos[label as usize];

        let mut rng = Rng::stream(
            self.seed ^ (self.split << 60),
            0xE9 ^ (idx as u64).wrapping_mul(0x9E37_79B9),
        );
        // Per-example smooth deformation: one extra cosine mode.
        let fy = 1.0 + rng.next_f32() * 2.0;
        let fx = 1.0 + rng.next_f32() * 2.0;
        let phase = rng.next_f32() * std::f32::consts::TAU;
        let amp = DEFORM * (0.5 + rng.next_f32());

        for y in 0..IMG_H {
            let ty = y as f32 / IMG_H as f32;
            for x in 0..IMG_W {
                let tx = x as f32 / IMG_W as f32;
                let d = amp
                    * 0.1
                    * (std::f32::consts::TAU * (fy * ty + fx * tx) + phase).cos();
                for c in 0..IMG_C {
                    let i = (y * IMG_W + x) * IMG_C + c;
                    let raw = (proto[i] + d + NOISE * rng.next_normal()).clamp(0.0, 1.0);
                    out[i] = (raw - MEAN[c]) / STD[c];
                }
            }
        }
        label
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let ds = SyntheticCifar::new(10, 100, true, 42);
        let mut a = vec![0f32; IMG_ELEMS];
        let mut b = vec![0f32; IMG_ELEMS];
        let la = ds.example(17, &mut a);
        let lb = ds.example(17, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticCifar::new(10, 100, true, 42);
        let mut a = vec![0f32; IMG_ELEMS];
        let mut b = vec![0f32; IMG_ELEMS];
        ds.example(0, &mut a);
        ds.example(10, &mut b); // same class, different example
        assert_ne!(a, b);
    }

    #[test]
    fn train_test_streams_disjoint() {
        let tr = SyntheticCifar::new(10, 100, true, 42);
        let te = SyntheticCifar::new(10, 100, false, 42);
        let mut a = vec![0f32; IMG_ELEMS];
        let mut b = vec![0f32; IMG_ELEMS];
        tr.example(3, &mut a);
        te.example(3, &mut b);
        assert_ne!(a, b, "same index, different split");
    }

    #[test]
    fn labels_balanced() {
        let ds = SyntheticCifar::new(10, 1000, true, 1);
        let mut counts = [0usize; 10];
        let mut buf = vec![0f32; IMG_ELEMS];
        for i in 0..1000 {
            counts[ds.example(i, &mut buf) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // Mean within-class distance must be well below between-class
        // distance — otherwise training signal is pure noise.
        let ds = SyntheticCifar::new(10, 1000, true, 5);
        let ex = |i: usize| {
            let mut v = vec![0f32; IMG_ELEMS];
            let l = ds.example(i, &mut v);
            (v, l)
        };
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>() // detlint: ordered — sequential sum in buffer order.
                .sqrt()
        };
        // Statistical: average over many pairs (the task is deliberately
        // hard per-pair — DEFORM/NOISE dominate single distances).
        let mut within = 0.0;
        let mut between = 0.0;
        let pairs = 30;
        for k in 0..pairs {
            let (a, _) = ex(k * 10); // class 0 examples
            let (b, _) = ex(k * 10 + 100); // class 0, other example
            let (c, _) = ex(k * 10 + 1); // class 1
            within += dist(&a, &b);
            between += dist(&a, &c);
        }
        assert!(
            between > within * 1.02,
            "between {between} vs within {within} over {pairs} pairs"
        );
    }

    #[test]
    fn cifar100_shape() {
        let ds = SyntheticCifar::new(100, 500, true, 9);
        assert_eq!(ds.num_classes(), 100);
        let mut buf = vec![0f32; IMG_ELEMS];
        let l = ds.example(499, &mut buf);
        assert!((0..100).contains(&l));
    }
}
