//! Minimal JSON parser (substrate — the offline build has no serde_json).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Parsing is recursive-descent over a byte slice; numbers parse as f64
//! with integer accessors that check exactness.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// lookups want loud failures, not silent Nones.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to compact JSON (used by metrics/log writers).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our manifests).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        // detlint: allow(d6) — the scanned span holds ASCII digits, sign,
        // dot, and exponent bytes only, so it is always valid UTF-8.
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c\n"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""A\t""#).unwrap(),
            Json::Str("A\t".into())
        );
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integer_exactness_guard() {
        assert_eq!(Json::parse("2.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("11173962").unwrap().as_usize(), Some(11173962));
    }
}
