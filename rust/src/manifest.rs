//! `artifacts/manifest.json` — the contract between the Python compile
//! path and the Rust runtime. Every shape, ordering, and artifact path the
//! runtime needs is read from here; nothing about models is hardcoded.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Precision codes — MUST match python/compile/kernels/ref.py.
pub const FP16: i32 = 0;
pub const BF16: i32 = 1;
pub const FP32: i32 = 2;

pub fn precision_name(code: i32) -> &'static str {
    match code {
        FP16 => "fp16",
        BF16 => "bf16",
        FP32 => "fp32",
        _ => "?",
    }
}

/// Bytes/element the memory model charges per precision code.
pub fn precision_bytes(code: i32) -> usize {
    match code {
        FP16 | BF16 => 2,
        _ => 4,
    }
}

#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: String, // "conv" | "dwconv" | "dense"
    pub param_elems: usize,
    pub act_elems: usize, // per sample
    pub flops: usize,     // MACs per sample
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub layer_idx: i64, // -1 => fp32-only (BN/bias)
    pub elems: usize,
}

/// Sentinel input index: the node reads the batch images, not another
/// node's output.
pub const NODE_INPUT_IMAGE: i64 = -1;

/// One typed operation of a model's layer graph. Parameter fields are
/// indices into [`ModelEntry::params`]; `layer` is the precision-layer
/// index the op's compute precision comes from; `state` (BN) is the
/// index of the running-mean vector in the state list (running variance
/// is `state + 1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeOp {
    /// SAME-padded k×k convolution with stride `stride` (pad = (k-1)/2
    /// on every side; 1×1 convs have no padding).
    Conv { k: usize, stride: usize, w: usize, layer: usize },
    /// Depthwise SAME-padded k×k convolution (one filter per channel).
    DwConv { k: usize, stride: usize, w: usize, layer: usize },
    /// BatchNorm (batch stats in train mode, running stats in eval).
    Bn { gamma: usize, beta: usize, state: usize },
    Relu,
    /// 2×2 stride-2 max pool.
    MaxPool2,
    /// Global average pool over the spatial dims.
    Gap,
    /// Dense head over (n, features) activations.
    Dense { w: usize, b: usize, layer: usize },
    /// Residual add: `out = input + nodes[rhs]` (same shape).
    Add { rhs: usize },
    /// Terminal mean softmax cross-entropy over the logits.
    SoftmaxCe,
}

/// A node of the layer graph: the op plus the index of the node whose
/// output it consumes ([`NODE_INPUT_IMAGE`] = the batch images).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSpec {
    pub op: NodeOp,
    pub input: i64,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub key: String,
    pub model: String,
    pub num_classes: usize,
    pub num_layers: usize,
    pub param_count: usize,
    pub layers: Vec<LayerSpec>,
    pub params: Vec<ParamSpec>,
    /// The typed layer graph the native executor walks. Empty for
    /// artifact-only entries (the PJRT backend runs compiled HLO and
    /// never consults it).
    pub nodes: Vec<NodeSpec>,
    pub state_shapes: Vec<Vec<usize>>,
    pub train_buckets: Vec<usize>,
    pub eval_buckets: Vec<usize>,
    pub curv_batch: usize,
    pub artifacts: BTreeMap<String, String>,
}

impl ModelEntry {
    pub fn artifact(&self, name: &str) -> Result<&str> {
        self.artifacts
            .get(name)
            .map(|s| s.as_str())
            .with_context(|| format!("model {}: no artifact `{name}`", self.key))
    }

    /// Total quantizable parameter elements across precision layers.
    pub fn quantizable_elems(&self) -> usize {
        self.layers.iter().map(|l| l.param_elems).sum()
    }

    /// Activation elements per sample summed over layers (memsim input).
    pub fn act_elems_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.act_elems).sum()
    }

    pub fn state_elems(&self) -> usize {
        self.state_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// FNV-1a fingerprint of the model's *definition* — architecture
    /// name, classes, layer table, parameter shapes, node graph, state
    /// shapes, bucket ladders, curvature batch. Stored in checkpoint
    /// headers (v3+) so resuming against a changed model definition
    /// fails at load with a clear error instead of as a downstream
    /// shape/state mismatch. Artifact paths are deliberately excluded:
    /// relocating artifacts does not change the graph.
    pub fn digest(&self) -> u64 {
        // The derived Debug formatting of the typed specs is a stable,
        // total description of the geometry; hashing it avoids a
        // hand-rolled (and drift-prone) field-by-field serializer.
        let desc = format!(
            "{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{:?}|{:?}|{}",
            self.model,
            self.num_classes,
            self.num_layers,
            self.layers,
            self.params,
            self.nodes,
            self.state_shapes,
            self.train_buckets,
            self.eval_buckets,
            self.curv_batch,
        );
        crate::checkpoint::fnv1a(desc.as_bytes())
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json")?;

        // Fail loudly if the python-side code contract drifted.
        let codes = root.req("precision_codes")?;
        anyhow::ensure!(
            codes.req("fp16")?.as_i64() == Some(FP16 as i64)
                && codes.req("bf16")?.as_i64() == Some(BF16 as i64)
                && codes.req("fp32")?.as_i64() == Some(FP32 as i64),
            "precision-code contract mismatch between manifest and runtime"
        );

        let mut models = BTreeMap::new();
        for (key, m) in root.req("models")?.as_obj().context("models not an object")? {
            models.insert(key.clone(), Self::parse_model(key, m)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    fn parse_model(key: &str, m: &Json) -> Result<ModelEntry> {
        let usize_of = |j: &Json, what: &str| -> Result<usize> {
            j.as_usize().with_context(|| format!("{key}: bad {what}"))
        };
        let layers = m
            .req("layers")?
            .as_arr()
            .context("layers")?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    name: l.req("name")?.as_str().context("name")?.to_string(),
                    kind: l.req("kind")?.as_str().context("kind")?.to_string(),
                    param_elems: usize_of(l.req("param_elems")?, "param_elems")?,
                    act_elems: usize_of(l.req("act_elems")?, "act_elems")?,
                    flops: usize_of(l.req("flops")?, "flops")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let params = m
            .req("params")?
            .as_arr()
            .context("params")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str().context("name")?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| usize_of(d, "dim"))
                        .collect::<Result<Vec<_>>>()?,
                    layer_idx: p.req("layer_idx")?.as_i64().context("layer_idx")?,
                    elems: usize_of(p.req("elems")?, "elems")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let nodes = match m.get("graph") {
            None => Vec::new(),
            Some(g) => g
                .as_arr()
                .context("graph")?
                .iter()
                .enumerate()
                .map(|(i, nd)| Self::parse_node(key, i, nd))
                .collect::<Result<Vec<_>>>()?,
        };
        let state_shapes = m
            .req("state_shapes")?
            .as_arr()
            .context("state_shapes")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .context("state shape")?
                    .iter()
                    .map(|d| usize_of(d, "dim"))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let buckets = |field: &str| -> Result<Vec<usize>> {
            m.req(field)?
                .as_arr()
                .with_context(|| field.to_string())?
                .iter()
                .map(|b| usize_of(b, field))
                .collect()
        };
        let mut artifacts = BTreeMap::new();
        for (k, v) in m.req("artifacts")?.as_obj().context("artifacts")? {
            artifacts.insert(k.clone(), v.as_str().context("artifact path")?.to_string());
        }
        let entry = ModelEntry {
            key: key.to_string(),
            model: m.req("model")?.as_str().context("model")?.to_string(),
            num_classes: usize_of(m.req("num_classes")?, "num_classes")?,
            num_layers: usize_of(m.req("num_layers")?, "num_layers")?,
            param_count: usize_of(m.req("param_count")?, "param_count")?,
            layers,
            params,
            nodes,
            state_shapes,
            train_buckets: buckets("train_buckets")?,
            eval_buckets: buckets("eval_buckets")?,
            curv_batch: usize_of(m.req("curv_batch")?, "curv_batch")?,
            artifacts,
        };
        anyhow::ensure!(
            entry.layers.len() == entry.num_layers,
            "{key}: layer count mismatch"
        );
        anyhow::ensure!(
            entry.params.iter().map(|p| p.elems).sum::<usize>() == entry.param_count,
            "{key}: param count mismatch"
        );
        Self::validate_graph(key, &entry)?;
        Ok(entry)
    }

    fn parse_node(key: &str, idx: usize, nd: &Json) -> Result<NodeSpec> {
        let ctx = |what: &str| format!("{key}: graph[{idx}] {what}");
        let usz = |field: &str| -> Result<usize> {
            nd.req(field)?.as_usize().with_context(|| ctx(field))
        };
        let op = nd.req("op")?.as_str().with_context(|| ctx("op"))?;
        let op = match op {
            "conv" => NodeOp::Conv {
                k: usz("k")?,
                stride: usz("stride")?,
                w: usz("w")?,
                layer: usz("layer")?,
            },
            "dwconv" => NodeOp::DwConv {
                k: usz("k")?,
                stride: usz("stride")?,
                w: usz("w")?,
                layer: usz("layer")?,
            },
            "bn" => NodeOp::Bn { gamma: usz("gamma")?, beta: usz("beta")?, state: usz("state")? },
            "relu" => NodeOp::Relu,
            "maxpool2" => NodeOp::MaxPool2,
            "gap" => NodeOp::Gap,
            "dense" => NodeOp::Dense { w: usz("w")?, b: usz("b")?, layer: usz("layer")? },
            "add" => NodeOp::Add { rhs: usz("rhs")? },
            "softmax_ce" => NodeOp::SoftmaxCe,
            other => anyhow::bail!("{}", ctx(&format!("unknown op `{other}`"))),
        };
        let input = match nd.get("in") {
            None => idx as i64 - 1, // default: the previous node
            Some(v) => v.as_i64().with_context(|| ctx("in"))?,
        };
        Ok(NodeSpec { op, input })
    }

    /// Structural validation of the layer graph: every index in range,
    /// inputs strictly earlier than the node (the executor walks the
    /// list forward once), and the loss node terminal-only.
    fn validate_graph(key: &str, e: &ModelEntry) -> Result<()> {
        let n = e.nodes.len();
        for (i, nd) in e.nodes.iter().enumerate() {
            let ctx = |what: &str| format!("{key}: graph[{i}]: {what}");
            anyhow::ensure!(
                nd.input >= NODE_INPUT_IMAGE && nd.input < i as i64,
                "{}",
                ctx("input must be an earlier node or the image (-1)")
            );
            let param_ok = |p: usize| -> Result<()> {
                anyhow::ensure!(p < e.params.len(), "{}", ctx("param index out of range"));
                Ok(())
            };
            let layer_ok = |l: usize| -> Result<()> {
                anyhow::ensure!(l < e.num_layers, "{}", ctx("layer index out of range"));
                Ok(())
            };
            match nd.op {
                NodeOp::Conv { k, stride, w, layer }
                | NodeOp::DwConv { k, stride, w, layer } => {
                    anyhow::ensure!(
                        k >= 1 && k % 2 == 1 && stride >= 1,
                        "{}",
                        ctx("conv needs odd k >= 1 and stride >= 1")
                    );
                    param_ok(w)?;
                    layer_ok(layer)?;
                }
                NodeOp::Bn { gamma, beta, state } => {
                    param_ok(gamma)?;
                    param_ok(beta)?;
                    anyhow::ensure!(
                        state + 2 <= e.state_shapes.len(),
                        "{}",
                        ctx("bn needs state slots [rm, rv]")
                    );
                }
                NodeOp::Dense { w, b, layer } => {
                    param_ok(w)?;
                    param_ok(b)?;
                    layer_ok(layer)?;
                }
                NodeOp::Add { rhs } => {
                    anyhow::ensure!(rhs < i, "{}", ctx("add rhs must be an earlier node"));
                }
                NodeOp::Relu | NodeOp::MaxPool2 | NodeOp::Gap => {}
                NodeOp::SoftmaxCe => {
                    anyhow::ensure!(i + 1 == n, "{}", ctx("softmax_ce must be the last node"));
                }
            }
        }
        if n > 0 {
            anyhow::ensure!(
                matches!(e.nodes[n - 1].op, NodeOp::SoftmaxCe),
                "{key}: graph must end in softmax_ce"
            );
        }
        Ok(())
    }

    pub fn model(&self, key: &str) -> Result<&ModelEntry> {
        self.models
            .get(key)
            .with_context(|| format!("model `{key}` not in manifest (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, entry: &ModelEntry, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(entry.artifact(name)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "precision_codes": {"fp16":0,"bf16":1,"fp32":2},
      "models": {
        "m_c10": {
          "model":"m","num_classes":10,"num_layers":1,"param_count":6,
          "layers":[{"name":"l0","kind":"conv","param_elems":6,"act_elems":4,"flops":24}],
          "params":[{"name":"l0/w","shape":[2,3],"layer_idx":0,"elems":6}],
          "state_shapes":[[3]],
          "train_buckets":[8,16],"eval_buckets":[16],"curv_batch":8,
          "artifacts":{"train_b8":"m_t8.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_minimal() {
        let m = Manifest::parse(MINI, Path::new("/tmp/a")).unwrap();
        let e = m.model("m_c10").unwrap();
        assert_eq!(e.num_layers, 1);
        assert_eq!(e.quantizable_elems(), 6);
        assert_eq!(e.act_elems_per_sample(), 4);
        assert_eq!(e.state_elems(), 3);
        assert_eq!(
            m.artifact_path(e, "train_b8").unwrap(),
            PathBuf::from("/tmp/a/m_t8.hlo.txt")
        );
        assert!(e.artifact("nope").is_err());
        assert!(m.model("zzz").is_err());
    }

    #[test]
    fn code_contract_enforced() {
        let bad = MINI.replace(r#""fp16":0"#, r#""fp16":5"#);
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn count_mismatch_rejected() {
        let bad = MINI.replace(r#""param_count":6"#, r#""param_count":7"#);
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    const GRAPHED: &str = r#"{
      "precision_codes": {"fp16":0,"bf16":1,"fp32":2},
      "models": {
        "g_c10": {
          "model":"g","num_classes":10,"num_layers":2,"param_count":158,
          "layers":[
            {"name":"c","kind":"conv","param_elems":108,"act_elems":1024,"flops":110592},
            {"name":"h","kind":"dense","param_elems":40,"act_elems":10,"flops":40}
          ],
          "params":[
            {"name":"c/w","shape":[3,3,3,4],"layer_idx":0,"elems":108},
            {"name":"h/w","shape":[4,10],"layer_idx":1,"elems":40},
            {"name":"h/b","shape":[10],"layer_idx":-1,"elems":10}
          ],
          "graph":[
            {"op":"conv","k":3,"stride":1,"w":0,"layer":0,"in":-1},
            {"op":"relu"},
            {"op":"gap"},
            {"op":"dense","w":1,"b":2,"layer":1},
            {"op":"softmax_ce"}
          ],
          "state_shapes":[],
          "train_buckets":[16],"eval_buckets":[16],"curv_batch":16,
          "artifacts":{}
        }
      }
    }"#;

    #[test]
    fn graph_schema_parses_and_defaults_inputs() {
        let m = Manifest::parse(GRAPHED, Path::new("/x")).unwrap();
        let e = m.model("g_c10").unwrap();
        assert_eq!(e.nodes.len(), 5);
        assert_eq!(e.nodes[0].input, NODE_INPUT_IMAGE, "explicit in:-1");
        assert_eq!(e.nodes[1].input, 0, "default input is the previous node");
        assert!(matches!(e.nodes[0].op, NodeOp::Conv { k: 3, stride: 1, w: 0, layer: 0 }));
        assert!(matches!(e.nodes[3].op, NodeOp::Dense { w: 1, b: 2, layer: 1 }));
        assert!(matches!(e.nodes[4].op, NodeOp::SoftmaxCe));
    }

    #[test]
    fn graph_validation_rejects_bad_indices() {
        // Forward reference: add pulling from a later node.
        let bad = GRAPHED.replace(r#"{"op":"relu"}"#, r#"{"op":"add","rhs":3}"#);
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err(), "forward add rhs");
        // Param index out of range.
        let bad = GRAPHED.replace(r#""op":"dense","w":1"#, r#""op":"dense","w":9"#);
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err(), "param idx");
        // Loss node must be terminal.
        let bad = GRAPHED.replace(r#"{"op":"relu"}"#, r#"{"op":"softmax_ce"}"#);
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err(), "mid-graph loss");
        // Graph must end in the loss node.
        let bad = GRAPHED.replace(r#",
            {"op":"softmax_ce"}"#, "");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err(), "missing loss");
        // Even kernels are rejected (SAME padding needs odd k).
        let bad = GRAPHED.replace(r#""op":"conv","k":3"#, r#""op":"conv","k":2"#);
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err(), "even k");
    }

    #[test]
    fn graphless_entries_stay_valid() {
        let m = Manifest::parse(MINI, Path::new("/tmp/a")).unwrap();
        assert!(m.model("m_c10").unwrap().nodes.is_empty());
    }

    #[test]
    fn precision_helpers() {
        assert_eq!(precision_name(FP16), "fp16");
        assert_eq!(precision_bytes(FP16), 2);
        assert_eq!(precision_bytes(BF16), 2);
        assert_eq!(precision_bytes(FP32), 4);
    }

    #[test]
    fn digest_tracks_definition_not_location() {
        let m = Manifest::parse(GRAPHED, Path::new("/tmp/a")).unwrap();
        let e = m.model("g_c10").unwrap();
        assert_eq!(e.digest(), e.digest(), "deterministic");
        // Same manifest parsed from a different artifact root: same graph.
        let m2 = Manifest::parse(GRAPHED, Path::new("/somewhere/else")).unwrap();
        assert_eq!(e.digest(), m2.model("g_c10").unwrap().digest());
        // A changed layer table changes the digest.
        let mut altered = e.clone();
        altered.layers[0].param_elems += 1;
        assert_ne!(e.digest(), altered.digest());
        // A changed node graph changes the digest.
        let mut rewired = e.clone();
        rewired.nodes.pop();
        assert_ne!(e.digest(), rewired.digest());
    }
}
