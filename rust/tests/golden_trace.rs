//! Golden-trace regression: the manifest-driven graph executor must be
//! **bit-identical** to the pre-refactor hand-written `tiny_cnn`
//! executor it replaced.
//!
//! The reference below is the PR-2 `runtime/native/tiny_cnn.rs`
//! forward/backward/train-step reproduced verbatim against the public
//! kernel APIs (`gemm`, `ops`, `qdq` — the exact kernels both
//! executors share). A 20-step mixed-precision training run is
//! compared step by step: loss, overflow flag, per-layer grad
//! variance/norms, parameters, momentum, and BN state must match to
//! the bit, and the FNV-1a digests of the two full traces must agree.
//! Any reordering of a reduction, a changed quantization point, or a
//! dropped cache in the graph path fails loudly here.
//!
//! SIMD dispatch note: both executors call the same public kernels, so
//! both resolve the same `simd::active()` tier and the comparison is
//! *relative* — it holds under scalar, AVX2, or NEON dispatch alike
//! (and under any autotuned blocking, which is bit-invariant within a
//! tier). No per-tier re-pinning is needed; forcing
//! `TRIACCEL_DISPATCH=scalar` reproduces the historical reference bits.

use tri_accel::manifest::{ModelEntry, BF16, FP16, FP32};
use tri_accel::runtime::backend::{Backend, ModelState};
use tri_accel::runtime::native::{builtin_manifest, gemm, ops, qdq, Exec, NativeBackend};
use tri_accel::runtime::{Batch, StepCtrl};
use tri_accel::util::rng::Rng;

// ------------------------------------------------------------------
// The pre-refactor executor, verbatim (hardcoded tiny_cnn geometry).
// ------------------------------------------------------------------

const CHANNELS: [usize; 3] = [16, 32, 64];
const DIMS: [usize; 3] = [32, 16, 8];
const FEATURES: usize = 64;
const MOMENTUM: f32 = 0.9;
const N_PARAMS: usize = 11;

struct RefFwd {
    cols: [Vec<f32>; 3],
    wq: [Vec<f32>; 3],
    conv_out: [Vec<f32>; 3],
    bn_cache: Vec<ops::BnCache>,
    bn_out: [Vec<f32>; 3],
    arg: [Vec<u8>; 2],
    head_xq: Vec<f32>,
    head_wq: Vec<f32>,
    dlogits: Vec<f32>,
    new_state: [Vec<f32>; 6],
    loss: f32,
    correct: i64,
}

#[allow(clippy::too_many_arguments)]
fn ref_forward(
    ex: &mut Exec,
    entry: &ModelEntry,
    params: &[Vec<f32>],
    state: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    n: usize,
    codes: &[i32],
    train: bool,
) -> RefFwd {
    let classes = entry.num_classes;
    let mut cols: [Vec<f32>; 3] = Default::default();
    let mut wq: [Vec<f32>; 3] = Default::default();
    let mut conv_out: [Vec<f32>; 3] = Default::default();
    let mut bn_cache: Vec<ops::BnCache> = Vec::new();
    let mut bn_out: [Vec<f32>; 3] = Default::default();
    let mut arg: [Vec<u8>; 2] = Default::default();
    let mut new_state: [Vec<f32>; 6] = Default::default();

    let mut cur: Option<Vec<f32>> = None;
    let mut cin = 3usize;
    for li in 0..3 {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let code = codes[li];
        let rows = n * dim * dim;
        let k9 = 9 * cin;

        let mut c_buf = vec![0f32; rows * k9];
        {
            let src: &[f32] = cur.as_deref().unwrap_or(x);
            gemm::im2col3x3_qdq(&ex.pool, src, n, dim, dim, cin, code, &mut c_buf);
        }
        let w_buf = qdq::qdq(&params[li * 3], code);
        let mut conv = vec![0f32; rows * cout];
        gemm::gemm(&ex.pool, &mut ex.arena, &c_buf, &w_buf, &mut conv, rows, k9, cout, false);

        let (bnout, nrm, nrv, cache) = ops::bn_fwd(
            &conv,
            rows,
            cout,
            &params[li * 3 + 1],
            &params[li * 3 + 2],
            &state[li * 2],
            &state[li * 2 + 1],
            train,
        );
        new_state[li * 2] = nrm;
        new_state[li * 2 + 1] = nrv;

        let mut r = bnout.clone();
        ops::relu_inplace(&mut r);
        let next = if li < 2 {
            let (p_out, a_buf) = ops::maxpool2_fwd(&r, n, dim, dim, cout);
            arg[li] = a_buf;
            p_out
        } else {
            ops::gap_fwd(&r, n, dim, dim, cout)
        };
        cur = Some(next);

        cols[li] = c_buf;
        wq[li] = w_buf;
        conv_out[li] = conv;
        bn_cache.push(cache);
        bn_out[li] = bnout;
        cin = cout;
    }

    let code = codes[3];
    let h_act = cur.take().expect("three conv blocks ran");
    let head_xq = qdq::qdq(&h_act, code);
    let head_wq = qdq::qdq(&params[9], code);
    let mut logits = vec![0f32; n * classes];
    for r in 0..n {
        logits[r * classes..(r + 1) * classes].copy_from_slice(&params[10]);
    }
    gemm::gemm(&ex.pool, &mut ex.arena, &head_xq, &head_wq, &mut logits, n, FEATURES, classes, true);
    let (loss, correct, dlogits) = ops::softmax_ce(&logits, y, n, classes);

    RefFwd {
        cols,
        wq,
        conv_out,
        bn_cache,
        bn_out,
        arg,
        head_xq,
        head_wq,
        dlogits,
        new_state,
        loss,
        correct,
    }
}

#[allow(clippy::too_many_arguments)]
fn ref_backward(
    ex: &mut Exec,
    entry: &ModelEntry,
    fwd: &RefFwd,
    params: &[Vec<f32>],
    codes: &[i32],
    loss_scale: f32,
    n: usize,
) -> Vec<Vec<f32>> {
    let classes = entry.num_classes;
    let mut grads: Vec<Vec<f32>> = (0..N_PARAMS).map(|_| Vec::new()).collect();

    let mut g_logits = vec![0f32; n * classes];
    for (d, &v) in g_logits.iter_mut().zip(fwd.dlogits.iter()) {
        *d = v * loss_scale;
    }

    let gq = qdq::qdq(&g_logits, codes[3]);
    let mut dx_head = vec![0f32; n * FEATURES];
    gemm::gemm_a_bt(&ex.pool, &mut ex.arena, &gq, &fwd.head_wq, &mut dx_head, n, classes, FEATURES, false);
    let mut dw_head = vec![0f32; FEATURES * classes];
    gemm::gemm_at_b(&ex.pool, &mut ex.arena, &fwd.head_xq, &gq, &mut dw_head, n, FEATURES, classes);
    let mut db = vec![0f32; classes];
    for bi in 0..n {
        for (d, &v) in db.iter_mut().zip(g_logits[bi * classes..(bi + 1) * classes].iter()) {
            *d += v;
        }
    }
    grads[9] = dw_head;
    grads[10] = db;

    let mut g = dx_head;
    for li in (0..3).rev() {
        let dim = DIMS[li];
        let cout = CHANNELS[li];
        let cin = if li == 0 { 3 } else { CHANNELS[li - 1] };
        let rows = n * dim * dim;
        let k9 = 9 * cin;

        let mut gs = if li == 2 {
            ops::gap_bwd(&g, n, dim, dim, cout)
        } else {
            ops::maxpool2_bwd(&g, &fwd.arg[li], n, dim, dim, cout)
        };
        ops::relu_bwd_inplace(&mut gs, &fwd.bn_out[li]);

        let (dxbn, dgamma, dbeta) =
            ops::bn_bwd(&fwd.conv_out[li], &gs, rows, cout, &params[li * 3 + 1], &fwd.bn_cache[li]);

        let mut dw = vec![0f32; k9 * cout];
        gemm::gemm_at_b(&ex.pool, &mut ex.arena, &fwd.cols[li], &dxbn, &mut dw, rows, k9, cout);
        qdq::qdq_inplace(&mut dw, codes[li]);
        g = if li == 0 {
            Vec::new()
        } else {
            let mut dcols = vec![0f32; rows * k9];
            gemm::gemm_a_bt(&ex.pool, &mut ex.arena, &dxbn, &fwd.wq[li], &mut dcols, rows, cout, k9, false);
            let mut dx = vec![0f32; rows * cin];
            gemm::col2im3x3(&ex.pool, &dcols, n, dim, dim, cin, &mut dx);
            qdq::qdq_inplace(&mut dx, codes[li]);
            dx
        };

        grads[li * 3] = dw;
        grads[li * 3 + 1] = dgamma;
        grads[li * 3 + 2] = dbeta;
    }

    let inv = 1.0 / loss_scale;
    for gvec in grads.iter_mut() {
        for v in gvec.iter_mut() {
            *v *= inv;
        }
    }
    grads
}

fn ref_layer_stats(entry: &ModelEntry, grads: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let l_count = entry.num_layers;
    let mut sum = vec![0f64; l_count];
    let mut sq = vec![0f64; l_count];
    let mut count = vec![0usize; l_count];
    for (spec, g) in entry.params.iter().zip(grads) {
        if spec.layer_idx < 0 {
            continue;
        }
        let li = spec.layer_idx as usize;
        for &v in g {
            sum[li] += v as f64;
            sq[li] += (v as f64) * (v as f64);
        }
        count[li] += g.len();
    }
    let mut var = Vec::with_capacity(l_count);
    let mut norm = Vec::with_capacity(l_count);
    for li in 0..l_count {
        let cnt = count[li].max(1) as f64;
        let mean = sum[li] / cnt;
        let raw = sq[li] / cnt - mean * mean;
        let v = if raw.is_nan() { f64::NAN } else { raw.max(0.0) };
        var.push(v as f32);
        norm.push(sq[li] as f32);
    }
    (var, norm)
}

struct RefOut {
    loss: f32,
    correct: i64,
    grad_var: Vec<f32>,
    grad_norm: Vec<f32>,
    overflow: bool,
}

fn ref_train_step(
    ex: &mut Exec,
    entry: &ModelEntry,
    st: &mut ModelState,
    batch: &Batch,
    ctrl: &StepCtrl,
) -> RefOut {
    let n = batch.n;
    let mut fwd = ref_forward(
        ex,
        entry,
        &st.params,
        &st.state,
        &batch.x,
        &batch.y,
        n,
        &ctrl.codes,
        true,
    );
    let grads = ref_backward(ex, entry, &fwd, &st.params, &ctrl.codes, ctrl.loss_scale, n);
    let overflow = grads.iter().any(|g| g.iter().any(|v| !v.is_finite()));
    let (grad_var, grad_norm) = ref_layer_stats(entry, &grads);

    let mask = if overflow { 0f32 } else { 1f32 };
    for (i, spec) in entry.params.iter().enumerate() {
        let scale = if spec.layer_idx >= 0 {
            ctrl.lr_scales[spec.layer_idx as usize]
        } else {
            1.0
        };
        let lr_eff = ctrl.lr * scale;
        let p = &mut st.params[i];
        let m = &mut st.mom[i];
        let g = &grads[i];
        for k in 0..p.len() {
            let g_eff = (g[k] + ctrl.weight_decay * p[k]) * mask;
            let m_new = MOMENTUM * m[k] + g_eff;
            let m_out = if mask > 0.5 { m_new } else { m[k] };
            p[k] -= lr_eff * mask * m_out;
            m[k] = m_out;
        }
    }
    if !overflow {
        for (dst, src) in st.state.iter_mut().zip(fwd.new_state.iter_mut()) {
            std::mem::swap(dst, src);
        }
    }
    RefOut { loss: fwd.loss, correct: fwd.correct, grad_var, grad_norm, overflow }
}

// ------------------------------------------------------------------
// The golden-trace comparison.
// ------------------------------------------------------------------

fn rand_batch(n: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    Batch::new(x, y)
}

fn fnv1a(trace: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in trace {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

fn push_state(trace: &mut Vec<u64>, st: &ModelState) {
    for group in [&st.params, &st.mom, &st.state] {
        for t in group {
            trace.extend(t.iter().map(|v| v.to_bits() as u64));
        }
    }
}

#[test]
fn graph_executor_is_bit_identical_to_pre_refactor_tiny_cnn() {
    let manifest = builtin_manifest();
    let entry = manifest.model("tiny_cnn_c10").unwrap().clone();
    let backend = NativeBackend::with_threads(2);
    let mut st_graph = backend.init(&entry, 11).unwrap();
    let mut st_ref = st_graph.clone();
    let mut ex = Exec::new(2);

    // Mixed precision schedule cycling every paper-relevant regime,
    // with non-trivial lr scales, weight decay, and a loss scale.
    let schedules: [[i32; 4]; 4] = [
        [FP16, BF16, FP32, BF16],
        [BF16, BF16, BF16, FP32],
        [FP32, FP32, FP32, FP32],
        [FP16, FP16, BF16, FP16],
    ];
    let mut trace_graph: Vec<u64> = Vec::new();
    let mut trace_ref: Vec<u64> = Vec::new();

    for step in 0..20u64 {
        let batch = rand_batch(16, 100 + step);
        let mut ctrl = StepCtrl::uniform(4, FP32, 0.05, 5e-4);
        ctrl.codes = schedules[(step % 4) as usize].to_vec();
        ctrl.loss_scale = 256.0;
        ctrl.lr_scales = vec![1.0, 0.5, 1.5, 1.0];

        let og = backend.train_step(&entry, &mut st_graph, &batch, &ctrl).unwrap();
        let or = ref_train_step(&mut ex, &entry, &mut st_ref, &batch, &ctrl);

        assert_eq!(og.loss.to_bits(), or.loss.to_bits(), "step {step}: loss");
        assert_eq!(og.correct, or.correct, "step {step}: correct");
        assert_eq!(og.overflow, or.overflow, "step {step}: overflow");
        for li in 0..4 {
            assert_eq!(
                og.grad_var[li].to_bits(),
                or.grad_var[li].to_bits(),
                "step {step}: grad_var[{li}]"
            );
            assert_eq!(
                og.grad_norm[li].to_bits(),
                or.grad_norm[li].to_bits(),
                "step {step}: grad_norm[{li}]"
            );
        }
        assert_eq!(st_graph, st_ref, "step {step}: params/momentum/BN state diverged");

        for (trace, loss, gv, gn) in [
            (&mut trace_graph, og.loss, &og.grad_var, &og.grad_norm),
            (&mut trace_ref, or.loss, &or.grad_var, &or.grad_norm),
        ] {
            trace.push(loss.to_bits() as u64);
            trace.extend(gv.iter().map(|v| v.to_bits() as u64));
            trace.extend(gn.iter().map(|v| v.to_bits() as u64));
        }
    }
    push_state(&mut trace_graph, &st_graph);
    push_state(&mut trace_ref, &st_ref);
    assert_eq!(
        fnv1a(&trace_graph),
        fnv1a(&trace_ref),
        "golden-trace digest mismatch after 20 steps"
    );

    // Eval parity on the trained state (running-stat BN path).
    let eb = rand_batch(16, 999);
    let codes = vec![FP32; 4];
    let ev = backend.eval_batch(&entry, &st_graph, &eb, &codes).unwrap();
    let rf = ref_forward(
        &mut ex,
        &entry,
        &st_ref.params,
        &st_ref.state,
        &eb.x,
        &eb.y,
        eb.n,
        &codes,
        false,
    );
    assert_eq!(ev.loss.to_bits(), rf.loss.to_bits(), "eval loss");
    assert_eq!(ev.correct, rf.correct, "eval correct");
}
