"""qdq Pallas kernel vs pure-jnp oracle — the core numeric-format contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import qdq as qdq_mod
from compile.kernels import ref
from compile.kernels.qdq import qdq

CODES = [ref.FP16, ref.BF16, ref.FP32]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@pytest.mark.parametrize("code", CODES)
@pytest.mark.parametrize(
    "shape", [(7,), (128,), (3, 5), (32, 32, 3), (257,), (2, 130, 130)]
)
def test_qdq_matches_ref(code, shape):
    x = _rand(shape, seed=hash((code, shape)) % 2**31)
    got = qdq(x, jnp.int32(code))
    want = ref.qdq_ref(x, code)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("code", CODES)
def test_qdq_large_multiblock(code):
    # > BLOCK elements with a non-divisible tail — exercises grid + padding.
    n = qdq_mod.BLOCK * 2 + 12345
    x = _rand((n,), seed=1, scale=100.0)
    got = qdq(x, jnp.int32(code))
    want = ref.qdq_ref(x, code)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fp32_is_identity():
    x = _rand((1000,), seed=2, scale=1e30)
    np.testing.assert_array_equal(np.asarray(qdq(x, jnp.int32(ref.FP32))), np.asarray(x))


def test_fp16_overflows_to_inf():
    x = jnp.asarray([1e6, -1e6, 65504.0, 65520.0], jnp.float32)
    out = np.asarray(qdq(x, jnp.int32(ref.FP16)))
    assert np.isinf(out[0]) and np.isinf(out[1]) and out[1] < 0
    assert out[2] == 65504.0  # max finite fp16 survives
    assert np.isinf(out[3])  # rounds up past max finite


def test_bf16_keeps_fp32_range():
    x = jnp.asarray([1e38, -1e38, 1e-38], jnp.float32)
    out = np.asarray(qdq(x, jnp.int32(ref.BF16)))
    assert np.all(np.isfinite(out))


def test_bf16_round_to_nearest_even():
    # 1 + 2^-8 is exactly between bf16(1.0) and bf16(1+2^-7): ties-to-even → 1.0
    x = jnp.asarray([1.0 + 2.0**-8], jnp.float32)
    out = np.asarray(qdq(x, jnp.int32(ref.BF16)))
    assert out[0] == 1.0


def test_qdq_idempotent():
    x = _rand((4096,), seed=3, scale=10.0)
    for code in CODES:
        once = qdq(x, jnp.int32(code))
        twice = qdq(once, jnp.int32(code))
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_qdq_grad_is_quantized():
    # The custom_vjp rounds the cotangent to the same precision.
    x = _rand((64,), seed=4)

    def f(x):
        return jnp.sum(qdq(x, jnp.int32(ref.BF16)) * 3.14159)

    g = jax.grad(f)(x)
    expected = ref.qdq_ref(jnp.full((64,), 3.14159, jnp.float32), ref.BF16)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(expected))


def test_qdq_grad_fp32_identity():
    x = _rand((64,), seed=5)
    g = jax.grad(lambda x: jnp.sum(qdq(x, jnp.int32(ref.FP32)) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x), rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2048),
    code=st.sampled_from(CODES),
    scale=st.floats(min_value=1e-6, max_value=1e6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_qdq_hypothesis_matches_ref(n, code, scale, seed):
    x = _rand((n,), seed=seed, scale=scale)
    got = qdq(x, jnp.int32(code))
    want = ref.qdq_ref(x, code)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    code=st.sampled_from([ref.FP16, ref.BF16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_qdq_error_bounded_by_ulp(code, seed):
    x = _rand((512,), seed=seed)
    out = np.asarray(qdq(x, jnp.int32(code)))
    # Relative error ≤ 2^-mantissa_bits (11 for fp16, 8 for bf16).
    rel = 2.0 ** -(11 if code == ref.FP16 else 8)
    np.testing.assert_allclose(out, np.asarray(x), rtol=rel, atol=1e-7)


def test_qdq_under_jit():
    x = _rand((300,), seed=6)
    f = jax.jit(lambda x, c: qdq(x, c))
    for code in CODES:
        got = f(x, jnp.int32(code))
        want = ref.qdq_ref(x, code)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
