fn resident_pages() -> Option<u64> {
    // A prose mention of /proc/self/statm in a comment is not a read.
    // detlint: allow(d2) — fixture: host-meter read feeding telemetry
    // only, never a deterministic artifact.
    let text = std::fs::read_to_string("/proc/self/statm").ok()?;
    text.split_whitespace().nth(1)?.parse().ok()
}
