"""CIFAR ResNet-18 (He et al. 2016), the paper's primary architecture.

Standard CIFAR variant: 3×3 stem (no 7×7/maxpool), 4 stages × 2 BasicBlocks
with widths (64, 128, 256, 512) and strides (1, 2, 2, 2), global average
pool, dense head. 21 precision layers (17 main convs + 3 downsample convs
+ head), ~11.2M params — matching the paper's setup.
"""

from __future__ import annotations

import jax.nn

from . import common as C

NAME = "resnet18"

STAGES = ((64, 1), (128, 2), (256, 2), (512, 2))
BLOCKS_PER_STAGE = 2


def _basic_block(store: C.Store, name: str, x, features: int, stride: int):
    identity = x
    out = C.conv2d(store, f"{name}/conv1", x, features, kernel=3, stride=stride)
    out = C.batchnorm(store, f"{name}/bn1", out)
    out = jax.nn.relu(out)
    out = C.conv2d(store, f"{name}/conv2", out, features, kernel=3)
    out = C.batchnorm(store, f"{name}/bn2", out)
    if stride != 1 or x.shape[-1] != features:
        identity = C.conv2d(store, f"{name}/down", x, features, kernel=1, stride=stride)
        identity = C.batchnorm(store, f"{name}/bn_down", identity)
    return jax.nn.relu(out + identity)


def make_forward(num_classes: int):
    def forward(store: C.Store, x):
        x = C.conv2d(store, "stem", x, 64, kernel=3)
        x = C.batchnorm(store, "bn_stem", x)
        x = jax.nn.relu(x)
        for si, (features, stride) in enumerate(STAGES):
            for bi in range(BLOCKS_PER_STAGE):
                s = stride if bi == 0 else 1
                x = _basic_block(store, f"stage{si}/block{bi}", x, features, s)
        x = C.global_avg_pool(x)
        return C.dense(store, "head", x, num_classes)

    return forward


def build(num_classes: int = 10, seed: int = 0) -> C.Model:
    return C.build_model(NAME, num_classes, make_forward(num_classes), seed=seed)
