//! The pluggable runtime backend abstraction.
//!
//! A [`Backend`] executes the four entry points the manifest contract
//! names — `init`, `train_b{n}`, `eval_b{n}`, `curv` — over plain host
//! `f32` vectors. Everything above this trait (Session, Trainer,
//! harness, CLI) is backend-agnostic; everything below it owns the
//! compute: the built-in pure-Rust reference executor
//! ([`super::native::NativeBackend`]), the PJRT/XLA artifact executor
//! (`--features pjrt`), and any future CUDA / remote backend.
//!
//! IO orderings mirror the manifest `io` contract exactly:
//!   train: params*N, mom*N, state*S, x, y, codes, lr_scales, lr,
//!          loss_scale, wd -> params*N, mom*N, state*S, loss, correct,
//!          grad_var, grad_norm, overflow
//!   eval:  params*N, state*S, x, y, codes -> loss, correct
//!   curv:  params*N, state*S, x, y, u*N, codes -> u_next*N, lambdas
//!   init:  seed -> params*N, state*S

use anyhow::Result;

use super::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::manifest::ModelEntry;

/// Host-resident model state: flat `f32` tensors ordered positionally
/// per the manifest (`entry.params` for params/momentum,
/// `entry.state_shapes` for BN state).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelState {
    pub params: Vec<Vec<f32>>,
    pub mom: Vec<Vec<f32>>,
    pub state: Vec<Vec<f32>>,
}

/// A runtime executor for the manifest's model entry points.
///
/// Contract (enforced by the conformance suite in
/// `tests/backend_conformance.rs`):
/// * all four calls are deterministic functions of their inputs;
/// * `init` is deterministic per seed and seed-sensitive;
/// * `train_step` mutates `st` in place, EXCEPT when it reports
///   `overflow` — then params/momentum/state are left untouched;
/// * `grad_var`/`grad_norm`/`curv` lambdas have `entry.num_layers`
///   arity; `eval` reports `total == batch.n`.
pub trait Backend {
    /// Short platform name for logs/CLI (e.g. "native-cpu", "pjrt-cpu").
    fn name(&self) -> &'static str;

    /// Can this backend execute `entry`? (The native backend implements
    /// `tiny_cnn`; the PJRT backend anything with compiled artifacts.)
    fn supports(&self, entry: &ModelEntry) -> bool;

    /// Materialize params + zero momentum + BN state from `seed`.
    fn init(&self, entry: &ModelEntry, seed: i32) -> Result<ModelState>;

    /// One optimizer step (the `train_b{n}` entry point).
    fn train_step(
        &self,
        entry: &ModelEntry,
        st: &mut ModelState,
        batch: &Batch,
        ctrl: &StepCtrl,
    ) -> Result<TrainOutputs>;

    /// One eval batch (the `eval_b{n}` entry point).
    fn eval_batch(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        codes: &[i32],
    ) -> Result<EvalResult>;

    /// One amortized power-iteration step (the `curv` entry point).
    /// Updates `probes` in place and returns per-layer Rayleigh
    /// quotients λ_l.
    fn curv_step(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        probes: &mut [Vec<f32>],
        codes: &[i32],
    ) -> Result<Vec<f32>>;

    /// Data-parallel replica engines this backend holds (the elastic
    /// ceiling). Non-replicated backends report 1.
    fn replica_capacity(&self) -> usize {
        1
    }

    /// Replicas currently executing shards (`1..=replica_capacity`).
    fn live_replicas(&self) -> usize {
        1
    }

    /// Elastically set the live replica count, clamped to
    /// `1..=replica_capacity`. The replicated native backend guarantees
    /// this never changes training numerics (canonical batch shards +
    /// ordered reduction); non-replicated backends ignore it.
    fn set_live_replicas(&self, _n: usize) {}
}
