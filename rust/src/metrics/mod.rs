//! Metrics substrate: the paper's four measurement axes (§4.2) — top-1
//! accuracy, time per epoch, peak VRAM, aggregate efficiency score —
//! plus the traces §4.2 says are logged (effective batch size) and the
//! adaptive-behaviour series the abstract describes (efficiency over
//! training). CSV/JSON writers for offline plotting, plus the
//! schema-versioned streaming [`telemetry`] events the experiment
//! scheduler persists as JSONL (`docs/TELEMETRY.md`).

// Enforced as an error by the docs CI job (`cargo doc` with
// `RUSTDOCFLAGS=-D warnings`); kept at `warn` here so tier-1
// `cargo build`/`cargo test` never hard-fails on a doc regression.
#![warn(missing_docs)]

pub mod telemetry;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::manifest::{BF16, FP16, FP32};
use crate::util::json::Json;

/// The paper's aggregate efficiency score (§4.2):
///
/// ```text
/// Score = Accuracy(%) / (Time(s) × MemoryUsage(%)) × 100
/// ```
///
/// Table 1 is consistent with MemoryUsage(%) = VRAM_GB × 100 (e.g.
/// 77.0 / (21.0 × 35) × 100 = 10.48 for the FP32 ResNet row), i.e. the
/// score reduces to `acc / (time × vram_gb)`.
pub fn efficiency_score(acc_pct: f64, time_s: f64, vram_gb: f64) -> f64 {
    if time_s <= 0.0 || vram_gb <= 0.0 {
        return 0.0;
    }
    acc_pct / (time_s * vram_gb)
}

/// Precision-mix summary of a codes vector: fraction of layers at each
/// precision (telemetry for the adaptive-behaviour figure).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrecisionMix {
    /// Fraction of precision layers computing in FP16.
    pub fp16: f64,
    /// Fraction of precision layers computing in BF16.
    pub bf16: f64,
    /// Fraction of precision layers computing in FP32.
    pub fp32: f64,
}

impl PrecisionMix {
    /// Fractions of each precision code in a per-layer codes vector.
    pub fn of(codes: &[i32]) -> PrecisionMix {
        if codes.is_empty() {
            return PrecisionMix::default();
        }
        let n = codes.len() as f64;
        PrecisionMix {
            fp16: codes.iter().filter(|&&c| c == FP16).count() as f64 / n,
            bf16: codes.iter().filter(|&&c| c == BF16).count() as f64 / n,
            fp32: codes.iter().filter(|&&c| c == FP32).count() as f64 / n,
        }
    }
}

/// One epoch's record — one row of the per-run log.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Optimizer steps taken this epoch.
    pub steps: u64,
    /// Mean training loss over the epoch's steps.
    pub train_loss: f64,
    /// Training accuracy (%) over the examples consumed this epoch.
    pub train_acc: f64,
    /// Test loss from the end-of-epoch evaluation.
    pub test_loss: f64,
    /// Test accuracy (%) from the end-of-epoch evaluation.
    pub test_acc: f64,
    /// Examples consumed this epoch (varies with elastic batching).
    pub examples: usize,
    /// Measured wallclock for the epoch's train steps (CPU substrate).
    pub wall_s: f64,
    /// Analytic accelerator-terms seconds (DESIGN.md §5 speed model),
    /// raw over the steps actually taken.
    pub modeled_s: f64,
    /// `modeled_s` normalized to one *nominal* epoch (train_examples
    /// examples) — the Table-1 comparable: reduced-step runs and elastic
    /// batch sizes otherwise distort per-epoch time.
    pub modeled_s_norm: f64,
    /// Peak simulated VRAM (GiB) over the run so far.
    pub peak_vram_gb: f64,
    /// Mean effective batch size over the epoch's steps.
    pub mean_batch: f64,
    /// Per-layer precision mix at epoch end.
    pub mix: PrecisionMix,
    /// Learning rate at the epoch's final step.
    pub lr: f64,
    /// Live loss scale at epoch end.
    pub loss_scale: f64,
    /// The §4.2 aggregate efficiency score on normalized modeled time.
    pub eff_score: f64,
}

/// Full run log: epoch rows plus the §4.2 effective-batch-size trace and
/// the control-decision counters.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// One record per completed epoch, in order.
    pub epochs: Vec<EpochRecord>,
    /// (step, batch size) — recorded at every change plus epoch marks.
    pub batch_trace: Vec<(u64, usize)>,
    /// Precision-policy layer transitions over the run.
    pub precision_transitions: u64,
    /// Curvature-driven precision promotions over the run.
    pub promotions: u64,
    /// Loss-scaler overflow events over the run.
    pub overflows: u64,
    /// Simulated out-of-memory events over the run.
    pub oom_events: u64,
    /// Curvature probe steps executed over the run.
    pub curv_firings: u64,
    /// §3.4 control windows evaluated (policy-decision telemetry).
    pub ctrl_windows: u64,
    /// Batch-policy moves + vetoes decided (0 for static baselines).
    pub batch_decisions: u64,
    /// Replica-policy sheds + restores + vetoes decided (0 unless an
    /// `elastic_replicas` method runs with `--replicas > 1`).
    pub replica_decisions: u64,
    /// Smallest live replica count over the run's steps (how far the
    /// elastic policy shed under pressure; 0 until a step records).
    pub min_replicas: usize,
}

impl RunMetrics {
    /// Record the live batch size at `step` (deduplicates consecutive
    /// identical values — the §4.2 effective-batch-size trace).
    pub fn record_batch(&mut self, step: u64, b: usize) {
        if self.batch_trace.last().map(|&(_, pb)| pb) != Some(b) {
            self.batch_trace.push((step, b));
        }
    }

    /// Record the live replica count a step ran with (keeps the min).
    pub fn record_replicas(&mut self, r: usize) {
        if self.min_replicas == 0 || r < self.min_replicas {
            self.min_replicas = r;
        }
    }

    /// Test accuracy (%) of the final epoch (0 if no epochs ran).
    pub fn final_test_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    /// Peak simulated VRAM (GiB) over all epochs.
    pub fn peak_vram_gb(&self) -> f64 {
        self.epochs.iter().map(|e| e.peak_vram_gb).fold(0.0, f64::max)
    }

    /// Time/epoch averaged over the last `k` epochs (paper §4.2 averages
    /// the final five to mitigate data-loading variance).
    pub fn time_per_epoch(&self, k: usize, modeled: bool) -> f64 {
        let n = self.epochs.len();
        if n == 0 {
            return 0.0;
        }
        let take = k.min(n).max(1);
        let slice = &self.epochs[n - take..];
        let sum: f64 = slice
            .iter()
            .map(|e| if modeled { e.modeled_s_norm } else { e.wall_s })
            .sum();
        sum / take as f64
    }

    /// CSV of the epoch rows.
    pub fn epochs_csv(&self) -> String {
        let mut s = String::from(
            "epoch,steps,examples,train_loss,train_acc,test_loss,test_acc,wall_s,modeled_s,modeled_s_norm,\
             peak_vram_gb,mean_batch,fp16_frac,bf16_frac,fp32_frac,lr,loss_scale,eff_score\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{},{},{:.6},{:.4},{:.6},{:.4},{:.4},{:.4},{:.4},{:.5},{:.2},{:.3},{:.3},{:.3},{:.6},{},{:.4}\n",
                e.epoch,
                e.steps,
                e.examples,
                e.train_loss,
                e.train_acc,
                e.test_loss,
                e.test_acc,
                e.wall_s,
                e.modeled_s,
                e.modeled_s_norm,
                e.peak_vram_gb,
                e.mean_batch,
                e.mix.fp16,
                e.mix.bf16,
                e.mix.fp32,
                e.lr,
                e.loss_scale,
                e.eff_score,
            ));
        }
        s
    }

    /// CSV of the batch-size trace (the §4.2 log).
    pub fn batch_trace_csv(&self) -> String {
        let mut s = String::from("step,batch\n");
        for &(st, b) in &self.batch_trace {
            s.push_str(&format!("{st},{b}\n"));
        }
        s
    }

    /// The full run log as one JSON document (`runs/<tag>.json`).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "epochs".into(),
            Json::Arr(
                self.epochs
                    .iter()
                    .map(|e| {
                        let mut m = BTreeMap::new();
                        let mut put = |k: &str, v: f64| {
                            m.insert(k.to_string(), Json::Num(v));
                        };
                        put("epoch", e.epoch as f64);
                        put("steps", e.steps as f64);
                        put("examples", e.examples as f64);
                        put("modeled_s_norm", e.modeled_s_norm);
                        put("train_loss", e.train_loss);
                        put("train_acc", e.train_acc);
                        put("test_loss", e.test_loss);
                        put("test_acc", e.test_acc);
                        put("wall_s", e.wall_s);
                        put("modeled_s", e.modeled_s);
                        put("peak_vram_gb", e.peak_vram_gb);
                        put("mean_batch", e.mean_batch);
                        put("fp16_frac", e.mix.fp16);
                        put("bf16_frac", e.mix.bf16);
                        put("fp32_frac", e.mix.fp32);
                        put("lr", e.lr);
                        put("loss_scale", e.loss_scale);
                        put("eff_score", e.eff_score);
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert(
            "batch_trace".into(),
            Json::Arr(
                self.batch_trace
                    .iter()
                    .map(|&(s, b)| Json::Arr(vec![Json::Num(s as f64), Json::Num(b as f64)]))
                    .collect(),
            ),
        );
        let mut counters = BTreeMap::new();
        counters.insert("precision_transitions".into(), Json::Num(self.precision_transitions as f64));
        counters.insert("promotions".into(), Json::Num(self.promotions as f64));
        counters.insert("overflows".into(), Json::Num(self.overflows as f64));
        counters.insert("oom_events".into(), Json::Num(self.oom_events as f64));
        counters.insert("curv_firings".into(), Json::Num(self.curv_firings as f64));
        counters.insert("ctrl_windows".into(), Json::Num(self.ctrl_windows as f64));
        counters.insert("batch_decisions".into(), Json::Num(self.batch_decisions as f64));
        counters.insert("replica_decisions".into(), Json::Num(self.replica_decisions as f64));
        obj.insert("counters".into(), Json::Obj(counters));
        Json::Obj(obj)
    }

    /// Write the epoch CSV, batch-trace CSV, and JSON log under `dir`
    /// with the given file-name `tag`.
    pub fn write(&self, dir: &Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        std::fs::write(dir.join(format!("{tag}_epochs.csv")), self.epochs_csv())?;
        std::fs::write(dir.join(format!("{tag}_batch_trace.csv")), self.batch_trace_csv())?;
        std::fs::write(
            dir.join(format!("{tag}.json")),
            self.to_json().to_string_compact(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, acc: f64, wall: f64, peak: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            steps: 100,
            train_loss: 1.0,
            train_acc: acc - 1.0,
            test_loss: 1.2,
            test_acc: acc,
            examples: 9600,
            wall_s: wall,
            modeled_s: wall / 10.0,
            modeled_s_norm: wall,
            peak_vram_gb: peak,
            mean_batch: 96.0,
            mix: PrecisionMix { fp16: 0.2, bf16: 0.5, fp32: 0.3 },
            lr: 0.1,
            loss_scale: 1024.0,
            eff_score: efficiency_score(acc, wall, peak),
        }
    }

    #[test]
    fn score_matches_paper_table1_rows() {
        // CIFAR-10 / ResNet-18 rows of Table 1.
        assert!((efficiency_score(77.0, 21.0, 0.35) - 10.48).abs() < 0.01);
        assert!((efficiency_score(77.2, 19.4, 0.32) - 12.25).abs() < 0.20);
        assert!((efficiency_score(78.1, 19.5, 0.31) - 12.92).abs() < 0.01);
        // EfficientNet CIFAR-100 row.
        assert!((efficiency_score(74.3, 19.0, 0.29) - 13.48).abs() < 0.01);
    }

    #[test]
    fn score_guards_degenerate_inputs() {
        assert_eq!(efficiency_score(50.0, 0.0, 0.3), 0.0);
        assert_eq!(efficiency_score(50.0, 10.0, 0.0), 0.0);
    }

    #[test]
    fn precision_mix_fractions() {
        let m = PrecisionMix::of(&[FP16, BF16, BF16, FP32]);
        assert!((m.fp16 - 0.25).abs() < 1e-12);
        assert!((m.bf16 - 0.50).abs() < 1e-12);
        assert!((m.fp32 - 0.25).abs() < 1e-12);
        assert_eq!(PrecisionMix::of(&[]), PrecisionMix::default());
    }

    #[test]
    fn batch_trace_dedupes_consecutive() {
        let mut m = RunMetrics::default();
        m.record_batch(0, 96);
        m.record_batch(5, 96);
        m.record_batch(10, 128);
        m.record_batch(20, 128);
        m.record_batch(30, 96);
        assert_eq!(m.batch_trace, vec![(0, 96), (10, 128), (30, 96)]);
    }

    #[test]
    fn time_per_epoch_last_k() {
        let mut m = RunMetrics::default();
        for (i, w) in [100.0, 100.0, 10.0, 20.0, 30.0].iter().enumerate() {
            m.epochs.push(rec(i, 70.0, *w, 0.3));
        }
        assert!((m.time_per_epoch(3, false) - 20.0).abs() < 1e-9);
        assert!((m.time_per_epoch(99, false) - 52.0).abs() < 1e-9, "clamps to n");
        assert_eq!(RunMetrics::default().time_per_epoch(5, false), 0.0);
    }

    #[test]
    fn csv_and_json_roundtrip_shapes() {
        let mut m = RunMetrics::default();
        m.epochs.push(rec(0, 70.0, 10.0, 0.3));
        m.record_batch(0, 96);
        let csv = m.epochs_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("eff_score"));
        let j = Json::parse(&m.to_json().to_string_compact()).unwrap();
        assert_eq!(j.req("epochs").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.req("counters").unwrap().get("oom_events").is_some());
    }

    #[test]
    fn write_creates_files() {
        let mut m = RunMetrics::default();
        m.epochs.push(rec(0, 70.0, 10.0, 0.3));
        let dir = std::env::temp_dir().join(format!("triaccel_metrics_{}", std::process::id()));
        m.write(&dir, "t").unwrap();
        assert!(dir.join("t_epochs.csv").exists());
        assert!(dir.join("t_batch_trace.csv").exists());
        assert!(dir.join("t.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peak_and_final_acc() {
        let mut m = RunMetrics::default();
        m.epochs.push(rec(0, 60.0, 10.0, 0.30));
        m.epochs.push(rec(1, 70.0, 10.0, 0.35));
        m.epochs.push(rec(2, 75.0, 10.0, 0.32));
        assert_eq!(m.final_test_acc(), 75.0);
        assert_eq!(m.peak_vram_gb(), 0.35);
    }
}
