//! Table/figure regeneration harness (DESIGN.md §3): runs the paper's
//! (dataset × architecture × method) grid over seeds and prints rows in
//! Table 1 / Table 2 format with mean±std, exactly the §4.3 protocol
//! ("each experiment is repeated 3 times with different random seeds").
//!
//! Absolute numbers live on this CPU substrate; the *shape* — method
//! ordering, memory reductions, ablation progression — is the
//! reproduction target (repro band 0/5 ⇒ simulated hardware, DESIGN.md
//! §5).
//!
//! The unit of execution is [`run_seed`]: one (model, method, seed)
//! run producing a [`SeedResult`]. Everything above it — the serial
//! [`table1`]/[`table2`]/[`pressure`] helpers here and the parallel
//! [`crate::sched`] grid scheduler — composes seed runs and reduces
//! them with [`aggregate_cell`]/[`aggregate_pressure`], which sort by
//! seed before reducing so the aggregate is independent of execution
//! order (serial, parallel, or resumed-from-ledger).

use anyhow::{Context, Result};

use crate::config::{Ablation, Config, Method};

use crate::metrics::efficiency_score;
use crate::metrics::telemetry::TelemetrySink;
use crate::runtime::Engine;
use crate::train::Trainer;
use crate::util::json::Json;
use crate::util::stats::Welford;

/// Everything one seed's run contributes to a cell aggregate: the
/// Table-1 scalars plus the decision/survival counters the pressure
/// sweep and `BENCH_grid.json` report. This is the value persisted
/// per job in the scheduler's `ledger.json` (see `docs/TELEMETRY.md`),
/// so it round-trips through JSON exactly ([`Self::to_json`] /
/// [`Self::from_json`]; f64 serialization is shortest-roundtrip).
#[derive(Debug, Clone, PartialEq)]
pub struct SeedResult {
    /// The seed this run trained with.
    pub seed: u64,
    /// Final test accuracy (%).
    pub test_acc_pct: f64,
    /// Wall seconds per epoch (CPU substrate; varies across reruns —
    /// never rendered into deterministic artifacts).
    pub wall_s: f64,
    /// Modeled accelerator seconds per epoch (deterministic).
    pub modeled_s: f64,
    /// Peak simulated VRAM (GiB).
    pub peak_gb: f64,
    /// §4.2 efficiency score.
    pub score: f64,
    /// Simulated OOM events over the run.
    pub oom_events: u64,
    /// Batch-policy decisions (moves + vetoes) over the run.
    pub batch_decisions: u64,
    /// §3.4 control windows evaluated.
    pub ctrl_windows: u64,
    /// Precision-policy layer transitions.
    pub precision_transitions: u64,
    /// Curvature probe steps executed.
    pub curv_firings: u64,
    /// Smallest batch size the run was squeezed to.
    pub min_batch: usize,
    /// Replica-policy decisions (sheds + restores + vetoes) over the
    /// run; 0 for every single-replica or fixed-replica method.
    pub replica_decisions: u64,
    /// Smallest live replica count the run was squeezed to (1 for
    /// single-replica runs).
    pub min_replicas: usize,
}

impl SeedResult {
    /// Serialize for the scheduler ledger / `run_finished` event.
    /// The seed is a decimal *string*: u64 seeds above 2^53 would lose
    /// bits through a JSON number (all other counts here are bounded
    /// by run length and stay numeric).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        let mut put = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        put("test_acc_pct", self.test_acc_pct);
        put("wall_s", self.wall_s);
        put("modeled_s", self.modeled_s);
        put("peak_gb", self.peak_gb);
        put("score", self.score);
        put("oom_events", self.oom_events as f64);
        put("batch_decisions", self.batch_decisions as f64);
        put("ctrl_windows", self.ctrl_windows as f64);
        put("precision_transitions", self.precision_transitions as f64);
        put("curv_firings", self.curv_firings as f64);
        put("min_batch", self.min_batch as f64);
        put("replica_decisions", self.replica_decisions as f64);
        put("min_replicas", self.min_replicas as f64);
        Json::Obj(m)
    }

    /// Parse a [`Self::to_json`] object (ledger resume path). The
    /// replica fields default when absent — ledgers written before the
    /// replica axis existed (implicitly 1 replica, 0 decisions) must
    /// keep resuming.
    pub fn from_json(j: &Json) -> Result<SeedResult> {
        let f = |k: &str| -> Result<f64> {
            j.req(k)?.as_f64().with_context(|| format!("seed result `{k}` not a number"))
        };
        let u = |k: &str| -> Result<u64> {
            j.req(k)?
                .as_i64()
                .and_then(|v| u64::try_from(v).ok())
                .with_context(|| format!("seed result `{k}` not a count"))
        };
        let u_opt = |k: &str, default: u64| -> Result<u64> {
            match j.get(k) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .and_then(|v| u64::try_from(v).ok())
                    .with_context(|| format!("seed result `{k}` not a count")),
            }
        };
        let seed: u64 = j
            .req("seed")?
            .as_str()
            .context("seed result `seed` not a string")?
            .parse()
            .context("seed result `seed` not a u64")?;
        Ok(SeedResult {
            seed,
            test_acc_pct: f("test_acc_pct")?,
            wall_s: f("wall_s")?,
            modeled_s: f("modeled_s")?,
            peak_gb: f("peak_gb")?,
            score: f("score")?,
            oom_events: u("oom_events")?,
            batch_decisions: u("batch_decisions")?,
            ctrl_windows: u("ctrl_windows")?,
            precision_transitions: u("precision_transitions")?,
            curv_firings: u("curv_firings")?,
            min_batch: u("min_batch")? as usize,
            replica_decisions: u_opt("replica_decisions", 0)?,
            min_replicas: u_opt("min_replicas", 1)? as usize,
        })
    }
}

/// Run one fully-specified config (model/method/seed all inside `cfg`)
/// and condense it to a [`SeedResult`]. This is the single entry point
/// both the serial helpers and the parallel scheduler execute, so a
/// grid cell's numbers cannot depend on which path ran it. An optional
/// telemetry sink streams the per-step JSONL events.
pub fn run_seed(
    engine: &Engine,
    cfg: Config,
    telemetry: Option<Box<dyn TelemetrySink>>,
) -> Result<SeedResult> {
    let seed = cfg.seed;
    let mut tr = Trainer::new(engine, cfg)?;
    if let Some(sink) = telemetry {
        tr.set_telemetry(sink);
    }
    let s = tr.run()?;
    let min_batch = tr
        .metrics
        .batch_trace
        .iter()
        .map(|&(_, b)| b)
        .min()
        .unwrap_or(0);
    Ok(SeedResult {
        seed,
        test_acc_pct: s.test_acc_pct,
        wall_s: s.wall_s_per_epoch,
        modeled_s: s.modeled_s_per_epoch,
        peak_gb: s.peak_vram_gb,
        score: s.eff_score,
        oom_events: tr.metrics.oom_events,
        batch_decisions: tr.metrics.batch_decisions,
        ctrl_windows: tr.metrics.ctrl_windows,
        precision_transitions: tr.metrics.precision_transitions,
        curv_firings: tr.metrics.curv_firings,
        min_batch,
        replica_decisions: tr.metrics.replica_decisions,
        min_replicas: tr.metrics.min_replicas.max(1),
    })
}

/// Normalize a CLI seed list: sorted ascending and deduplicated.
///
/// Every aggregate divides by the number of *runs*, so a duplicated
/// seed (`--seeds 0,0,1`) used to both waste a run and silently weight
/// one seed double in the mean±std denominators. Sorting additionally
/// fixes the reduction order: aggregates are identical however the
/// seeds were listed.
pub fn normalize_seeds(seeds: &[u64]) -> Vec<u64> {
    let mut s = seeds.to_vec();
    s.sort_unstable();
    s.dedup();
    s
}

/// Aggregate of one (model, method, config) cell over seeds.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub model_key: String,
    pub label: String,
    pub acc: Welford,
    pub wall_s: Welford,
    pub modeled_s: Welford,
    pub peak_gb: Welford,
    pub score: Welford,
}

impl CellResult {
    pub fn row(&self) -> String {
        format!(
            "{:<18} {:<16} acc {:>5.1}±{:>4.2}%  time {:>7.2}±{:.2}s (wall {:>6.2}s)  vram {:>6.4}±{:.4}GB  score {:>6.2}",
            self.model_key,
            self.label,
            self.acc.mean(),
            self.acc.std(),
            self.modeled_s.mean(),
            self.modeled_s.std(),
            self.wall_s.mean(),
            self.peak_gb.mean(),
            self.peak_gb.std(),
            self.score.mean(),
        )
    }
}

/// Sort per-seed results by seed and reject duplicates — the shared
/// front half of every cell reduction. Sorting here is what makes the
/// aggregates *provably* independent of scheduler completion order:
/// Welford accumulation is order-sensitive in the last float bits, so
/// every path (serial loop, `--jobs N` pool, ledger resume) reduces in
/// the same canonical order.
fn sorted_by_seed(results: &[SeedResult]) -> Result<Vec<SeedResult>> {
    anyhow::ensure!(!results.is_empty(), "cell aggregation needs at least one seed result");
    let mut rs = results.to_vec();
    rs.sort_by_key(|r| r.seed);
    for w in rs.windows(2) {
        anyhow::ensure!(
            w[0].seed != w[1].seed,
            "duplicate seed {} in cell aggregation (seed lists must be deduplicated)",
            w[0].seed
        );
    }
    Ok(rs)
}

/// Reduce per-seed results to one Table-1/2 cell row. Results are
/// sorted by seed internally (see [`normalize_seeds`] for the CLI-side
/// dedup), so the output is bit-identical for any input order.
pub fn aggregate_cell(model_key: &str, label: &str, results: &[SeedResult]) -> Result<CellResult> {
    let rs = sorted_by_seed(results)?;
    let mut cell = CellResult {
        model_key: model_key.to_string(),
        label: label.to_string(),
        acc: Welford::default(),
        wall_s: Welford::default(),
        modeled_s: Welford::default(),
        peak_gb: Welford::default(),
        score: Welford::default(),
    };
    for r in &rs {
        cell.acc.push(r.test_acc_pct);
        cell.wall_s.push(r.wall_s);
        cell.modeled_s.push(r.modeled_s);
        cell.peak_gb.push(r.peak_gb);
        cell.score.push(r.score);
    }
    Ok(cell)
}

/// Run one cell (fixed model/method/ablation) across `seeds`, applying
/// `tweak` to each seed's config (epoch budget etc.). Seeds are
/// normalized ([`normalize_seeds`]) so duplicates neither rerun nor
/// skew the mean±std denominators.
pub fn run_cell(
    engine: &Engine,
    model_key: &str,
    method: Method,
    label: &str,
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<CellResult> {
    let seeds = normalize_seeds(seeds);
    let mut results = Vec::with_capacity(seeds.len());
    for &seed in &seeds {
        let mut cfg = Config::cell(model_key, method, seed);
        tweak(&mut cfg);
        results.push(run_seed(engine, cfg, None)?);
    }
    aggregate_cell(model_key, label, &results)
}

/// Table 1: methods × model keys. Returns rows in paper order.
pub fn table1(
    engine: &Engine,
    model_keys: &[&str],
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<CellResult>> {
    let mut rows = Vec::new();
    for key in model_keys {
        for method in [Method::Fp32, Method::AmpStatic, Method::TriAccel] {
            rows.push(run_cell(engine, key, method, method.name(), seeds, tweak)?);
        }
    }
    Ok(rows)
}

/// The Table-2 ablation rows for one model, in paper order: (label,
/// family, toggles). Shared by the serial helper below and the
/// scheduler's grid builder so the two can never drift.
pub const TABLE2_ROWS: [(&str, Method, Ablation); 4] = [
    ("Standard Training", Method::Fp32, Ablation::none()),
    (
        "+ Dynamic Batch",
        Method::TriAccel,
        Ablation { dynamic_precision: false, dynamic_batch: true, curvature: false },
    ),
    (
        "+ Dynamic Precision",
        Method::TriAccel,
        Ablation { dynamic_precision: true, dynamic_batch: false, curvature: false },
    ),
    ("+ Full Tri-Accel", Method::TriAccel, Ablation::full()),
];

/// Table 2 ablation rows for one model: standard, +batch, +precision,
/// full (paper order).
pub fn table2(
    engine: &Engine,
    model_key: &str,
    seeds: &[u64],
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<CellResult>> {
    let mut rows = Vec::new();
    for (label, method, ablation) in TABLE2_ROWS {
        let t = move |cfg: &mut Config| {
            cfg.ablation = ablation;
            tweak(cfg);
        };
        rows.push(run_cell(engine, model_key, method, label, seeds, &t)?);
    }
    Ok(rows)
}

/// Print Table 2 with the paper's "Reduction" column (vs the first row).
pub fn print_table2(rows: &[CellResult]) {
    let base = rows[0].peak_gb.mean();
    println!("{:<22} {:>10} {:>10}", "Configuration", "VRAM (GB)", "Reduction");
    for (i, r) in rows.iter().enumerate() {
        let red = if i == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", 100.0 * (base - r.peak_gb.mean()) / base)
        };
        println!("{:<22} {:>10.4} {:>10}", r.label, r.peak_gb.mean(), red);
    }
}

/// The adaptive-behaviour figure (abstract: "efficiency gradually
/// improving over the course of training"): per-epoch efficiency-score
/// and batch-size series for one Tri-Accel run.
pub struct AdaptiveTrace {
    pub epoch_eff: Vec<(usize, f64)>,
    pub batch_trace: Vec<(u64, usize)>,
    pub mix_trace: Vec<(usize, f64, f64, f64)>,
}

pub fn fig_adaptive(
    engine: &Engine,
    model_key: &str,
    seed: u64,
    tweak: &dyn Fn(&mut Config),
) -> Result<AdaptiveTrace> {
    let mut cfg = Config::cell(model_key, Method::TriAccel, seed);
    tweak(&mut cfg);
    let mut tr = Trainer::new(engine, cfg)?;
    tr.run()?;
    let epoch_eff = tr
        .metrics
        .epochs
        .iter()
        .map(|e| (e.epoch, e.eff_score))
        .collect();
    let mix_trace = tr
        .metrics
        .epochs
        .iter()
        .map(|e| (e.epoch, e.mix.fp16, e.mix.bf16, e.mix.fp32))
        .collect();
    Ok(AdaptiveTrace {
        epoch_eff,
        batch_trace: tr.metrics.batch_trace.clone(),
        mix_trace,
    })
}

/// Shared "small budget" tweak used by the bench targets so `cargo
/// bench` completes in minutes on this single-core CPU substrate;
/// `reproduce_tables` exposes knobs for bigger runs.
///
/// `batch_init` drops to 64 (the smallest bucket above b_curv): the
/// memory model and controller dynamics are batch-relative, so the
/// Table-1/2 *shape* is preserved while a full B=96 CPU step budget
/// would make regeneration needlessly slow. The paper's B=96 is
/// restored by `--set batch_init=96` / env overrides.
pub fn quick_budget(steps: usize, epochs: usize) -> impl Fn(&mut Config) {
    move |cfg: &mut Config| {
        cfg.steps_per_epoch = Some(steps);
        cfg.epochs = epochs;
        cfg.train_examples = 4096;
        cfg.eval_examples = 128;
        // B=64 keeps the paper's b_curv(32) < B geometry so probe
        // buffers hide under the activation headroom (memsim test
        // `paper_geometry_probe_hides_under_activation_headroom`).
        cfg.batch_init = 64;
        // Place the utilization band so the BF16 footprint (~0.65 of
        // the strict budget) holds rather than grows — the paper's
        // shrink-or-hold Table-2 regime.
        cfg.rho_low = 0.55;
        cfg.t_ctrl = 3;
        cfg.t_curv = 4;
        cfg.curv_warmup = 1;
        cfg.batch_cooldown = 4;
        cfg.warmup_epochs = 1;
        cfg.mem_budget_gb = 0.0; // auto: strict budget around the workload
    }
}

/// Report the headline abstract claims from a Table-1 triple
/// (FP32, AMP, Tri-Accel) — % time reduction, % memory reduction,
/// accuracy delta — so EXPERIMENTS.md can quote ours vs the paper's.
pub fn headline(fp32: &CellResult, tri: &CellResult) -> String {
    let dt = 100.0 * (fp32.modeled_s.mean() - tri.modeled_s.mean()) / fp32.modeled_s.mean();
    let dm = 100.0 * (fp32.peak_gb.mean() - tri.peak_gb.mean()) / fp32.peak_gb.mean();
    let da = tri.acc.mean() - fp32.acc.mean();
    format!(
        "vs FP32: time −{dt:.1}%  memory −{dm:.1}%  accuracy {}{da:.1}pp  score ×{:.2}",
        if da >= 0.0 { "+" } else { "" },
        tri.score.mean() / fp32.score.mean().max(1e-9),
    )
}

/// Aggregate of one (model, method, trace) pressure cell over seeds:
/// how a method behaves when the budget moves under it.
#[derive(Debug, Clone)]
pub struct PressureCell {
    pub method_key: String,
    pub label: String,
    pub acc: Welford,
    pub peak_gb: Welford,
    pub score: Welford,
    /// Simulated OOMs across seeds (a real static-batch run would have
    /// crashed at the first one).
    pub oom_events: u64,
    /// Batch-policy decisions (moves + vetoes) across seeds.
    pub batch_decisions: u64,
    /// Smallest batch the run was squeezed to (min over seeds).
    pub min_batch: usize,
    /// Replica-policy decisions (sheds + restores + vetoes) across seeds.
    pub replica_decisions: u64,
    /// Smallest live replica count any seed was squeezed to.
    pub min_replicas: usize,
}

/// Reduce per-seed results to one pressure-sweep row. All reductions —
/// mean±std *and* the min-over-seeds `min_batch` and summed counters —
/// happen here on the numeric values (never on formatted output), in
/// canonical seed order.
pub fn aggregate_pressure(
    method_key: &str,
    label: &str,
    results: &[SeedResult],
) -> Result<PressureCell> {
    let rs = sorted_by_seed(results)?;
    let mut cell = PressureCell {
        method_key: method_key.to_string(),
        label: label.to_string(),
        acc: Welford::default(),
        peak_gb: Welford::default(),
        score: Welford::default(),
        oom_events: 0,
        batch_decisions: 0,
        min_batch: usize::MAX,
        replica_decisions: 0,
        min_replicas: usize::MAX,
    };
    for r in &rs {
        cell.acc.push(r.test_acc_pct);
        cell.peak_gb.push(r.peak_gb);
        cell.score.push(r.score);
        cell.oom_events += r.oom_events;
        cell.batch_decisions += r.batch_decisions;
        cell.min_batch = cell.min_batch.min(r.min_batch);
        cell.replica_decisions += r.replica_decisions;
        cell.min_replicas = cell.min_replicas.min(r.min_replicas);
    }
    Ok(cell)
}

/// The VRAM-pressure scenario sweep (ROADMAP "as many scenarios as you
/// can imagine"): run each registry method under a time-varying budget
/// trace and report survival metrics. This is the stress test the
/// paper's memory-elastic claim (§3.3) implies but Table 1/2 never
/// exercises: the static baselines keep B and accumulate simulated
/// OOMs; the elastic methods shed batch and finish inside the budget.
pub fn pressure(
    engine: &Engine,
    model_key: &str,
    method_keys: &[&str],
    seeds: &[u64],
    trace: &str,
    tweak: &dyn Fn(&mut Config),
) -> Result<Vec<PressureCell>> {
    // Fail on a bad trace or a bad method key before any training
    // burns time — a typo in the last method must not discard minutes
    // of earlier cells. Configs carry the *canonical* spec form
    // (`to_spec`) so a `replay:` trace's content digest is part of
    // every config fingerprint.
    let trace = crate::memsim::BudgetTrace::parse(trace)?.to_spec();
    let specs: Vec<&crate::policy::MethodSpec> = method_keys
        .iter()
        .map(|k| crate::policy::registry::resolve(k.trim()))
        .collect::<Result<_>>()?;
    let seeds = normalize_seeds(seeds);
    let mut rows = Vec::new();
    for spec in specs {
        let mut results = Vec::with_capacity(seeds.len());
        for &seed in &seeds {
            let mut cfg = Config::cell(model_key, spec.family, seed);
            crate::policy::registry::apply(&mut cfg, spec);
            tweak(&mut cfg);
            cfg.mem_trace = trace.to_string();
            results.push(run_seed(engine, cfg, None)?);
        }
        rows.push(aggregate_pressure(spec.key, spec.label, &results)?);
    }
    Ok(rows)
}

/// Pretty-print the pressure sweep (one row per method). `B decs` /
/// `R decs` split the elastic response by lever: batch-ladder moves vs
/// replica sheds/restores (replicas are the numerics-free lever, so an
/// elastic-replica method should show `R_min` dropping before `B_min`).
pub fn print_pressure(rows: &[PressureCell], trace: &str) {
    println!(
        "{:<18} {:>12} {:>10} {:>6} {:>7} {:>7} {:>7} {:>7} {:>8}   (trace {trace})",
        "Method", "Acc(%)", "VRAM(GB)", "OOMs", "B_min", "R_min", "B decs", "R decs", "Score"
    );
    for r in rows {
        let min_b = if r.min_batch == usize::MAX { 0 } else { r.min_batch };
        let min_r = if r.min_replicas == usize::MAX { 0 } else { r.min_replicas };
        let acc = format!("{:.1}±{:.2}", r.acc.mean(), r.acc.std());
        println!(
            "{:<18} {:>12} {:>10.4} {:>6} {:>7} {:>7} {:>7} {:>7} {:>8.2}",
            r.label,
            acc,
            r.peak_gb.mean(),
            r.oom_events,
            min_b,
            min_r,
            r.batch_decisions,
            r.replica_decisions,
            r.score.mean(),
        );
    }
}

/// Validate CLI-supplied model keys against the engine's manifest
/// before any session spins up — unknown keys fail at argument-parse
/// time with the supported-model list instead of deep inside a
/// manifest lookup mid-run.
pub fn validate_models(engine: &Engine, keys: &[&str]) -> Result<()> {
    for key in keys {
        if !engine.manifest.models.contains_key(*key) {
            let supported: Vec<&str> =
                engine.manifest.models.keys().map(|s| s.as_str()).collect();
            anyhow::bail!(
                "unknown model `{key}` — supported models: {}",
                supported.join(", ")
            );
        }
    }
    Ok(())
}

/// Sanity used by tests: a VramSim-backed budget check that the elastic
/// controller's ladder can actually express (at least two buckets fit).
pub fn ladder_headroom(engine: &Engine, model_key: &str, budget_gb: f64) -> Result<usize> {
    let entry = engine.manifest.model(model_key)?.clone();
    let mut sim = crate::memsim::VramSim::new(&entry, budget_gb, 0.0, 0);
    let codes = vec![crate::manifest::BF16; entry.num_layers];
    Ok(entry
        .train_buckets
        .iter()
        .filter(|&&b| sim.would_fit(b, &codes, false))
        .count())
}

/// Convenience: pretty header + rows.
pub fn print_table1(rows: &[CellResult]) {
    println!(
        "{:<18} {:<16} {:>7} {:>12} {:>12} {:>8}",
        "Model", "Method", "Acc(%)", "Time(s)", "VRAM(GB)", "Score"
    );
    for r in rows {
        println!(
            "{:<18} {:<16} {:>6.1}±{:<4.2} {:>8.2}±{:<4.2} {:>8.4}±{:<6.4} {:>8.2}",
            r.model_key,
            r.label,
            r.acc.mean(),
            r.acc.std(),
            r.modeled_s.mean(),
            r.modeled_s.std(),
            r.peak_gb.mean(),
            r.peak_gb.std(),
            r.score.mean()
        );
    }
    let _ = efficiency_score(0.0, 1.0, 1.0); // keep the import honest
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sr(seed: u64, acc: f64) -> SeedResult {
        SeedResult {
            seed,
            test_acc_pct: acc,
            wall_s: 0.5 + seed as f64,
            modeled_s: 10.0 + acc / 7.0,
            peak_gb: 0.3 + seed as f64 * 0.01,
            score: acc / 3.0,
            oom_events: seed,
            batch_decisions: 2 * seed,
            ctrl_windows: 5,
            precision_transitions: 1,
            curv_firings: 3,
            min_batch: 32 + seed as usize,
            replica_decisions: seed / 2,
            min_replicas: 1 + seed as usize % 2,
        }
    }

    #[test]
    fn normalize_seeds_sorts_and_dedups() {
        assert_eq!(normalize_seeds(&[2, 0, 1, 0, 2]), vec![0, 1, 2]);
        assert_eq!(normalize_seeds(&[]), Vec::<u64>::new());
    }

    #[test]
    fn aggregation_is_order_independent() {
        let fwd = [sr(0, 60.0), sr(1, 61.5), sr(2, 59.0)];
        let rev = [sr(2, 59.0), sr(0, 60.0), sr(1, 61.5)];
        let a = aggregate_cell("m", "l", &fwd).unwrap();
        let b = aggregate_cell("m", "l", &rev).unwrap();
        assert_eq!(a.acc.mean().to_bits(), b.acc.mean().to_bits());
        assert_eq!(a.acc.std().to_bits(), b.acc.std().to_bits());
        assert_eq!(a.modeled_s.mean().to_bits(), b.modeled_s.mean().to_bits());
        let pa = aggregate_pressure("k", "l", &fwd).unwrap();
        let pb = aggregate_pressure("k", "l", &rev).unwrap();
        assert_eq!(pa.acc.mean().to_bits(), pb.acc.mean().to_bits());
        assert_eq!(pa.min_batch, 32);
        assert_eq!(pa.oom_events, pb.oom_events);
    }

    #[test]
    fn aggregation_rejects_duplicates_and_empty() {
        let dup = [sr(1, 60.0), sr(1, 61.0)];
        assert!(aggregate_cell("m", "l", &dup).is_err());
        assert!(aggregate_cell("m", "l", &[]).is_err());
        assert!(aggregate_pressure("k", "l", &dup).is_err());
    }

    #[test]
    fn denominator_counts_unique_seeds() {
        // The dedup fix: three listed seeds with one duplicate must
        // aggregate as two runs, not three.
        let seeds = normalize_seeds(&[0, 1, 1]);
        let results: Vec<SeedResult> = seeds.iter().map(|&s| sr(s, 60.0 + s as f64)).collect();
        let cell = aggregate_cell("m", "l", &results).unwrap();
        assert_eq!(cell.acc.count(), 2);
    }

    #[test]
    fn seed_result_json_roundtrip_is_exact() {
        let r = sr(3, 61.234567890123);
        let j = r.to_json().to_string_compact();
        let back = SeedResult::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, r, "shortest-roundtrip f64 serialization must be exact");
        assert_eq!(back.test_acc_pct.to_bits(), r.test_acc_pct.to_bits());
        // Seeds ride as decimal strings: u64 values past 2^53 must
        // survive the JSON round trip bit-exactly too.
        let big = SeedResult { seed: u64::MAX - 1, ..sr(0, 50.0) };
        let j = big.to_json().to_string_compact();
        let back = SeedResult::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.seed, u64::MAX - 1);
        assert_eq!(back, big);
    }

    #[test]
    fn seed_result_json_rejects_missing_fields() {
        let j = Json::parse(r#"{"seed": 0}"#).unwrap();
        assert!(SeedResult::from_json(&j).is_err());
    }

    #[test]
    fn seed_result_json_accepts_pre_replica_ledger_records() {
        // Ledgers written before the replica axis existed carry no
        // replica keys; resuming them must default to the values those
        // runs actually had (1 replica, 0 replica decisions) rather
        // than fail the whole grid resume.
        let mut r = sr(2, 61.0);
        r.replica_decisions = 0;
        r.min_replicas = 1;
        let j = r.to_json();
        let stripped = match j {
            Json::Obj(mut m) => {
                assert!(m.remove("replica_decisions").is_some());
                assert!(m.remove("min_replicas").is_some());
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        let back = SeedResult::from_json(&stripped).unwrap();
        assert_eq!(back, r, "absent replica keys must default to 1 replica / 0 decisions");
    }
}
