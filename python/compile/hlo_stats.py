"""HLO cost analysis over the AOT artifacts — the §Perf L2 profiling
tool (DESIGN.md §7).

Parses HLO *text* (the interchange format) and reports, per artifact:

  * op histogram (convolution / dot / elementwise / reduce / ...)
  * estimated FLOPs for convolution+dot ops (from shapes)
  * parameter + output bytes (HBM traffic floor)
  * arithmetic intensity (FLOPs / byte) — roofline position
  * duplicate-computation smells: identical convolution shapes appearing
    more than forward+backward would explain

Usage:
    python -m compile.hlo_stats artifacts/resnet18_c10_train_b96.hlo.txt
    python -m compile.hlo_stats --all artifacts/   # summary table
"""

from __future__ import annotations

import argparse
import math
import pathlib
import re
import sys
from collections import Counter, defaultdict

# f32[32,32,32,3]{3,2,1,0} — capture dtype and dims.
SHAPE_RE = re.compile(r"(f16|bf16|f32|f64|s32|u32|pred|s8|u8)\[([0-9,]*)\]")
# op name after " = <shape> opcode(" — e.g. "convolution(", "dot("
OP_RE = re.compile(r"=\s+[^ ]+\s+([a-z][a-z0-9\-]*)\(")

DTYPE_BYTES = {"f16": 2, "bf16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4, "pred": 1, "s8": 1, "u8": 1}


def parse_shape(text: str, pos: int = 0):
    m = SHAPE_RE.search(text, pos)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims, m.end()


def elems(dims) -> int:
    return math.prod(dims) if dims else 1


class ArtifactStats:
    def __init__(self, path: pathlib.Path):
        self.path = path
        self.ops = Counter()
        self.flops = 0
        self.param_bytes = 0
        self.out_bytes = 0
        self.conv_shapes = Counter()
        self._analyze(path.read_text())

    def _analyze(self, text: str):
        # First pass: symbol table name -> (dtype, dims) from each LHS.
        self.symbols: dict[str, tuple[str, list[int]]] = {}
        lines = [l.strip() for l in text.splitlines()]
        for line in lines:
            if " = " not in line:
                continue
            name = line.split(" = ", 1)[0].lstrip("%")
            if name.startswith("ROOT "):
                name = name[5:].lstrip("%")
            s = parse_shape(line.split(" = ", 1)[1])
            if s:
                self.symbols[name] = (s[0], s[1])
        # Second pass: histogram + cost.
        for line in lines:
            m = OP_RE.search(line)
            if not m:
                if line.startswith("ROOT") or "parameter(" in line:
                    self._param_or_root(line)
                continue
            op = m.group(1)
            self.ops[op] += 1
            if op == "convolution":
                self._conv_flops(line)
            elif op == "dot":
                self._dot_flops(line)
            if "parameter(" in line or line.startswith("ROOT"):
                self._param_or_root(line)

    def _operand_shapes(self, line: str) -> list[list[int]]:
        """Shapes of the operands named inside the op's parens."""
        m = re.search(r"\(([^)]*)\)", line)
        if not m:
            return []
        out = []
        for name in m.group(1).split(","):
            name = name.strip().lstrip("%")
            if name in self.symbols:
                out.append(self.symbols[name][1])
        return out

    def _param_or_root(self, line: str):
        if "parameter(" in line:
            s = parse_shape(line)
            if s:
                dt, dims, _ = s
                self.param_bytes += elems(dims) * DTYPE_BYTES.get(dt, 4)
        if line.startswith("ROOT"):
            # Sum every shape in the ROOT tuple.
            pos = 0
            while True:
                s = parse_shape(line, pos)
                if not s:
                    break
                dt, dims, pos = s
                self.out_bytes += elems(dims) * DTYPE_BYTES.get(dt, 4)

    def _conv_flops(self, line: str):
        # FLOPs = 2 × prod(result) × per-output reduction size.
        s = parse_shape(line.split(" = ", 1)[1]) if " = " in line else None
        operands = self._operand_shapes(line)
        if s and len(operands) >= 2:
            out = s[1]
            rhs = operands[1]
            # rhs = kernel [kh,kw,cin,cout] (or permuted); reduction size
            # = prod(kernel)/cout, where cout is the rhs dim matching
            # out's channel dim.
            cout = out[-1] if out else 1
            red = elems(rhs) // max(cout, 1)
            self.flops += 2 * elems(out) * red
            self.conv_shapes[f"{out}x{rhs}"] += 1

    def _dot_flops(self, line: str):
        s = parse_shape(line.split(" = ", 1)[1]) if " = " in line else None
        operands = self._operand_shapes(line)
        if s and len(operands) >= 1:
            out = s[1]
            lhs = operands[0]
            # Contraction size = prod(lhs) / prod(out's row dims).
            k = elems(lhs) // max(elems(out[:-1]) if out else 1, 1)
            self.flops += 2 * elems(out) * max(k, 1)

    @property
    def total_ops(self) -> int:
        return sum(self.ops.values())

    @property
    def intensity(self) -> float:
        traffic = self.param_bytes + self.out_bytes
        return self.flops / traffic if traffic else 0.0

    def duplicate_convs(self):
        """Conv shapes appearing >3× (fwd + input-grad + weight-grad is 3)."""
        return {k: v for k, v in self.conv_shapes.items() if v > 3}

    def report(self) -> str:
        lines = [f"== {self.path.name} =="]
        lines.append(
            f"ops {self.total_ops}  estFLOPs {self.flops/1e6:.1f}M  "
            f"param {self.param_bytes/1e6:.2f}MB  out {self.out_bytes/1e6:.2f}MB  "
            f"intensity {self.intensity:.1f} FLOP/B"
        )
        top = ", ".join(f"{op}:{n}" for op, n in self.ops.most_common(8))
        lines.append(f"top ops: {top}")
        dups = self.duplicate_convs()
        if dups:
            lines.append("duplicate-conv smells (shape → count >3):")
            for k, v in sorted(dups.items(), key=lambda kv: -kv[1])[:5]:
                lines.append(f"  {v}× {k}")
        else:
            lines.append("no duplicate-computation smells (convs ≤3× per shape)")
        return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="artifact file, or directory with --all")
    ap.add_argument("--all", action="store_true", help="summarize a directory")
    args = ap.parse_args()
    p = pathlib.Path(args.path)
    if args.all:
        rows = []
        for f in sorted(p.glob("*.hlo.txt")):
            s = ArtifactStats(f)
            rows.append(
                f"{f.name:<42} ops {s.total_ops:>5}  estFLOPs {s.flops/1e6:>9.1f}M  "
                f"conv {s.ops.get('convolution', 0):>3}  dot {s.ops.get('dot', 0):>3}  "
                f"fusable-elemwise {s.ops.get('add', 0) + s.ops.get('multiply', 0):>5}"
            )
        print("\n".join(rows))
    else:
        print(ArtifactStats(p).report())
    return 0


if __name__ == "__main__":
    sys.exit(main())
