fn stamp() -> std::time::Instant {
    // detlint: allow(d2) — fixture: observability-only timing that never
    // feeds a deterministic artifact.
    std::time::Instant::now()
}
