"""`sgd_update` — fused SGD+momentum optimizer-update Pallas kernel.

The paper claims its "adaptive precision scheduling" routines run as
custom kernels with minimal overhead; the optimizer update is the other
per-parameter hot loop in the training step. This kernel fuses the whole
§3 update for one parameter tensor into a single pass:

    g_eff = g + wd·p                         (decoupled L2 as in SGD-W/D)
    m'    = μ·m + g_eff                      (momentum)
    p'    = p − lr·scale·m'                  (per-layer curvature scale)

with the overflow gate applied as a multiplicative mask (1 = apply,
0 = hold), so the same executable serves clean and skipped steps — no
branch recompilation, matching the qdq precision-as-input design
(DESIGN.md §6.1).

Hardware adaptation: elementwise streaming kernel, tiled at BLOCK f32
elements per grid step (three inputs + two outputs per block stay well
inside VMEM with double-buffering headroom). Lowered interpret=True for
the CPU PJRT plugin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per block: 64Ki f32 × (3 in + 2 out) = 1.25 MiB resident per
# grid step — VMEM-safe with double buffering on a real TPU.
BLOCK = 64 * 1024

MOMENTUM = 0.9


def _sgd_kernel(scalars_ref, p_ref, m_ref, g_ref, p_out_ref, m_out_ref):
    # scalars: [lr·scale, wd, apply_mask]
    lr_eff = scalars_ref[0]
    wd = scalars_ref[1]
    apply = scalars_ref[2]
    p = p_ref[...]
    m = m_ref[...]
    g = g_ref[...]
    g_eff = (g + wd * p) * apply
    m_new = MOMENTUM * m + g_eff
    m_out_ref[...] = jnp.where(apply > 0.5, m_new, m)
    p_out_ref[...] = p - lr_eff * apply * jnp.where(apply > 0.5, m_new, m)


def _sgd_flat(p_flat, m_flat, g_flat, scalars):
    n = p_flat.shape[0]
    grid = n // BLOCK if n >= BLOCK else 1
    block = BLOCK if n >= BLOCK else n
    return pl.pallas_call(
        _sgd_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),  # scalars broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(scalars, p_flat, m_flat, g_flat)


def sgd_update(p, m, g, lr_eff, wd, apply_mask):
    """Fused momentum update for one tensor.

    Args:
      p, m, g: parameter / momentum / gradient tensors (same shape).
      lr_eff: scalar f32 — lr × per-layer curvature scale (§3.2).
      wd: scalar f32 weight decay.
      apply_mask: scalar f32, 1.0 = apply step, 0.0 = hold (overflow).

    Returns (p_new, m_new). Matches `ref.sgd_update_ref` exactly.
    """
    shape = p.shape
    flat = lambda t: t.astype(jnp.float32).reshape(-1)
    p_flat, m_flat, g_flat = flat(p), flat(m), flat(g)
    n = p_flat.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        p_flat = jnp.concatenate([p_flat, z])
        m_flat = jnp.concatenate([m_flat, z])
        g_flat = jnp.concatenate([g_flat, z])
    scalars = jnp.stack(
        [
            jnp.asarray(lr_eff, jnp.float32),
            jnp.asarray(wd, jnp.float32),
            jnp.asarray(apply_mask, jnp.float32),
        ]
    )
    p_new, m_new = _sgd_flat(p_flat, m_flat, g_flat, scalars)
    if pad:
        p_new = p_new[:n]
        m_new = m_new[:n]
    return p_new.reshape(shape), m_new.reshape(shape)
