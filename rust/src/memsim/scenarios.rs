//! The adversarial pressure-scenario library: named, deterministic
//! budget shapes modeling real co-tenant behavior, exposed on the CLI
//! as `pressure --scenario NAME` (spec form `scenario:NAME`).
//!
//! Each scenario is a closed-form step-indexed factor in (0, 1] over
//! the base budget — pure integer/rational arithmetic only (no
//! transcendental functions), so the series is bit-identical across
//! platforms and mirrors exactly in the Python twin
//! (`python/tools/schema_digest.py --scenarios`). The factor-series
//! digests are pinned in the tests below; a formula change must re-pin
//! them (the same twin recomputes the expected values).

use anyhow::Result;

/// A named pressure scenario (see the table in `docs/MEMORY.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Bursty inference co-tenant: short deep budget dips on two
    /// interleaved periods, full budget between bursts.
    Spike,
    /// Fragmentation ratchet: the usable budget shrinks in steps and
    /// never recovers, flooring at ~60%.
    Frag,
    /// Slow co-tenant leak: a linear decline to a 50% floor.
    Leak,
}

/// Every scenario, in presentation order.
pub const ALL: [ScenarioKind; 3] = [ScenarioKind::Spike, ScenarioKind::Frag, ScenarioKind::Leak];

impl ScenarioKind {
    /// Parse a scenario name (the `NAME` of `scenario:NAME`).
    pub fn parse(name: &str) -> Result<ScenarioKind> {
        match name {
            "spike" => Ok(ScenarioKind::Spike),
            "frag" => Ok(ScenarioKind::Frag),
            "leak" => Ok(ScenarioKind::Leak),
            other => anyhow::bail!("unknown scenario `{other}` (spike|frag|leak)"),
        }
    }

    /// Stable lowercase name (spec form, report rows).
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Spike => "spike",
            ScenarioKind::Frag => "frag",
            ScenarioKind::Leak => "leak",
        }
    }

    /// One-line description (CLI errors, report headers, docs table).
    pub fn describe(&self) -> &'static str {
        match self {
            ScenarioKind::Spike => {
                "bursty inference co-tenant: 3-step dips to 0.45 every 23 steps, \
                 rarer single-step dips to 0.30"
            }
            ScenarioKind::Frag => {
                "fragmentation ratchet: budget shrinks 4.5% every 6 steps, floors at 0.595, \
                 never recovers"
            }
            ScenarioKind::Leak => "slow co-tenant leak: linear 0.4%/step decline to a 0.50 floor",
        }
    }

    /// Budget factor at `step`, in (0, 1]. Pure integer/rational
    /// arithmetic — bit-identical everywhere, mirrored by the Python
    /// twin.
    pub fn factor(&self, step: u64) -> f64 {
        match self {
            ScenarioKind::Spike => {
                let p = step % 23;
                if (8..11).contains(&p) {
                    0.45
                } else if step % 37 == 18 {
                    0.3
                } else {
                    1.0
                }
            }
            ScenarioKind::Frag => 1.0 - 0.045 * (step / 6).min(9) as f64,
            ScenarioKind::Leak => {
                let f = 1.0 - 0.004 * step as f64;
                if f < 0.5 {
                    0.5
                } else {
                    f
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::fnv1a;

    /// FNV-1a-64 over the little-endian bits of `factor(0..256)` — the
    /// same digest `python/tools/schema_digest.py --scenarios` prints.
    fn series_digest(kind: ScenarioKind) -> u64 {
        let mut bytes = Vec::with_capacity(256 * 8);
        for step in 0..256u64 {
            bytes.extend_from_slice(&kind.factor(step).to_bits().to_le_bytes());
        }
        fnv1a(&bytes)
    }

    #[test]
    fn factors_stay_in_unit_interval() {
        for kind in ALL {
            for step in 0..2000u64 {
                let f = kind.factor(step);
                assert!(f > 0.0 && f <= 1.0, "{}.factor({step}) = {f}", kind.name());
            }
        }
    }

    #[test]
    fn spike_bursts_and_recovers() {
        let k = ScenarioKind::Spike;
        assert_eq!(k.factor(0), 1.0);
        assert_eq!(k.factor(8), 0.45, "burst opens at phase 8");
        assert_eq!(k.factor(10), 0.45, "burst holds 3 steps");
        assert_eq!(k.factor(11), 1.0, "budget returns after the burst");
        assert_eq!(k.factor(18), 0.3, "deep dip on the 37-step period");
        assert_eq!(k.factor(23 + 8), 0.45, "bursts are periodic");
    }

    #[test]
    fn frag_ratchets_down_monotonically_to_a_floor() {
        let k = ScenarioKind::Frag;
        assert_eq!(k.factor(0), 1.0);
        for step in 1..400u64 {
            assert!(k.factor(step) <= k.factor(step - 1), "ratchet never recovers");
        }
        assert!((k.factor(1000) - 0.595).abs() < 1e-12, "floor at 10 notches");
    }

    #[test]
    fn leak_declines_to_half() {
        let k = ScenarioKind::Leak;
        assert_eq!(k.factor(0), 1.0);
        assert!((k.factor(50) - 0.8).abs() < 1e-12);
        assert_eq!(k.factor(125), 0.5);
        assert_eq!(k.factor(10_000), 0.5, "floor holds");
    }

    #[test]
    fn parse_and_names_round_trip() {
        for kind in ALL {
            assert_eq!(ScenarioKind::parse(kind.name()).unwrap(), kind);
            assert!(!kind.describe().is_empty());
        }
        let err = ScenarioKind::parse("surge").unwrap_err().to_string();
        assert!(err.contains("spike|frag|leak"), "{err}");
    }

    #[test]
    fn factor_series_digests_are_pinned() {
        // Recompute with `python/tools/schema_digest.py --scenarios`
        // after any deliberate formula change.
        assert_eq!(
            series_digest(ScenarioKind::Spike),
            0x5b30ae23e42fd331,
            "spike series drifted"
        );
        assert_eq!(series_digest(ScenarioKind::Frag), 0x51444d17cc4a10a5, "frag series drifted");
        assert_eq!(series_digest(ScenarioKind::Leak), 0xf6527648fec1021f, "leak series drifted");
    }
}
