//! Rendering for detlint results: human text and machine JSON.
//!
//! The JSON shape is what CI uploads as a build artifact (see the
//! `lint` job in `.github/workflows/ci.yml`): findings plus the
//! computed schema digests, so a D7 failure's report carries the new
//! digest to re-pin. Rendering goes through [`crate::util::json::Json`]
//! so the output is valid JSON with deterministic key order.

use std::collections::BTreeMap;

use super::rules::{Finding, RULES};
use super::schema::SchemaStatus;
use crate::util::json::Json;

/// One full lint run over a tree.
pub struct Report {
    /// Root directory that was scanned.
    pub root: String,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, sorted by `(path, line, rule)`.
    pub findings: Vec<Finding>,
    /// Computed-vs-pinned status for every D7 schema pin.
    pub schemas: Vec<SchemaStatus>,
}

impl Report {
    /// True when the tree is lint-clean.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render the human-readable report.
    pub fn human(&self) -> String {
        let mut out = String::new();
        let files = self.files_scanned;
        let root = &self.root;
        out.push_str(&format!("detlint: scanned {files} files under {root}\n"));
        for f in &self.findings {
            out.push_str(&format!("{}:{} [{}] {}\n", f.path, f.line, f.rule, f.message));
            if !f.snippet.is_empty() {
                out.push_str(&format!("    > {}\n", f.snippet));
            }
        }
        for s in &self.schemas {
            let ok = if s.version == s.pinned_version && s.digest == s.pinned_digest {
                "ok"
            } else {
                "DRIFT"
            };
            let file = &s.file;
            let (v, pv) = (s.version, s.pinned_version);
            let digest = format!("{:016x}", s.digest);
            let pinned = format!("{:016x}", s.pinned_digest);
            out.push_str(&format!(
                "schema {file}: v{v} digest {digest} (pinned v{pv} {pinned}) {ok}\n"
            ));
        }
        let n = self.findings.len();
        if n == 0 {
            out.push_str("detlint: clean\n");
        } else {
            out.push_str(&format!("detlint: {n} finding(s)\n"));
        }
        out
    }

    /// Render the JSON report (compact, deterministic key order).
    pub fn json(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("tool".to_string(), Json::Str("detlint".to_string()));
        m.insert("root".to_string(), Json::Str(self.root.clone()));
        m.insert("files_scanned".to_string(), Json::Num(self.files_scanned as f64));
        m.insert("clean".to_string(), Json::Bool(self.clean()));
        let findings: Vec<Json> = self.findings.iter().map(finding_json).collect();
        m.insert("findings".to_string(), Json::Arr(findings));
        let schemas: Vec<Json> = self.schemas.iter().map(schema_json).collect();
        m.insert("schemas".to_string(), Json::Arr(schemas));
        let rules: Vec<Json> = RULES.iter().map(rule_json).collect();
        m.insert("rules".to_string(), Json::Arr(rules));
        Json::Obj(m).to_string_compact()
    }
}

fn finding_json(f: &Finding) -> Json {
    let mut m = BTreeMap::new();
    m.insert("rule".to_string(), Json::Str(f.rule.clone()));
    m.insert("path".to_string(), Json::Str(f.path.clone()));
    m.insert("line".to_string(), Json::Num(f.line as f64));
    m.insert("message".to_string(), Json::Str(f.message.clone()));
    m.insert("snippet".to_string(), Json::Str(f.snippet.clone()));
    Json::Obj(m)
}

fn schema_json(s: &SchemaStatus) -> Json {
    let mut m = BTreeMap::new();
    m.insert("file".to_string(), Json::Str(s.file.clone()));
    m.insert("version".to_string(), Json::Num(s.version as f64));
    m.insert("digest".to_string(), Json::Str(format!("{:016x}", s.digest)));
    m.insert("pinned_version".to_string(), Json::Num(s.pinned_version as f64));
    m.insert("pinned_digest".to_string(), Json::Str(format!("{:016x}", s.pinned_digest)));
    let ok = s.version == s.pinned_version && s.digest == s.pinned_digest;
    m.insert("ok".to_string(), Json::Bool(ok));
    Json::Obj(m)
}

fn rule_json(r: &super::rules::RuleInfo) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Str(r.id.to_string()));
    m.insert("title".to_string(), Json::Str(r.title.to_string()));
    m.insert("scope".to_string(), Json::Str(r.scope.to_string()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            root: "src".to_string(),
            files_scanned: 2,
            findings: vec![Finding {
                rule: "d1".to_string(),
                path: "policy/x.rs".to_string(),
                line: 3,
                message: "HashMap".to_string(),
                snippet: "use std::collections::HashMap;".to_string(),
            }],
            schemas: Vec::new(),
        }
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let r = sample();
        let parsed = Json::parse(&r.json()).expect("valid json");
        assert_eq!(parsed.req("clean").unwrap().as_bool(), Some(false));
        let findings = parsed.req("findings").unwrap().as_arr().unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].req("rule").unwrap().as_str(), Some("d1"));
        assert_eq!(findings[0].req("line").unwrap().as_usize(), Some(3));
        let rules = parsed.req("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), 7);
    }

    #[test]
    fn human_report_lists_findings_and_verdict() {
        let r = sample();
        let text = r.human();
        assert!(text.contains("policy/x.rs:3 [d1]"));
        assert!(text.contains("detlint: 1 finding(s)"));
        let clean = Report { findings: Vec::new(), ..r };
        assert!(clean.human().contains("detlint: clean"));
    }
}
