//! Micro benchmarks (DESIGN.md P1): hot-path component latencies —
//! train-step per batch bucket and precision mix, eval, curvature probe,
//! pure controller overhead, memsim accounting, and the data pipeline.
//! The controller/memsim rows quantify the paper's "negligible overhead"
//! claim: control-loop work must be orders of magnitude below a step.

use tri_accel::config::{Config, Method};
use tri_accel::coordinator::Controller;
use tri_accel::data::{synthetic::SyntheticCifar, BatchIter};
use tri_accel::manifest::{BF16, FP16, FP32};
use tri_accel::memsim::VramSim;
use tri_accel::runtime::{Engine, Session, StepCtrl};
use tri_accel::util::bench::{black_box, Bencher};

fn main() {
    let engine = Engine::native();
    let key = "tiny_cnn_c10";
    let entry = engine.manifest.model(key).unwrap().clone();
    let n_layers = entry.num_layers;

    println!("== micro: L3 hot path ({key}) ==");
    let heavy = Bencher::heavy();
    let quick = Bencher::default();

    // -- data pipeline ----------------------------------------------------
    let ds = SyntheticCifar::new(10, 4096, true, 0);
    let mut it = BatchIter::new(Box::new(ds), 0, true);
    quick.run("data/next_batch(B=32, augmented)", || {
        black_box(it.next_batch(32).unwrap());
    });

    // -- train step per bucket ---------------------------------------------
    let mut session = Session::init(&engine, key, 0).unwrap();
    for &b in &[16usize, 32, 64, 96] {
        if !entry.train_buckets.contains(&b) {
            continue;
        }
        let batch = it.next_batch(b).unwrap();
        let ctrl = StepCtrl::uniform(n_layers, BF16, 0.05, 5e-4);
        heavy.run(&format!("train_step(B={b}, bf16)"), || {
            black_box(session.train_step(&batch, &ctrl).unwrap());
        });
    }

    // -- precision mix sensitivity at fixed B -------------------------------
    let batch = it.next_batch(32).unwrap();
    for (name, code) in [("fp16", FP16), ("bf16", BF16), ("fp32", FP32)] {
        let ctrl = StepCtrl::uniform(n_layers, code, 0.05, 5e-4);
        heavy.run(&format!("train_step(B=32, uniform {name})"), || {
            black_box(session.train_step(&batch, &ctrl).unwrap());
        });
    }

    // -- eval + curvature ---------------------------------------------------
    let eval_b = it.next_batch(16).unwrap();
    let codes = vec![FP32; n_layers];
    heavy.run("eval_batch(B=16)", || {
        black_box(session.eval_batch(&eval_b, &codes).unwrap());
    });
    let curv_b = it.next_batch(entry.curv_batch).unwrap();
    heavy.run(&format!("curv_step(B={})", entry.curv_batch), || {
        black_box(session.curv_step(&curv_b, &codes, 7).unwrap());
    });

    // -- controller-only overhead (the paper's "negligible" claim) ----------
    let mut cfg = Config::cell(key, Method::TriAccel, 0);
    cfg.t_ctrl = 1;
    let mut ctl = Controller::new(&cfg, &entry);
    let vars: Vec<f32> = (0..n_layers).map(|i| 1e-6 * (i + 1) as f32).collect();
    quick.run("controller/observe_step", || {
        ctl.observe_step(black_box(&vars), false);
    });
    let mut step = 0u64;
    quick.run("controller/control_window", || {
        step += 1;
        black_box(ctl.control_window(step, 0.8, 1.0, |_| true));
    });

    // -- memsim accounting ---------------------------------------------------
    let mut sim = VramSim::new(&entry, 0.45, 0.01, 0);
    let codes2: Vec<i32> = (0..n_layers).map(|i| (i % 3) as i32).collect();
    quick.run("memsim/usage", || {
        black_box(sim.usage(96, &codes2, false));
    });
    quick.run("memsim/would_fit", || {
        black_box(sim.would_fit(128, &codes2, false));
    });

    println!("\n(controller+memsim rows are the per-step control overhead;");
    println!(" compare against the train_step rows — expect ≥1000× headroom.)");
}
