//! Precision-adaptivity demo (paper §3.1 + §3.2): trace each layer's
//! gradient-variance EMA, its assigned precision over training, and the
//! curvature promotions that pin unstable layers to FP32.
//!
//!     cargo run --release --example precision_schedule

use anyhow::Result;

use tri_accel::config::{Config, Method};
use tri_accel::manifest::precision_name;
use tri_accel::policy::{CurvaturePolicy, PrecisionPolicy};
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

fn main() -> Result<()> {
    let engine = Engine::native();

    let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 1);
    cfg.epochs = 1;
    cfg.steps_per_epoch = Some(120);
    cfg.train_examples = 4096;
    cfg.eval_examples = 256;
    cfg.batch_init = 32;
    cfg.t_ctrl = 10;
    cfg.t_curv = 30;
    cfg.curv_warmup = 2;
    cfg.warmup_epochs = 0;
    cfg.mem_budget_gb = 0.05;

    let mut tr = Trainer::new(&engine, cfg)?;
    let num_layers = tr.session.num_layers();
    println!("tracking {num_layers} precision layers; control window every 10 steps\n");
    println!("{:>5}  {:<24}  {:<20}  lr-scales", "step", "codes", "v_l (EMA)");

    for _ in 0..120 {
        tr.step()?;
        let step = tr.global_step();
        if step % 10 == 0 {
            let codes = tr.controller.codes();
            let names: Vec<&str> = codes.iter().map(|&c| precision_name(c)).collect();
            let vars = tr.controller.precision.variances();
            let scales = tr.controller.lr_scales();
            println!(
                "{:>5}  {:<24}  [{}]  [{}]",
                step,
                names.join(","),
                vars.iter().map(|v| format!("{v:.1e}")).collect::<Vec<_>>().join(" "),
                scales.iter().map(|s| format!("{s:.2}")).collect::<Vec<_>>().join(" ")
            );
        }
    }

    if let Some((lo, hi)) = tr.controller.precision.thresholds() {
        println!("\ncalibrated thresholds: τ_low={lo:.3e} τ_high={hi:.3e}");
    }
    println!(
        "transitions {}  curvature firings {}  promotions {}  λ = {:?}",
        tr.controller.precision.transitions(),
        tr.controller.curvature.firings(),
        tr.metrics.promotions,
        tr.controller
            .curvature
            .lambdas()
            .iter()
            .map(|l| format!("{l:.2}"))
            .collect::<Vec<_>>()
    );
    let (test_loss, test_acc) = tr.evaluate()?;
    println!("eval: loss {test_loss:.4}  acc {test_acc:.1}%");
    Ok(())
}
