//! Engine: a manifest plus the [`Backend`] that executes it.
//!
//! Backend selection:
//! * [`Engine::native`] — the hermetic default: pure-Rust reference
//!   executor with its built-in manifest. Works from a fresh checkout
//!   with no artifacts, no Python, no native deps.
//! * [`Engine::pjrt`] (`--features pjrt`) — the PJRT/XLA executor over
//!   AOT HLO artifacts produced by `make artifacts`.
//! * [`Engine::new`] — compatibility constructor: picks PJRT when the
//!   feature is enabled *and* an artifact manifest exists at the given
//!   path, else falls back to the native backend.

use std::path::Path;

use anyhow::Result;

use super::backend::Backend;
use super::native;
use crate::manifest::Manifest;

pub struct Engine {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
}

impl Engine {
    /// The hermetic pure-Rust engine (built-in manifest, no disk IO).
    /// Worker count comes from `TRIACCEL_THREADS` (default: machine
    /// parallelism capped at 8); results are bit-identical regardless.
    pub fn native() -> Engine {
        Engine {
            manifest: native::builtin_manifest(),
            backend: Box::new(native::NativeBackend::new()),
        }
    }

    /// The native engine with an explicit worker count (the CLI's
    /// `--threads` flag and the cross-thread determinism tests — an
    /// env-free hook, so parallel test runs don't race on the process
    /// environment).
    pub fn native_with_threads(threads: usize) -> Engine {
        Engine {
            manifest: native::builtin_manifest(),
            backend: Box::new(native::NativeBackend::with_threads(threads)),
        }
    }

    /// The native engine over an existing compute-pool handle. The
    /// experiment scheduler ([`crate::sched`]) builds one engine per
    /// job-pool worker this way and reuses it across every job that
    /// worker runs, so consecutive jobs share the pool handle and the
    /// warm scratch arena behind it. Results are bit-identical to any
    /// other construction — the pool width is a pure performance knob.
    pub fn native_with_pool(pool: native::pool::Pool) -> Engine {
        Engine {
            manifest: native::builtin_manifest(),
            backend: Box::new(native::NativeBackend::with_pool(pool)),
        }
    }

    /// The native engine with `replicas` data-parallel engine instances
    /// per step, each computing with `threads_each` pool workers
    /// (budget the pair via [`native::pool::budget_threads`] so
    /// jobs × replicas × threads never oversubscribes). Training
    /// numerics are bit-identical for every replica count — see
    /// [`native::replica`].
    pub fn native_replicated(replicas: usize, threads_each: usize) -> Engine {
        Engine {
            manifest: native::builtin_manifest(),
            backend: Box::new(native::replica::ReplicaBackend::new(replicas, threads_each)),
        }
    }

    /// Compatibility constructor: PJRT over `artifacts_dir` when built
    /// with `--features pjrt` and a manifest is present there, else the
    /// native backend (ignoring `artifacts_dir`).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        #[cfg(feature = "pjrt")]
        {
            if artifacts_dir.join("manifest.json").exists() {
                return Engine::pjrt(artifacts_dir);
            }
        }
        let _ = artifacts_dir;
        Ok(Engine::native())
    }

    /// The PJRT/XLA artifact executor.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: &Path) -> Result<Engine> {
        let backend = super::pjrt::PjrtBackend::new(artifacts_dir)?;
        Ok(Engine {
            manifest: Manifest::load(artifacts_dir)?,
            backend: Box::new(backend),
        })
    }

    /// Select a backend by name (the CLI's `--backend` flag).
    pub fn by_name(backend: &str, artifacts_dir: &Path) -> Result<Engine> {
        match backend {
            "native" => Ok(Engine::native()),
            #[cfg(feature = "pjrt")]
            "pjrt" => Engine::pjrt(artifacts_dir),
            other => {
                let _ = artifacts_dir;
                anyhow::bail!(
                    "unknown backend `{other}` (available: native{})",
                    if cfg!(feature = "pjrt") {
                        "|pjrt"
                    } else {
                        "; rebuild with --features pjrt for the XLA executor"
                    }
                )
            }
        }
    }

    /// The backend's platform name (e.g. "native-cpu").
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Data-parallel replica ceiling of the backend (1 when not
    /// replicated).
    pub fn replica_capacity(&self) -> usize {
        self.backend.replica_capacity()
    }

    /// Replicas currently live (1 when not replicated).
    pub fn live_replicas(&self) -> usize {
        self.backend.live_replicas()
    }

    /// Elastically set the live replica count (no-op on
    /// non-replicated backends; never changes numerics on the native
    /// replicated backend).
    pub fn set_live_replicas(&self, n: usize) {
        self.backend.set_live_replicas(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_is_hermetic() {
        let e = Engine::native();
        assert_eq!(e.platform(), "native-cpu");
        assert!(e.manifest.model("tiny_cnn_c10").is_ok());
        assert!(e.manifest.model("resnet18_c10").is_err(), "not built in");
    }

    #[test]
    fn native_with_threads_serves_same_manifest() {
        let e = Engine::native_with_threads(2);
        assert_eq!(e.platform(), "native-cpu");
        assert!(e.manifest.model("tiny_cnn_c10").is_ok());
    }

    #[test]
    fn replicated_engine_exposes_elastic_replicas() {
        let e = Engine::native_replicated(2, 1);
        assert_eq!(e.platform(), "native-replica");
        assert_eq!(e.replica_capacity(), 2);
        e.set_live_replicas(1);
        assert_eq!(e.live_replicas(), 1);
        let single = Engine::native();
        assert_eq!(single.replica_capacity(), 1);
        single.set_live_replicas(4); // no-op on non-replicated backends
        assert_eq!(single.live_replicas(), 1);
    }

    #[test]
    fn new_falls_back_to_native_without_artifacts() {
        let e = Engine::new(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(e.platform(), "native-cpu");
    }

    #[test]
    fn by_name_selects_and_rejects() {
        let e = Engine::by_name("native", Path::new("artifacts")).unwrap();
        assert_eq!(e.platform(), "native-cpu");
        let err = Engine::by_name("cuda", Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("unknown backend"));
        #[cfg(not(feature = "pjrt"))]
        assert!(Engine::by_name("pjrt", Path::new("artifacts")).is_err());
    }
}
