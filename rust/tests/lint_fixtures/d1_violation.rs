use std::collections::HashMap;

fn table() -> HashMap<String, u64> {
    HashMap::new()
}
