fn fill(v: &mut Vec<u8>, len: usize) {
    // SAFETY: fixture — the caller reserved and initialized the first
    // `len` bytes before handing the buffer over.
    unsafe { v.set_len(len) };
}
