//! Runtime-dispatched SIMD micro-kernels for the GEMM core.
//!
//! Three dispatch **tiers**, selected once per process:
//! * `scalar` — the always-available reference tier: plain mul+add
//!   (two roundings per update), bit-identical to the seed kernel;
//! * `avx2` — x86-64 AVX2+FMA (8-wide f32, fused mul+add), taken only
//!   when `is_x86_feature_detected!` confirms both features;
//! * `neon` — aarch64 NEON (4-wide f32, fused mul+add), mandatory on
//!   aarch64 so no runtime detection is needed.
//!
//! `TRIACCEL_DISPATCH=scalar|avx2|neon` forces a tier (an unavailable
//! or unknown value falls back to `scalar` — forcing the reference
//! tier must work on every machine); unset, the best available tier
//! wins.
//!
//! Numeric contract (docs/DETERMINISM.md "Dispatch tiers"): every tier
//! keeps each output element's k-chain in ascending-k order —
//! vectorization is across the independent `j` output columns, never
//! across `k` — so within a tier, results are bit-identical for every
//! thread count and every [`super::autotune::TuneCfg`] blocking. The
//! SIMD tiers fuse mul+add (one rounding instead of two), so their
//! bits differ from `scalar` by rounding only: bits are a pure
//! function of (inputs, tier).

#![allow(clippy::needless_range_loop)]

use std::fmt;
use std::sync::OnceLock;

/// Micro-tile rows (the register-tile unroll shared by every tier).
pub const MR: usize = 4;
/// Widest supported micro-tile column count. Panels are packed at the
/// active config's `nr` (8 or 16); accumulator tiles are sized for the
/// widest so one buffer type fits every tier and config.
pub const NR_MAX: usize = 16;

/// A dispatch tier — which micro-kernel family executes GEMM tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    /// Reference mul+add kernel; available everywhere.
    Scalar,
    /// x86-64 AVX2 + FMA (8 f32 lanes, fused mul+add).
    Avx2,
    /// aarch64 NEON (4 f32 lanes, fused mul+add).
    Neon,
}

impl Tier {
    /// Stable lower-case name (cache keys, bench rows, env parsing).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }

    /// Inverse of [`Tier::name`]; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "scalar" => Some(Tier::Scalar),
            "avx2" => Some(Tier::Avx2),
            "neon" => Some(Tier::Neon),
            _ => None,
        }
    }
}

impl fmt::Display for Tier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Does this CPU execute the avx2 tier? (Both AVX2 and FMA are
/// required; the detection macro caches in an atomic, so re-checking
/// at dispatch sites is cheap.)
fn avx2_ok() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Every tier this machine can execute, worst-first (so `.last()` is
/// the best). Always starts with [`Tier::Scalar`].
pub fn available_tiers() -> Vec<Tier> {
    let mut tiers = vec![Tier::Scalar];
    if avx2_ok() {
        tiers.push(Tier::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    {
        tiers.push(Tier::Neon);
    }
    tiers
}

static ACTIVE: OnceLock<Tier> = OnceLock::new();

/// The process-wide dispatch tier: `TRIACCEL_DISPATCH` if it names an
/// available tier, `scalar` if it names anything else, and the best
/// available tier when unset. Resolved once and latched, so a run
/// never mixes tiers.
pub fn active() -> Tier {
    *ACTIVE.get_or_init(|| {
        let avail = available_tiers();
        match std::env::var("TRIACCEL_DISPATCH") {
            Ok(s) => match Tier::parse(s.trim()) {
                Some(t) if avail.contains(&t) => t,
                // Unknown or unavailable: the reference tier, never an
                // error — forcing `scalar` must work on every machine,
                // and a typo degrading to slow-but-correct beats a
                // crash mid-grid.
                _ => Tier::Scalar,
            },
            Err(_) => *avail.last().unwrap_or(&Tier::Scalar),
        }
    })
}

// ---------------------------------------------------------------- tile

/// One `mr`×`nr` register tile against a packed panel:
/// `acc[r][j] += Σ_kk a[r][kk] · bp[kk*nr + j]` for `r < mr`,
/// `j < nr`. Lanes `nr..NR_MAX` of `acc` and rows `mr..MR` of `a` are
/// never touched (true 1/2/3-row tail kernels — the seed computed
/// wasted lanes for tail rows and discarded them). Safe wrapper: the
/// SIMD paths re-verify CPU features (a cached atomic) before entering
/// `unsafe` kernels, falling back to scalar otherwise.
pub fn tile(
    tier: Tier,
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    nr: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    debug_assert!((1..=MR).contains(&mr));
    debug_assert!(nr == 8 || nr == NR_MAX);
    debug_assert!(bp.len() >= k * nr);
    match tier {
        Tier::Scalar => scalar_tile(a, mr, bp, k, nr, acc),
        Tier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if avx2_ok() {
                    // SAFETY: avx2+fma presence re-verified just above
                    // (cached atomic), satisfying the kernels'
                    // `#[target_feature]` contract; every load/store
                    // stays inside the length-asserted slices.
                    unsafe {
                        if nr == NR_MAX {
                            avx2_tile16(a, mr, bp, k, acc);
                        } else {
                            avx2_tile8(a, mr, bp, k, acc);
                        }
                    }
                    return;
                }
            }
            scalar_tile(a, mr, bp, k, nr, acc);
        }
        Tier::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is a mandatory aarch64 feature, so the
                // kernels' `#[target_feature(enable = "neon")]`
                // contract holds on every aarch64 CPU; every
                // load/store stays inside the length-asserted slices.
                unsafe {
                    if nr == NR_MAX {
                        neon_tile16(a, mr, bp, k, acc);
                    } else {
                        neon_tile8(a, mr, bp, k, acc);
                    }
                }
                return;
            }
            #[allow(unreachable_code)]
            scalar_tile(a, mr, bp, k, nr, acc);
        }
    }
}

/// Reference tile, monomorphized per (rows, width) so tails dispatch
/// to true 1/2/3-row kernels and the compiler sees fixed trip counts.
fn scalar_tile(
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    nr: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    match (nr, mr) {
        (8, 1) => scalar_rows::<1, 8>(a, bp, k, acc),
        (8, 2) => scalar_rows::<2, 8>(a, bp, k, acc),
        (8, 3) => scalar_rows::<3, 8>(a, bp, k, acc),
        (8, _) => scalar_rows::<4, 8>(a, bp, k, acc),
        (_, 1) => scalar_rows::<1, 16>(a, bp, k, acc),
        (_, 2) => scalar_rows::<2, 16>(a, bp, k, acc),
        (_, 3) => scalar_rows::<3, 16>(a, bp, k, acc),
        _ => scalar_rows::<4, 16>(a, bp, k, acc),
    }
}

/// The scalar R×W tile: plain mul+add (two roundings per update) in
/// ascending-k order per element — bit-identical to the seed kernel
/// for every R, since the seed's wasted tail lanes were never stored.
#[inline]
fn scalar_rows<const R: usize, const W: usize>(
    a: &[&[f32]; MR],
    bp: &[f32],
    k: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    for kk in 0..k {
        let brow = &bp[kk * W..kk * W + W];
        for r in 0..R {
            let av = a[r][kk];
            let row = &mut acc[r];
            for j in 0..W {
                row[j] += av * brow[j];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller guarantees avx2+fma are present (runtime-detected in
// `tile`); `bp` holds ≥ k rows of 8 packed floats, `a[r]` rows hold
// ≥ k values, and `acc` rows are NR_MAX ≥ 8 wide, so every unaligned
// load/store below is in bounds.
unsafe fn avx2_tile8(
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    use std::arch::x86_64::*;
    debug_assert!(bp.len() >= k * 8);
    let mut va = [_mm256_setzero_ps(); MR];
    for r in 0..mr {
        va[r] = _mm256_loadu_ps(acc[r].as_ptr());
    }
    for kk in 0..k {
        let vb = _mm256_loadu_ps(bp.as_ptr().add(kk * 8));
        for r in 0..mr {
            // detlint: ordered — per-element k-chain stays ascending-k;
            // lanes are the 8 independent j columns of this panel. The
            // FMA fuses mul+add into one rounding, the avx2 tier's
            // pinned numeric contract (bits = f(inputs, tier)).
            va[r] = _mm256_fmadd_ps(_mm256_set1_ps(a[r][kk]), vb, va[r]);
        }
    }
    for r in 0..mr {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), va[r]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller guarantees avx2+fma are present (runtime-detected in
// `tile`); `bp` holds ≥ k rows of 16 packed floats, `a[r]` rows hold
// ≥ k values, and `acc` rows are exactly NR_MAX = 16 wide, so every
// unaligned load/store below is in bounds.
unsafe fn avx2_tile16(
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    use std::arch::x86_64::*;
    debug_assert!(bp.len() >= k * 16);
    let mut lo = [_mm256_setzero_ps(); MR];
    let mut hi = [_mm256_setzero_ps(); MR];
    for r in 0..mr {
        lo[r] = _mm256_loadu_ps(acc[r].as_ptr());
        hi[r] = _mm256_loadu_ps(acc[r].as_ptr().add(8));
    }
    for kk in 0..k {
        let b0 = _mm256_loadu_ps(bp.as_ptr().add(kk * 16));
        let b1 = _mm256_loadu_ps(bp.as_ptr().add(kk * 16 + 8));
        for r in 0..mr {
            let av = _mm256_set1_ps(a[r][kk]);
            // detlint: ordered — ascending-k chain; lanes are the
            // independent j columns 0..8 of this panel (fused, the
            // avx2 tier contract).
            lo[r] = _mm256_fmadd_ps(av, b0, lo[r]);
            // detlint: ordered — ascending-k chain; lanes are the
            // independent j columns 8..16 of this panel (fused, the
            // avx2 tier contract).
            hi[r] = _mm256_fmadd_ps(av, b1, hi[r]);
        }
    }
    for r in 0..mr {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), lo[r]);
        _mm256_storeu_ps(acc[r].as_mut_ptr().add(8), hi[r]);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
// SAFETY: NEON is mandatory on aarch64 (the caller dispatches this
// under cfg(target_arch = "aarch64") only); `bp` holds ≥ k rows of 8
// packed floats, `a[r]` rows hold ≥ k values, and `acc` rows are
// NR_MAX ≥ 8 wide, so every load/store below is in bounds.
unsafe fn neon_tile8(
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    use std::arch::aarch64::*;
    debug_assert!(bp.len() >= k * 8);
    let mut v0 = [vdupq_n_f32(0.0); MR];
    let mut v1 = [vdupq_n_f32(0.0); MR];
    for r in 0..mr {
        v0[r] = vld1q_f32(acc[r].as_ptr());
        v1[r] = vld1q_f32(acc[r].as_ptr().add(4));
    }
    for kk in 0..k {
        let b0 = vld1q_f32(bp.as_ptr().add(kk * 8));
        let b1 = vld1q_f32(bp.as_ptr().add(kk * 8 + 4));
        for r in 0..mr {
            let av = vdupq_n_f32(a[r][kk]);
            // detlint: ordered — ascending-k chain; lanes are the
            // independent j columns 0..4 of this panel (fused, the
            // neon tier contract).
            v0[r] = vfmaq_f32(v0[r], av, b0);
            // detlint: ordered — ascending-k chain; lanes are the
            // independent j columns 4..8 of this panel (fused, the
            // neon tier contract).
            v1[r] = vfmaq_f32(v1[r], av, b1);
        }
    }
    for r in 0..mr {
        vst1q_f32(acc[r].as_mut_ptr(), v0[r]);
        vst1q_f32(acc[r].as_mut_ptr().add(4), v1[r]);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
// SAFETY: NEON is mandatory on aarch64 (the caller dispatches this
// under cfg(target_arch = "aarch64") only); `bp` holds ≥ k rows of 16
// packed floats, `a[r]` rows hold ≥ k values, and `acc` rows are
// exactly NR_MAX = 16 wide, so every load/store below is in bounds.
unsafe fn neon_tile16(
    a: &[&[f32]; MR],
    mr: usize,
    bp: &[f32],
    k: usize,
    acc: &mut [[f32; NR_MAX]; MR],
) {
    use std::arch::aarch64::*;
    debug_assert!(bp.len() >= k * 16);
    let mut v = [[vdupq_n_f32(0.0); 4]; MR];
    for r in 0..mr {
        for q in 0..4 {
            v[r][q] = vld1q_f32(acc[r].as_ptr().add(4 * q));
        }
    }
    for kk in 0..k {
        let base = bp.as_ptr().add(kk * 16);
        let mut bv = [vdupq_n_f32(0.0); 4];
        for q in 0..4 {
            bv[q] = vld1q_f32(base.add(4 * q));
        }
        for r in 0..mr {
            let av = vdupq_n_f32(a[r][kk]);
            for q in 0..4 {
                // detlint: ordered — ascending-k chain; lanes are the
                // independent j columns 4q..4q+4 of this panel (fused,
                // the neon tier contract).
                v[r][q] = vfmaq_f32(v[r][q], av, bv[q]);
            }
        }
    }
    for r in 0..mr {
        for q in 0..4 {
            vst1q_f32(acc[r].as_mut_ptr().add(4 * q), v[r][q]);
        }
    }
}

// ------------------------------------------------- elementwise helpers

/// `acc[j] += s · x[j]` over `j < min(lengths)` — the rank-1 row
/// update of `gemm_at_b`. j-parallel: each `acc[j]` takes exactly one
/// update per call, so no reduction order is created here; the
/// ascending-m chain order is owned by the caller's loop.
pub fn axpy(tier: Tier, acc: &mut [f32], x: &[f32], s: f32) {
    match tier {
        Tier::Scalar => scalar_axpy(acc, x, s),
        Tier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if avx2_ok() {
                    // SAFETY: avx2+fma presence re-verified just above
                    // (cached atomic); the kernel bounds every access
                    // by min(acc.len(), x.len()).
                    unsafe {
                        avx2_axpy(acc, x, s);
                    }
                    return;
                }
            }
            scalar_axpy(acc, x, s);
        }
        Tier::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is mandatory on aarch64; the kernel
                // bounds every access by min(acc.len(), x.len()).
                unsafe {
                    neon_axpy(acc, x, s);
                }
                return;
            }
            #[allow(unreachable_code)]
            scalar_axpy(acc, x, s);
        }
    }
}

/// `acc[j] += x[j] · w[j]` over `j < min(lengths)` — the per-channel
/// tap update of the depthwise convolutions. j-parallel like [`axpy`]:
/// the ascending-tap chain order is owned by the caller's loop.
pub fn mul_acc(tier: Tier, acc: &mut [f32], x: &[f32], w: &[f32]) {
    match tier {
        Tier::Scalar => scalar_mul_acc(acc, x, w),
        Tier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if avx2_ok() {
                    // SAFETY: avx2+fma presence re-verified just above
                    // (cached atomic); the kernel bounds every access
                    // by the minimum of the three slice lengths.
                    unsafe {
                        avx2_mul_acc(acc, x, w);
                    }
                    return;
                }
            }
            scalar_mul_acc(acc, x, w);
        }
        Tier::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is mandatory on aarch64; the kernel
                // bounds every access by the minimum of the three
                // slice lengths.
                unsafe {
                    neon_mul_acc(acc, x, w);
                }
                return;
            }
            #[allow(unreachable_code)]
            scalar_mul_acc(acc, x, w);
        }
    }
}

fn scalar_axpy(acc: &mut [f32], x: &[f32], s: f32) {
    for (av, &xv) in acc.iter_mut().zip(x) {
        *av += s * xv;
    }
}

fn scalar_mul_acc(acc: &mut [f32], x: &[f32], w: &[f32]) {
    for ((av, &xv), &wv) in acc.iter_mut().zip(x).zip(w) {
        *av += xv * wv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller guarantees avx2+fma are present; every load/store is
// bounded by n = min(acc.len(), x.len()) — the vector loop covers
// whole 8-lane groups below n, the scalar tail covers the rest.
unsafe fn avx2_axpy(acc: &mut [f32], x: &[f32], s: f32) {
    use std::arch::x86_64::*;
    let n = acc.len().min(x.len());
    let vs = _mm256_set1_ps(s);
    let mut j = 0usize;
    while j + 8 <= n {
        let va = _mm256_loadu_ps(acc.as_ptr().add(j));
        let vx = _mm256_loadu_ps(x.as_ptr().add(j));
        // detlint: ordered — j-parallel FMA over 8 distinct output
        // elements (one fused update each); the lane split at the
        // largest multiple of 8 ≤ n depends on lengths only, so it is
        // identical for every thread count.
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_fmadd_ps(vs, vx, va));
        j += 8;
    }
    while j < n {
        acc[j] += s * x[j];
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller guarantees avx2+fma are present; every load/store is
// bounded by n = min of the three slice lengths — the vector loop
// covers whole 8-lane groups below n, the scalar tail the rest.
unsafe fn avx2_mul_acc(acc: &mut [f32], x: &[f32], w: &[f32]) {
    use std::arch::x86_64::*;
    let n = acc.len().min(x.len()).min(w.len());
    let mut j = 0usize;
    while j + 8 <= n {
        let va = _mm256_loadu_ps(acc.as_ptr().add(j));
        let vx = _mm256_loadu_ps(x.as_ptr().add(j));
        let vw = _mm256_loadu_ps(w.as_ptr().add(j));
        // detlint: ordered — j-parallel FMA over 8 distinct output
        // elements (one fused update each); the lane split at the
        // largest multiple of 8 ≤ n depends on lengths only, so it is
        // identical for every thread count.
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_fmadd_ps(vx, vw, va));
        j += 8;
    }
    while j < n {
        acc[j] += x[j] * w[j];
        j += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
// SAFETY: NEON is mandatory on aarch64; every load/store is bounded by
// n = min(acc.len(), x.len()) — the vector loop covers whole 4-lane
// groups below n, the scalar tail covers the rest.
unsafe fn neon_axpy(acc: &mut [f32], x: &[f32], s: f32) {
    use std::arch::aarch64::*;
    let n = acc.len().min(x.len());
    let vs = vdupq_n_f32(s);
    let mut j = 0usize;
    while j + 4 <= n {
        let va = vld1q_f32(acc.as_ptr().add(j));
        let vx = vld1q_f32(x.as_ptr().add(j));
        // detlint: ordered — j-parallel FMA over 4 distinct output
        // elements (one fused update each); the lane split at the
        // largest multiple of 4 ≤ n depends on lengths only, so it is
        // identical for every thread count.
        vst1q_f32(acc.as_mut_ptr().add(j), vfmaq_f32(va, vs, vx));
        j += 4;
    }
    while j < n {
        acc[j] += s * x[j];
        j += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
// SAFETY: NEON is mandatory on aarch64; every load/store is bounded by
// n = min of the three slice lengths — the vector loop covers whole
// 4-lane groups below n, the scalar tail covers the rest.
unsafe fn neon_mul_acc(acc: &mut [f32], x: &[f32], w: &[f32]) {
    use std::arch::aarch64::*;
    let n = acc.len().min(x.len()).min(w.len());
    let mut j = 0usize;
    while j + 4 <= n {
        let va = vld1q_f32(acc.as_ptr().add(j));
        let vx = vld1q_f32(x.as_ptr().add(j));
        let vw = vld1q_f32(w.as_ptr().add(j));
        // detlint: ordered — j-parallel FMA over 4 distinct output
        // elements (one fused update each); the lane split at the
        // largest multiple of 4 ≤ n depends on lengths only, so it is
        // identical for every thread count.
        vst1q_f32(acc.as_mut_ptr().add(j), vfmaq_f32(va, vx, vw));
        j += 4;
    }
    while j < n {
        acc[j] += x[j] * w[j];
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!((x - y).abs() / scale < tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    /// Run one tile through `tile()` and return the acc rows.
    fn run_tile(tier: Tier, mr: usize, nr: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..MR).map(|_| randv(&mut rng, k)).collect();
        let a: [&[f32]; MR] = std::array::from_fn(|r| rows[r].as_slice());
        let bp = randv(&mut rng, k * nr);
        let mut acc = [[0f32; NR_MAX]; MR];
        tile(tier, &a, mr, &bp, k, nr, &mut acc);
        (0..mr).flat_map(|r| acc[r][..nr].to_vec()).collect()
    }

    /// f64 reference for the same tile.
    fn naive_tile(mr: usize, nr: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..MR).map(|_| randv(&mut rng, k)).collect();
        let bp = randv(&mut rng, k * nr);
        let mut out = vec![0f64; mr * nr];
        for r in 0..mr {
            for kk in 0..k {
                for j in 0..nr {
                    out[r * nr + j] += rows[r][kk] as f64 * bp[kk * nr + j] as f64;
                }
            }
        }
        out.iter().map(|&v| v as f32).collect()
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in [Tier::Scalar, Tier::Avx2, Tier::Neon] {
            assert_eq!(Tier::parse(t.name()), Some(t));
            assert_eq!(format!("{t}"), t.name());
        }
        assert_eq!(Tier::parse("avx512"), None);
        assert_eq!(Tier::parse(""), None);
    }

    #[test]
    fn scalar_is_always_available_and_first() {
        let tiers = available_tiers();
        assert_eq!(tiers[0], Tier::Scalar);
        let mut sorted = tiers.clone();
        sorted.dedup();
        assert_eq!(sorted, tiers, "no duplicate tiers");
        assert!(tiers.contains(&active()), "active tier must be available");
    }

    #[test]
    fn every_tier_matches_naive_on_every_tile_shape() {
        for &tier in &available_tiers() {
            for nr in [8usize, 16] {
                for mr in 1..=MR {
                    for k in [1usize, 2, 7, 33] {
                        let seed = 90 + (mr * 31 + nr * 7 + k) as u64;
                        let got = run_tile(tier, mr, nr, k, seed);
                        let want = naive_tile(mr, nr, k, seed);
                        close(&got, &want, 1e-4, &format!("{tier} mr={mr} nr={nr} k={k}"));
                    }
                }
            }
        }
    }

    #[test]
    fn tile_touches_only_live_rows_and_lanes() {
        for &tier in &available_tiers() {
            for nr in [8usize, 16] {
                let (mr, k) = (2usize, 9usize);
                let mut rng = Rng::new(7);
                let rows: Vec<Vec<f32>> = (0..MR).map(|_| randv(&mut rng, k)).collect();
                let a: [&[f32]; MR] = std::array::from_fn(|r| rows[r].as_slice());
                let bp = randv(&mut rng, k * nr);
                let mut acc = [[7.5f32; NR_MAX]; MR];
                tile(tier, &a, mr, &bp, k, nr, &mut acc);
                for r in mr..MR {
                    assert_eq!(acc[r], [7.5f32; NR_MAX], "{tier}: dead row {r} written");
                }
                for r in 0..mr {
                    for j in nr..NR_MAX {
                        assert_eq!(acc[r][j], 7.5, "{tier}: dead lane {j} written");
                    }
                }
            }
        }
    }

    #[test]
    fn axpy_and_mul_acc_match_scalar_per_tier() {
        for &tier in &available_tiers() {
            for n in [1usize, 3, 8, 9, 16, 31] {
                let mut rng = Rng::new(100 + n as u64);
                let x = randv(&mut rng, n);
                let w = randv(&mut rng, n);
                let init = randv(&mut rng, n);

                let mut want = init.clone();
                scalar_axpy(&mut want, &x, 0.37);
                let mut got = init.clone();
                axpy(tier, &mut got, &x, 0.37);
                close(&got, &want, 1e-5, &format!("axpy {tier} n={n}"));

                let mut want = init.clone();
                scalar_mul_acc(&mut want, &x, &w);
                let mut got = init.clone();
                mul_acc(tier, &mut got, &x, &w);
                close(&got, &want, 1e-5, &format!("mul_acc {tier} n={n}"));
            }
        }
    }

    #[test]
    fn scalar_tile_matches_seed_kernel_bitwise() {
        // The seed's micro_kernel loop order was kk → j → rows; the
        // scalar tier is kk → rows → j. Per-element chains are the
        // same (ascending k), so bits must match exactly.
        let (k, nr) = (57usize, 8usize);
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..MR).map(|_| randv(&mut rng, k)).collect();
        let a: [&[f32]; MR] = std::array::from_fn(|r| rows[r].as_slice());
        let bp = randv(&mut rng, k * nr);
        let mut acc = [[0f32; NR_MAX]; MR];
        tile(Tier::Scalar, &a, MR, &bp, k, nr, &mut acc);
        // Seed loop order, reproduced inline.
        let mut seed_acc = [[0f32; 8]; MR];
        for kk in 0..k {
            let brow = &bp[kk * 8..kk * 8 + 8];
            for j in 0..8 {
                for r in 0..MR {
                    seed_acc[r][j] += rows[r][kk] * brow[j];
                }
            }
        }
        for r in 0..MR {
            for j in 0..8 {
                assert_eq!(
                    acc[r][j].to_bits(),
                    seed_acc[r][j].to_bits(),
                    "element ({r},{j}) drifted from the seed kernel"
                );
            }
        }
    }
}
