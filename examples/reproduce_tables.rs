//! End-to-end driver: regenerates every paper artifact — Table 1 (all
//! 12 cells), Table 2 (ablation), and the adaptive-behaviour figure —
//! on the simulated substrate, logging per-epoch loss curves along the
//! way. This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example reproduce_tables            # default budget
//!     cargo run --release --example reproduce_tables -- --steps 100 --epochs 5 --seeds 0,1,2
//!
//! Scale knobs trade fidelity for wallclock; the method ordering and
//! memory/time reductions (the reproduction target) are stable across
//! budgets.

use anyhow::Result;

use tri_accel::config::Config;
use tri_accel::harness;
use tri_accel::runtime::Engine;
use tri_accel::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps: usize = args.parse_or("steps", 8)?;
    let epochs: usize = args.parse_or("epochs", 2)?;
    let seeds: Vec<u64> = args
        .get_or("seeds", "0,1,2")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    // Native backend by default; the artifact models (resnet18/effnet)
    // come back with `--features pjrt` + `make artifacts`. The default
    // model list is whatever the selected backend's manifest serves,
    // so it stays valid on both.
    let engine = Engine::new(std::path::Path::new("artifacts"))?;
    let models = match args.get("models") {
        Some(m) => m.to_string(),
        None => engine
            .manifest
            .models
            .keys()
            .cloned()
            .collect::<Vec<_>>()
            .join(","),
    };
    args.reject_unknown()?;
    println!("platform {} — {} steps/epoch × {} epochs × {} seeds", engine.platform(), steps, epochs, seeds.len());
    let tweak = harness::quick_budget(steps, epochs);

    // ---------------- Table 1 ------------------------------------------
    let keys: Vec<&str> = models.split(',').collect();
    println!("\n=== Table 1: Performance and Efficiency comparison ===");
    let rows = harness::table1(&engine, &keys, &seeds, &tweak)?;
    harness::print_table1(&rows);
    println!("\nheadlines (ours, modeled accelerator time):");
    for chunk in rows.chunks(3) {
        println!("  {:<18} {}", chunk[0].model_key, harness::headline(&chunk[0], &chunk[2]));
    }
    println!("paper: time −9.9% (max), memory −13.3% (max), accuracy +1.1–1.7pp vs FP32");

    // ---------------- Table 2 ------------------------------------------
    let ablation_key = keys[0];
    println!("\n=== Table 2: ablation — {ablation_key} ===");
    let rows = harness::table2(&engine, ablation_key, &seeds, &tweak)?;
    harness::print_table2(&rows);

    // ---------------- Figure: adaptive behaviour -----------------------
    println!("\n=== Figure: adaptive behaviour ({ablation_key}, Tri-Accel, seed 0) ===");
    let more_epochs = move |cfg: &mut Config| {
        tweak(cfg);
        cfg.epochs = (epochs * 2).max(4); // longer horizon to see the trend
    };
    let t = harness::fig_adaptive(&engine, ablation_key, 0, &more_epochs)?;
    println!("epoch  eff_score   fp16/bf16/fp32 mix");
    for ((e, s), (_, f16, b16, f32_)) in t.epoch_eff.iter().zip(&t.mix_trace) {
        println!("{e:>5}  {s:>9.3}   {:.2}/{:.2}/{:.2}", f16, b16, f32_);
    }
    println!("batch-size trace: {:?}", t.batch_trace);

    println!("\ndone — numbers above are CPU-substrate + analytic-accelerator-model;");
    println!("compare *shape* (ordering, reductions) against the paper per EXPERIMENTS.md.");
    Ok(())
}
