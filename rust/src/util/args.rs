//! Tiny CLI argument parser (substrate — no clap in the offline build).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value]... [--flag]...`
//! Unknown keys are an error (catches typos in experiment scripts).
//! `--key=value` splits at the first `=`, so `--set=batch_init=96`
//! reads key `set`, value `batch_init=96`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    /// `--flag=VALUE` spellings whose VALUE wasn't a recognized
    /// boolean — reported by [`Self::reject_unknown`] (same
    /// typo-catching stance as unknown keys).
    bad_bools: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        if i < argv.len() && !argv[i].starts_with("--") {
            out.subcommand = Some(argv[i].clone());
            i += 1;
        }
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --option, got `{a}`"))?;
            if let Some((k, v)) = key.split_once('=') {
                anyhow::ensure!(!k.is_empty(), "empty option name in `{a}`");
                out.kv.insert(k.to_string(), v.to_string());
                i += 1;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.kv.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                out.flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse `{v}`")),
        }
    }

    /// Boolean flag: bare `--flag`, or the `--flag=true|false` spelling
    /// (`true|1|yes` / `false|0|no`). Any other `=` value is recorded
    /// and reported as an error by [`Self::reject_unknown`] — a typo'd
    /// `--smoke=True` must not silently run the full-budget grid.
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        match self.kv.get(key).map(|s| s.as_str()) {
            Some("true" | "1" | "yes") => true,
            Some("false" | "0" | "no") | None => false,
            Some(other) => {
                self.bad_bools
                    .borrow_mut()
                    .push(format!("--{key}={other}"));
                false
            }
        }
    }

    /// Call after all gets: errors on any option the program never
    /// read, and on any boolean flag given a non-boolean `=` value.
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        if let Some(bad) = self.bad_bools.borrow().first() {
            anyhow::bail!("{bad}: boolean flags take true|false|1|0|yes|no");
        }
        let seen = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = Args::parse(&argv("train --model resnet18 --epochs 3 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("resnet18"));
        assert_eq!(a.parse_or("epochs", 0usize).unwrap(), 3);
        assert!(a.flag("verbose"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn equals_syntax_splits_at_first_equals() {
        let a = Args::parse(&argv("table1 --jobs=4 --set=batch_init=96 --smoke")).unwrap();
        assert_eq!(a.parse_or("jobs", 1usize).unwrap(), 4);
        assert_eq!(a.get("set"), Some("batch_init=96"));
        assert!(a.flag("smoke"));
        a.reject_unknown().unwrap();
        assert!(Args::parse(&argv("run --=v")).is_err(), "empty key rejected");
    }

    #[test]
    fn flags_accept_equals_boolean_spelling() {
        let a = Args::parse(&argv("table1 --smoke=true --quiet=false")).unwrap();
        assert!(a.flag("smoke"), "--smoke=true must behave like --smoke");
        assert!(!a.flag("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn flags_reject_typod_boolean_values() {
        let a = Args::parse(&argv("table1 --smoke=True")).unwrap();
        assert!(!a.flag("smoke"), "unrecognized value reads false pre-reject");
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("--smoke=True"), "{err}");
        assert!(err.contains("true|false"), "{err}");
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("run")).unwrap();
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.parse_or("n", 7i32).unwrap(), 7);
        assert!(!a.flag("f"));
    }

    #[test]
    fn unknown_rejected() {
        let a = Args::parse(&argv("run --oops 1")).unwrap();
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&argv("run --n abc")).unwrap();
        assert!(a.parse_or("n", 0usize).is_err());
    }
}
