//! Micro-benchmark harness (substrate — no criterion in the offline
//! build). `cargo bench` targets use `harness = false` and call into this.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean ± std
//! and p50/p90 per iteration.

use std::time::{Duration, Instant};

use super::stats::{percentile, Welford};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub p50: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters   mean {:>12?}   std {:>10?}   p50 {:>12?}   p90 {:>12?}",
            self.name, self.iters, self.mean, self.std, self.p50, self.p90
        )
    }
}

pub struct Bencher {
    pub warmup: u32,
    pub min_iters: u64,
    pub min_time: Duration,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 2,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000_000,
        }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end cases (train epochs etc.).
    pub fn heavy() -> Self {
        Bencher { warmup: 1, min_iters: 3, min_time: Duration::from_millis(100), max_iters: 20 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::default();
        let mut samples = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (iters < self.min_iters || start.elapsed() < self.min_time)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed();
            w.push(dt.as_secs_f64());
            samples.push(dt.as_secs_f64());
            iters += 1;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(w.mean()),
            std: Duration::from_secs_f64(w.std()),
            p50: Duration::from_secs_f64(percentile(&samples, 0.5)),
            p90: Duration::from_secs_f64(percentile(&samples, 0.9)),
        };
        println!("{}", res.row());
        res
    }
}

/// Prevents the optimizer from eliding a computed value (ptr read fence).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher { warmup: 1, min_iters: 5, min_time: Duration::from_millis(1), max_iters: 50 };
        let mut acc = 0u64;
        let r = b.run("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean >= Duration::ZERO);
    }
}
