//! Fault-injection & crash-recovery integration suite.
//!
//! The contracts under test (ISSUE 7 acceptance criteria):
//! * a ledger truncated at *every* byte offset loads to a usable valid
//!   prefix (or is cleanly diagnosed as corrupt while the header is
//!   damaged), healing is lossless, and resuming from any truncation
//!   class reproduces bit-identical artifacts;
//! * a torn telemetry stream can never pass a record off as authentic:
//!   any line that verifies its `crc` is byte-equal to the original;
//! * the supervisor retries transient faults (panics, OOM storms,
//!   IO errors) to a bit-identical completion, isolates a persistently
//!   failing job into quarantine with a partial report, and a full
//!   chaos plan — including a torn-ledger crash and resume — converges
//!   to artifacts byte-identical to the fault-free run.

use std::path::{Path, PathBuf};

use tri_accel::config::{Config, Method};
use tri_accel::faults::{FaultSpec, RealIo};
use tri_accel::metrics::telemetry;
use tri_accel::policy::registry;
use tri_accel::sched::{self, CellSpec, GridKind, GridSpec, Ledger, Loaded, SchedOptions};
use tri_accel::util::json::Json;

fn tweak(cfg: &mut Config) {
    cfg.steps_per_epoch = Some(2);
    cfg.epochs = 1;
    cfg.train_examples = 256;
    cfg.eval_examples = 128;
    cfg.batch_init = 32;
    cfg.t_ctrl = 2;
    cfg.t_curv = 3;
    cfg.curv_warmup = 1;
    cfg.batch_cooldown = 2;
    cfg.warmup_epochs = 0;
    cfg.mem_budget_gb = 0.0;
    cfg.mem_noise = 0.0;
}

/// 1 model × N methods × 1 seed = N jobs.
fn spec_n(methods: &[Method]) -> GridSpec {
    let mut cells = Vec::new();
    for &method in methods {
        let mut base = Config::cell("tiny_cnn_c10", method, 0);
        tweak(&mut base);
        cells.push(CellSpec {
            model_key: "tiny_cnn_c10".to_string(),
            label: method.name().to_string(),
            method_key: registry::effective_key(&base),
            seeds: vec![0],
            base,
        });
    }
    GridSpec { kind: GridKind::Table1, cells }
}

fn two_job_spec() -> GridSpec {
    spec_n(&[Method::Fp32, Method::TriAccel])
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "triaccel_faults_it_{name}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn opts(out: &Path, jobs: usize) -> SchedOptions {
    SchedOptions {
        jobs,
        total_threads: 4,
        out_dir: out.to_path_buf(),
        quiet: true,
        ..SchedOptions::default()
    }
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

fn result_bits(e: &sched::LedgerEntry) -> String {
    e.result.to_json().to_string_compact()
}

/// Run the grid under a fault plan, resuming across simulated
/// torn-write crashes the way an operator (or `tri-accel chaos`)
/// would. Returns (outcome, restart count).
fn run_with_resume(spec: &GridSpec, o: &SchedOptions, max: usize) -> (sched::GridOutcome, usize) {
    let mut restarts = 0usize;
    loop {
        match sched::run_grid(spec, o) {
            Ok(out) => return (out, restarts),
            Err(e) if format!("{e:#}").contains("injected") && restarts < max => restarts += 1,
            Err(e) => panic!("non-injected grid failure: {e:#}"),
        }
    }
}

#[test]
fn ledger_truncated_at_every_byte_offset_loads_a_valid_prefix() {
    let spec = two_job_spec();
    let ref_out = tmp("lprop");
    let reference = sched::run_grid(&spec, &opts(&ref_out, 1)).unwrap();
    assert!(reference.complete);
    let bytes = std::fs::read(reference.grid_dir.join("ledger.json")).unwrap();
    let ref_led = Ledger::load(&reference.grid_dir.join("ledger.json")).unwrap();
    assert_eq!(ref_led.entries.len(), 2);
    let header_end = bytes.iter().position(|&b| b == b'\n').unwrap();

    let scratch = tmp("lscratch");
    std::fs::create_dir_all(&scratch).unwrap();
    let f = scratch.join("ledger.json");
    for k in 0..=bytes.len() {
        std::fs::write(&f, &bytes[..k]).unwrap();
        match Ledger::load_relaxed(&f).unwrap() {
            Loaded::Usable { ledger, dropped } => {
                assert!(k >= header_end, "offset {k}: usable before the header is whole");
                // Every recovered entry is authentic — the checksum
                // makes a truncated record unrepresentable as data.
                for (key, e) in &ledger.entries {
                    let r = ref_led
                        .entries
                        .get(key)
                        .unwrap_or_else(|| panic!("offset {k}: phantom entry `{key}`"));
                    assert_eq!(result_bits(e), result_bits(r), "offset {k}");
                }
                if ledger.entries.len() == ref_led.entries.len() {
                    assert_eq!(dropped, 0, "offset {k}: full prefix drops nothing");
                }
                // Healing (what grid resume does) is lossless and
                // leaves a file that reloads clean.
                ledger.save(&f, &RealIo).unwrap();
                match Ledger::load_relaxed(&f).unwrap() {
                    Loaded::Usable { ledger: healed, dropped: d2 } => {
                        assert_eq!(d2, 0, "offset {k}: healed file has no torn tail");
                        assert_eq!(
                            healed.entries.keys().collect::<Vec<_>>(),
                            ledger.entries.keys().collect::<Vec<_>>(),
                            "offset {k}"
                        );
                    }
                    Loaded::Corrupt { reason } => panic!("offset {k}: healed corrupt: {reason}"),
                }
            }
            Loaded::Corrupt { .. } => {
                assert!(
                    k <= header_end,
                    "offset {k}: corrupt verdict with an intact header"
                );
            }
        }
    }
    std::fs::remove_dir_all(&ref_out).ok();
    std::fs::remove_dir_all(&scratch).ok();
}

#[test]
fn resume_from_each_truncation_class_is_bit_identical() {
    let spec = two_job_spec();
    let ref_out = tmp("ltrunc");
    let reference = sched::run_grid(&spec, &opts(&ref_out, 1)).unwrap();
    assert!(reference.complete);
    let bytes = std::fs::read(reference.grid_dir.join("ledger.json")).unwrap();
    let ref_table = read(&reference.grid_dir.join("table1.md"));
    let ref_bench = read(&reference.grid_dir.join("BENCH_grid.json"));
    let nl: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter_map(|(i, &b)| (b == b'\n').then_some(i))
        .collect();
    assert_eq!(nl.len(), 3, "header + 2 job records");
    // One offset per recovery class: empty file, mid-header, header
    // only, mid-record 1, record 1 whole, mid-record 2, whole file.
    let mut classes = vec![
        0,
        nl[0] / 2,
        nl[0] + 1,
        (nl[0] + nl[1]) / 2,
        nl[1] + 1,
        (nl[1] + nl[2]) / 2,
        bytes.len(),
    ];
    classes.dedup();
    for k in classes {
        let out = tmp(&format!("ltrunc_k{k}"));
        let grid_dir = out.join(&reference.grid_id);
        std::fs::create_dir_all(grid_dir.join("events")).unwrap();
        std::fs::write(grid_dir.join("ledger.json"), &bytes[..k]).unwrap();
        let resumed = sched::run_grid(&spec, &opts(&out, 2)).unwrap();
        assert!(resumed.complete, "offset {k}");
        assert_eq!(resumed.executed + resumed.reused, resumed.total, "offset {k}");
        assert_eq!(
            read(&resumed.grid_dir.join("table1.md")),
            ref_table,
            "table1.md diverged resuming from truncation at {k}"
        );
        assert_eq!(
            read(&resumed.grid_dir.join("BENCH_grid.json")),
            ref_bench,
            "BENCH_grid.json diverged resuming from truncation at {k}"
        );
        std::fs::remove_dir_all(&out).ok();
    }
    std::fs::remove_dir_all(&ref_out).ok();
}

#[test]
fn torn_event_stream_never_passes_a_tampered_record() {
    let spec = sched::fig_spec("tiny_cnn_c10", 0, &tweak);
    let out = tmp("etorn");
    let o = sched::run_grid(&spec, &opts(&out, 1)).unwrap();
    assert!(o.complete);
    let led = Ledger::load(&o.grid_dir.join("ledger.json")).unwrap();
    let key = led.cells[0].job_keys[0].clone();
    let events = o.grid_dir.join("events").join(format!("{key}.jsonl"));
    let bytes = std::fs::read(&events).unwrap();
    let full = String::from_utf8(bytes.clone()).expect("events are UTF-8");
    let orig: Vec<&str> = full.lines().collect();
    assert!(orig.len() >= 4, "run_started + steps + epoch + run_finished");
    for line in &orig {
        let j = Json::parse(line).unwrap();
        assert!(telemetry::crc_ok(&j), "reference stream is fully sealed: {line}");
    }
    // Crash at every byte offset: any line in the torn prefix that
    // still verifies its crc must be byte-identical to the original —
    // truncation can lose the tail record but never corrupt one.
    for k in 0..=bytes.len() {
        let Ok(text) = std::str::from_utf8(&bytes[..k]) else {
            continue; // mid-UTF-8 cut: no line of this prefix parses anyway
        };
        for (i, seg) in text.split('\n').enumerate() {
            if seg.is_empty() {
                continue;
            }
            if let Ok(j) = Json::parse(seg) {
                if telemetry::crc_ok(&j) {
                    assert_eq!(
                        seg, orig[i],
                        "offset {k}: a truncated line verified without being authentic"
                    );
                }
            }
        }
    }
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn rerun_after_events_truncation_rebuilds_a_sealed_stream() {
    let spec = sched::fig_spec("tiny_cnn_c10", 0, &tweak);
    let out = tmp("eresume");
    let o = sched::run_grid(&spec, &opts(&out, 1)).unwrap();
    assert!(o.complete);
    let ledger_path = o.grid_dir.join("ledger.json");
    let led = Ledger::load(&ledger_path).unwrap();
    let key = led.cells[0].job_keys[0].clone();
    let events = o.grid_dir.join("events").join(format!("{key}.jsonl"));
    let bytes = std::fs::read(&events).unwrap();
    let ref_bench = read(&o.grid_dir.join("BENCH_grid.json"));
    for k in [0, bytes.len() / 3, bytes.len() - 1] {
        // Simulate a crash mid-job: torn events, no ledger record.
        std::fs::write(&events, &bytes[..k]).unwrap();
        let mut crashed = led.clone();
        crashed.entries.clear();
        crashed.save(&ledger_path, &RealIo).unwrap();
        let resumed = sched::run_grid(&spec, &opts(&out, 1)).unwrap();
        assert!(resumed.complete, "offset {k}");
        assert_eq!(resumed.executed, 1, "offset {k}: the torn job reran");
        let text = read(&events);
        for line in text.lines() {
            let j = Json::parse(line).unwrap_or_else(|e| panic!("offset {k}: {e}"));
            assert!(telemetry::crc_ok(&j), "offset {k}: rebuilt stream is sealed");
        }
        assert_eq!(
            read(&resumed.grid_dir.join("BENCH_grid.json")),
            ref_bench,
            "offset {k}"
        );
        // And the figure still reconstructs from the healed stream.
        let reled = Ledger::load(&ledger_path).unwrap();
        sched::report::fig_series(&resumed.grid_dir, &reled).unwrap();
    }
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn supervisor_retries_a_panicking_job_to_a_clean_finish() {
    let spec = two_job_spec();
    let clean_out = tmp("pclean");
    let clean = sched::run_grid(&spec, &opts(&clean_out, 1)).unwrap();
    assert!(clean.complete);

    let fault_out = tmp("pfault");
    let mut o = opts(&fault_out, 1);
    o.retries = 2;
    o.faults = Some(FaultSpec::parse("seed:5,panic:1").unwrap());
    let faulted = sched::run_grid(&spec, &o).unwrap();
    assert!(faulted.complete, "one panic within the retry budget recovers");
    assert!(faulted.quarantined.is_empty());
    let log = read(&faulted.grid_dir.join("faults.jsonl"));
    let kinds: Vec<String> = log
        .lines()
        .map(|l| {
            Json::parse(l).unwrap().get("kind").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    assert_eq!(kinds, ["panic"], "exactly one fault fired: {log}");
    assert_eq!(
        read(&faulted.grid_dir.join("table1.md")),
        read(&clean.grid_dir.join("table1.md")),
        "a retried panic leaves no trace in the artifacts"
    );
    assert_eq!(
        read(&faulted.grid_dir.join("BENCH_grid.json")),
        read(&clean.grid_dir.join("BENCH_grid.json"))
    );
    std::fs::remove_dir_all(&clean_out).ok();
    std::fs::remove_dir_all(&fault_out).ok();
}

#[test]
fn simulated_oom_storms_retry_without_contaminating_results() {
    let spec = two_job_spec();
    let clean_out = tmp("oclean");
    let clean = sched::run_grid(&spec, &opts(&clean_out, 1)).unwrap();

    let fault_out = tmp("ofault");
    let mut o = opts(&fault_out, 1);
    o.retries = 3;
    o.faults = Some(FaultSpec::parse("seed:5,oom:1:2").unwrap());
    let faulted = sched::run_grid(&spec, &o).unwrap();
    assert!(faulted.complete, "storms clear within the retry budget");
    let log = read(&faulted.grid_dir.join("faults.jsonl"));
    assert_eq!(log.lines().count(), 2, "both storm hits fired: {log}");
    assert!(log.contains("\"kind\":\"oom\""), "{log}");
    assert_eq!(
        read(&faulted.grid_dir.join("table1.md")),
        read(&clean.grid_dir.join("table1.md")),
        "OOM storms kill attempts, never results"
    );
    std::fs::remove_dir_all(&clean_out).ok();
    std::fs::remove_dir_all(&fault_out).ok();
}

#[test]
fn retry_exhaustion_quarantines_and_renders_a_partial_report() {
    let spec = two_job_spec();
    let out = tmp("quar");
    let mut o = opts(&out, 1);
    o.retries = 1;
    // 5 hits > 1+1 attempts: the targeted job cannot complete.
    o.faults = Some(FaultSpec::parse("seed:5,panic:1:5").unwrap());
    let outcome = sched::run_grid(&spec, &o).unwrap();
    assert!(!outcome.complete, "a quarantined job leaves the grid incomplete");
    assert_eq!(outcome.quarantined.len(), 1);
    let q = &outcome.quarantined[0];
    assert_eq!(q.attempts, 2, "initial attempt + 1 retry");
    assert!(q.error.contains("injected fault"), "{}", q.error);
    // The healthy job still completed — panic isolation.
    let led = Ledger::load(&out.join(&outcome.grid_id).join("ledger.json")).unwrap();
    assert_eq!(led.entries.len(), 1, "the untargeted job is unaffected");
    assert!(!led.entries.contains_key(&q.key));
    // A partial report marks the damage; the diffable summary is not
    // written for incomplete grids.
    assert_eq!(outcome.artifacts.len(), 1);
    let partial = read(&outcome.artifacts[0]);
    assert!(partial.contains("PARTIAL"), "{partial}");
    assert!(partial.contains("Quarantined cells"), "{partial}");
    assert!(partial.contains(&q.key), "{partial}");
    assert!(!outcome.grid_dir.join("BENCH_grid.json").exists());

    // Rerunning without faults retries the quarantined job and
    // overwrites the partial report with the full one.
    let healed = sched::run_grid(&spec, &opts(&out, 1)).unwrap();
    assert!(healed.complete);
    assert_eq!(healed.reused, 1);
    assert!(!read(&healed.grid_dir.join("table1.md")).contains("PARTIAL"));
    assert!(healed.grid_dir.join("BENCH_grid.json").exists());
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn full_chaos_plan_converges_to_bit_identical_artifacts() {
    let spec = spec_n(&[Method::Fp32, Method::AmpStatic, Method::TriAccel]);
    let clean_out = tmp("cclean");
    let clean = sched::run_grid(&spec, &opts(&clean_out, 1)).unwrap();
    assert!(clean.complete);

    let fault_out = tmp("cfault");
    let mut o = opts(&fault_out, 2);
    o.retries = 3;
    let fspec = FaultSpec::parse("seed:7,io:1,ledger_io:1,panic:1,oom:1,torn:1").unwrap();
    o.faults = Some(fspec.clone());
    let (faulted, restarts) = run_with_resume(&spec, &o, fspec.torn + 2);
    assert!(faulted.complete, "the full plan is survivable at --retries 3");
    assert!(faulted.quarantined.is_empty());
    assert_eq!(restarts, 1, "the torn write killed exactly one process");
    let log = read(&faulted.grid_dir.join("faults.jsonl"));
    let mut kinds: Vec<String> = log
        .lines()
        .map(|l| {
            Json::parse(l).unwrap().get("kind").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    kinds.sort();
    assert_eq!(
        kinds,
        ["io", "ledger_io", "oom", "panic", "torn"],
        "every scheduled fault fired exactly once: {log}"
    );
    assert_eq!(
        read(&faulted.grid_dir.join("table1.md")),
        read(&clean.grid_dir.join("table1.md")),
        "chaos run artifacts must be bit-identical to the fault-free run"
    );
    assert_eq!(
        read(&faulted.grid_dir.join("BENCH_grid.json")),
        read(&clean.grid_dir.join("BENCH_grid.json"))
    );
    std::fs::remove_dir_all(&clean_out).ok();
    std::fs::remove_dir_all(&fault_out).ok();
}
