//! Design-choice ablations (DESIGN.md §6) — the decisions the paper
//! leaves implicit, measured so EXPERIMENTS.md can justify them:
//!
//!   A1  auto-thresholding vs the paper's fixed τ_low/τ_high
//!   A2  curvature LR-scaling on/off (η_l = η₀/(1+α·λ) vs η₀)
//!   A3  batch-growth cooldown 0 vs tuned (oscillation damping)
//!   A4  linear LR/batch scaling on/off under elastic batching
//!
//! Env knobs: AB_STEPS, AB_EPOCHS, AB_SEEDS, AB_MODEL.

use tri_accel::config::{Config, Method};
use tri_accel::harness::{self, quick_budget};
use tri_accel::runtime::Engine;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let engine = Engine::native();
    let steps = env_usize("AB_STEPS", 30);
    let epochs = env_usize("AB_EPOCHS", 2);
    let seeds: Vec<u64> = std::env::var("AB_SEEDS")
        .unwrap_or_else(|_| "0,1".into())
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let model = std::env::var("AB_MODEL").unwrap_or_else(|_| "tiny_cnn_c10".into());
    let base = quick_budget(steps, epochs);

    type Tweak = Box<dyn Fn(&mut Config)>;
    let variants: Vec<(&str, Tweak)> = vec![
        ("baseline (all defaults)", Box::new(|_: &mut Config| {})),
        (
            "A1: fixed τ (no auto-threshold)",
            Box::new(|c: &mut Config| {
                c.auto_threshold = false;
            }),
        ),
        (
            "A2: curvature LR-scaling off",
            Box::new(|c: &mut Config| {
                c.ablation.curvature = false;
            }),
        ),
        (
            "A3: batch cooldown 0",
            Box::new(|c: &mut Config| {
                c.batch_cooldown = 0;
            }),
        ),
        (
            "A4: linear LR/batch scaling",
            Box::new(|c: &mut Config| {
                c.lr_batch_scaling = true;
            }),
        ),
    ];

    println!(
        "== design ablations — {model}, Tri-Accel, {} seed(s) × {steps} steps × {epochs} epochs ==",
        seeds.len()
    );
    for (label, tweak) in &variants {
        let t = |cfg: &mut Config| {
            base(cfg);
            tweak(cfg);
        };
        let cell = harness::run_cell(&engine, &model, Method::TriAccel, label, &seeds, &t)
            .expect("ablation cell");
        println!("{}", cell.row());
    }
    println!("\n(rows share data/seeds; deltas isolate each design choice.)");
}
