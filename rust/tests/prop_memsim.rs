//! Property suite for the memory record/replay subsystem (ISSUE 10):
//!
//! * recording a pressure grid's `MemMax` series and replaying it via
//!   `replay:FILE#DIGEST` reproduces the original grid bit-for-bit
//!   (ledger results and telemetry, modulo the wall-clock/crc fields
//!   `sched::replay` normalizes away);
//! * replayed grids are `--jobs`-width invariant (byte-identical
//!   report artifacts) and the replayed ceiling is `--replicas`-width
//!   invariant (the absolute series lands bit-exact at any width);
//! * a host-memory meter feeds `host_mem` telemetry only — even an
//!   absurd fake sample never moves a loss, a batch decision, or any
//!   other telemetry line;
//! * malformed / oversized / non-finite / stale-digest replay specs
//!   fail at validation time, never mid-grid.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use tri_accel::config::Config;
use tri_accel::manifest::BF16;
use tri_accel::memsim::hostmem::{FakeMeter, MemSample};
use tri_accel::memsim::tracefile::{TraceFile, MAX_TRACE_STEPS};
use tri_accel::memsim::{BudgetTrace, VramSim};
use tri_accel::metrics::telemetry::TelemetrySink;
use tri_accel::policy::registry;
use tri_accel::runtime::Engine;
use tri_accel::sched::{self, replay, SchedOptions};
use tri_accel::train::Trainer;
use tri_accel::util::json::Json;

const STEPS: usize = 12;

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("triaccel_memsim_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn opts(out: &Path, jobs: usize) -> SchedOptions {
    SchedOptions {
        jobs,
        total_threads: 4,
        out_dir: out.to_path_buf(),
        quiet: true,
        ..SchedOptions::default()
    }
}

/// The squeeze base: B=32 at uniform 2-byte precision with 20%
/// headroom, so scenario dips below ~0.83 actually bite.
fn calibrated_base() -> f64 {
    let e = Engine::native();
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    sim.usage(32, &codes, false).total_gb * 1.2
}

fn run_pressure(
    trace: &str,
    out: &Path,
    jobs: usize,
    replicas: usize,
    methods: &[&str],
    base: f64,
) -> sched::GridOutcome {
    let tweak = move |cfg: &mut Config| {
        cfg.epochs = 1;
        cfg.steps_per_epoch = Some(STEPS);
        cfg.train_examples = 1024;
        cfg.eval_examples = 128;
        cfg.batch_init = 32;
        cfg.t_ctrl = 3;
        cfg.t_curv = 0;
        cfg.batch_cooldown = 2;
        cfg.warmup_epochs = 0;
        cfg.mem_budget_gb = base;
        cfg.mem_noise = 0.0;
        cfg.replicas = replicas;
    };
    let spec = sched::pressure_spec("tiny_cnn_c10", methods, &[0], trace, &tweak).unwrap();
    sched::run_grid(&spec, &opts(out, jobs)).unwrap()
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Record a trace from the first job of a finished grid.
fn record_first_job(o: &sched::GridOutcome) -> (String, TraceFile) {
    let led = sched::Ledger::load(&o.grid_dir.join("ledger.json")).unwrap();
    let key = led.cells[0].job_keys[0].clone();
    let text = read(&o.grid_dir.join("events").join(format!("{key}.jsonl")));
    let tf = TraceFile::from_events(&text, &key).unwrap();
    (key, tf)
}

/// The bit pattern of every step event's `max_gb`, in step order.
fn max_gb_series(events_text: &str) -> Vec<u64> {
    let mut out = Vec::new();
    for line in events_text.lines().filter(|l| !l.trim().is_empty()) {
        let ev = Json::parse(line).unwrap();
        if ev.get("event").and_then(Json::as_str) == Some("step") {
            out.push(ev.req("max_gb").unwrap().as_f64().unwrap().to_bits());
        }
    }
    out
}

#[test]
fn record_replay_round_trips_bit_identically_across_jobs_widths() {
    let root = tmp("roundtrip");
    let base = calibrated_base();
    let methods = ["amp_static", "greedy_batch"];

    // Record: a spike-scenario grid whose dips force real decisions.
    let a = run_pressure("scenario:spike", &root.join("rec"), 1, 1, &methods, base);
    assert!(a.complete);
    let (key, tf) = record_first_job(&a);
    assert_eq!(tf.gb.len(), STEPS, "one ceiling sample per optimizer step ({key})");
    let trace_path = root.join("trace.json");
    tf.save(&trace_path).unwrap();
    let spec = format!("replay:{}#{:016x}", trace_path.display(), tf.digest());

    // Replay the recorded squeeze at two job widths.
    let b1 = run_pressure(&spec, &root.join("b1"), 1, 1, &methods, base);
    let b4 = run_pressure(&spec, &root.join("b4"), 4, 1, &methods, base);
    assert!(b1.complete && b4.complete);

    // Replay ≡ recording: same results and telemetry once the
    // wall/crc/config-hash channels are normalized away.
    let rep = replay::compare_grids(&a.grid_dir, &b1.grid_dir).unwrap();
    assert!(rep.ok(), "record vs replay diverged:\n{}", rep.render());

    // The two replay widths share a grid id and byte-identical
    // wall-free report artifacts.
    assert_eq!(b1.grid_id, b4.grid_id, "grid id is content-derived");
    assert_ne!(a.grid_id, b1.grid_id, "the trace spec is part of grid identity");
    for name in ["pressure.md", "BENCH_grid.json"] {
        assert_eq!(
            read(&b1.grid_dir.join(name)),
            read(&b4.grid_dir.join(name)),
            "{name} must not depend on job-pool width"
        );
    }
    let rep14 = replay::compare_grids(&b1.grid_dir, &b4.grid_dir).unwrap();
    assert!(rep14.ok(), "jobs 1 vs 4 diverged:\n{}", rep14.render());

    // The replayed grid really saw the recorded ceilings, bit for bit.
    let led = sched::Ledger::load(&b1.grid_dir.join("ledger.json")).unwrap();
    for cell in &led.cells {
        for key in &cell.job_keys {
            let ev_path = b1.grid_dir.join("events").join(format!("{key}.jsonl"));
            let got = max_gb_series(&read(&ev_path));
            let want: Vec<u64> = tf.gb.iter().map(|g| g.to_bits()).collect();
            assert_eq!(got, want, "replayed ceiling series for {key}");
        }
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn replayed_ceiling_is_replica_width_invariant() {
    let root = tmp("replica");
    let base = calibrated_base();

    // Record at one replica under the frag ratchet...
    let a = run_pressure("scenario:frag", &root.join("rec"), 1, 1, &["greedy_batch"], base);
    let (_, tf) = record_first_job(&a);
    let trace_path = root.join("trace.json");
    tf.save(&trace_path).unwrap();
    let spec = format!("replay:{}#{:016x}", trace_path.display(), tf.digest());
    let want: Vec<u64> = tf.gb.iter().map(|g| g.to_bits()).collect();

    // ...then replay at 1 and 2 replicas: the absolute series is pure
    // step-indexed data, so the imposed ceiling is identical even
    // though the replicated footprint (and hence the decisions made
    // under that ceiling) may differ.
    for (replicas, tag) in [(1usize, "r1"), (2, "r2")] {
        let o = run_pressure(&spec, &root.join(tag), 1, replicas, &["greedy_batch"], base);
        assert!(o.complete, "replicas={replicas}");
        let led = sched::Ledger::load(&o.grid_dir.join("ledger.json")).unwrap();
        let key = led.cells[0].job_keys[0].clone();
        if replicas == 2 {
            assert!(key.contains("_r2"), "replicated jobs get suffixed keys: {key}");
        }
        let got = max_gb_series(&read(&o.grid_dir.join("events").join(format!("{key}.jsonl"))));
        assert_eq!(got, want, "replayed ceiling series at replicas={replicas}");
    }
    std::fs::remove_dir_all(&root).ok();
}

struct VecSink(Arc<Mutex<Vec<String>>>);

impl TelemetrySink for VecSink {
    fn emit(&mut self, event: &Json) {
        self.0.lock().unwrap().push(event.to_string_compact());
    }
}

#[test]
fn fake_host_meter_feeds_telemetry_without_moving_the_run() {
    let e = Engine::native();
    let run = |meter: Option<FakeMeter>| {
        let spec = registry::resolve("greedy_batch").unwrap();
        let mut cfg = Config::cell("tiny_cnn_c10", spec.family, 0);
        registry::apply(&mut cfg, spec);
        cfg.epochs = 1;
        cfg.steps_per_epoch = Some(STEPS);
        cfg.train_examples = 1024;
        cfg.eval_examples = 128;
        cfg.batch_init = 32;
        cfg.t_ctrl = 3;
        cfg.t_curv = 0;
        cfg.batch_cooldown = 2;
        cfg.warmup_epochs = 0;
        cfg.mem_budget_gb = 0.0;
        cfg.mem_noise = 0.0;
        let mut tr = Trainer::new(&e, cfg).unwrap();
        let events = Arc::new(Mutex::new(Vec::new()));
        tr.set_telemetry(Box::new(VecSink(events.clone())));
        if let Some(m) = meter {
            tr.set_mem_meter(Box::new(m));
        }
        let rec = tr.run_epoch(0).unwrap();
        let lines = events.lock().unwrap().clone();
        (rec.train_loss, tr.metrics.oom_events, tr.metrics.batch_trace.clone(), lines)
    };

    let (loss0, oom0, batch0, ev0) = run(None);
    // An absurd sample — "used" far above "max" — would force an
    // emergency shrink if the meter could steer the §3.3 policy.
    let samples = vec![
        MemSample { used_gb: 123.0, max_gb: 8.0 },
        MemSample { used_gb: 0.001, max_gb: 8.0 },
    ];
    let (loss1, oom1, batch1, ev1) = run(Some(FakeMeter::new(samples)));

    assert_eq!(loss0.to_bits(), loss1.to_bits(), "loss trajectory untouched");
    assert_eq!(oom0, oom1, "OOM accounting untouched");
    assert_eq!(batch0, batch1, "batch decisions untouched");

    // Every non-host_mem line is byte-identical once the wall-clock
    // channel (epoch wall_s) is normalized away.
    let normalized = |evs: &[String]| {
        evs.iter()
            .filter(|l| !l.contains("\"event\":\"host_mem\""))
            .map(|l| replay::normalize_line(l).unwrap())
            .collect::<Vec<_>>()
    };
    assert_eq!(normalized(&ev0), normalized(&ev1), "telemetry unchanged outside host_mem");

    assert!(
        ev0.iter().all(|l| !l.contains("\"event\":\"host_mem\"")),
        "no meter, no host_mem events"
    );
    let host: Vec<&String> = ev1.iter().filter(|l| l.contains("\"event\":\"host_mem\"")).collect();
    assert!(!host.is_empty(), "control windows must sample the installed meter");
    assert!(host.iter().all(|l| l.contains("\"source\":\"fake\"")), "{host:?}");
    assert!(host[0].contains("\"used_gb\":123"), "first sample replayed in order: {}", host[0]);
}

#[test]
fn replay_specs_reject_bad_traces_at_validation_time() {
    let root = tmp("reject");
    std::fs::create_dir_all(&root).unwrap();

    // Non-finite, non-positive, empty, and oversized series never
    // construct (standard JSON cannot even spell NaN, so a NaN file
    // already dies in the parser; this guards direct construction).
    assert!(TraceFile::new("t", vec![1.0, f64::NAN]).is_err());
    assert!(TraceFile::new("t", vec![1.0, f64::INFINITY]).is_err());
    assert!(TraceFile::new("t", vec![0.0]).is_err());
    assert!(TraceFile::new("t", Vec::new()).is_err());
    assert!(TraceFile::new("t", vec![1.0; MAX_TRACE_STEPS + 1]).is_err());

    // A config carrying a bad replay spec fails at `validate()` —
    // i.e. at CLI arg parsing, before any training work.
    let check_err = |spec: String, needle: &str| {
        let mut cfg = Config::default();
        cfg.set("mem_trace", &spec).unwrap();
        let err = format!("{:#}", cfg.validate().unwrap_err());
        assert!(err.contains(needle), "spec `{spec}` error must mention `{needle}`: {err}");
    };
    check_err(format!("replay:{}", root.join("absent.json").display()), "trace file");
    let bad = root.join("bad.json");
    std::fs::write(&bad, "{not json").unwrap();
    check_err(format!("replay:{}", bad.display()), "trace file");
    let big = root.join("big.json");
    std::fs::write(&big, vec![b' '; 16 * 1024 * 1024 + 1]).unwrap();
    check_err(format!("replay:{}", big.display()), "cap");
    let good = root.join("good.json");
    TraceFile::new("t", vec![0.5, 0.25]).unwrap().save(&good).unwrap();
    check_err(format!("replay:{}#{:016x}", good.display(), 1u64), "does not match");

    // The pinned canonical form parses and round-trips through
    // `to_spec`, so the string grid identity hashes is stable.
    let tf = TraceFile::load(&good).unwrap();
    let spec = format!("replay:{}#{:016x}", good.display(), tf.digest());
    let parsed = BudgetTrace::parse(&spec).unwrap();
    assert_eq!(parsed.to_spec(), spec, "replay specs canonicalize to themselves");
    std::fs::remove_dir_all(&root).ok();
}
