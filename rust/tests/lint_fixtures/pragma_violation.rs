fn f() -> u32 {
    // detlint: allow(d6)
    let x: Result<u32, ()> = Ok(1);
    // detlint: allow(d9) — no such rule exists.
    x.unwrap()
}
