//! High-throughput f32 GEMM core for the native backend, plus the
//! im2col/col2im pack stage that turns SAME 3×3 convolution into GEMM.
//!
//! Kernel structure (the FlashOptim-style restructuring the Tri-Accel
//! wall-clock claims lean on):
//! * `B` is packed into `nr`-wide column panels once per call, so the
//!   micro-kernel streams both operands contiguously; the backward
//!   `A·Bᵀ` shape packs panels straight from the transposed storage
//!   ([`pack_b_from_t`]) instead of materializing `Bᵀ` first;
//! * the register-tiled micro-kernel lives in [`super::simd`]: an
//!   `MR`×`nr` accumulator block held across the whole K loop, with
//!   runtime-dispatched AVX2/FMA and NEON tiers over the
//!   always-available scalar reference (true 1/2/3-row kernels for
//!   MR tails — no wasted lanes);
//! * the blocking parameters (`row_chunk` rows per parallel chunk,
//!   `nr` panel width) come from the [`super::autotune`] cache per
//!   (tier, shape class, thread count) — every candidate is
//!   bit-identical within a tier, so tuning is pure scheduling;
//! * for convolution, im2col itself plays the role of the A-panel pack
//!   (rows are already contiguous K-major), with the fp16/bf16 qdq
//!   round-trip fused into the pack instead of materializing a
//!   quantized activation copy.
//!
//! Determinism contract (shared with [`super::pool`] and stated in
//! full in `docs/DETERMINISM.md`): every output element accumulates in
//! a fixed order — ascending k within a chunk (SIMD tiers vectorize
//! across the `j` lanes, never across k, so the per-element k chain
//! is preserved; FMA fuses each multiply-add's rounding, which makes
//! bits a pure function of (inputs, tier)) — and cross-chunk
//! reductions ([`gemm_at_b`]) combine partials in chunk index order on
//! the caller thread. Chunk sizes come from the tuning config, never
//! from the thread count, so results are bit-identical for any
//! `TRIACCEL_THREADS` within a tier; `TRIACCEL_DISPATCH=scalar`
//! reproduces the reference bits anywhere.

#![allow(clippy::too_many_arguments)]

use super::arena::Arena;
use super::autotune::{self, TuneCfg};
use super::pool::Pool;
use super::qdq;
use super::simd::{self, Tier, MR, NR_MAX};

/// Reduction rows per partial product in [`gemm_at_b`] (fixed — not
/// part of the autotune search space, because regrouping the partials
/// would change bits).
const RED_CHUNK: usize = 1024;
/// Flop threshold below which spawning threads costs more than it buys.
/// Compared against problem size only — identical for every thread
/// count, so the serial/parallel decision is itself deterministic.
const PAR_MIN_FLOPS: usize = 1 << 20;
/// Element threshold for the copy-bound pack/unpack stages.
const PAR_MIN_ELEMS: usize = 1 << 19;

#[inline]
fn panels_of(n: usize, nr: usize) -> usize {
    n.div_ceil(nr)
}

/// Pack `b` (k×n row-major) into `nr`-wide column panels, zero-padded
/// to a multiple of `nr` columns: panel `p` stores `b[.., p*nr..]` as
/// `k` rows of `nr` contiguous values.
fn pack_b(b: &[f32], k: usize, n: usize, nr: usize, out: &mut [f32]) {
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), panels_of(n, nr) * k * nr);
    for p in 0..panels_of(n, nr) {
        let c0 = p * nr;
        let cols = (n - c0).min(nr);
        let dst = &mut out[p * k * nr..(p + 1) * k * nr];
        for kk in 0..k {
            dst[kk * nr..kk * nr + cols].copy_from_slice(&b[kk * n + c0..kk * n + c0 + cols]);
            dst[kk * nr + cols..(kk + 1) * nr].fill(0.0);
        }
    }
}

/// Pack `Bᵀ` storage (`bt`, n×k row-major — i.e. `B` is k×n) into the
/// same `nr`-wide column panels [`pack_b`] produces, reading columns of
/// `B` as contiguous rows of `bt`. Panel bytes are identical to
/// `transpose(bt)` followed by [`pack_b`] (pinned by a test), but the
/// full k×n transpose — formerly a serial copy on the caller thread
/// before every backward `g · Wᵀ` GEMM — never materializes.
fn pack_b_from_t(bt: &[f32], k: usize, n: usize, nr: usize, out: &mut [f32]) {
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(out.len(), panels_of(n, nr) * k * nr);
    for p in 0..panels_of(n, nr) {
        let c0 = p * nr;
        let cols = (n - c0).min(nr);
        let dst = &mut out[p * k * nr..(p + 1) * k * nr];
        for (j, col) in bt[c0 * k..].chunks_exact(k).take(cols).enumerate() {
            for (kk, &v) in col.iter().enumerate() {
                dst[kk * nr + j] = v;
            }
        }
        for kk in 0..k {
            dst[kk * nr + cols..(kk + 1) * nr].fill(0.0);
        }
    }
}

/// Macro-kernel over one row block of C (rows `row0..row0+rows` of the
/// full problem, stored in `c_chunk`), dispatching `tier`'s micro-tile.
fn gemm_rows(
    tier: Tier,
    a: &[f32],
    bp: &[f32],
    c_chunk: &mut [f32],
    row0: usize,
    k: usize,
    n: usize,
    nr: usize,
    accumulate: bool,
) {
    let rows = c_chunk.len() / n;
    let panels = panels_of(n, nr);
    let mut i = 0;
    while i < rows {
        let mr = (rows - i).min(MR);
        // Row slices of A for this tile; tail entries clamp to the last
        // live row and are never read — every kernel loops `r < mr`, so
        // tails run true 1/2/3-row micro-kernels (the seed aliased
        // row 0 and computed lanes it then threw away).
        let ar: [&[f32]; MR] = std::array::from_fn(|t| {
            let rr = row0 + i + t.min(mr - 1);
            &a[rr * k..rr * k + k]
        });
        for p in 0..panels {
            let c0 = p * nr;
            let cols = (n - c0).min(nr);
            let mut acc = [[0f32; NR_MAX]; MR];
            if accumulate {
                for t in 0..mr {
                    let base = (i + t) * n + c0;
                    acc[t][..cols].copy_from_slice(&c_chunk[base..base + cols]);
                }
            }
            simd::tile(tier, &ar, mr, &bp[p * k * nr..(p + 1) * k * nr], k, nr, &mut acc);
            for t in 0..mr {
                let base = (i + t) * n + c0;
                c_chunk[base..base + cols].copy_from_slice(&acc[t][..cols]);
            }
        }
        i += mr;
    }
}

/// Shared macro-kernel driver over a pre-packed B: parallel over fixed
/// `cfg.row_chunk` row blocks (boundaries depend on the config only,
/// never the thread count).
fn gemm_packed(
    tier: Tier,
    cfg: TuneCfg,
    pool: &Pool,
    a: &[f32],
    bp: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    let parallel = 2 * m * k * n >= PAR_MIN_FLOPS;
    pool.for_each_chunk(c, cfg.row_chunk * n, parallel, |ci, c_chunk| {
        gemm_rows(tier, a, bp, c_chunk, ci * cfg.row_chunk, k, n, cfg.nr, accumulate);
    });
}

/// `C (m×n) = A (m×k) · B (k×n)`, overwriting `c`; with `accumulate`
/// the product is added onto the existing contents instead (per-element
/// order: `c_init + a_0·b_0 + a_1·b_1 + …`, which is how the dense
/// layer preloads its bias). Runs the active dispatch tier with the
/// autotuned blocking for this (tier, shape class, thread count).
pub fn gemm(
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    let tier = simd::active();
    let cfg = autotune::lookup(tier, pool.threads(), m, k, n);
    gemm_with(tier, cfg, pool, arena, a, b, c, m, k, n, accumulate);
}

/// [`gemm`] pinned to an explicit tier and blocking config — the
/// entry point the tuner times and the cross-tier property tests
/// drive. `cfg` is sanitized; any legal config produces identical
/// bits within a tier.
pub fn gemm_with(
    tier: Tier,
    cfg: TuneCfg,
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            c.fill(0.0);
        }
        return;
    }
    let cfg = cfg.sanitized();
    let mut bp = arena.take(panels_of(n, cfg.nr) * k * cfg.nr);
    pack_b(b, k, n, cfg.nr, &mut bp);
    gemm_packed(tier, cfg, pool, a, &bp, c, m, k, n, accumulate);
    arena.put(bp);
}

/// `out (cols×rows) = mᵀ` for `m` stored (rows×cols) row-major.
pub fn transpose(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = m[r * cols + c];
        }
    }
}

/// `C (m×n) = A (m×k) · Bᵀ` with `B` stored (n×k) — the `g · Wᵀ`
/// backward shape. Packs panels directly from the transposed storage
/// ([`pack_b_from_t`]), so no k×n transpose copy runs on the caller
/// thread; bits are pinned identical to the old transpose-then-[`gemm`]
/// path (the packed panels are byte-identical).
pub fn gemm_a_bt(
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    let tier = simd::active();
    let cfg = autotune::lookup(tier, pool.threads(), m, k, n);
    gemm_a_bt_with(tier, cfg, pool, arena, a, b, c, m, k, n, accumulate);
}

/// [`gemm_a_bt`] pinned to an explicit tier and blocking config.
pub fn gemm_a_bt_with(
    tier: Tier,
    cfg: TuneCfg,
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            c.fill(0.0);
        }
        return;
    }
    let cfg = cfg.sanitized();
    let mut bp = arena.take(panels_of(n, cfg.nr) * k * cfg.nr);
    pack_b_from_t(b, k, n, cfg.nr, &mut bp);
    gemm_packed(tier, cfg, pool, a, &bp, c, m, k, n, accumulate);
    arena.put(bp);
}

/// `C (ka×n) = Aᵀ · B` with `A` (m×ka) and `B` (m×n) — the
/// `x_colsᵀ · g` weight-gradient shape, a reduction over the m
/// (row/pixel) dimension. Runs the active dispatch tier.
pub fn gemm_at_b(
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    ka: usize,
    n: usize,
) {
    gemm_at_b_with(simd::active(), pool, arena, a, b, c, m, ka, n);
}

/// [`gemm_at_b`] pinned to an explicit tier.
///
/// Parallel scheme: fixed [`RED_CHUNK`]-row partial products computed
/// independently (rank-1 [`simd::axpy`] updates in ascending m order
/// within a chunk — lanes are independent output columns, each
/// keeping its ascending-m chain), then an *ordered* reduction in
/// chunk-index order on the caller thread. The partial/reduce
/// structure is used even serially, so one thread and eight threads
/// produce the same bits.
pub fn gemm_at_b_with(
    tier: Tier,
    pool: &Pool,
    arena: &mut Arena,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    ka: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * ka);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), ka * n);
    c.fill(0.0);
    if m == 0 || ka == 0 || n == 0 {
        return;
    }
    let n_chunks = m.div_ceil(RED_CHUNK);
    let mut partials = arena.take(n_chunks * ka * n);
    let parallel = 2 * m * ka * n >= PAR_MIN_FLOPS;
    pool.for_each_chunk(&mut partials, ka * n, parallel, |ci, part| {
        let lo = ci * RED_CHUNK;
        let hi = (lo + RED_CHUNK).min(m);
        for mm in lo..hi {
            let arow = &a[mm * ka..(mm + 1) * ka];
            let brow = &b[mm * n..(mm + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                simd::axpy(tier, &mut part[i * n..(i + 1) * n], brow, av);
            }
        }
    });
    // Ordered reduction: chunk-index order, fixed for every thread count.
    for ci in 0..n_chunks {
        let part = &partials[ci * ka * n..(ci + 1) * ka * n];
        for (cv, &pv) in c.iter_mut().zip(part) {
            *cv += pv;
        }
    }
    arena.put(partials);
}

/// Output side length of a SAME-padded stride-`s` convolution:
/// `ceil(h / s)` (pad = (k-1)/2 on every side, torch-style symmetric).
#[inline]
pub fn conv_out_dim(h: usize, stride: usize) -> usize {
    h.div_ceil(stride)
}

/// im2col for SAME-padded k×k stride-`s` convolution with the precision
/// round-trip fused into the pack:
/// `cols[m, (ky*k+kx)*cin + ci] = qdq(x[bi, oy*s+ky-p, ox*s+kx-p, ci])`
/// with `m = (bi*ho + oy)*wo + ox`, `p = (k-1)/2`, and zeros in the
/// padding halo. The column layout matches the HWIO weight layout, so
/// `cols · W (k²cin×cout)` is exactly the convolution. One parallel
/// chunk per image; each chunk owns that image's row block. For
/// `k = 3, stride = 1` this is bit-identical to the pre-graph
/// `im2col3x3_qdq` pack (same loop order, same slices).
pub fn im2col_qdq(
    pool: &Pool,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    code: i32,
    cols: &mut [f32],
) {
    let kk = k * k * cin;
    let pad = (k - 1) / 2;
    let (ho, wo) = (conv_out_dim(h, stride), conv_out_dim(w, stride));
    debug_assert_eq!(x.len(), n * h * w * cin);
    debug_assert_eq!(cols.len(), n * ho * wo * kk);
    let parallel = cols.len() >= PAR_MIN_ELEMS;
    pool.for_each_chunk(cols, ho * wo * kk, parallel, |bi, img| {
        for oy in 0..ho {
            for ox in 0..wo {
                let mrow = &mut img[(oy * wo + ox) * kk..(oy * wo + ox + 1) * kk];
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        let dst = &mut mrow[(ky * k + kx) * cin..(ky * k + kx + 1) * cin];
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                            dst.fill(0.0);
                        } else {
                            let base = ((bi * h + iy as usize) * w + ix as usize) * cin;
                            qdq::qdq_into(&x[base..base + cin], dst, code);
                        }
                    }
                }
            }
        }
    });
}

/// Compat wrapper: the 3×3 stride-1 pack (the tiny_cnn shape).
pub fn im2col3x3_qdq(
    pool: &Pool,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    code: i32,
    cols: &mut [f32],
) {
    im2col_qdq(pool, x, n, h, w, cin, 3, 1, code, cols);
}

/// Gather-form col2im (the adjoint of [`im2col_qdq`]'s layout):
/// `dx[bi,iy,ix,ci] = Σ_(ky,kx) dcols[(bi*ho+oy)*wo+ox, (ky*k+kx)*cin+ci]`
/// over the valid output positions `oy = (iy+p-ky)/s`,
/// `ox = (ix+p-kx)/s` (only when the division is exact). Each `dx`
/// element is written by exactly one chunk with a fixed (ky,kx)
/// summation order — no scatter races, deterministic bits.
pub fn col2im(
    pool: &Pool,
    dcols: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    dx: &mut [f32],
) {
    let kk = k * k * cin;
    let pad = (k - 1) / 2;
    let (ho, wo) = (conv_out_dim(h, stride), conv_out_dim(w, stride));
    debug_assert_eq!(dcols.len(), n * ho * wo * kk);
    debug_assert_eq!(dx.len(), n * h * w * cin);
    let parallel = dcols.len() >= PAR_MIN_ELEMS;
    pool.for_each_chunk(dx, h * w * cin, parallel, |bi, img| {
        for iy in 0..h {
            for ix in 0..w {
                let drow = &mut img[(iy * w + ix) * cin..(iy * w + ix + 1) * cin];
                drow.fill(0.0);
                for ky in 0..k {
                    let t = iy + pad;
                    if t < ky || (t - ky) % stride != 0 {
                        continue;
                    }
                    let oy = (t - ky) / stride;
                    if oy >= ho {
                        continue;
                    }
                    for kx in 0..k {
                        let u = ix + pad;
                        if u < kx || (u - kx) % stride != 0 {
                            continue;
                        }
                        let ox = (u - kx) / stride;
                        if ox >= wo {
                            continue;
                        }
                        let m = (bi * ho + oy) * wo + ox;
                        let base = m * kk + (ky * k + kx) * cin;
                        let src = &dcols[base..base + cin];
                        for (d, &s) in drow.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
            }
        }
    });
}

/// Compat wrapper: the 3×3 stride-1 unpack.
pub fn col2im3x3(
    pool: &Pool,
    dcols: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dx: &mut [f32],
) {
    col2im(pool, dcols, n, h, w, cin, 3, 1, dx);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{FP16, FP32};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    fn gemm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk] as f64;
                for j in 0..n {
                    c[i * n + j] += av * b[kk * n + j] as f64;
                }
            }
        }
        c.iter().map(|&v| v as f32).collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!((x - y).abs() / scale < tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_matches_naive_over_odd_shapes() {
        let mut rng = Rng::new(11);
        // m covers every MR tail (1, 2, 3 leftover rows) and n crosses
        // both panel widths raggedly.
        let shapes = [
            (1usize, 1usize, 1usize),
            (2, 5, 3),
            (3, 4, 17),
            (5, 3, 9),
            (6, 9, 5),
            (17, 27, 16),
            (130, 144, 33),
            (64, 288, 100),
        ];
        for &(m, k, n) in &shapes {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            let pool = Pool::new(1);
            let mut arena = Arena::new();
            gemm(&pool, &mut arena, &a, &b, &mut c, m, k, n, false);
            close(&c, &gemm_naive(&a, &b, m, k, n), 1e-4, &format!("gemm {m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_accumulate_adds_onto_preload() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (9usize, 7usize, 11usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let mut c = vec![0f32; m * n];
        for r in 0..m {
            c[r * n..(r + 1) * n].copy_from_slice(&bias);
        }
        let pool = Pool::new(1);
        let mut arena = Arena::new();
        gemm(&pool, &mut arena, &a, &b, &mut c, m, k, n, true);
        let plain = gemm_naive(&a, &b, m, k, n);
        for r in 0..m {
            for j in 0..n {
                let want = plain[r * n + j] + bias[j];
                assert!((c[r * n + j] - want).abs() < 1e-4 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn gemm_bits_identical_across_thread_counts_in_every_tier() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (400usize, 96usize, 40usize); // crosses the parallel threshold
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        for tier in simd::available_tiers() {
            let run = |threads: usize| {
                let pool = Pool::new(threads);
                let mut arena = Arena::new();
                let mut c = vec![0f32; m * n];
                let cfg = TuneCfg::default();
                gemm_with(tier, cfg, &pool, &mut arena, &a, &b, &mut c, m, k, n, false);
                bits(&c)
            };
            let base = run(1);
            for t in [2usize, 4, 8] {
                assert_eq!(run(t), base, "tier={tier} threads={t}");
            }
        }
    }

    #[test]
    fn blocking_configs_are_bit_invariant_within_a_tier() {
        // The property that makes autotuning safe: every candidate
        // blocking produces identical bits, per tier.
        let mut rng = Rng::new(21);
        let (m, k, n) = (70usize, 33usize, 25usize); // ragged in every dim
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        for tier in simd::available_tiers() {
            let run = |cfg: TuneCfg| {
                let pool = Pool::new(2);
                let mut arena = Arena::new();
                let mut c = vec![0f32; m * n];
                gemm_with(tier, cfg, &pool, &mut arena, &a, &b, &mut c, m, k, n, false);
                bits(&c)
            };
            let base = run(TuneCfg::default());
            for cfg in autotune::candidates() {
                assert_eq!(run(cfg), base, "tier={tier} cfg={cfg:?}");
            }
        }
    }

    #[test]
    fn at_b_matches_naive_and_is_thread_invariant() {
        let mut rng = Rng::new(14);
        let (m, ka, n) = (2500usize, 27usize, 16usize); // > 2 reduction chunks
        let a = randv(&mut rng, m * ka);
        let b = randv(&mut rng, m * n);
        let run = |threads: usize| {
            let pool = Pool::new(threads);
            let mut arena = Arena::new();
            let mut c = vec![0f32; ka * n];
            gemm_at_b(&pool, &mut arena, &a, &b, &mut c, m, ka, n);
            c
        };
        let c1 = run(1);
        // naive: c[i,j] = sum_m a[m,i] b[m,j]
        let mut want = vec![0f64; ka * n];
        for mm in 0..m {
            for i in 0..ka {
                for j in 0..n {
                    want[i * n + j] += a[mm * ka + i] as f64 * b[mm * n + j] as f64;
                }
            }
        }
        let wantf: Vec<f32> = want.iter().map(|&v| v as f32).collect();
        close(&c1, &wantf, 1e-3, "at_b");
        for t in [2usize, 4] {
            let ct = run(t);
            assert_eq!(bits(&c1), bits(&ct), "threads={t}");
        }
    }

    #[test]
    fn a_bt_matches_naive() {
        let mut rng = Rng::new(15);
        let (m, k, n) = (13usize, 10usize, 21usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k); // stored n×k
        let pool = Pool::new(2);
        let mut arena = Arena::new();
        let mut c = vec![0f32; m * n];
        gemm_a_bt(&pool, &mut arena, &a, &b, &mut c, m, k, n, false);
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[j * k + kk] as f64;
                }
                want[i * n + j] = s as f32;
            }
        }
        close(&c, &want, 1e-4, "a_bt");
    }

    #[test]
    fn a_bt_direct_pack_matches_transpose_then_gemm_bitwise() {
        // The pack_b_from_t bugfix pin: the direct-pack path must
        // reproduce the old transpose-then-gemm path bit-for-bit, in
        // every tier and panel width.
        let mut rng = Rng::new(22);
        let (m, k, n) = (29usize, 14usize, 19usize);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k); // stored n×k
        for tier in simd::available_tiers() {
            for nr in [8usize, 16] {
                let cfg = TuneCfg { row_chunk: 64, nr };
                let pool = Pool::new(2);
                let mut arena = Arena::new();
                let mut direct = vec![0f32; m * n];
                gemm_a_bt_with(tier, cfg, &pool, &mut arena, &a, &b, &mut direct, m, k, n, false);
                let mut bt = vec![0f32; k * n];
                transpose(&b, n, k, &mut bt);
                let mut two_step = vec![0f32; m * n];
                gemm_with(tier, cfg, &pool, &mut arena, &a, &bt, &mut two_step, m, k, n, false);
                assert_eq!(bits(&direct), bits(&two_step), "tier={tier} nr={nr}");
            }
        }
    }

    #[test]
    fn pack_from_t_matches_transpose_then_pack() {
        let mut rng = Rng::new(23);
        let (k, n) = (7usize, 21usize); // ragged for both panel widths
        let bt = randv(&mut rng, n * k);
        let mut b = vec![0f32; k * n];
        transpose(&bt, n, k, &mut b);
        for nr in [8usize, 16] {
            let len = panels_of(n, nr) * k * nr;
            let mut via_t = vec![1f32; len]; // nonzero: fills must overwrite
            let mut via_b = vec![2f32; len];
            pack_b_from_t(&bt, k, n, nr, &mut via_t);
            pack_b(&b, k, n, nr, &mut via_b);
            assert_eq!(bits(&via_t), bits(&via_b), "nr={nr}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(16);
        let (r, c) = (7usize, 5usize);
        let m = randv(&mut rng, r * c);
        let mut t = vec![0f32; r * c];
        transpose(&m, r, c, &mut t);
        let mut back = vec![0f32; r * c];
        transpose(&t, c, r, &mut back);
        assert_eq!(m, back);
        // t is (c × r): t[cc*r + rr] = m[rr*c + cc]; spot-check (0, 3).
        assert_eq!(t[3], m[3 * c], "t[0][3] must be m[3][0]");
    }

    #[test]
    fn im2col_identity_kernel_reproduces_input() {
        // cols · e_center must reproduce x (SAME padding sanity).
        let (n, h, w, cin) = (1usize, 3usize, 3usize, 1usize);
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let pool = Pool::new(1);
        let mut cols = vec![0f32; n * h * w * 9 * cin];
        im2col3x3_qdq(&pool, &x, n, h, w, cin, FP32, &mut cols);
        // center tap is (ky=1,kx=1) -> column 4.
        for m in 0..9 {
            assert_eq!(cols[m * 9 + 4], x[m], "center column");
        }
        // top-left output pixel reads the halo for (ky=0,kx=0).
        assert_eq!(cols[0], 0.0);
        // and x[0,0] appears at output (1,1) tap (0,0): m=4.
        assert_eq!(cols[4 * 9], x[0]);
    }

    #[test]
    fn im2col_fuses_qdq() {
        let (n, h, w, cin) = (1usize, 2usize, 2usize, 2usize);
        let x = vec![1.0002f32, -3.00007, 0.5, 2.0, 1.0, -1.0, 0.25, 65519.9];
        let pool = Pool::new(1);
        let mut cols = vec![0f32; n * h * w * 9 * cin];
        im2col3x3_qdq(&pool, &x, n, h, w, cin, FP16, &mut cols);
        use crate::runtime::native::qdq::f16_qdq;
        // center tap of pixel (0,0) is x[0..2] rounded through fp16.
        assert_eq!(cols[4 * cin], f16_qdq(x[0]));
        assert_eq!(cols[4 * cin + 1], f16_qdq(x[1]));
        assert_ne!(cols[4 * cin], x[0], "fp16 rounding must be visible");
    }

    #[test]
    fn strided_im2col_subsamples_and_pads() {
        // h=4, k=3, s=2 → ho=2; output (0,0) center tap reads x[0,0],
        // output (1,1) center tap reads x[2,2].
        let (n, h, w, cin) = (1usize, 4usize, 4usize, 1usize);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let pool = Pool::new(1);
        let ho = conv_out_dim(h, 2);
        assert_eq!(ho, 2);
        let mut cols = vec![0f32; n * ho * ho * 9 * cin];
        im2col_qdq(&pool, &x, n, h, w, cin, 3, 2, FP32, &mut cols);
        assert_eq!(cols[4], x[0], "out (0,0) center tap");
        assert_eq!(cols[(ho + 1) * 9 + 4], x[2 * w + 2], "out (1,1) center tap");
        assert_eq!(cols[0], 0.0, "out (0,0) corner tap reads the halo");
    }

    #[test]
    fn conv1x1_im2col_is_a_channel_copy() {
        let (n, h, w, cin) = (1usize, 3usize, 3usize, 2usize);
        let mut rng = Rng::new(18);
        let x = randv(&mut rng, n * h * w * cin);
        let pool = Pool::new(1);
        let mut cols = vec![0f32; x.len()];
        im2col_qdq(&pool, &x, n, h, w, cin, 1, 1, FP32, &mut cols);
        assert_eq!(cols, x, "k=1 s=1 pack is the identity");
        // stride-2 1×1 subsamples the grid.
        let ho = conv_out_dim(h, 2);
        let mut sub = vec![0f32; n * ho * ho * cin];
        im2col_qdq(&pool, &x, n, h, w, cin, 1, 2, FP32, &mut sub);
        assert_eq!(&sub[0..cin], &x[0..cin]);
        assert_eq!(&sub[cin..2 * cin], &x[2 * cin..3 * cin], "(0,1) reads x[0,2]");
    }

    #[test]
    fn general_wrappers_match_3x3_path_bitwise() {
        let mut rng = Rng::new(19);
        let (n, h, w, cin) = (2usize, 5usize, 4usize, 3usize);
        let x = randv(&mut rng, n * h * w * cin);
        let y = randv(&mut rng, n * h * w * 9 * cin);
        let pool = Pool::new(1);
        let mut a = vec![0f32; y.len()];
        let mut b = vec![0f32; y.len()];
        im2col3x3_qdq(&pool, &x, n, h, w, cin, FP16, &mut a);
        im2col_qdq(&pool, &x, n, h, w, cin, 3, 1, FP16, &mut b);
        assert_eq!(a, b, "wrapper must be the same pack");
        let mut da = vec![0f32; x.len()];
        let mut db = vec![0f32; x.len()];
        col2im3x3(&pool, &y, n, h, w, cin, &mut da);
        col2im(&pool, &y, n, h, w, cin, 3, 1, &mut db);
        assert_eq!(bits(&da), bits(&db));
    }

    #[test]
    fn col2im_is_adjoint_of_strided_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for every (k, stride) the
        // model grid uses — pins the strided index maps to each other.
        let mut rng = Rng::new(20);
        for &(k, s) in &[(3usize, 2usize), (1, 1), (1, 2), (5, 1)] {
            let (n, h, w, cin) = (2usize, 6usize, 5usize, 3usize);
            let (ho, wo) = (conv_out_dim(h, s), conv_out_dim(w, s));
            let x = randv(&mut rng, n * h * w * cin);
            let y = randv(&mut rng, n * ho * wo * k * k * cin);
            let pool = Pool::new(1);
            let mut cols = vec![0f32; y.len()];
            im2col_qdq(&pool, &x, n, h, w, cin, k, s, FP32, &mut cols);
            let mut back = vec![0f32; x.len()];
            col2im(&pool, &y, n, h, w, cin, k, s, &mut back);
            // detlint: ordered — sequential dot products in buffer order.
            let lhs: f64 = cols.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
            // detlint: ordered — sequential dot products in buffer order.
            let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "k={k} s={s}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> pins the index maps to each
        // other (the standard adjoint identity).
        let mut rng = Rng::new(17);
        let (n, h, w, cin) = (2usize, 4usize, 3usize, 3usize);
        let x = randv(&mut rng, n * h * w * cin);
        let y = randv(&mut rng, n * h * w * 9 * cin);
        let pool = Pool::new(1);
        let mut cols = vec![0f32; y.len()];
        im2col3x3_qdq(&pool, &x, n, h, w, cin, FP32, &mut cols);
        let mut back = vec![0f32; x.len()];
        col2im3x3(&pool, &y, n, h, w, cin, &mut back);
        // detlint: ordered — sequential dot products in buffer order.
        let lhs: f64 = cols.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
        // detlint: ordered — sequential dot products in buffer order.
        let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }
}
