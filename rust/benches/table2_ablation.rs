//! Table-2 regeneration bench (DESIGN.md T2): the memory-optimization
//! ablation — standard → +dynamic batch → +dynamic precision → full
//! Tri-Accel — on CIFAR-10 for both architectures, reporting peak VRAM
//! and the paper's "Reduction" column.
//!
//! Env knobs: T2_STEPS, T2_EPOCHS, T2_SEEDS, T2_MODELS.

use tri_accel::harness;
use tri_accel::runtime::Engine;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let engine = Engine::native();
    let steps = env_usize("T2_STEPS", 6);
    let epochs = env_usize("T2_EPOCHS", 1);
    let seeds: Vec<u64> = std::env::var("T2_SEEDS")
        .unwrap_or_else(|_| "0".into())
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let models_env =
        std::env::var("T2_MODELS").unwrap_or_else(|_| "tiny_cnn_c10".into()); // artifact models via T2_MODELS

    for key in models_env.split(',') {
        println!("\n== bench table2 (ablation) — {key}, CIFAR-10 ==");
        let rows = harness::table2(&engine, key, &seeds, &harness::quick_budget(steps, epochs))
            .expect("table2 run");
        harness::print_table2(&rows);

        // Shape check vs paper Table 2: every added component reduces
        // (or at worst holds) peak VRAM, and full Tri-Accel is the min.
        let peaks: Vec<f64> = rows.iter().map(|r| r.peak_gb.mean()).collect();
        let base = peaks[0];
        let full = *peaks.last().unwrap();
        let monotone_vs_base = peaks[1..].iter().all(|&p| p <= base + 1e-9);
        let full_is_min = peaks.iter().all(|&p| full <= p + 1e-9);
        println!(
            "shape: all-below-baseline {}  full-is-min {}  total reduction {:.1}% (paper: 12.3%/13.3%)",
            if monotone_vs_base { "OK" } else { "MISS" },
            if full_is_min { "OK" } else { "MISS" },
            100.0 * (base - full) / base
        );
    }
}
