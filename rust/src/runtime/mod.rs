//! Runtime layer: backend-agnostic sessions over a pluggable executor.
//!
//! * [`backend`] — the [`Backend`] trait: the four manifest entry
//!   points (`init`, `train_b{n}`, `eval_b{n}`, `curv`) over host `f32`
//!   vectors, plus [`ModelState`].
//! * [`native`] — the default pure-Rust executor: a manifest-driven
//!   layer-graph walker (conv/dwconv/bn/relu/pool/residual/dense
//!   forward+backward, qdq precision emulation, loss-scaled SGD, grad
//!   stats, FD power-iteration curvature) with a built-in manifest
//!   covering tiny_cnn/resnet_mini/effnet_lite ×{c10,c100}. Hermetic:
//!   no artifacts, no Python, no native deps.
//! * `pjrt` (`--features pjrt`) — the PJRT/XLA executor that loads AOT
//!   HLO artifacts (`make artifacts`) and runs them on the CPU PJRT
//!   client. The only module that touches the external `xla` crate.
//! * [`Engine`] / [`Session`] — backend selection and per-run state.

pub mod backend;
mod engine;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
mod session;

pub use backend::{Backend, ModelState};
pub use engine::Engine;
pub use session::{Batch, EvalResult, Session, StepCtrl, TrainOutputs};
