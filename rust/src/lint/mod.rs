//! `detlint` — the repo-native determinism & safety static-analysis
//! pass.
//!
//! The determinism contract (bit-identical traces across thread
//! counts, `--jobs` widths, and kill/resume — `docs/DETERMINISM.md`)
//! is enforced at runtime by property tests, but those only catch
//! violations after they ship and only on executed paths. This module
//! rejects them at the source level: a dependency-free line/token
//! scanner ([`scan`]) feeds a rule engine ([`rules`]) encoding the
//! contract as seven rules (D1–D7, table in `docs/DETERMINISM.md`),
//! plus a schema-drift guard ([`schema`]) that pins digests of the
//! serialized telemetry/ledger field sets.
//!
//! The pass runs three ways, all sharing this module:
//!
//! * `tri-accel lint [--format json] [--out report.json]` — the CLI
//!   subcommand CI runs (failing on any finding, uploading the JSON
//!   report as an artifact);
//! * `cargo test --test lint_rules` — fixture corpus plus a
//!   whole-tree lint-clean assertion;
//! * [`lint_source`] — the library entry for linting one in-memory
//!   file (what the fixture tests use).
//!
//! Exemptions are explicit and justified in-source via pragmas
//! (grammar in [`scan`]); an unjustified or malformed pragma is itself
//! a finding.

pub mod report;
pub mod rules;
pub mod scan;
pub mod schema;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use report::Report;
pub use rules::{Finding, RuleInfo, RULES};

/// Lint one in-memory source file. `rel` is the path relative to the
/// lint root (forward slashes) — rules are scoped by it.
pub fn lint_source(rel: &str, text: &str) -> Vec<Finding> {
    rules::check_file(&scan::scan_source(rel, text))
}

/// Lint every `.rs` file under `root` (recursively, sorted order) and
/// check the D7 schema pins. Findings are sorted by (path, line, rule)
/// so reports are deterministic.
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&rel, &text));
    }
    let (schema_findings, schemas) = schema::check_tree(root)?;
    findings.extend(schema_findings);
    findings.sort_by(|a, b| (&a.path, a.line, &a.rule).cmp(&(&b.path, b.line, &b.rule)));
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        findings,
        schemas,
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}
