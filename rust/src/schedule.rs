//! Learning-rate schedule: linear warmup then cosine decay (paper §4.3:
//! "learning rates are warmed up for the first 5 epochs and decayed
//! following a cosine schedule").

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: u64, total_steps: u64) -> LrSchedule {
        LrSchedule { base_lr, warmup_steps, total_steps: total_steps.max(1), min_lr: 0.0 }
    }

    pub fn lr_at(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            // Linear warmup from base_lr/warmup to base_lr.
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let t = (step - self.warmup_steps) as f64;
        let total = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let frac = (t / total).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
        self.min_lr + (self.base_lr - self.min_lr) * cos as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(0.1, 10, 100);
        assert!((s.lr_at(0) - 0.01).abs() < 1e-6);
        assert!((s.lr_at(4) - 0.05).abs() < 1e-6);
        assert!((s.lr_at(9) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = LrSchedule::new(0.1, 0, 100);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!(s.lr_at(50) < 0.06 && s.lr_at(50) > 0.04);
        assert!(s.lr_at(100) < 1e-6);
        assert!(s.lr_at(500) < 1e-6, "clamps past the end");
    }

    #[test]
    fn monotone_after_warmup() {
        let s = LrSchedule::new(0.2, 5, 50);
        let mut prev = f32::INFINITY;
        for step in 5..=50 {
            let lr = s.lr_at(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
